"""``input_specs()`` — ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation: what the multi-pod dry-run
lowers against. The modality frontends are stubs per the assignment: audio
supplies frame embeddings, VLM supplies patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {}
        if cfg.embed_is_input_stub:
            batch["features"] = sds((B, S, cfg.vision_dim), jnp.float32)
        else:
            batch["tokens"] = sds((B, S), jnp.int32)
        batch["labels"] = sds((B, S), jnp.int32)
        if cfg.num_image_tokens:
            batch["image_features"] = sds(
                (B, cfg.num_image_tokens, cfg.vision_dim), jnp.float32
            )
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.embed_is_input_stub:
            batch["features"] = sds((B, S, cfg.vision_dim), jnp.float32)
        else:
            batch["tokens"] = sds((B, S), jnp.int32)
        if cfg.num_image_tokens:
            batch["image_features"] = sds(
                (B, cfg.num_image_tokens, cfg.vision_dim), jnp.float32
            )
        return batch
    if shape.kind == "decode":
        return {
            "tokens": sds((B, 1), jnp.int32),
            "pos": sds((), jnp.int32),
        }
    raise ValueError(shape.kind)


def applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether this (arch, shape) combo runs, with the recorded reason."""
    if shape.kind == "decode" and not cfg.causal:
        return False, "encoder-only: no decode step (DESIGN.md §5)"
    return True, ""
