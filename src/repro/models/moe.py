"""Mixture-of-Experts channel mixer: top-k router + two sharding layouts.

* ``tp``  — every expert's d_ff is sharded over the model axis; dispatch is
  device-local and the only collective is the block-exit psum. Used when the
  expert count doesn't divide the TP degree (mixtral: 8e over 16 shards).
* ``ep``  — experts sharded over the model axis (arctic: 128e → 8/shard);
  tokens are split over the model axis, dispatched via ``all_to_all`` to
  their expert owners, processed, returned via the mirrored ``all_to_all``,
  and re-replicated with an all-gather. This is the paper-relevant pattern:
  the all-to-all wire bytes show up in the roofline's collective term.

Dispatch is sort-based with a static capacity (no (T,E,C) one-hot blow-up):
tokens are ranked within their expert via ``searchsorted`` over the sorted
expert ids and scattered into an (E, C, d) buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.env import Env
from repro.transport import axis_size
from repro.utils.trees import round_up


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _token_split(x, axis_name):
    """fwd: take this rank's token chunk; bwd: all-gather chunk cotangents."""
    m = lax.axis_index(axis_name)
    tloc = x.shape[0] // axis_size(axis_name)  # version-compat helper
    return lax.dynamic_slice_in_dim(x, m * tloc, tloc, axis=0)


def _tsplit_fwd(x, axis_name):
    return _token_split(x, axis_name), None


def _tsplit_bwd(axis_name, _, g):
    # lint: allow(RAW-COLLECTIVE): EP token-split transpose — lossless re-layout, raw dtype is the wire format (audited as relayout)
    return (lax.all_gather(g, axis_name, axis=0, tiled=True),)


_token_split.defvjp(_tsplit_fwd, _tsplit_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _token_merge(x_loc, axis_name):
    """fwd: all-gather token chunks; bwd: slice this rank's cotangent."""
    # lint: allow(RAW-COLLECTIVE): EP token-merge — lossless re-layout, raw dtype is the wire format (audited as relayout)
    return lax.all_gather(x_loc, axis_name, axis=0, tiled=True)


def _tmerge_fwd(x_loc, axis_name):
    return _token_merge(x_loc, axis_name), None


def _tmerge_bwd(axis_name, _, g):
    m = lax.axis_index(axis_name)
    tloc = g.shape[0] // axis_size(axis_name)  # version-compat helper
    return (lax.dynamic_slice_in_dim(g, m * tloc, tloc, axis=0),)


_token_merge.defvjp(_tmerge_fwd, _tmerge_bwd)


def _route(x, router_w, num_experts: int, top_k: int):
    """Top-k routing in fp32. Returns (probs (T,k), experts (T,k), aux)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs_full, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize
    # switch-style load-balance loss
    T = x.shape[0]
    me = jnp.mean(probs_full, axis=0)
    one_hot = jax.nn.one_hot(top_e[:, 0], num_experts, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)
    aux = num_experts * jnp.sum(me * ce)
    return top_p, top_e, aux


def _dispatch_indices(top_e: jnp.ndarray, num_experts: int, capacity: int):
    """Sort-based capacity dispatch.

    Returns (src_token (N,), dest_slot (N,), keep (N,), probs_order (N,))
    where N = T*k and dest_slot indexes an (E*C,) buffer (dropped tokens
    point at slot E*C, which is sliced away)."""
    T, k = top_e.shape
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts))
    rank = jnp.arange(T * k) - starts[sorted_e]
    keep = rank < capacity
    dest = jnp.where(keep, sorted_e * capacity + rank, num_experts * capacity)
    src = order // k
    return src, dest, keep, order


def _expert_ffn(buf, w_gate, w_up, w_down):
    """(E, C, d) x per-expert SwiGLU -> (E, C, d)."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", g * u, w_down)


def moe_block(x: jnp.ndarray, w: dict, cfg, env: Env) -> tuple[jnp.ndarray, jnp.ndarray]:
    """MoE mixer on (B, S, d) -> (out, aux_loss). Dispatch per cfg.moe_impl.

    Under ``env.seq_parallel`` the incoming ``x`` is a sequence shard.
    The ``tp`` layout gathers it at the block boundary (``env.enter``,
    fwd all-gather) and reduce-scatters the partial outputs back
    (``env.exit``) — the same contract as the dense mixers. The ``ep``
    layout needs no boundary collective at all: the sequence shards
    *are* this rank's token split, so dispatch goes straight to the
    expert all_to_alls and the combined output already is the shard."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    impl = cfg.moe_impl if env.tp > 1 else "tp"
    sp = env.seq_parallel_active

    dense_y = None
    if cfg.moe_dense_ff and impl == "ep":
        # arctic's parallel dense residual: computed TP-style on the
        # replicated tokens (EP token-splitting below must not see it —
        # its weights are model-axis sharded and need the exit psum).
        # Boundary collectives run at (B, S, d) so the seq-parallel
        # gather/scatter land on the sequence axis.
        xr = env.enter(x).reshape(-1, d)
        g = jax.nn.silu(xr @ w["dense_gate"])
        u = xr @ w["dense_up"]
        dy = ((g * u) @ w["dense_down"]).reshape(B, -1, d)
        dense_y = env.exit(dy)

    # EP needs the token count to split evenly over the model axis; decode
    # steps have a handful of tokens, so they run "replicated EP": every
    # rank dispatches the full (tiny) token set and the all_to_all carries
    # M redundant copies — negligible at decode token counts.
    ep_split = impl == "ep" and (B * S) % env.tp == 0 and (B * S) >= env.tp

    if impl == "ep" and sp:
        # sequence shards are already a disjoint per-rank token split
        xf = x.reshape(B * S, d)
    elif impl == "ep" and ep_split:
        xf = _token_split(env.psum_enter(x.reshape(B * S, d)), env.model_axis)
    elif impl == "ep":
        xf = env.psum_enter(x.reshape(B * S, d))
    else:  # tp layout: boundary collectives at (B, S, d)
        xf = env.enter(x).reshape(-1, d)
    T = xf.shape[0]

    top_p, top_e, aux = _route(xf, w["router"], E, k)
    capacity = max(8, round_up(int(cfg.capacity_factor * T * k / E), 8))
    src, dest, keep, order = _dispatch_indices(top_e, E, capacity)

    buf = jnp.zeros((E * capacity + 1, d), xf.dtype)
    buf = buf.at[dest].add(xf[src] * keep[:, None].astype(xf.dtype))
    buf = buf[:-1].reshape(E, capacity, d)

    if impl == "ep":
        M = env.tp
        e_loc = E // M
        # (E, C, d) -> exchange expert dim: every rank keeps its e_loc experts
        # lint: allow(RAW-COLLECTIVE): EP expert exchange — a permutation of token buffers, lossless by definition (audited as relayout)
        sent = lax.all_to_all(
            buf, env.model_axis, split_axis=0, concat_axis=1, tiled=True
        )  # (e_loc, M*C, d)
        out_loc = _expert_ffn(sent, w["w_gate"], w["w_up"], w["w_down"])
        # lint: allow(RAW-COLLECTIVE): EP expert return exchange — same lossless permutation on the way back
        buf_out = lax.all_to_all(
            out_loc, env.model_axis, split_axis=1, concat_axis=0, tiled=True
        )  # (E, C, d)
    else:
        out_full = _expert_ffn(buf, w["w_gate"], w["w_up"], w["w_down"])
        buf_out = out_full  # psum applied at block exit

    flat_out = buf_out.reshape(E * capacity, d)
    flat_out = jnp.concatenate([flat_out, jnp.zeros((1, d), xf.dtype)], axis=0)
    gathered = flat_out[dest] * (top_p.reshape(-1)[order] * keep)[:, None].astype(
        xf.dtype
    )
    y = jnp.zeros((T, d), xf.dtype).at[src].add(gathered)

    if cfg.moe_dense_ff and impl != "ep":
        # dense residual in the TP layout shares the block-exit psum
        g = jax.nn.silu(xf @ w["dense_gate"])
        u = xf @ w["dense_up"]
        y = y + (g * u) @ w["dense_down"]

    if impl == "ep" and sp:
        # y is complete for this rank's tokens == the sequence shard
        # lint: allow(RAW-COLLECTIVE): scalar MoE aux-loss reduction — metrics traffic, audited as a scalar psum
        aux = lax.psum(aux, env.model_axis) / env.tp
        y = y.reshape(B, S, d)
    elif impl == "ep" and ep_split:
        y = _token_merge(y, env.model_axis).reshape(B, S, d)
        # lint: allow(RAW-COLLECTIVE): scalar MoE aux-loss reduction — metrics traffic, audited as a scalar psum
        aux = lax.psum(aux, env.model_axis) / env.tp
    elif impl == "ep":
        y = y.reshape(B, S, d)  # replicated EP: complete on every rank
    else:
        # (B, S_full, d) under seq_parallel: exit scatters back to shards
        y = env.exit(y.reshape(B, -1, d))
    if dense_y is not None:
        y = y + dense_y
    return y, aux
