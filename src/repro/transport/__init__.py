"""Unified compression transport layer (see docs/transport.md).

Public surface:

  * :class:`CompressionPolicy` / :func:`policy_for` — wire-format policy
    and the single source of truth for wire-byte accounting.
  * :class:`Transport` and the functional :func:`all_gather`,
    :func:`reduce_scatter`, :func:`quantize` — the pack -> collective ->
    unpack pipelines with ADT semantics and training-ready VJPs.
  * :func:`seq_gather` / :func:`seq_scatter` / :func:`all_reduce` — the
    activation-path (TP axis) collectives: compressed fwd AND bwd
    (docs/collectives.md documents the wire contract per entry point).
  * :func:`pack_planes` / :func:`unpack_planes` — kernel dispatch
    (Pallas compiled on TPU / interpret off-TPU, or the jnp oracle).
  * :func:`pack_tokens` / :func:`unpack_tokens` (+ ``_host`` twins) —
    lossless byte-plane staging of token ids across the host<->device
    boundary (the serve engine's ``host_device`` traffic class).
  * :class:`FabricChannel` + the KV-page / weight parcel codecs — the
    metered inter-replica channel behind the fleet's ``kv_migration``
    and ``weight_publish`` traffic classes (docs/fleet.md).
"""
from repro.transport.fabric import (
    FABRIC_CLASSES,
    FabricChannel,
    FabricError,
    KVPageParcel,
    WeightParcel,
    pack_kv_pages,
    pack_weight_parcel,
    unpack_kv_pages,
    unpack_weight_parcel,
)
from repro.transport.hostdev import (
    pack_tokens,
    pack_tokens_host,
    stage,
    unpack_tokens,
    unpack_tokens_host,
)
from repro.transport.policy import (
    CompressionPolicy,
    act_policy_for,
    policy_for,
    ring_wire_bytes,
)
from repro.transport.transport import (
    Transport,
    all_gather,
    all_reduce,
    axis_size,
    pack_planes,
    pick_split_axis,
    quantize,
    reduce_scatter,
    resolve_impl,
    seq_gather,
    seq_scatter,
    unpack_planes,
)

__all__ = [
    "CompressionPolicy",
    "FABRIC_CLASSES",
    "FabricChannel",
    "FabricError",
    "KVPageParcel",
    "Transport",
    "WeightParcel",
    "pack_kv_pages",
    "pack_weight_parcel",
    "unpack_kv_pages",
    "unpack_weight_parcel",
    "act_policy_for",
    "all_gather",
    "all_reduce",
    "axis_size",
    "pack_planes",
    "pack_tokens",
    "pack_tokens_host",
    "stage",
    "pick_split_axis",
    "policy_for",
    "quantize",
    "reduce_scatter",
    "resolve_impl",
    "ring_wire_bytes",
    "seq_gather",
    "seq_scatter",
    "unpack_planes",
    "unpack_tokens",
    "unpack_tokens_host",
]
