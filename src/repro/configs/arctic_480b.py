"""arctic-480b [moe] — 128 experts top-2 + dense residual  [hf:Snowflake/snowflake-arctic-base].

Dense-MoE hybrid: every MoE layer also has a parallel dense SwiGLU residual
branch. Experts are expert-parallel over the model axis (128 / 16 = 8
experts per shard) — the all-to-all dispatch pattern is one of the three
hillclimb targets (EXPERIMENTS.md §Perf).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    top_k=2,
    moe_dense_ff=4864,  # parallel dense residual branch (arctic model card)
    moe_impl="ep",
    rope_theta=1e6,
    num_precision_groups=5,
)
