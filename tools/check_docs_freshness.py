#!/usr/bin/env python
"""Docs-freshness check: fail if docs/*.md references a symbol or file
that no longer exists under the repo's source tree.

Grep-based and deliberately conservative (CI must not cry wolf):

  * fenced code blocks are stripped; only inline `backtick` spans are
    inspected;
  * spans containing spaces, operators, colons, or newlines are skipped
    (prose, shell lines, pseudo-code);
  * file-path spans (``a/b.py``, ``x.md``) must resolve relative to the
    repo root, ``src/repro/``, ``docs/``, or ``tests/``;
  * dotted ``repro.*`` module paths must resolve to a module or package;
  * identifier-looking spans (snake_case with an underscore, CamelCase,
    or dotted names) must appear verbatim somewhere in the source corpus
    (``src/``, ``tests/``, ``benchmarks/``, ``examples/`` contents +
    file names). Plain lowercase words are ignored.

Run from anywhere: paths are resolved against the repo root (parent of
this file's directory). Exit code 1 lists every stale reference.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
# docs/ is deliberately NOT part of the corpus: a stale reference must
# not satisfy itself (or another doc) — only real source keeps it alive
SOURCE_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
SEARCH_EXTS = {".py", ".md", ".toml", ".yml"}
# every registered doc must exist: deleting one without de-registering
# it here fails CI the same way a stale symbol reference does
REQUIRED_DOCS = (
    "architecture.md",
    "audit.md",
    "collectives.md",
    "data.md",
    "fleet.md",
    "plan.md",
    "serving.md",
    "transport.md",
)

FENCE_RE = re.compile(r"```.*?```", re.S)
SPAN_RE = re.compile(r"`([^`\n]+)`")
IDENT_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_.]*$")
CAMEL_RE = re.compile(r"[a-z][A-Z]")


def _corpus() -> str:
    parts = []
    for d in SOURCE_DIRS:
        base = ROOT / d
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*")):
            if p.suffix in SEARCH_EXTS and p.is_file():
                parts.append(str(p.relative_to(ROOT)))
                try:
                    parts.append(p.read_text(errors="ignore"))
                except OSError:
                    pass
    return "\n".join(parts)


def _path_exists(token: str) -> bool:
    cands = [token, f"src/repro/{token}", f"docs/{token}", f"tests/{token}",
             f"tests/scenarios/{token}", f"src/{token}"]
    return any((ROOT / c).exists() for c in cands)


def _module_exists(token: str) -> bool:
    rel = token.replace(".", "/")
    return (ROOT / "src" / f"{rel}.py").exists() or (
        ROOT / "src" / rel
    ).is_dir()


def _looks_like_symbol(token: str) -> bool:
    if not IDENT_RE.match(token):
        return False
    return "_" in token or "." in token or bool(CAMEL_RE.search(token))


def check(doc_paths=None) -> list[str]:
    corpus = _corpus()
    stale = []
    docs = doc_paths or sorted((ROOT / "docs").glob("*.md"))
    if doc_paths is None:
        present = {d.name for d in docs}
        stale.extend(
            f"docs/{name}: registered in REQUIRED_DOCS but missing"
            for name in REQUIRED_DOCS
            if name not in present
        )
    for doc in docs:
        text = FENCE_RE.sub("", doc.read_text())
        for m in SPAN_RE.finditer(text):
            token = m.group(1).strip().rstrip(",").rstrip("()")
            if not token or any(c in token for c in " =<>:[]{}|*\"'-/+"):
                # paths are the one slash-bearing form we do check
                if "/" in token and re.match(r"^[\w./-]+\.(py|md)$", token):
                    if not _path_exists(token):
                        stale.append(f"{doc.name}: missing file `{token}`")
                continue
            if re.match(r"^[\w.]+\.(py|md)$", token):
                if not _path_exists(token):
                    stale.append(f"{doc.name}: missing file `{token}`")
                continue
            if token.startswith("repro."):
                if _module_exists(token):
                    continue
                # repro.pkg.attr: module prefix + attr searched in corpus
                head, _, attr = token.rpartition(".")
                if _module_exists(head) and re.search(
                    rf"\b{re.escape(attr)}\b", corpus
                ):
                    continue
                stale.append(f"{doc.name}: unresolvable module `{token}`")
                continue
            if not _looks_like_symbol(token):
                continue
            # dotted attr chains: every component must appear somewhere
            names = [n for n in token.split(".") if n]
            if all(
                re.search(rf"\b{re.escape(n)}\b", corpus) for n in names
            ):
                continue
            stale.append(f"{doc.name}: unknown symbol `{token}`")
    return stale


def main() -> int:
    stale = check()
    if stale:
        print("docs reference symbols/files that no longer exist:")
        for s in stale:
            print(f"  {s}")
        return 1
    print(f"docs freshness OK ({len(list((ROOT / 'docs').glob('*.md')))} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
