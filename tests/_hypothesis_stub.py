"""Minimal, dependency-free fallback for the `hypothesis` API surface this
suite uses (given / settings / a handful of strategies).

Loaded by ``conftest.py`` ONLY when the real package is missing (e.g. an
offline container). It is not a shrinker — just a deterministic seeded
sampler so the property tests still execute their invariants with a few
dozen examples. CI installs real hypothesis via ``pip install -e .[dev]``.
"""
from __future__ import annotations

import functools
import inspect
import random
import struct
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: rng.choice(seq))


def floats(
    min_value=None,
    max_value=None,
    allow_nan: bool = True,
    allow_infinity: bool = True,
    width: int = 64,
) -> _Strategy:
    def draw(rng):
        if min_value is not None or max_value is not None:
            lo = (
                float(min_value)
                if min_value is not None
                else float(max_value) - 1000.0
            )
            hi = (
                float(max_value)
                if max_value is not None
                else float(min_value) + 1000.0
            )
            return rng.uniform(lo, hi)
        # unbounded: mix exact specials with log-scale magnitudes, kept
        # finite and representable at the requested width
        roll = rng.random()
        if roll < 0.1:
            return rng.choice([0.0, -0.0, 1.0, -1.0])
        sign = -1.0 if rng.random() < 0.5 else 1.0
        exp_hi = 37 if width == 32 else 300
        val = sign * 10.0 ** rng.uniform(-exp_hi, exp_hi)
        if width == 32:  # round-trip through f32 so the value is exact
            val = struct.unpack("f", struct.pack("f", val))[0]
        return val

    return _Strategy(draw)


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        size = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(size)]

    return _Strategy(draw)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            max_examples = getattr(
                wrapper, "_stub_max_examples",
                getattr(fn, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES),
            )
            # stable digest, not hash(): str hashing is randomized per
            # process and would make failing draws unreproducible
            rng = random.Random(
                zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            )
            for _ in range(max_examples):
                drawn = [s.draw(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)

        # the drawn params are filled here, not by pytest: hide them so
        # the test runner does not mistake them for fixtures
        wrapper.__signature__ = inspect.Signature()
        del wrapper.__wrapped__
        return wrapper

    return deco


strategies = types.SimpleNamespace(
    integers=integers,
    floats=floats,
    lists=lists,
    sampled_from=sampled_from,
)
