"""Fleet request router (`repro.fleet.router`).

The host-side control plane of the disaggregated serving tier: an
admission queue drained in strict FIFO order onto a fleet of
:class:`~repro.fleet.replica.DecodeReplica` engines, with prefill
delegated to :class:`~repro.fleet.replica.PrefillWorker` round-robin
and the resulting KV pages migrated through the priced
:class:`~repro.transport.FabricChannel` (``kv_migration`` class).

Determinism contract (pinned by ``tests/scenarios/scenario_fleet.py``):
greedy sampling over independent slots makes every request's stream a
pure function of ``(prompt, weight version)``, and every fleet hop is
lossless — worker prefill is bit-identical to local prefill, parcels
round-trip exactly, replicas only swap weights while idle. So router
streams are BIT-EXACT against a single engine and against
``generate_static`` for the same request set, under arrival-order
permutations, any replica count, replica join/leave, fp32 or int8 KV
pools, and across a mid-run weight refresh boundary.

Live weight refresh is **versioned-at-admission**: ``submit`` pins each
request to the latest published version; a replica installs a newer
version only when idle AND no queued request still pins its current
one (rolling refresh — in-flight requests never pause, new-version
requests steer to already-swapped replicas). Weight parcels cross the
fabric once per install (``weight_publish`` class).
"""
from __future__ import annotations

import collections

from repro.fleet.errors import RouterError
from repro.transport import FabricChannel, pack_kv_pages, unpack_weight_parcel


class FleetRouter:
    """Route requests across ``replicas`` using ``workers`` for
    prefill. All replicas must share the engine geometry the parcels
    assume (page size, capacity, slots); workers must match it too."""

    def __init__(self, replicas, workers, *, fabric: FabricChannel | None = None):
        replicas, workers = list(replicas), list(workers)
        if not replicas:
            raise RouterError("a fleet needs at least one decode replica")
        if not workers:
            raise RouterError("a fleet needs at least one prefill worker")
        names = [r.name for r in replicas] + [w.name for w in workers]
        if len(set(names)) != len(names):
            raise RouterError(f"duplicate fleet member names in {names}")
        e0 = replicas[0].engine
        for r in replicas[1:]:
            e = r.engine
            if (e.page_size, e.cache_capacity, e.max_slots) != (
                    e0.page_size, e0.cache_capacity, e0.max_slots):
                raise RouterError(
                    f"replica {r.name}: geometry "
                    f"{(e.page_size, e.cache_capacity, e.max_slots)} != "
                    f"{(e0.page_size, e0.cache_capacity, e0.max_slots)}"
                )
        for w in workers:
            if (w.page_size, w.cache_capacity) != (
                    e0.page_size, e0.cache_capacity):
                raise RouterError(
                    f"worker {w.name}: geometry "
                    f"{(w.page_size, w.cache_capacity)} != "
                    f"{(e0.page_size, e0.cache_capacity)}"
                )
        self.replicas = replicas
        self.workers = workers
        self.fabric = fabric if fabric is not None else FabricChannel()
        self.plan = e0.plan
        self._kv_policy = self.plan.kv_migration_policy()
        self.versions: dict[int, object] = {}
        self._parcels: dict[int, object] = {}
        self.latest: int | None = None
        self.queue: collections.deque = collections.deque()
        self._rids: set[int] = set()
        self.results: dict[int, object] = {}
        self.placements: dict[int, dict] = {}
        self.migrated_pages = 0
        self._rr = 0
        self.ticks = 0

    # -- weight publishing -------------------------------------------------
    def publish(self, parcel) -> None:
        """Register a trainer weight parcel. Replicas install it on
        their next idle tick (rolling refresh); requests submitted from
        now on pin this version."""
        if self.latest is not None and parcel.version <= self.latest:
            raise RouterError(
                f"publish version {parcel.version} is not newer than "
                f"{self.latest}"
            )
        storage_like = self.replicas[0].engine.storage
        self.versions[parcel.version] = unpack_weight_parcel(
            parcel, storage_like
        )
        self._parcels[parcel.version] = parcel
        self.latest = parcel.version

    def _install(self, replica, version: int) -> None:
        self.fabric.send(
            self._parcels[version], cls="weight_publish",
            src="trainer", dst=replica.name,
        )
        replica.install(self.versions[version], version)

    # -- membership --------------------------------------------------------
    def add_replica(self, replica) -> None:
        """Join: the new replica installs the latest published version
        through the fabric before taking traffic."""
        if self.latest is None:
            raise RouterError("publish weights before adding a replica")
        if replica.name in {r.name for r in self.replicas}:
            raise RouterError(f"duplicate replica name {replica.name!r}")
        e, e0 = replica.engine, self.replicas[0].engine
        if (e.page_size, e.cache_capacity, e.max_slots) != (
                e0.page_size, e0.cache_capacity, e0.max_slots):
            raise RouterError(
                f"replica {replica.name}: geometry mismatch on join"
            )
        self.replicas.append(replica)
        self._install(replica, self.latest)

    def remove_replica(self, name: str) -> None:
        """Leave: mark the replica draining — no new admissions; it is
        dropped (with its conservation audits run) once its in-flight
        requests finish."""
        match = [r for r in self.replicas if r.name == name]
        if not match:
            raise RouterError(f"unknown replica {name!r}")
        if all(r.draining or r.name == name for r in self.replicas):
            raise RouterError("cannot drain the last replica")
        match[0].draining = True

    # -- admission ---------------------------------------------------------
    def submit(self, req) -> None:
        """Queue one request, pinned to the latest published version."""
        if self.latest is None:
            raise RouterError("no weights published: submit after publish")
        if req.rid in self._rids:
            raise RouterError(f"duplicate request id {req.rid}")
        self.replicas[0].engine.validate_request(req)
        self._rids.add(req.rid)
        self.queue.append((req, self.latest))

    def _pick(self, req, version: int):
        """Deterministic placement: among non-draining replicas at the
        request's version with admission capacity, least-loaded first,
        lowest index breaking ties."""
        best, best_key = None, None
        for i, r in enumerate(self.replicas):
            if r.draining or r.version != version:
                continue
            ok, _ = r.probe(req)
            if not ok:
                continue
            key = (r.engine.active_slots, i)
            if best is None or key < best_key:
                best, best_key = r, key
        return best

    def _dispatch(self, req, version: int, replica) -> None:
        ok, hits = replica.probe(req)
        if not ok:
            raise RouterError(
                f"request {req.rid}: placement picked a full replica"
            )
        n_hits = len(hits)
        worker = self.workers[self._rr % len(self.workers)]
        self._rr += 1
        pages, first = worker.prefill(
            self.versions[version], req, n_hits=n_hits
        )
        S = len(req.prompt_ids)
        n_new = -(-S // replica.engine.page_size) - n_hits
        parcel = pack_kv_pages(pages, self._kv_policy, meta={
            "rid": req.rid, "version": version, "prompt_len": S,
            "n_hits": n_hits, "pages": n_new, "first": first,
        })
        self.fabric.send(
            parcel, cls="kv_migration", src=worker.name, dst=replica.name
        )
        self.migrated_pages += n_new
        replica.admit_parcel(req, parcel)
        self.placements[req.rid] = {
            "replica": replica.name, "worker": worker.name,
            "version": version,
        }

    def _collect(self, replica) -> None:
        for rid, res in replica.engine.take_completed().items():
            self.results[rid] = res

    # -- the scheduling loop -----------------------------------------------
    def tick(self) -> None:
        """One fleet step: rolling refresh, drained-leaver cleanup,
        FIFO admissions, then one decode tick per busy replica."""
        self.ticks += 1
        # rolling refresh: an idle replica moves to the latest version
        # unless a queued request still pins its current one
        if self.latest is not None:
            pinned = {v for _, v in self.queue}
            for r in self.replicas:
                if (not r.draining and r.version != self.latest
                        and r.engine.active_slots == 0
                        and (r.version is None or r.version not in pinned)):
                    self._install(r, self.latest)
        # drop drained leavers (conservation audits included)
        keep = []
        for r in self.replicas:
            if (r.draining and not r.engine.has_work
                    and not r.engine.pending_record):
                self._collect(r)
                r.engine.finish()
            else:
                keep.append(r)
        self.replicas = keep
        # strict FIFO admission: the head of line waits for a replica
        # at its version with free residency
        while self.queue:
            req, version = self.queue[0]
            replica = self._pick(req, version)
            if replica is None:
                break
            self.queue.popleft()
            self._dispatch(req, version, replica)
        # decode: one engine step per replica with pending work
        for r in self.replicas:
            if r.engine.has_work or r.engine.pending_record:
                r.tick()
            self._collect(r)

    def run(self, requests, *, max_ticks: int = 1_000_000, on_tick=None):
        """Submit ``requests`` and tick the fleet until drained.

        ``on_tick(router)`` runs before every tick — the hook the
        launch driver uses to publish a mid-run weight refresh or
        submit follow-up traffic. Returns ``{rid: GenResult}``.
        """
        for req in requests:
            self.submit(req)
        while self.queue or any(
            r.engine.has_work or r.engine.pending_record or r.draining
            for r in self.replicas
        ):
            if self.ticks >= max_ticks:
                raise RouterError(
                    f"fleet stopped at max_ticks={max_ticks} with "
                    f"{len(self.queue)} queued and "
                    f"{sum(r.engine.active_slots for r in self.replicas)} "
                    "in flight"
                )
            if on_tick is not None:
                on_tick(self)
            self.tick()
        for r in self.replicas:
            self._collect(r)
            r.engine.finish()
        return dict(self.results)

    # -- accounting --------------------------------------------------------
    def wire_summary(self) -> dict:
        """Fabric per-class totals + the observed quantities the
        analytic :func:`repro.roofline.analysis.fleet_migration_bytes`
        model takes as inputs."""
        out = self.fabric.wire_summary()
        out["migrated_pages"] = self.migrated_pages
        out["publish_installs"] = out["hops"]["weight_publish"]
        out["ticks"] = self.ticks
        return out
