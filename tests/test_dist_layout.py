"""Storage-layout math: to-storage + (emulated) gather reconstructs the
exact TP-local logical weights — property-tested over shapes/meshes."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.dist.spec import (
    DIST, REPL, TP_SMALL, MeshCfg, build_leaf_spec, leaf_to_storage,
)
from repro.models.meta import ParamMeta


def _reconstruct(storage, spec, mesh, rank):
    """Emulate what materialize_leaf does on model-rank `rank`."""
    if mesh.tp == 1 and mesh.dshards == 1:
        return np.asarray(storage)  # trivial mesh: storage is logical
    if spec.kind == REPL:
        return np.asarray(storage)
    if spec.kind == TP_SMALL:
        return np.asarray(storage)[rank]
    arr = np.asarray(storage)
    flat = (arr[rank] if spec.meta.tp_dim is not None else arr).reshape(-1)
    n = math.prod(spec.local_logical)
    return flat[:n].reshape(spec.local_logical)


def _expected_slice(x, spec, mesh, rank):
    meta = spec.meta
    if meta.tp_dim is None or mesh.tp == 1:
        return np.asarray(x)
    start = meta.tp_slice_index(rank, spec.logical, mesh.tp)
    width = spec.local_logical[meta.tp_dim]
    sl = [slice(None)] * x.ndim
    sl[meta.tp_dim] = slice(start, start + width)
    return np.asarray(x)[tuple(sl)]


@given(
    st.sampled_from([(64, 32), (33, 16), (128,), (8, 4, 16)]),
    st.sampled_from([1, 2, 4]),      # tp
    st.sampled_from([1, 2, 4]),      # dshards
    st.sampled_from([None, 0, 1]),   # tp_dim
)
@settings(max_examples=60, deadline=None)
def test_property_storage_roundtrip(shape, tp, dsh, tp_dim):
    if tp_dim is not None and tp_dim >= len(shape):
        tp_dim = None
    if tp_dim is not None and shape[tp_dim] % tp:
        return  # uneven unit split not allowed without tp_units
    mesh = MeshCfg(tp=tp, dp=dsh, compress_min_size=1)
    meta = ParamMeta(tp_dim=tp_dim, compress=True)
    rng = np.random.default_rng(hash((shape, tp, dsh, tp_dim)) % 2**31)
    x = jnp.asarray(rng.normal(0, 1, shape).astype(np.float32))
    spec = build_leaf_spec(x.shape, meta, mesh, stacked=False)
    storage = leaf_to_storage(x, spec, mesh)
    for rank in range(tp):
        got = _reconstruct(storage, spec, mesh, rank)
        want = _expected_slice(x, spec, mesh, rank)
        np.testing.assert_array_equal(got.reshape(want.shape), want)


def test_kv_replication_slices():
    """kv units < tp: ranks share unit content per the replication rule."""
    mesh = MeshCfg(tp=4, dp=1, compress_min_size=1)
    kv, hd, d = 2, 8, 16
    meta = ParamMeta(tp_dim=1, tp_units=kv)
    x = jnp.asarray(
        np.random.default_rng(0).normal(0, 1, (d, kv * hd)).astype(np.float32)
    )
    spec = build_leaf_spec(x.shape, meta, mesh, stacked=False)
    storage = np.asarray(leaf_to_storage(x, spec, mesh))
    # ranks 0,1 share kv head 0; ranks 2,3 share kv head 1
    np.testing.assert_array_equal(storage[0], storage[1])
    np.testing.assert_array_equal(storage[2], storage[3])
    assert not np.array_equal(storage[0], storage[2])


def test_stacked_layout():
    mesh = MeshCfg(tp=2, dp=2, compress_min_size=1)
    meta = ParamMeta(tp_dim=1)
    R, a, b = 3, 8, 16
    x = jnp.asarray(
        np.random.default_rng(1).normal(0, 1, (R, a, b)).astype(np.float32)
    )
    spec = build_leaf_spec(x.shape, meta, mesh, stacked=True)
    storage = np.asarray(leaf_to_storage(x, spec, mesh))
    assert storage.shape[0] == R and storage.shape[1] == mesh.tp
    # rep 1, rank 1: flat == x[1][:, 8:] flattened
    want = np.asarray(x)[1][:, 8:].reshape(-1)
    got = storage[1, 1].reshape(-1)[: want.size]
    np.testing.assert_array_equal(got, want)
