"""Data-parallel CNN train step with per-layer ADT compression — the
paper's exact setting (host master weights, per-batch compressed sends,
uncompressed gradient returns, per-layer AWP)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.dist.shard import shard_map
from repro.dist.spec import (
    DIST,
    LeafSpec,
    MeshCfg,
    build_leaf_spec,
    leaf_partition_spec,
    leaf_to_storage,
    materialize_leaf,
)
from repro.models.cnn import CNNConfig, cnn_loss, topk_error
from repro.optim.sgd import SGDConfig, sgd_update
from repro.transport import policy_for
from repro.transport import transport as _T


def _act_quant_fn(act_policy):
    """Activation policy -> straight-through stage-boundary truncation
    (None when the policy keeps fp32: zero-cost identity)."""
    if act_policy is None:
        return None
    pol = policy_for(act_policy)
    if not pol.compresses:
        return None

    def aq(x):
        return _T.quantize(x.astype(jnp.float32), pol).astype(x.dtype)

    return aq


def build_cnn_spec_tree(params, metas, mesh_cfg: MeshCfg):
    return jax.tree_util.tree_map(
        lambda x, m: build_leaf_spec(x.shape, m, mesh_cfg, stacked=False),
        params, metas,
    )


def cnn_to_storage(params, spec_tree, mesh_cfg: MeshCfg):
    return jax.tree_util.tree_map(
        lambda x, s: leaf_to_storage(x, s, mesh_cfg),
        params, spec_tree, is_leaf=lambda x: not isinstance(x, (dict,)),
    )


def _mat(storage, spec_tree, mesh_cfg, groups, round_tos):
    """Materialize every layer with its own AWP format (per-layer mode)."""
    policies = {name: policy_for(round_tos[g]) for name, g in groups.items()}
    out = {}
    for name, leafs in storage["layers"].items():
        pol = policies[name]
        out[name] = {
            k: materialize_leaf(v, spec_tree["layers"][name][k], mesh_cfg, pol)
            for k, v in leafs.items()
        }
    return out


def make_cnn_train_step(
    cfg: CNNConfig,
    mesh_cfg: MeshCfg,
    mesh,
    spec_tree,
    groups_info,
    round_tos: tuple[int, ...],
    opt_cfg: SGDConfig,
    batch_shapes: dict,
    *,
    act_policy=None,
):
    groups, num_groups = groups_info
    assert len(round_tos) == num_groups
    dp = mesh_cfg.fsdp_axes[0] if mesh_cfg.dshards > 1 else None
    aq = _act_quant_fn(act_policy)

    def step(storage, momentum, batch, lr, key):
        def loss_fn(st):
            layers = _mat(st, spec_tree, mesh_cfg, groups, round_tos)
            return cnn_loss(
                layers, batch["images"], batch["labels"], cfg,
                train=True, key=key, act_quant=aq,
            ) / max(mesh_cfg.dshards, 1)

        loss, grads = jax.value_and_grad(loss_fn)(storage)

        def fix(g, s: LeafSpec):
            if s.kind != DIST and dp is not None:
                g = lax.psum(g, dp)
            return g

        grads = jax.tree_util.tree_map(
            fix, grads, spec_tree, is_leaf=lambda x: isinstance(x, LeafSpec)
        )
        wd = jax.tree_util.tree_map(
            lambda s: 1.0 if s.meta.compress else 0.0,
            spec_tree, is_leaf=lambda x: isinstance(x, LeafSpec),
        )
        new_storage, new_momentum = sgd_update(
            storage, grads, momentum, wd, opt_cfg, lr
        )

        # AWP per-group Σw² (paper Algorithm 1 line 6 input)
        sums = jnp.zeros((num_groups,), jnp.float32)
        for name, leafs in new_storage["layers"].items():
            g = groups[name]
            for k, v in leafs.items():
                if spec_tree["layers"][name][k].meta.compress:
                    vf = v.astype(jnp.float32)
                    sums = sums.at[g].add(jnp.sum(vf * vf))
        if dp is not None:
            sums = lax.psum(sums, dp)
            loss = lax.psum(loss, dp)
        return new_storage, new_momentum, {"loss": loss, "group_norms_sq": sums}

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1))

    pspecs = jax.tree_util.tree_map(
        lambda s: leaf_partition_spec(s, mesh_cfg),
        spec_tree, is_leaf=lambda x: isinstance(x, LeafSpec),
    )
    bspecs = {
        "images": P(dp, None, None, None),
        "labels": P(dp),
    }
    sharded = shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, pspecs, bspecs, P(), P(None)),
        out_specs=(pspecs, pspecs, {"loss": P(), "group_norms_sq": P(None)}),
    )
    return jax.jit(sharded, donate_argnums=(0, 1))


def make_cnn_eval(cfg, mesh_cfg, mesh, spec_tree, groups_info, round_tos):
    groups, _ = groups_info

    def evaluate(storage, images, labels):
        layers = _mat(storage, spec_tree, mesh_cfg, groups, round_tos)
        return topk_error(layers, images, labels, cfg, k=5)

    if mesh is None:
        return jax.jit(evaluate)
    pspecs = jax.tree_util.tree_map(
        lambda s: leaf_partition_spec(s, mesh_cfg),
        spec_tree, is_leaf=lambda x: isinstance(x, LeafSpec),
    )
    sharded = shard_map(
        evaluate, mesh=mesh,
        in_specs=(pspecs, P(None, None, None, None), P(None)),
        out_specs=P(),
    )
    return jax.jit(sharded)
