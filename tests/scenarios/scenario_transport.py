"""Subprocess scenario: the transport layer's collective paths on an
8-device host mesh — Transport dispatch (both impls), chunked
double-buffered gather, multi-axis reduce-scatter, and the compressed
backward path (grad_round_to < 4)."""
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.shard import shard_map
from repro.kernels import ref
from repro.transport import CompressionPolicy, Transport


def main():
    devs = np.array(jax.devices())
    mesh = Mesh(devs.reshape(4, 2), ("data", "model"))
    mesh3 = Mesh(devs.reshape(2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    S = 4 * 1024
    w = jnp.asarray(rng.normal(0, 1, (S,)).astype(np.float32))

    # ---- Transport.all_gather, both impls, all round_tos --------------
    for impl in ("ref", "pallas"):
        for rt in (1, 2, 3, 4):
            pol = CompressionPolicy(round_to=rt, impl=impl)
            t = Transport("data")

            f = shard_map(
                lambda x: t.all_gather(x, pol),
                mesh=mesh, in_specs=P("data"), out_specs=P(None),
            )
            got = np.asarray(jax.jit(f)(w))
            want = np.asarray(ref.quantize_ref(w, rt))
            np.testing.assert_array_equal(
                got, want, err_msg=f"impl={impl} rt={rt}"
            )
    print("  transport gather: ref/pallas x rt{1..4} exact OK")

    # ---- chunked double-buffered gather matches unchunked -------------
    for chunks in (2, 4, 8):
        pol = CompressionPolicy(round_to=2, chunks=chunks)
        t = Transport("data")
        f = shard_map(
            lambda x: t.all_gather(x, pol),
            mesh=mesh, in_specs=P("data"), out_specs=P(None),
        )
        got = np.asarray(jax.jit(f)(w))
        np.testing.assert_array_equal(
            got, np.asarray(ref.quantize_ref(w, 2)),
            err_msg=f"chunks={chunks}",
        )
    print("  chunked gather: interleave-exact for 2/4/8 blocks OK")

    # ---- multi-axis gather + multi-axis compressed reduce-scatter -----
    t3 = Transport(("pod", "data"))
    f = shard_map(
        lambda x: t3.all_gather(x, CompressionPolicy(round_to=2)),
        mesh=mesh3, in_specs=P(("pod", "data")), out_specs=P(None),
    )
    np.testing.assert_array_equal(
        np.asarray(jax.jit(f)(w)), np.asarray(ref.quantize_ref(w, 2))
    )

    D = 4  # pod x data
    gmat = jnp.asarray(rng.normal(0, 1, (D, S)).astype(np.float32))

    def rs(g_all):
        i = jax.lax.axis_index(("pod", "data"))
        return t3.reduce_scatter(
            g_all[i], CompressionPolicy(grad_round_to=2)
        )

    f = shard_map(
        rs, mesh=mesh3, in_specs=P(None, None),
        out_specs=P(("pod", "data")),
    )
    got = np.asarray(jax.jit(f)(gmat))
    want = np.sum(np.asarray(gmat), axis=0)
    tol = np.abs(want) * 2**-7 + 4 * 2**-7  # rt=2 nearest: ~2^-8 relative
    assert np.all(np.abs(got - want) <= tol), np.max(np.abs(got - want) - tol)

    # rt=4 multi-axis is exact
    def rs4(g_all):
        i = jax.lax.axis_index(("pod", "data"))
        return t3.reduce_scatter(g_all[i], CompressionPolicy())

    f4 = shard_map(
        rs4, mesh=mesh3, in_specs=P(None, None),
        out_specs=P(("pod", "data")),
    )
    np.testing.assert_allclose(np.asarray(jax.jit(f4)(gmat)), want, rtol=1e-6)
    print("  multi-axis (pod,data) gather + reduce-scatter OK")

    # ---- compressed backward path: grad_round_to < 4 ------------------
    D = 4
    coef = jnp.asarray(rng.normal(0, 1, (D, S)).astype(np.float32))
    pol_cg = CompressionPolicy(round_to=2, grad_round_to=2)
    t = Transport("data")

    def loss_fn(w_local, coef_row):
        w_full = t.all_gather(w_local, pol_cg)
        return jnp.sum(w_full * coef_row) / D

    def per_shard(w_local, coef_shard):
        return jax.grad(loss_fn)(w_local, coef_shard[0])

    f = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P("data"), P("data", None)), out_specs=P("data"),
    )
    got = np.asarray(jax.jit(f)(w, coef)).reshape(-1)
    want_full = np.sum(np.asarray(coef), axis=0) / D
    # the cotangent rides a rt=2 nearest-rounded reduce-scatter: each of
    # the D contributions carries ~2^-8 relative format error
    tol = np.abs(want_full) * 2**-7 + D * 2**-7
    assert np.all(np.abs(got - want_full) <= tol), np.max(
        np.abs(got - want_full) - tol
    )

    # and grad_round_to=4 (paper-faithful) stays exact to fp tolerance
    pol_ex = CompressionPolicy(round_to=2, grad_round_to=4)

    def loss_ex(w_local, coef_row):
        return jnp.sum(t.all_gather(w_local, pol_ex) * coef_row) / D

    f = shard_map(
        lambda wl, cs: jax.grad(loss_ex)(wl, cs[0]),
        mesh=mesh, in_specs=(P("data"), P("data", None)),
        out_specs=P("data"),
    )
    got = np.asarray(jax.jit(f)(w, coef)).reshape(-1)
    np.testing.assert_allclose(got, want_full, rtol=1e-6)
    print("  compressed VJP (grad_round_to=2) within format tolerance OK")

    print("scenario_transport OK")


if __name__ == "__main__":
    main()
