"""AdamW — used by the LM example drivers (the paper's CNN recipe stays on
momentum SGD). Elementwise on storage shards, like SGD."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def init_adamw(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"mu": z, "nu": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, wd_mask, cfg: AdamWConfig, lr):
    t = state["t"] + 1
    tf = t.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** tf
    c2 = 1.0 - cfg.b2 ** tf

    def upd(p, g, mu, nu, wd):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        step = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        p = p - lr * (step + cfg.weight_decay * wd * p)
        return p, mu, nu

    out = jax.tree_util.tree_map(upd, params, grads, state["mu"], state["nu"], wd_mask)
    pick = lambda i: jax.tree_util.tree_map(
        lambda tup: tup[i], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    return pick(0), {"mu": pick(1), "nu": pick(2), "t": t}
