"""CompressionPolicy — the single source of truth for ADT wire formats.

Every component that either *moves* compressed bytes (the transport
collectives) or *accounts* for them (the training loop's wire-byte log,
the roofline model, the benchmark harness) derives its numbers from this
module, so the analytical model and the implementation cannot drift —
the failure mode that ``test_collective_wire_bytes`` exists to catch.

A policy describes one precision group's transfer behaviour:

  * ``round_to``      — bytes kept per fp32 weight on the gather path
                        (paper §III: 1=fp8e7, 2=bf16, 3=bf24, 4=fp32),
  * ``mode``          — rounding applied before truncation on that path,
  * ``grad_round_to`` / ``grad_mode`` — the same for the backward
                        reduce-scatter (4 = paper-faithful uncompressed),
  * ``impl``          — kernel dispatch: ``auto`` picks the Pallas kernels
                        on TPU (compiled) and the pure-jnp oracle on CPU;
                        ``pallas`` forces the kernels (interpret off-TPU),
                        ``ref`` forces the oracle,
  * ``chunks``        — >1 splits the weight gather into that many plane
                        blocks so pack / wire / unpack of successive
                        blocks overlap (double buffering).
"""
from __future__ import annotations

import dataclasses

VALID_ROUND_TO = (1, 2, 3, 4)
VALID_MODES = ("truncate", "nearest", "stochastic")
VALID_IMPLS = ("auto", "pallas", "ref")
FP32_BYTES = 4


def ring_wire_bytes(kind: str, payload_bytes: float, group_size: int) -> float:
    """Per-device wire bytes of one ring-algorithm collective.

    ``payload_bytes`` is the *output* size for all-gather / all-to-all,
    the *input* size for all-reduce / reduce-scatter, and the transferred
    size for collective-permute. This is the one formula shared by the
    transport accounting and the HLO cost analyzer.
    """
    n = max(int(group_size), 1)
    kind = kind.replace("-start", "")
    if kind == "all-gather":
        return payload_bytes * (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * payload_bytes * (n - 1) / n
    if kind in ("reduce-scatter", "all-to-all"):
        return payload_bytes * (n - 1) / n
    if kind == "collective-permute":
        return float(payload_bytes)
    raise ValueError(f"unknown collective kind {kind!r}")


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    """Wire format + dispatch choices for one precision group."""

    round_to: int = 4
    grad_round_to: int = 4
    mode: str = "truncate"
    grad_mode: str = "nearest"
    impl: str = "auto"
    chunks: int = 1

    def __post_init__(self):
        if self.round_to not in VALID_ROUND_TO:
            raise ValueError(f"round_to must be in {VALID_ROUND_TO}")
        if self.grad_round_to not in VALID_ROUND_TO:
            raise ValueError(f"grad_round_to must be in {VALID_ROUND_TO}")
        if self.mode not in VALID_MODES:
            raise ValueError(f"mode must be in {VALID_MODES}")
        if self.grad_mode not in VALID_MODES:
            raise ValueError(f"grad_mode must be in {VALID_MODES}")
        if self.impl not in VALID_IMPLS:
            raise ValueError(f"impl must be in {VALID_IMPLS}")
        if self.chunks < 1:
            raise ValueError("chunks must be >= 1")

    # -- format properties ------------------------------------------------
    @property
    def compresses(self) -> bool:
        return self.round_to < FP32_BYTES

    @property
    def compresses_grads(self) -> bool:
        return self.grad_round_to < FP32_BYTES

    @property
    def bytes_per_element(self) -> int:
        """Wire bytes per fp32 element on the weight path."""
        return self.round_to

    @property
    def wire_fraction(self) -> float:
        """Fraction of fp32 bytes that actually hit the wire (weights)."""
        return self.round_to / FP32_BYTES

    # -- canonical byte accounting ---------------------------------------
    def all_gather_wire_bytes(self, s_local: int, axis_size: int) -> int:
        """Bytes received per device for one compressed all-gather of a
        shard of ``s_local`` fp32 elements over ``axis_size`` devices."""
        payload = axis_size * s_local * self.round_to
        return round(ring_wire_bytes("all-gather", payload, axis_size))

    def reduce_scatter_wire_bytes(self, s_local: int, axis_size: int) -> int:
        """Bytes received per device for one (compressed) reduce-scatter
        producing an ``s_local``-element shard."""
        payload = axis_size * s_local * self.grad_round_to
        return round(ring_wire_bytes("reduce-scatter", payload, axis_size))

    def host_device_bytes(self, elems: int) -> int:
        """Paper's host->device model: every weight moves once per batch."""
        return elems * self.round_to


def policy_for(
    round_to, grad_round_to: int | None = None, **overrides
) -> CompressionPolicy:
    """Coerce an int ``round_to`` (legacy call sites) or an existing policy
    into a CompressionPolicy, optionally overriding fields."""
    if isinstance(round_to, CompressionPolicy):
        pol = round_to
        if grad_round_to is not None and grad_round_to != pol.grad_round_to:
            overrides = {"grad_round_to": grad_round_to, **overrides}
        return dataclasses.replace(pol, **overrides) if overrides else pol
    return CompressionPolicy(
        round_to=int(round_to),
        grad_round_to=4 if grad_round_to is None else int(grad_round_to),
        **overrides,
    )
