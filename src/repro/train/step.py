"""Distributed train step: shard_map(grad(forward)) with ADT weight gathers.

The step is built *per* :class:`~repro.plan.PrecisionPlan`: the wire
format of every weight gather is static inside the compiled program, and
the AWP controller swaps compiled steps when the plan's weight formats
change (DESIGN.md §2). The plan's ``weights`` tuple has
``cfg.num_groups + 1`` entries; the last entry covers the top-level
weights (embedding / head / projectors).

``plan.needs_rng`` (stochastic rounding anywhere on the weight/grad
path) changes the step signature to
``step(storage, momentum, batch, lr, key)``: the key is folded per
materialization site and reaches the backward gradient pack through the
transport's ``all_gather`` VJP. Within a scanned layer group all
repetitions share one noise realization per step (the scan body is one
traced materialization site); keys differ across steps, groups, leaves
and fwd/bwd directions.

``plan=`` is the only configuration entry point: the pre-plan
``round_tos``/``env_kw`` kwarg sprawl (and its deprecation shims) is
gone. Build a plan with :meth:`~repro.plan.PrecisionPlan.build` or load
one from JSON.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.shard import shard_map
from repro.dist.spec import (
    DIST,
    LeafSpec,
    MeshCfg,
    materialize_leaf,
    materialize_placed_leaf,
    tree_partition_specs,
)
from repro.plan import PrecisionPlan, policy_uses_rng
from repro.models import model as M
from repro.optim.sgd import SGDConfig, sgd_update
from repro.transport import policy_for

def resolve_plan(
    cfg: ModelConfig,
    *,
    plan: PrecisionPlan | None,
    caller: str = "step factory",
    num_groups: int | None = None,
) -> PrecisionPlan:
    """One validation point for the required ``plan=`` argument shared
    by the train, serve and cnn step factories: type-check and broadcast
    to the architecture's group count."""
    if plan is None:
        raise TypeError(
            f"{caller}: needs plan= (a repro.plan.PrecisionPlan; the "
            "legacy round_tos/env_kw kwargs were removed)"
        )
    if not isinstance(plan, PrecisionPlan):
        raise TypeError(f"{caller}: plan must be a PrecisionPlan")
    n = num_groups if num_groups is not None else cfg.num_groups + 1
    return plan.broadcast(n)


def check_seq_parallel(batch_shapes: dict, mesh_cfg: MeshCfg):
    """Sequence-parallel layout precondition: every sequence dim must
    split evenly over the model axis (reduce-scatter semantics)."""
    for key in ("tokens", "labels", "features"):
        v = batch_shapes.get(key)
        if v is not None and v.ndim >= 2 and v.shape[1] % mesh_cfg.tp:
            raise ValueError(
                f"seq_parallel needs batch[{key!r}] seq dim {v.shape[1]} "
                f"divisible by tp={mesh_cfg.tp}"
            )


def _dp_axes(mesh_cfg: MeshCfg):
    return (
        mesh_cfg.fsdp_axes
        if len(mesh_cfg.fsdp_axes) > 1
        else mesh_cfg.fsdp_axes[0]
    )


def make_mat_fns(
    spec_tree, mesh_cfg: MeshCfg, round_tos, dtype=jnp.float32,
    grad_round_to: int | None = None, placed: bool = False, rng=None,
):
    """(mat_group, mat_top_factory) shared by train and serve steps.

    Materialized weights are cast to the compute dtype (fp32 faithful /
    bf16 beyond-paper+serving); the fp32 master stays in storage.
    Per-group wire behaviour is bundled into a
    :class:`~repro.transport.CompressionPolicy` (``round_tos`` entries may
    be ints or ready-made policies — a plan passes
    ``plan.weight_policies()``). ``placed=True`` consumes pre-gathered
    weights (see serve.place: weight-stationary decode). ``rng`` is the
    stochastic-rounding key: each materialization site of a policy that
    needs one gets a distinct ``fold_in``."""
    policies = tuple(policy_for(rt, grad_round_to) for rt in round_tos)
    fold = itertools.count()

    def _key_for(pol):
        if rng is None or not policy_uses_rng(pol):
            return None
        return jax.random.fold_in(rng, next(fold))

    def _cast(x):
        return x.astype(dtype) if x.dtype == jnp.float32 else x

    def _mat(x, s, pol):
        if placed:
            return _cast(materialize_placed_leaf(x, s, mesh_cfg))
        return _cast(
            materialize_leaf(x, s, mesh_cfg, pol, key=_key_for(pol))
        )

    def mat_group(g, key, storage):
        specs = spec_tree["groups"][g][key]
        pol = policies[g]
        return jax.tree_util.tree_map(
            lambda x, s: _mat(x, s, pol),
            storage,
            specs,
            is_leaf=lambda x: isinstance(x, LeafSpec),
        )

    def mat_top_factory(storage):
        pol = policies[-1]

        def mat_top(name):
            return _mat(storage[name], spec_tree[name], pol)

        return mat_top

    return mat_group, mat_top_factory


def _sync_grads(grads, spec_tree, mesh_cfg: MeshCfg, seq_parallel: bool = False):
    """Explicit cross-shard grad reductions not already handled by the
    compressed-gather VJP (DESIGN.md §3 / ParamMeta.grad_sync_model).

    ``seq_parallel``: the step ran with sequence-sharded activations, so
    leaves marked ``grad_sync_seq`` (pre-boundary norm scales) carry
    token-partial grads and get the model-axis psum too."""
    dp = _dp_axes(mesh_cfg) if mesh_cfg.dshards > 1 else None
    tp = mesh_cfg.model_axis if mesh_cfg.tp > 1 else None

    def fix(g, s: LeafSpec):
        if s.kind != DIST and dp is not None:
            # lint: allow(RAW-COLLECTIVE): grad-sync psum for replicated leaves — fp32 by the paper's accuracy contract, audited as grad_sync
            g = lax.psum(g, dp)
        if tp is not None and (
            s.meta.grad_sync_model or (seq_parallel and s.meta.grad_sync_seq)
        ):
            # lint: allow(RAW-COLLECTIVE): model-axis grad sync (grad_sync_model leaves) — fp32 contract, audited as grad_sync
            g = lax.psum(g, tp)
        return g

    def walk(gt, st):
        return jax.tree_util.tree_map(
            fix, gt, st, is_leaf=lambda x: isinstance(x, LeafSpec)
        )

    groups = [walk(g, s) for g, s in zip(grads["groups"], spec_tree["groups"])]
    top = {k: fix(grads[k], spec_tree[k]) for k in grads if k != "groups"}
    return {"groups": groups, **top}


def awp_group_norms(storage, spec_tree, mesh_cfg: MeshCfg):
    """Per-precision-group Σw² of the compressed (DIST) weights, exact up to
    fp accumulation: replication factors divided out (DESIGN.md §3)."""
    dp = _dp_axes(mesh_cfg) if mesh_cfg.dshards > 1 else None
    tp = mesh_cfg.model_axis if mesh_cfg.tp > 1 else None

    def leaf_sum(x, s: LeafSpec):
        if s.kind != DIST:
            return 0.0
        xf = x.astype(jnp.float32)
        return jnp.sum(xf * xf) / s.repl_factor

    def subtree_sum(pt, st):
        sums = jax.tree_util.tree_map(
            leaf_sum, pt, st, is_leaf=lambda x: isinstance(x, LeafSpec)
        )
        return sum(jax.tree_util.tree_leaves(sums))

    norms = [
        subtree_sum(gp, gs)
        for gp, gs in zip(storage["groups"], spec_tree["groups"])
    ]
    norms.append(
        sum(
            subtree_sum(storage[k], spec_tree[k])
            for k in storage
            if k != "groups"
        )
    )
    out = jnp.stack([jnp.asarray(n, jnp.float32) for n in norms])
    if dp is not None:
        # lint: allow(RAW-COLLECTIVE): AWP per-group norm reduction — (G+1,) metrics vector, audited as metrics
        out = lax.psum(out, dp)
    if tp is not None:
        # lint: allow(RAW-COLLECTIVE): AWP norm reduction over the model axis — metrics vector, audited as metrics
        out = lax.psum(out, tp)
    return out  # (num_groups + 1,)


def build_wd_mask(spec_tree):
    """1.0 for matrices (compressible), 0.0 for norms/biases/gates."""
    return jax.tree_util.tree_map(
        lambda s: 1.0 if s.meta.compress else 0.0,
        spec_tree,
        is_leaf=lambda x: isinstance(x, LeafSpec),
    )


def batch_pspecs(batch_shapes: dict, mesh_cfg: MeshCfg, shard_batch: bool):
    dp = _dp_axes(mesh_cfg) if (mesh_cfg.dshards > 1 and shard_batch) else None
    out = {}
    for k, v in batch_shapes.items():
        if v.ndim == 0:
            out[k] = P()
        else:
            out[k] = P(dp, *([None] * (v.ndim - 1)))
    return out


def make_train_step(
    cfg: ModelConfig,
    mesh_cfg: MeshCfg,
    mesh,
    spec_tree,
    opt_cfg: SGDConfig | None = None,
    batch_shapes: dict | None = None,
    *,
    plan: PrecisionPlan | None = None,
    aux_coef: float = 1e-2,
):
    """Returns jit-able ``step(storage, momentum, batch, lr[, key]) ->
    (storage', momentum', metrics)``. metrics: loss, token_count, group
    norms (for AWP). The trailing ``key`` argument exists exactly when
    ``plan.needs_rng`` (stochastic rounding on the weight/grad path).

    Call::

        make_train_step(cfg, mesh_cfg, mesh, spec_tree, opt_cfg,
                        batch_shapes, plan=plan)

    The plan owns every precision + layout lever: per-group weight
    formats, the gradient reduce-scatter entry, the activation /
    seq-boundary policies, compute dtype, ``accum_steps``, ``chunks``
    and ``seq_parallel``.
    """
    if opt_cfg is None or batch_shapes is None:
        raise TypeError("make_train_step: opt_cfg and batch_shapes required")
    plan = resolve_plan(cfg, plan=plan, caller="make_train_step")

    env = plan.make_env(mesh_cfg)
    if env.seq_parallel and mesh_cfg.tp > 1:
        check_seq_parallel(batch_shapes, mesh_cfg)
    dtype = plan.compute_dtype
    accum_steps = plan.accum_steps
    policies = plan.weight_policies()
    needs_rng = plan.needs_rng
    dp = _dp_axes(mesh_cfg) if mesh_cfg.dshards > 1 else None
    wd_mask = build_wd_mask(spec_tree)

    def grad_one(storage, batch, total, rng):
        mat_group, mat_top_factory = make_mat_fns(
            spec_tree, mesh_cfg, policies, dtype, rng=rng
        )

        def loss_fn(st):
            loss_sum, metrics = M.forward_loss(
                st, batch, cfg, env,
                mat_group=mat_group, mat_top=mat_top_factory(st),
            )
            n_shards = mesh_cfg.dshards
            loss = loss_sum / total + aux_coef * metrics["aux"] / (
                cfg.num_layers * n_shards * accum_steps
            )
            return loss, metrics

        return jax.value_and_grad(loss_fn, has_aux=True)(storage)

    def _step(storage, momentum, batch, lr, rng):
        # one count pass is avoided by normalising with the static token
        # count (all labels valid in our pipelines); per-microbatch valid
        # counts still feed the reported loss.
        b_any = next(iter(batch.values()))
        local_tokens = b_any.shape[0] * (
            batch["labels"].shape[1] if "labels" in batch else 1
        )
        total = jnp.asarray(local_tokens * max(mesh_cfg.dshards, 1), jnp.float32)

        if accum_steps == 1:
            (loss, metrics), grads = grad_one(storage, batch, total, rng)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]),
                batch,
            )

            def body(carry, mb):
                acc, loss_acc, cnt_acc = carry
                (l, m), g = grad_one(storage, mb, total, rng)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return (acc, loss_acc + l, cnt_acc + m["token_count"]), None

            zeros = jax.tree_util.tree_map(jnp.zeros_like, storage)
            (grads, loss, count), _ = lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)), micro
            )
            metrics = {"token_count": count, "aux": 0.0}
        grads = _sync_grads(
            grads, spec_tree, mesh_cfg, seq_parallel=env.seq_parallel_active
        )

        new_storage, new_momentum = sgd_update(
            storage, grads, momentum, wd_mask, opt_cfg, lr
        )
        norms = awp_group_norms(new_storage, spec_tree, mesh_cfg)

        # lint: allow(RAW-COLLECTIVE): scalar loss/token-count reductions — metrics traffic, audited as metrics
        loss_global = lax.psum(loss, dp) if dp is not None else loss
        count_global = (
            # lint: allow(RAW-COLLECTIVE): scalar loss/token-count reductions — metrics traffic, audited as metrics
            lax.psum(metrics["token_count"], dp)
            if dp is not None
            else metrics["token_count"]
        )
        out_metrics = {
            "loss": loss_global,
            "token_count": count_global,
            "group_norms_sq": norms,
        }
        return new_storage, new_momentum, out_metrics

    if needs_rng:
        def step(storage, momentum, batch, lr, key):
            return _step(storage, momentum, batch, lr, key)
    else:
        def step(storage, momentum, batch, lr):
            return _step(storage, momentum, batch, lr, None)

    if mesh is None:  # single-device path (tests, CNN repro)
        return jax.jit(step, donate_argnums=(0, 1))

    pspecs = tree_partition_specs(spec_tree, mesh_cfg)
    bspecs = batch_pspecs(batch_shapes, mesh_cfg, shard_batch=True)
    metrics_specs = {"loss": P(), "token_count": P(), "group_norms_sq": P(None)}
    in_specs = (pspecs, pspecs, bspecs, P())
    if needs_rng:
        in_specs = in_specs + (P(None),)
    sharded = shard_map(
        step,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(pspecs, pspecs, metrics_specs),
    )
    return jax.jit(sharded, donate_argnums=(0, 1))
