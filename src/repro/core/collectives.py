"""Explicit-transpose collective pairs for manual tensor parallelism —
thin shims over the unified :mod:`repro.transport`.

Megatron-style TP needs two conjugate operators around each block:

  * :func:`tp_region_enter` ("f"): forward identity on the (model-axis
    replicated) activations, backward ``psum`` of the cotangent over the
    model axis — column-parallel weights each produce a partial ``dx``.
  * :func:`tp_region_exit`  ("g"): forward ``psum`` of the partial block
    output over the model axis, backward identity.

We pin both directions down with ``custom_vjp`` instead of relying on the
AD transpose of ``lax.psum``, whose semantics for replicated inputs are a
classic source of silent double-counting. The data movers inside the VJPs
are ``repro.transport``'s: an activation :class:`CompressionPolicy` routes
every psum through the compressed reduce-scatter + all-gather
decomposition (``transport.all_reduce``) and the sequence-parallel pair
through ``transport.seq_gather`` / ``transport.seq_scatter``, so TP-axis
activation traffic shrinks by the policy's packing ratio exactly like the
DP-axis weight traffic (docs/collectives.md has the wire contract).

Invariants (previously stated only in test comments):

  * The TP axis is always named ``"model"`` (``MeshCfg.model_axis``);
    ``axis_names`` may also be a tuple treated as one logical group.
  * Activations entering :func:`tp_region_enter` are model-axis
    *replicated*; partial outputs entering :func:`tp_region_exit` are
    *unreduced partials*. Calling either on the wrong flavor
    double-counts — that is what the pinned transposes prevent.
  * Uncompressed cotangent psums accumulate in the COMPUTE dtype (the
    cotangent is cast to the forward input's dtype before the psum —
    bf16 activation grads stay bf16 on the wire; asserted by
    ``scenario_compressed_collectives``). Compressed psums instead
    unpack and accumulate in fp32, then cast back to the compute dtype.
  * ``policy`` must be hashable (``CompressionPolicy`` is frozen) —
    it rides ``custom_vjp`` nondiff argnums. ``None`` = uncompressed,
    bit-identical to the historical ``lax.psum`` paths.
"""
from __future__ import annotations

import functools
from typing import Hashable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.transport import policy_for
from repro.transport import transport as _T

AxisNames = Hashable | Sequence[Hashable]


def _act_policy(policy):
    """None -> None (uncompressed legacy path); else a CompressionPolicy."""
    return None if policy is None else policy_for(policy)


def _compressed_psum(g, axis_names, policy, *, use_grad_format: bool):
    return _T.all_reduce(
        g, axis_names, policy, use_grad_format=use_grad_format
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tp_region_enter(x, axis_names: AxisNames, policy=None):
    return x


def _enter_fwd(x, axis_names, policy):
    return x, jnp.zeros((0,), x.dtype)  # zero-size dtype carrier


def _enter_bwd(axis_names, policy, marker, g):
    pol = _act_policy(policy)
    if pol is not None and pol.compresses_grads:
        # the cotangent all-reduce rides packed planes (reduce-scatter +
        # all-gather at grad_round_to); unpacked contributions accumulate
        # in fp32 inside the transport, result cast to the compute dtype.
        return (
            _compressed_psum(
                g.astype(marker.dtype), axis_names, pol, use_grad_format=True
            ),
        )
    # cotangents are psum'd in the COMPUTE dtype (asserted by
    # scenario_compressed_collectives): fp32-accumulated attention
    # einsums would otherwise silently upcast every backward all-reduce
    # (bf16 activation grads are standard practice; noted in DESIGN.md §7).
    # The optimization barrier stops XLA's excess-precision pass from
    # cancelling the down-cast against the CPU backend's f32 promotion —
    # on TPU the collective runs natively in the compute dtype.
    g = lax.optimization_barrier(g.astype(marker.dtype))
    # lint: allow(RAW-COLLECTIVE): psum_enter's uncompressed bwd leg — one of the two pinned TP-region psum sites the auditor prices
    return (lax.psum(g, axis_names),)


tp_region_enter.defvjp(_enter_fwd, _enter_bwd)


def _exit_impl(x, axis_names, policy):
    pol = _act_policy(policy)
    if pol is not None and pol.compresses:
        return _compressed_psum(x, axis_names, pol, use_grad_format=False)
    # lint: allow(RAW-COLLECTIVE): psum_exit's uncompressed fwd leg — the other pinned TP-region psum site the auditor prices
    return lax.psum(lax.optimization_barrier(x), axis_names)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tp_region_exit(x, axis_names: AxisNames, policy=None):
    return _exit_impl(x, axis_names, policy)


def _exit_fwd(x, axis_names, policy):
    return _exit_impl(x, axis_names, policy), jnp.zeros((0,), x.dtype)


def _exit_bwd(axis_names, policy, marker, g):
    return (g.astype(marker.dtype),)


tp_region_exit.defvjp(_exit_fwd, _exit_bwd)


def seq_gather(x, axis_names: AxisNames, policy=None, axis: int = 1):
    """Sequence-parallel enter: all-gather sequence shards over the model
    axis (axis 1 == sequence), backward reduce-scatter. Dispatches through
    ``transport.seq_gather``; an activation policy compresses both the
    forward planes (``round_to``) and the cotangent (``grad_round_to``).
    Beyond-paper lever for shrinking the model-axis collective term."""
    pol = _act_policy(policy) or policy_for(4)
    return _T.seq_gather(x, axis_names, pol, axis)


def seq_scatter(x, axis_names: AxisNames, policy=None, axis: int = 1):
    """Sequence-parallel exit: reduce-scatter partial outputs over the
    model axis along the sequence dim, backward all-gather. Dispatches
    through ``transport.seq_scatter`` with the same compression contract
    as :func:`seq_gather` (planes are never summed — contributions unpack
    to fp32 first)."""
    pol = _act_policy(policy) or policy_for(4)
    return _T.seq_scatter(x, axis_names, pol, axis)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def seq_split(x, axis_name: Hashable, axis: int = 1):
    """Sequence-parallel entry for *replicated* activations: forward slices
    this rank's sequence shard, backward all-gathers the shard cotangents.

    This is the conjugate of :func:`seq_gather` for tensors that are
    already identical on every model rank (e.g. the audio feature-stub
    embedding) — no reduction is needed in either direction, so the
    cotangent rides an uncompressed all-gather. ``x.shape[axis]`` must
    divide the axis size."""
    n = _T.axis_size(axis_name)
    loc = x.shape[axis] // n
    rank = lax.axis_index(axis_name)
    return lax.dynamic_slice_in_dim(x, rank * loc, loc, axis=axis)


def _split_fwd(x, axis_name, axis):
    return seq_split(x, axis_name, axis), None


def _split_bwd(axis_name, axis, _, g):
    # lint: allow(RAW-COLLECTIVE): seq_split's lossless re-layout transpose — raw dtype is the wire format (audited as relayout)
    return (lax.all_gather(g, axis_name, axis=axis, tiled=True),)


seq_split.defvjp(_split_fwd, _split_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def seq_merge(x, axis_name: Hashable, axis: int = 1):
    """Sequence shards -> the full replicated sequence, for regions whose
    compute is *replicated* over the model axis (sLSTM, the prefill
    logits entry): forward all-gathers the shards, backward slices this
    rank's shard of the cotangent.

    This is :func:`seq_split`'s inverse, NOT :func:`seq_gather`'s twin:
    ``seq_gather``'s reduce-scatter transpose assumes each rank's
    cotangent is a *partial* sum (TP-sharded weights downstream); after
    replicated compute every rank holds the identical full cotangent and
    a reduce-scatter would double-count by the axis size."""
    # lint: allow(RAW-COLLECTIVE): seq_merge's lossless re-layout — raw dtype is the wire format (audited as relayout)
    return lax.all_gather(x, axis_name, axis=axis, tiled=True)


def _merge_fwd(x, axis_name, axis):
    return seq_merge(x, axis_name, axis), None


def _merge_bwd(axis_name, axis, _, g):
    n = _T.axis_size(axis_name)
    loc = g.shape[axis] // n
    rank = lax.axis_index(axis_name)
    return (lax.dynamic_slice_in_dim(g, rank * loc, loc, axis=axis),)


seq_merge.defvjp(_merge_fwd, _merge_bwd)
