"""Repo invariant linter: rule engine + rule semantics + repo cleanliness.

Fixture files are written under a tmp repo root mirroring the real
layout (``src/repro/...``), so the path-scoped rules (allowed-prefix
exemptions) behave exactly as they do in-tree.
"""
import pathlib
import sys
import textwrap

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from tools.lint import Finding, parse_suppressions, run_lint  # noqa: E402
from tools.lint.rules import (  # noqa: E402
    ALL_RULES,
    BareAssert,
    DeprecatedShim,
    HardcodedInterpret,
    RawCollective,
    UnpricedTransfer,
    UnseededRng,
)


def _lint(tmp_path, rel, source, rules):
    """Write one fixture file at ``rel`` under a tmp repo root and lint
    it with ``rules``."""
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return run_lint(rules, root=tmp_path, paths=[p])


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def test_suppression_with_reason_silences_the_finding(tmp_path):
    src = """\
    from jax import lax

    def f(x):
        # lint: allow(RAW-COLLECTIVE): pinned site, priced by hand
        return lax.psum(x, "model")
    """
    assert _lint(tmp_path, "src/repro/x.py", src, [RawCollective()]) == []


def test_suppression_on_code_line_binds_to_that_line(tmp_path):
    src = """\
    from jax import lax

    def f(x):
        return lax.psum(x, "model")  # lint: allow(RAW-COLLECTIVE): pinned
    """
    assert _lint(tmp_path, "src/repro/x.py", src, [RawCollective()]) == []


def test_reasonless_suppression_is_itself_a_finding(tmp_path):
    src = """\
    from jax import lax

    def f(x):
        # lint: allow(RAW-COLLECTIVE)
        return lax.psum(x, "model")
    """
    got = _lint(tmp_path, "src/repro/x.py", src, [RawCollective()])
    rules = sorted(f.rule for f in got)
    # the allow is malformed AND does not suppress
    assert rules == ["LINT-SUPPRESS", "RAW-COLLECTIVE"]


def test_suppressing_the_wrong_rule_does_not_silence(tmp_path):
    src = """\
    from jax import lax

    def f(x):
        # lint: allow(BARE-ASSERT): wrong rule name
        return lax.psum(x, "model")
    """
    got = _lint(tmp_path, "src/repro/x.py", src, [RawCollective()])
    assert [f.rule for f in got] == ["RAW-COLLECTIVE"]


def test_syntax_error_becomes_parse_finding(tmp_path):
    got = _lint(tmp_path, "src/repro/x.py", "def f(:\n", [RawCollective()])
    assert [f.rule for f in got] == ["PARSE"]


def test_finding_str_is_path_line_rule():
    f = Finding("RULE-X", "src/repro/x.py", 7, "msg")
    assert str(f) == "src/repro/x.py:7: RULE-X: msg"


def test_parse_suppressions_comment_line_covers_next_line():
    by_line, bad = parse_suppressions(
        ["x = 1",
         "# lint: allow(R-A): reason one",
         "y = 2  # lint: allow(R-B): reason two"],
        "f.py",
    )
    assert bad == []
    assert by_line == {3: {"R-A": "reason one", "R-B": "reason two"}}


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


def test_raw_collective_flags_lax_and_jax_lax_spellings(tmp_path):
    src = """\
    import jax
    from jax import lax

    def f(x):
        a = lax.psum(x, "model")
        b = jax.lax.all_gather(x, "data")
        c = lax.pmax(x, "model")
        return a, b, c
    """
    got = _lint(tmp_path, "src/repro/x.py", src, [RawCollective()])
    assert [f.line for f in got] == [5, 6, 7]


def test_raw_collective_exempts_the_transport(tmp_path):
    src = """\
    from jax import lax

    def f(x):
        return lax.psum(x, "model")
    """
    assert _lint(
        tmp_path, "src/repro/transport/x.py", src, [RawCollective()]
    ) == []


def test_unpriced_transfer_flags_device_put_outside_metered_dirs(tmp_path):
    src = """\
    import jax

    def f(x):
        return jax.device_put(x)
    """
    got = _lint(tmp_path, "src/repro/serve/x.py", src, [UnpricedTransfer()])
    assert [f.rule for f in got] == ["UNPRICED-TRANSFER"]
    assert _lint(
        tmp_path, "src/repro/transport/x.py", src, [UnpricedTransfer()]
    ) == []


def test_unseeded_rng_flags_global_state_not_generators(tmp_path):
    src = """\
    import numpy as np

    def f():
        bad = np.random.rand(3)
        np.random.seed(0)
        rng = np.random.default_rng(np.random.SeedSequence(7))
        return bad, rng
    """
    got = _lint(tmp_path, "src/repro/x.py", src, [UnseededRng()])
    assert [f.line for f in got] == [4, 5]


def test_bare_assert_flags_library_code_only(tmp_path):
    src = """\
    def f(x):
        assert x > 0
        return x
    """
    got = _lint(tmp_path, "src/repro/x.py", src, [BareAssert()])
    assert [f.rule for f in got] == ["BARE-ASSERT"]
    # tests/tooling are exempt: asserts are their idiom
    assert _lint(tmp_path, "tools/x.py", src, [BareAssert()]) == []


def test_hardcoded_interpret_flags_bool_literals_only(tmp_path):
    src = """\
    def f(kernel, mode):
        a = kernel(interpret=True)
        b = kernel(interpret=mode)
        return a, b
    """
    got = _lint(tmp_path, "src/repro/x.py", src, [HardcodedInterpret()])
    assert [f.line for f in got] == [2]


def test_deprecated_shim_flags_callers_but_not_the_definer(tmp_path):
    src = """\
    def f(x, axis):
        return compressed_all_gather(x, axis)
    """
    got = _lint(tmp_path, "src/repro/x.py", src, [DeprecatedShim()])
    assert [f.rule for f in got] == ["DEPRECATED-SHIM"]
    assert _lint(
        tmp_path, "src/repro/core/compressed.py", src, [DeprecatedShim()]
    ) == []


# ---------------------------------------------------------------------------
# the repo itself
# ---------------------------------------------------------------------------


def test_repo_lints_clean():
    got = run_lint(ALL_RULES)
    assert got == [], "\n".join(str(f) for f in got)
