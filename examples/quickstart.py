"""Quickstart: train a tiny qwen3-family LM with A²DTWP on one CPU device.

Shows the three moving parts in ~60 lines of user code:
  1. a config from the registry (reduced for CPU),
  2. the FSDP/TP storage transform + compiled train step,
  3. the AWP controller adapting the ADT wire format during training.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.data.pipeline import synthetic_lm_batch
from repro.dist.spec import (
    MeshCfg, build_spec_tree, dist_elems_per_group, tree_to_storage,
)
from repro.models.init import init_params
from repro.optim.sgd import SGDConfig, init_momentum
from repro.plan import PrecisionPlan
from repro.train.loop import Trainer
from repro.train.step import make_train_step


def main():
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=4096)
    B, S = 8, 64

    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    batch_shapes = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    opt = SGDConfig(lr=0.05, momentum=0.9, weight_decay=1e-4)
    nrt = cfg.num_groups + 1

    # one declarative plan owns schedule + formats + layout (docs/plan.md)
    plan = PrecisionPlan.build(
        nrt, schedule="awp", awp_threshold=1e-3, awp_interval=10,
    )

    def builder(round_tos):
        return make_train_step(
            cfg, mesh_cfg, None, spec_tree, opt, batch_shapes,
            plan=plan.with_round_tos(round_tos),
        )

    trainer = Trainer(
        builder, nrt, plan=plan,
        dist_elems_per_group=dist_elems_per_group(spec_tree, mesh_cfg, nrt),
        gather_axis_size=1,
    )
    mom = init_momentum(storage)
    for step in range(120):
        tokens, labels = synthetic_lm_batch(cfg.vocab_size, B, S, step)
        storage, mom, metrics = trainer.run_step(
            storage, mom, {"tokens": tokens, "labels": labels}, 0.05
        )
        if step % 20 == 19:
            r = trainer.records[-1]
            print(
                f"step {step+1:3d}  loss {r.loss:.3f}  formats "
                f"{r.round_tos}  wire {r.wire_bytes/1e6:.1f} MB/step"
            )
    s = trainer.summary()
    print(
        f"\nwire-byte reduction vs fp32: {s['wire_reduction']*100:.1f}%  "
        f"(recompiles: {s['recompiles']})"
    )
    print(f"AWP format history: {s['bits_history']}")


if __name__ == "__main__":
    main()
