"""Substrate tests: data pipelines, checkpointing, optimizers, trainer."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.core.awp import AWPConfig, AWPController
from repro.data.pipeline import (
    SyntheticImageNet, synthetic_feature_batch, synthetic_lm_batch,
)
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw
from repro.optim.sgd import SGDConfig, init_momentum, lr_at, sgd_update
from repro.train.loop import Trainer


def test_synthetic_imagenet_deterministic_and_learnable():
    d = SyntheticImageNet(num_classes=5, hw=8)
    a1, l1 = d.batch(16, 3)
    a2, l2 = d.batch(16, 3)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    # images of the same class correlate more than across classes
    imgs, labels = d.batch(256, 0)
    imgs, labels = np.asarray(imgs), np.asarray(labels)
    protos = d.prototypes[labels]
    corr_true = np.mean(imgs * protos)
    corr_false = np.mean(imgs * d.prototypes[(labels + 1) % 5])
    assert corr_true > corr_false + 0.02


def test_synthetic_imagenet_steps_do_not_collide():
    """The old ``abs(seed·p + step) + 1`` mix folded (seed, step) pairs
    symmetric about zero onto one RNG stream — e.g. seed=1 collided with
    (seed=-1, step=2·1_000_003): repeated batches. The SeedSequence mix
    keeps every pair (validation's step=-1 included) independent."""
    # compare the label streams directly — they come straight from the
    # per-step RNG, so a stream collision means identical labels even
    # though the two datasets have different prototype tensors
    d = SyntheticImageNet(num_classes=5, hw=8, seed=1)
    d_neg = SyntheticImageNet(num_classes=5, hw=8, seed=-1)
    _, la = d.batch(64, 0)
    _, lb = d_neg.batch(64, 2 * 1_000_003)  # old mix: identical stream
    assert not np.array_equal(np.asarray(la), np.asarray(lb))
    # consecutive steps differ, and validation (step=-1) is not a
    # training batch in disguise
    i0, _ = d.batch(16, 0)
    i1, _ = d.batch(16, 1)
    assert not np.allclose(np.asarray(i0), np.asarray(i1))
    v, _ = d.validation(16)
    for s in range(4):
        tr, _ = d.batch(16, s)
        assert not np.allclose(np.asarray(v), np.asarray(tr))


def test_synthetic_lm_has_structure():
    t, l = synthetic_lm_batch(64, 8, 32, 0)
    assert t.shape == (8, 32) and l.shape == (8, 32)
    # labels shifted: next-token of the same stream
    t2, l2 = synthetic_lm_batch(64, 8, 32, 0)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(t)[:, 1:], np.asarray(l)[:, :-1])


def test_feature_batch():
    f, l = synthetic_feature_batch(32, 10, 4, 16, 0)
    assert f.shape == (4, 16, 32)
    assert l.shape == (4, 16)
    assert int(l.max()) < 10


def test_sgd_momentum_and_decay():
    cfg = SGDConfig(lr=0.1, momentum=0.9, weight_decay=0.0,
                    lr_decay_rate=0.16, lr_decay_every=30)
    assert lr_at(cfg, 0) == 0.1
    assert abs(lr_at(cfg, 30) - 0.016) < 1e-9
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 2.0)}
    m = init_momentum(p)
    wd = {"w": 0.0}
    p2, m2 = sgd_update(p, g, m, wd, cfg, 0.1)
    np.testing.assert_allclose(np.asarray(p2["w"]), 1 - 0.1 * 2.0)
    p3, m3 = sgd_update(p2, g, m2, wd, cfg, 0.1)
    # momentum: second step moves further
    np.testing.assert_allclose(np.asarray(p3["w"]),
                               np.asarray(p2["w"]) - 0.1 * (0.9 * 2 + 2))


def test_adamw_update_moves_params():
    cfg = AdamWConfig(lr=1e-2)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 0.5)}
    st = init_adamw(p)
    p2, st2 = adamw_update(p, g, st, {"w": 1.0}, cfg, 1e-2)
    assert float(jnp.max(jnp.abs(p2["w"] - p["w"]))) > 1e-4
    assert int(st2["t"]) == 1


def test_checkpoint_roundtrip(tmp_path):
    storage = {"a": jnp.arange(10, dtype=jnp.float32),
               "b": {"c": jnp.ones((3, 3))}}
    opt = {"m": jnp.zeros((10,))}
    awp = AWPController(3, AWPConfig())
    awp.update([1.0, 2.0, 3.0])
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, storage, opt, awp, step=7)

    awp2 = AWPController(3, AWPConfig())
    s2, o2, step = load_checkpoint(path, storage, opt, awp2)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(s2["a"]), np.asarray(storage["a"]))
    np.testing.assert_array_equal(awp2.state.bits, awp.state.bits)
    np.testing.assert_allclose(awp2.state.prev_norms, awp.state.prev_norms)


def test_trainer_policies_and_wire_accounting():
    calls = []

    def builder(rts):
        calls.append(rts)

        def step(storage, opt, batch, lr):
            return storage, opt, {
                "loss": jnp.asarray(1.0),
                "group_norms_sq": jnp.asarray([4.0, 4.0]),
            }

        return step

    tr = Trainer(
        builder, 2, policy="oracle:2",
        dist_elems_per_group=[1000, 2000], gather_axis_size=4,
    )
    tr.run_step({}, {}, {}, 0.1)
    assert calls == [(2, 2)]
    # ring all-gather wire: (n-1) * s_loc * rt per group
    assert tr.records[0].wire_bytes == 3 * (1000 // 4) * 2 + 3 * (2000 // 4) * 2
    s = tr.summary()
    assert 0.49 < s["wire_reduction"] < 0.51
