"""Disaggregated fleet serving launcher (`repro.fleet`): a request
router over N decode replicas with dedicated prefill workers, KV pages
migrating replica-to-replica as compressed fabric parcels, and an
optional mid-run live weight refresh.

One :class:`~repro.plan.PrecisionPlan` drives everything the serve
launcher's plan drives PLUS the two fleet traffic classes
(``kv_migration`` / ``weight_publish``): pass ``--plan plan.json``, or
use the same plan-builder sugar flags. Streams are bit-exact vs the
static one-shot reference under every fleet topology —
``--check-static`` asserts it per weight version, including across the
``--refresh-at`` boundary (pre-refresh requests check against the v0
static streams, post-refresh traffic against v1).

  PYTHONPATH=src python -m repro.launch.fleet --arch qwen3-1.7b --reduced \
      --replicas 2 --workers 1 --prompt-lens 16,12,16,8 --gen 8 \
      --page-size 8 [--int8-kv] [--refresh-at 2] [--check-static]

After the drain the launcher prints the fabric hop totals and asserts
them EQUAL to the analytic
:func:`repro.roofline.analysis.fleet_migration_bytes` model — the
fleet's measured==analytic pin, enforced on every run.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config, reduced
from repro.dist.spec import build_spec_tree, tree_to_storage
from repro.fleet import DecodeReplica, FleetRouter, PrefillWorker, WeightPublisher
from repro.launch.mesh import make_mesh_from_cfg
from repro.launch.train import _null, parse_mesh
from repro.models.init import init_params
from repro.launch.serve import sampling_from_args
from repro.plan import PrecisionPlan
from repro.roofline.analysis import fleet_migration_bytes
from repro.serve.engine import Request, ServeEngine, generate_static


def _plan_from_args(args, nrt: int) -> PrecisionPlan:
    if args.plan:
        plan = PrecisionPlan.from_file(args.plan).broadcast(nrt)
    else:
        plan = PrecisionPlan.build(
            nrt,
            round_to=args.round_to if args.round_to is not None else 2,
            act_round_to=(
                args.act_round_to if args.act_round_to is not None else 4
            ),
        )
    if args.int8_kv:
        plan = dataclasses.replace(plan, int8_kv=True)
    return plan


def _build_requests(args, cfg, *, rid_base: int, seed: int) -> list[Request]:
    if args.prompt_lens:
        lens = [int(s) for s in args.prompt_lens.split(",")]
    else:
        lens = [args.prompt_len] * args.requests
    rng = np.random.default_rng(seed)
    shared = tuple(
        int(t) for t in rng.integers(0, cfg.vocab_size, args.shared_prefix)
    )
    return [
        Request(
            rid=rid_base + i,
            prompt_ids=shared + tuple(
                int(t) for t in rng.integers(0, cfg.vocab_size, S)
            ),
            max_new=args.gen,
            sampling=sampling_from_args(args, rid_base + i),
        )
        for i, S in enumerate(lens)
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--replicas", type=int, default=2,
                    help="decode replicas (each one paged ServeEngine)")
    ap.add_argument("--workers", type=int, default=1,
                    help="dedicated prefill workers (round-robin)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--prompt-lens", default="",
                    help="comma-separated per-request prompt lengths; "
                         "overrides --requests/--prompt-len")
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--max-slots", type=int, default=2,
                    help="KV slots per replica")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many common tokens to every prompt "
                         "(prefix pages then migrate once per replica)")
    ap.add_argument("--plan", default="",
                    help="PrecisionPlan JSON incl. the kv_migration / "
                         "weight_publish fabric entries")
    ap.add_argument("--round-to", type=int, default=None,
                    help="ADT weight wire format (plan-builder sugar)")
    ap.add_argument("--act-round-to", type=int, default=None,
                    help="activation wire format (plan-builder sugar)")
    ap.add_argument("--int8-kv", action="store_true")
    # per-request sampling (same contract as repro.launch.serve: request
    # i samples under seed + i; 0 temperature = the greedy fast path)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus cutoff (with --temperature > 0)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k cutoff, 0 = all (with --temperature > 0)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed; request i uses seed + i")
    ap.add_argument("--refresh-at", type=int, default=0,
                    help="after this many completed requests, publish "
                         "refreshed weights (PRNGKey(1) init) and submit "
                         "a second request wave under the new version")
    ap.add_argument("--check-static", action="store_true",
                    help="assert router streams bit-exact vs the static "
                         "reference, per weight version (CI smoke)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh_cfg = parse_mesh(args.mesh)
    mesh = make_mesh_from_cfg(mesh_cfg)

    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage0 = tree_to_storage(params, spec_tree, mesh_cfg)
    nrt = cfg.num_groups + 1
    plan = _plan_from_args(args, nrt)

    wave_a = _build_requests(args, cfg, rid_base=0, seed=0)
    wave_b = []
    storage1 = None
    if args.refresh_at:
        params1, _ = init_params(cfg, jax.random.PRNGKey(1), tp=mesh_cfg.tp)
        storage1 = tree_to_storage(params1, spec_tree, mesh_cfg)
        wave_b = _build_requests(
            args, cfg, rid_base=len(wave_a), seed=1
        )
    lens = [len(r.prompt_ids) for r in wave_a]
    cap = max(lens) + args.gen

    ctx = mesh if mesh is not None else _null()
    with ctx:
        replicas = [
            DecodeReplica(f"r{i}", ServeEngine(
                cfg, mesh_cfg, mesh, spec_tree, storage0, plan=plan,
                max_slots=args.max_slots, cache_capacity=cap, paged=True,
                page_size=args.page_size,
            ))
            for i in range(args.replicas)
        ]
        workers = [
            PrefillWorker(f"w{i}", cfg, mesh_cfg, mesh, spec_tree,
                          plan=plan, cache_capacity=cap,
                          page_size=args.page_size)
            for i in range(args.workers)
        ]
        router = FleetRouter(replicas, workers)
        publisher = WeightPublisher(cfg, spec_tree, plan=plan)
        parcel0 = publisher.publish(storage0)
        router.publish(parcel0)

        refreshed = {"done": not args.refresh_at}

        def do_refresh(r):
            refreshed["done"] = True
            r.publish(publisher.publish(storage1, step=1))
            for req in wave_b:
                r.submit(req)
            print(f"tick {r.ticks}: published v1 and submitted "
                  f"{len(wave_b)} refresh-wave requests")

        def on_tick(r):
            if not refreshed["done"] and len(r.results) >= args.refresh_at:
                do_refresh(r)

        t0 = time.time()
        results = router.run(wave_a, on_tick=on_tick)
        if not refreshed["done"]:
            # wave A drained before the threshold tripped mid-tick
            # (small fleets finish whole waves in one tick) — refresh
            # now and drain the second wave
            do_refresh(router)
            results = router.run([])
        wall = time.time() - t0

        static0 = static1 = None
        if args.check_static:
            static0 = generate_static(
                cfg, mesh_cfg, mesh, spec_tree, storage0, wave_a, plan=plan
            )
            if wave_b:
                static1 = generate_static(
                    cfg, mesh_cfg, mesh, spec_tree, storage1, wave_b,
                    plan=plan,
                )

    n_req = len(wave_a) + len(wave_b)
    total_new = sum(len(r.tokens) for r in results.values())
    ws = router.wire_summary()
    print(f"{cfg.name}: {n_req} requests over {args.replicas} replicas / "
          f"{args.workers} workers, prompts {min(lens)}..{max(lens)}, "
          f"+{args.gen} tokens, page_size={args.page_size}"
          + (", int8 KV" if plan.int8_kv else ""))
    print(f"fleet: {ws['ticks']} ticks in {wall:.2f}s "
          f"({total_new/max(wall, 1e-9):.1f} tok/s incl. compile)")
    print(f"fabric: kv_migration {ws['kv_migration']} B over "
          f"{ws['hops']['kv_migration']} hops ({ws['migrated_pages']} "
          f"pages), weight_publish {ws['weight_publish']} B over "
          f"{ws['publish_installs']} installs")
    by_replica = {}
    for meta in router.placements.values():
        by_replica[meta["replica"]] = by_replica.get(meta["replica"], 0) + 1
    print(f"placement: {dict(sorted(by_replica.items()))}")

    dtype_bytes = jnp.dtype(plan.compute_dtype).itemsize
    analytic = fleet_migration_bytes(
        plan, cfg, page_size=args.page_size,
        migrated_pages=ws["migrated_pages"], int8_kv=plan.int8_kv,
        dtype_bytes=dtype_bytes, publish_wire_bytes=parcel0.nbytes,
        publish_installs=ws["publish_installs"],
    )
    for cls in ("kv_migration", "weight_publish"):
        if ws[cls] != analytic[cls]:
            raise SystemExit(
                f"fleet fabric DIVERGED from the analytic model on "
                f"{cls}: measured {ws[cls]} != analytic {analytic[cls]}"
            )
    print(f"fabric == fleet_migration_bytes: kv {analytic['kv_migration']} "
          f"B at {analytic['kv_width']} B/elem, publish "
          f"{analytic['weight_publish']} B — measured equals analytic")

    if args.check_static:
        bad = [r.rid for r in wave_a
               if results[r.rid].tokens != static0[r.rid]]
        bad += [r.rid for r in wave_b
                if results[r.rid].tokens != static1[r.rid]]
        if bad:
            raise SystemExit(
                f"fleet vs static token streams DIVERGED for requests "
                f"{bad}"
            )
        print(f"check-static: {n_req} streams bit-exact vs the static "
              "reference"
              + (" (v0 and v1 waves)" if wave_b else ""))
    for r in (wave_a + wave_b)[:4]:
        print(f"  req{r.rid}: {results[r.rid].tokens[:16]}")


if __name__ == "__main__":
    main()
