"""Subprocess scenario: distributed train/serve steps on an 8-device mesh
match the single-device reference.

  * round_to=4 (uncompressed): losses/updates match the single-device run
    to fp tolerance — proves the FSDP storage transform, TP math, grad
    sync and optimizer are exact.
  * round_to=2: loss stays close (bf16-grade weight error), training still
    descends — the paper's "no deterioration" claim at small scale.
  * prefill+decode distributed == single-device logits.
  * act_policy=rt2: TP-axis activation collectives ride packed planes
    (fwd AND bwd) — loss still matches the single-device reference to
    format tolerance and keeps descending; act rt=4 policy is exact.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.dist.spec import MeshCfg, SINGLE, build_spec_tree, tree_to_storage
from repro.launch.mesh import make_mesh_from_cfg
from repro.models.init import init_params
from repro.optim.sgd import SGDConfig, init_momentum
from repro.plan import PrecisionPlan
from repro.serve.step import global_cache_shapes, make_decode_step, make_prefill_step
from repro.train.step import make_train_step
from repro.transport import CompressionPolicy


def _plan(nrt, rt=4, act_policy=None):
    p = PrecisionPlan.build(nrt, round_to=rt)
    import dataclasses
    return dataclasses.replace(p, activations=act_policy)
from repro.configs.base import InputShape
from repro.configs.shapes import input_specs


def _batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    return b


def run_arch(arch, mesh_cfg, mesh, *, atol_loss=2e-4):
    cfg = reduced(get_config(arch))
    B, S = 8, 32
    batch = _batch(cfg, B, S)
    batch_shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    opt = SGDConfig(lr=0.05, momentum=0.9, weight_decay=0.0)

    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    nrt = cfg.num_groups + 1

    # --- single-device reference (tp=1 params have identical values for
    # tp-independent shapes; reduced cfgs have no head padding so shapes
    # match across tp) -------------------------------------------------
    params1, _ = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec1 = build_spec_tree(params1, metas, SINGLE)
    storage1 = tree_to_storage(params1, spec1, SINGLE)
    step1 = make_train_step(
        cfg, SINGLE, None, spec1, opt, batch_shapes, plan=_plan(nrt)
    )
    mom1 = init_momentum(storage1)
    s1, m1, met1 = step1(storage1, mom1, batch, 0.05)

    # --- distributed, uncompressed -------------------------------------
    spec = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec, mesh_cfg)
    step = make_train_step(
        cfg, mesh_cfg, mesh, spec, opt, batch_shapes, plan=_plan(nrt)
    )
    mom = init_momentum(storage)
    s4, m4, met4 = step(storage, mom, batch, 0.05)
    l1, l4 = float(met1["loss"]), float(met4["loss"])
    assert abs(l1 - l4) < atol_loss, (arch, l1, l4)
    n1 = np.asarray(met1["group_norms_sq"])
    n4 = np.asarray(met4["group_norms_sq"])
    np.testing.assert_allclose(n1, n4, rtol=1e-3), arch

    # two more steps: losses keep matching (exercises updated storage)
    s4b, m4b, met4b = step(s4, m4, _batch(cfg, B, S, seed=1), 0.05)
    storage1b, mom1b, met1b = step1(s1, m1, _batch(cfg, B, S, seed=1), 0.05)
    assert abs(float(met4b["loss"]) - float(met1b["loss"])) < 5 * atol_loss, arch

    # --- distributed, compressed (rt=2): close + still training --------
    # (re-init: the uncompressed step donated the original buffers)
    params_c, _ = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    storage_c = tree_to_storage(params_c, spec, mesh_cfg)
    step_c = make_train_step(
        cfg, mesh_cfg, mesh, spec, opt, batch_shapes, plan=_plan(nrt, rt=2)
    )
    sc, mc, metc = step_c(storage_c, init_momentum(storage_c), batch, 0.05)
    lc = float(metc["loss"])
    assert abs(lc - l1) < 0.05 + 0.05 * abs(l1), (arch, l1, lc)
    sc2, mc2, metc2 = step_c(sc, mc, batch, 0.05)
    assert float(metc2["loss"]) < lc + 0.05, (arch, "compressed training diverged")

    print(f"  {arch}: loss match {l1:.4f} vs {l4:.4f}, rt2 {lc:.4f} OK")


def run_serve(arch, mesh_cfg, mesh):
    cfg = reduced(get_config(arch))
    if not cfg.causal:
        return
    B, S = 8, 16
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S)), jnp.int32
    )}
    batch_shapes = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    nrt = cfg.num_groups + 1

    params1, _ = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec1 = build_spec_tree(params1, metas, SINGLE)
    st1 = tree_to_storage(params1, spec1, SINGLE)
    pre1 = make_prefill_step(
        cfg, SINGLE, None, spec1, batch_shapes, plan=_plan(nrt),
        cache_capacity=S + 2,
    )
    logits1, caches1 = pre1(st1, batch)

    spec = build_spec_tree(params, metas, mesh_cfg)
    st = tree_to_storage(params, spec, mesh_cfg)
    pre = make_prefill_step(
        cfg, mesh_cfg, mesh, spec, batch_shapes, plan=_plan(nrt),
        cache_capacity=S + 2,
    )
    logits, caches = pre(st, batch)
    np.testing.assert_allclose(
        np.asarray(logits1[..., : cfg.vocab_size]),
        np.asarray(logits[..., : cfg.vocab_size]),
        rtol=5e-3, atol=5e-4,
    )

    dec_shapes = {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    dstep1 = make_decode_step(cfg, SINGLE, None, spec1, dec_shapes,
                              plan=_plan(nrt))
    dstep = make_decode_step(cfg, mesh_cfg, mesh, spec, dec_shapes,
                             plan=_plan(nrt))
    tok = {"tokens": jnp.ones((B, 1), jnp.int32), "pos": jnp.asarray(S, jnp.int32)}
    dl1, _ = dstep1(st1, caches1, tok)
    dl, _ = dstep(st, caches, tok)
    np.testing.assert_allclose(
        np.asarray(dl1[..., : cfg.vocab_size]),
        np.asarray(dl[..., : cfg.vocab_size]),
        rtol=5e-3, atol=5e-4,
    )
    print(f"  {arch}: serve prefill+decode match OK")


def run_act_compression(arch, mesh_cfg, mesh):
    """Activation-compressed TP collectives: train + serve vs reference."""
    cfg = reduced(get_config(arch))
    B, S = 8, 32
    batch = _batch(cfg, B, S)
    batch_shapes = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()
    }
    opt = SGDConfig(lr=0.05, momentum=0.9, weight_decay=0.0)
    nrt = cfg.num_groups + 1
    act2 = CompressionPolicy(round_to=2, grad_round_to=2, mode="nearest")

    params1, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec1 = build_spec_tree(params1, metas, SINGLE)
    st1 = tree_to_storage(params1, spec1, SINGLE)
    step1 = make_train_step(cfg, SINGLE, None, spec1, opt, batch_shapes,
                            plan=_plan(nrt))
    _, _, met1 = step1(st1, init_momentum(st1), batch, 0.05)
    l1 = float(met1["loss"])

    params, _ = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    spec = build_spec_tree(params, metas, mesh_cfg)
    st = tree_to_storage(params, spec, mesh_cfg)
    step = make_train_step(cfg, mesh_cfg, mesh, spec, opt, batch_shapes,
                           plan=_plan(nrt, act_policy=act2))
    st, mom, met = step(st, init_momentum(st), batch, 0.05)
    la = float(met["loss"])
    # every TP psum now carries rt=2 nearest-rounded planes: bf16-grade
    # activation error, same envelope as the rt=2 weight check above
    assert abs(la - l1) < 0.05 + 0.05 * abs(l1), (arch, l1, la)
    _, _, met_b = step(st, mom, batch, 0.05)
    assert float(met_b["loss"]) < la + 0.05, (arch, "act-compressed diverged")

    # act rt=4 policy must be numerically exact vs the no-policy step
    params_e, _ = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    st_e = tree_to_storage(params_e, spec, mesh_cfg)
    step4 = make_train_step(
        cfg, mesh_cfg, mesh, spec, opt, batch_shapes,
        plan=_plan(nrt, act_policy=CompressionPolicy(round_to=4,
                                                     grad_round_to=4)),
    )
    _, _, met4 = step4(st_e, init_momentum(st_e), batch, 0.05)
    assert abs(float(met4["loss"]) - l1) < 2e-4, (l1, float(met4["loss"]))

    # serve: act-compressed prefill+decode logits stay close to reference
    # (the train step donated st1 — rebuild the single-device storage)
    params1s, _ = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    st1 = tree_to_storage(params1s, spec1, SINGLE)
    sbatch = {"tokens": batch["tokens"][:, :16]}
    sshapes = {"tokens": jax.ShapeDtypeStruct((B, 16), jnp.int32)}
    pre1 = make_prefill_step(cfg, SINGLE, None, spec1, sshapes,
                             plan=_plan(nrt), cache_capacity=18)
    logits1, caches1 = pre1(st1, sbatch)
    params_s, _ = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    st_s = tree_to_storage(params_s, spec, mesh_cfg)
    pre = make_prefill_step(cfg, mesh_cfg, mesh, spec, sshapes,
                            plan=_plan(nrt, act_policy=act2),
                            cache_capacity=18)
    logits, caches = pre(st_s, sbatch)
    v = cfg.vocab_size
    err = np.max(np.abs(np.asarray(logits1[..., :v]) - np.asarray(logits[..., :v])))
    scale = np.max(np.abs(np.asarray(logits1[..., :v]))) + 1e-9
    assert err / scale < 0.05, (arch, err / scale)

    dshapes = {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    dstep1 = make_decode_step(cfg, SINGLE, None, spec1, dshapes,
                              plan=_plan(nrt))
    dstep = make_decode_step(cfg, mesh_cfg, mesh, spec, dshapes,
                             plan=_plan(nrt, act_policy=act2))
    tok = {"tokens": jnp.ones((B, 1), jnp.int32),
           "pos": jnp.asarray(16, jnp.int32)}
    dl1, _ = dstep1(st1, caches1, tok)
    dl, _ = dstep(st_s, caches, tok)
    derr = np.max(np.abs(np.asarray(dl1[..., :v]) - np.asarray(dl[..., :v])))
    dscale = np.max(np.abs(np.asarray(dl1[..., :v]))) + 1e-9
    assert derr / dscale < 0.05, (arch, derr / dscale)
    print(f"  {arch}: act-compressed train {l1:.4f}->{la:.4f}, "
          f"serve rel-err {err/scale:.4f}/{derr/dscale:.4f} OK")


def main():
    mesh_cfg = MeshCfg(tp=2, dp=4, pods=1)
    mesh = make_mesh_from_cfg(mesh_cfg)
    with mesh:
        # MoE capacity dropping is per-token-shard, so dp-sharded routing
        # legitimately drops different tokens than a single device: wider tol.
        for arch, tol in [("qwen3-1.7b", 2e-4), ("mixtral-8x7b", 5e-3),
                          ("xlstm-1.3b", 2e-4), ("recurrentgemma-9b", 2e-4)]:
            run_arch(arch, mesh_cfg, mesh, atol_loss=tol)
        for arch in ["qwen3-1.7b", "recurrentgemma-9b"]:
            run_serve(arch, mesh_cfg, mesh)
        run_act_compression("qwen3-1.7b", mesh_cfg, mesh)

    # multi-pod mesh geometry (2 pods x 2 data x 2 model)
    mesh_cfg3 = MeshCfg(tp=2, dp=2, pods=2)
    mesh3 = make_mesh_from_cfg(mesh_cfg3)
    with mesh3:
        run_arch("qwen3-1.7b", mesh_cfg3, mesh3)
    print("scenario_dist_train OK")


if __name__ == "__main__":
    main()
