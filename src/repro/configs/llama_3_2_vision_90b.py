"""llama-3.2-vision-90b [vlm] — cross-attn image layers  [hf:meta-llama/Llama-3.2-11B-Vision].

100 decoder layers; every 5th layer is a gated cross-attention layer over
vision-patch embeddings. The ViT frontend is a stub per the assignment:
``input_specs()`` supplies precomputed patch embeddings (B, 4096, 1280)
which a linear projector maps into d_model.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    num_image_tokens=4096,
    vision_dim=1280,
    rope_theta=5e5,
    num_precision_groups=5,
)
