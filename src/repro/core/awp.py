"""AWP — Adaptive Weight Precision (paper Algorithm 1).

AWP monitors the l²-norm of each precision group's weights after every batch
and widens the transfer format by ``N`` bits whenever the relative change
rate dips below ``T`` for ``INTERVAL`` consecutive observations.

The split between device and host mirrors the paper (AWP ran on the CPU
outside the CUDA graph):

  * the jitted train step returns ``norms: (num_groups,)`` — the only
    device-side cost, computed by the fused l2norm kernel on the *sharded*
    master weights (a psum of per-shard partial sums);
  * :class:`AWPController` consumes the norms on the host, applies
    Algorithm 1 verbatim, and reports the per-group byte widths. When a
    width changes, the trainer swaps in a (cached) re-jitted step — XLA
    collectives have static shapes, so the wire format is a compile-time
    property of the step function (DESIGN.md §2).

Precision granularity is per *group* of layers, not per layer — the paper
itself found block granularity superior for ResNet (§IV-B), and groups are
what keeps the layer stacks homogeneous for ``lax.scan``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.formats import MAX_BITS, MIN_BITS, bits_to_bytes


@dataclasses.dataclass
class AWPConfig:
    """Hyper-parameters of Algorithm 1 (paper §II, §V-A)."""

    threshold: float = -2e-3      # T      (paper: -5e-2 .. -2e-5, per model)
    interval: int = 100           # INTERVAL (paper: 2000/4000 ~ one epoch-ish)
    increment_bits: int = 8       # N      (paper: 8 — byte granularity)
    initial_bits: int = 8         # paper: training starts at 8-bit
    max_bits: int = MAX_BITS

    def __post_init__(self):
        if self.initial_bits < MIN_BITS:
            raise ValueError("initial_bits must be >= 8")
        if self.interval <= 0:
            raise ValueError("interval must be positive")


@dataclasses.dataclass
class AWPState:
    """Host-side mutable state of the controller (one entry per group)."""

    bits: np.ndarray              # int, current format width per group
    counters: np.ndarray          # int, IntervalCounter per group
    prev_norms: np.ndarray | None # float, |W_{i-1}| per group (l2, not squared)
    step: int = 0

    def round_to(self) -> tuple[int, ...]:
        return tuple(bits_to_bytes(int(b)) for b in self.bits)


class AWPController:
    """Host-side implementation of Algorithm 1 over precision groups."""

    def __init__(self, num_groups: int, config: AWPConfig | None = None):
        self.config = config or AWPConfig()
        self.num_groups = num_groups
        self.state = AWPState(
            bits=np.full((num_groups,), self.config.initial_bits, np.int64),
            counters=np.zeros((num_groups,), np.int64),
            prev_norms=None,
        )
        # trajectory of (step, bits-per-group) transitions for analysis
        self.history: list[tuple[int, tuple[int, ...]]] = [
            (0, tuple(int(b) for b in self.state.bits))
        ]

    # ------------------------------------------------------------------
    def update(self, norms_sq: Sequence[float]) -> tuple[int, ...]:
        """Feed one batch's per-group Σw² values; returns round_to bytes.

        ``norms_sq`` comes squared straight from the fused kernel; Algorithm 1
        is defined on the l²-norm so we sqrt here (host-side, num_groups
        floats — negligible, as in the paper's Table II profile).
        """
        cfg = self.config
        st = self.state
        norms = np.sqrt(np.asarray(norms_sq, np.float64))
        if norms.shape != (self.num_groups,):
            raise ValueError(
                f"expected {self.num_groups} group norms, got {norms.shape}"
            )
        if st.prev_norms is not None:
            with np.errstate(divide="ignore", invalid="ignore"):
                delta = (norms - st.prev_norms) / st.prev_norms
            delta = np.where(np.isfinite(delta), delta, 0.0)
            hit = delta < cfg.threshold
            # Algorithm 1 requires INTERVAL *consecutive* observations:
            # a miss resets the counter (a cumulative count would widen
            # far too early on noisy norm trajectories).
            st.counters = np.where(hit, st.counters + 1, 0)
            fire = st.counters >= cfg.interval
            if fire.any():
                new_bits = np.minimum(
                    st.bits + cfg.increment_bits * fire, cfg.max_bits
                )
                if not np.array_equal(new_bits, st.bits):
                    st.bits = new_bits
                    self.history.append(
                        (st.step + 1, tuple(int(b) for b in st.bits))
                    )
                st.counters = np.where(fire, 0, st.counters)
        st.prev_norms = norms
        st.step += 1
        return st.round_to()

    # ------------------------------------------------------------------
    @property
    def round_to(self) -> tuple[int, ...]:
        return self.state.round_to()

    def bytes_saved_fraction(self) -> float:
        """Mean wire-byte reduction vs fp32 across groups (equal weights)."""
        rts = self.state.round_to()
        return 1.0 - sum(rts) / (4.0 * len(rts))


def oracle_round_to(num_groups: int, round_to: int) -> tuple[int, ...]:
    """The paper's *oracle* policy: a fixed format for the whole run."""
    return tuple([round_to] * num_groups)
