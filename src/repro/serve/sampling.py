"""Per-request sampling for the serve engine (see docs/serving.md).

The determinism contract extends the engine's greedy pin to stochastic
decoding: the id sampled for the n-th emitted token of a request
(0-based — the token emitted from the prefill is n=0) depends only on
``(logits_row, seed, n)``. The PRNG key is

    ``jax.random.fold_in(jax.random.PRNGKey(seed), n)``

and every tensor op in :func:`sample_tokens` is row-independent
(argsort / softmax / cumsum / searchsorted all reduce along the vocab
axis only), so a request's stream is bitwise identical whatever batch
it shares a decode step with — engine (B = max_slots), static reference
(B = group size), and the (B, k+1) speculative verify step all agree.

``temperature == 0`` rows short-circuit to ``argmax`` — ballast slots
and greedy requests inside a mixed batch cost nothing and match the
dedicated greedy pack bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.plan.plan import SamplingParams

__all__ = ["SamplingParams", "fold_key", "sample_tokens", "uniform_for"]


def fold_key(seed, step):
    """The per-token key contract: fold the 0-based emitted-token index
    into the request's seed key. Scalar version (tests / docs); the
    samplers vmap the same construction."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def _uniform_one(seed, step):
    return jax.random.uniform(fold_key(seed, step), (), jnp.float32)


def uniform_for(seed, step):
    """One uniform draw per (seed, step) pair, any matching shape.

    vmap over the folded keys produces exactly the per-key scalars, so
    a row's draw never depends on its batch companions.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    step = jnp.asarray(step, jnp.int32)
    shape = jnp.broadcast_shapes(seed.shape, step.shape)
    seed = jnp.broadcast_to(seed, shape).reshape(-1)
    step = jnp.broadcast_to(step, shape).reshape(-1)
    return jax.vmap(_uniform_one)(seed, step).reshape(shape)


def sample_tokens(logits, vocab, temperature, top_p, top_k, seed, step):
    """Sample one id per row from ``logits (..., Vpad)``.

    ``temperature`` / ``top_p`` / ``top_k`` / ``seed`` / ``step`` all
    carry the row shape ``(...)`` (one entry per row). Rows with
    ``temperature <= 0`` return ``argmax``. The sampler is inverse-CDF
    over the descending-sorted temperature-softmax restricted to the
    ``top_k`` best ids (0 = all) and to ids whose *preceding*
    cumulative mass is below ``top_p`` (the best id always survives).
    """
    lg = logits[..., :vocab].astype(jnp.float32)
    greedy_tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)

    temp = jnp.asarray(temperature, jnp.float32)
    scaled = lg / jnp.maximum(temp, 1e-6)[..., None]
    order = jnp.argsort(-scaled, axis=-1)
    ranked = jnp.take_along_axis(scaled, order, axis=-1)
    probs = jax.nn.softmax(ranked, axis=-1)

    ranks = jnp.arange(vocab, dtype=jnp.int32)
    k = jnp.asarray(top_k, jnp.int32)[..., None]
    keep = (k <= 0) | (ranks < k)
    cum = jnp.cumsum(probs, axis=-1)
    keep &= (cum - probs) < jnp.asarray(top_p, jnp.float32)[..., None]

    w = probs * keep
    cw = jnp.cumsum(w, axis=-1)
    u = uniform_for(seed, step)
    target = u * cw[..., -1]
    # first index with cw > target; zero-weight entries repeat their
    # predecessor's cw, so the landing index always has weight > 0
    idx = jnp.sum((cw <= target[..., None]).astype(jnp.int32), axis=-1)
    idx = jnp.minimum(idx, vocab - 1)
    tok = jnp.take_along_axis(order, idx[..., None], axis=-1)[..., 0]
    return jnp.where(temp <= 0.0, greedy_tok, tok.astype(jnp.int32))
