"""npz-based checkpointing for storage pytrees + AWP controller state +
the :class:`~repro.plan.PrecisionPlan` that produced the run.

Works on sharded arrays (gathers to host) — adequate for the scales this
container trains; the format records the flattened key paths so restore is
structure-checked. The plan is persisted next to the AWP state so a
resumed run reconstructs the exact schedule + wire formats from the
checkpoint alone (``load_plan``).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.core.awp import AWPController
from repro.plan import PrecisionPlan
from repro.utils.trees import flatten_dict, unflatten_dict


def _flatten_pytree(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def _npz_path(path: str) -> str:
    """``np.savez`` appends ``.npz`` when the suffix is missing; normalize
    so save and load always agree on the on-disk name (a bare ``"ckpt"``
    used to save ``ckpt.npz`` and then fail to load ``"ckpt"``)."""
    return path if path.endswith(".npz") else path + ".npz"


def save_checkpoint(path: str, storage, opt_state, awp: AWPController | None,
                    step: int, plan: PrecisionPlan | None = None):
    path = _npz_path(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten((storage, opt_state))
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(flat)}
    meta = {"step": step, "num_arrays": len(flat)}
    if plan is not None:
        meta["plan"] = plan.to_json_dict()
    if awp is not None:
        meta["awp"] = {
            "bits": awp.state.bits.tolist(),
            "counters": awp.state.counters.tolist(),
            "prev_norms": (
                awp.state.prev_norms.tolist()
                if awp.state.prev_norms is not None
                else None
            ),
            "step": awp.state.step,
            "history": [[s, list(b)] for s, b in awp.history],
        }
    np.savez(path, __meta__=json.dumps(meta), **arrays)


def load_checkpoint(path: str, storage_like, opt_like,
                    awp: AWPController | None = None):
    data = np.load(_npz_path(path), allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    flat_like, treedef = jax.tree_util.tree_flatten((storage_like, opt_like))
    assert meta["num_arrays"] == len(flat_like), "checkpoint structure mismatch"
    flat = [data[f"a{i}"] for i in range(len(flat_like))]
    storage, opt_state = jax.tree_util.tree_unflatten(treedef, flat)
    if awp is not None and "awp" in meta:
        a = meta["awp"]
        awp.state.bits = np.asarray(a["bits"], np.int64)
        awp.state.counters = np.asarray(a["counters"], np.int64)
        awp.state.prev_norms = (
            np.asarray(a["prev_norms"]) if a["prev_norms"] is not None else None
        )
        awp.state.step = a["step"]
        awp.history = [(s, tuple(b)) for s, b in a["history"]]
    return storage, opt_state, meta["step"]


def load_storage(path: str, storage_like):
    """Weights-only restore for serving: the flattened ``(storage,
    opt_state)`` order puts the storage leaves first, so inference-time
    consumers can skip materializing (and immediately discarding) a
    momentum tree the size of the model. Returns ``(storage, step)``."""
    data = np.load(_npz_path(path), allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    flat_like, treedef = jax.tree_util.tree_flatten(storage_like)
    assert meta["num_arrays"] >= len(flat_like), "checkpoint structure mismatch"
    flat = [data[f"a{i}"] for i in range(len(flat_like))]
    for like, got in zip(flat_like, flat):
        assert like.shape == got.shape, "checkpoint storage shape mismatch"
    return jax.tree_util.tree_unflatten(treedef, flat), meta["step"]


def load_plan(path: str) -> PrecisionPlan | None:
    """The PrecisionPlan persisted with the checkpoint (None for
    checkpoints written without one)."""
    data = np.load(_npz_path(path), allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    if "plan" not in meta:
        return None
    return PrecisionPlan.from_json_dict(meta["plan"])
