"""Shared primitive layers: RMSNorm, rotary embeddings, embedding lookup."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dtype)


def head_rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """qk-norm (qwen3): RMSNorm over the trailing head_dim."""
    return rms_norm(x, scale, eps)


def rope_frequencies(head_dim: int, rotary_pct: float, theta: float) -> jnp.ndarray:
    """Inverse frequencies for the rotated fraction of head_dim."""
    rot = int(head_dim * rotary_pct)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    rotary_pct: float = 1.0,
    theta: float = 1e4,
) -> jnp.ndarray:
    """Rotary embedding on ``x: (..., S, H, head_dim)`` at ``positions``.

    ``positions`` is ``(S,)`` (shared across the batch — train/prefill and
    uniform decode) or ``(B, S)`` (per-request absolute positions — the
    serve engine's continuous-batching decode, where every KV slot sits at
    its own sequence offset).

    ``rotary_pct < 1`` rotates only the leading fraction of head dims
    (chatglm-style partial / "2d" RoPE); the tail passes through.
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, rotary_pct, theta)
    rot = 2 * inv_freq.shape[0]
    if rot == 0:
        return x
    dtype = x.dtype
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if positions.ndim == 1:
        cos, sin = cos[None], sin[None]
    cos = cos[..., None, :]  # (..., S, 1, rot/2)
    sin = sin[..., None, :]
    x_rot, x_pass = x[..., :rot].astype(jnp.float32), x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape).astype(dtype)
    return jnp.concatenate([y, x_pass], axis=-1) if x_pass.shape[-1] else y


def embed_lookup_vp(
    tokens: jnp.ndarray,
    table_local: jnp.ndarray,
    vocab_start: jnp.ndarray,
    env,
) -> jnp.ndarray:
    """Vocab-parallel embedding: each model rank holds a vocab slice;
    out-of-slice tokens contribute zero, a model-axis psum restores rows."""
    vloc = table_local.shape[0]
    local_ids = tokens - vocab_start
    in_range = (local_ids >= 0) & (local_ids < vloc)
    safe = jnp.clip(local_ids, 0, vloc - 1)
    out = jnp.take(table_local, safe, axis=0)
    out = jnp.where(in_range[..., None], out, 0)
    return env.exit(out)
