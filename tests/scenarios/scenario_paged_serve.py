"""Subprocess scenario: the block-paged KV serve engine on a tp=2 mesh.

  * paged continuous batching is BIT-exact vs the contiguous engine and
    the static one-shot reference (mixed prompt lengths, slot reuse,
    shared prefixes), fp32 and int8 KV alike;
  * the page pool's kv-head dim shards on the model axis (the pool
    itself never dp-shards), and the leak audit holds after the drain;
  * shared-prefix interning dedupes pages under tp exactly as on one
    device (the measured peak matches the analytic page model).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.launch.mesh import make_mesh_from_cfg
from repro.models.init import init_params
from repro.plan import PrecisionPlan, SamplingParams
from repro.roofline.analysis import serve_paged_kv_bytes
from repro.serve.engine import Request, ServeEngine, generate_static
from repro.transport import CompressionPolicy

MESH_CFG = MeshCfg(tp=2, dp=1)
PAGE = 8
GEN = 6


def _requests(cfg):
    # odd rids sample (per-request key fold), even rids stay greedy —
    # the tp=2 engine must keep the mixed batch bit-exact vs static
    rng = np.random.default_rng(3)
    shared = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 2 * PAGE))
    return [
        Request(
            rid=i,
            prompt_ids=shared + tuple(
                int(t) for t in rng.integers(0, cfg.vocab_size, tail)
            ),
            max_new=GEN,
            sampling=(
                SamplingParams(temperature=0.9, top_p=0.95, top_k=40,
                               seed=50 + i)
                if i % 2 else SamplingParams()
            ),
        )
        for i, tail in enumerate((4, 9, 12, 7))
    ]


def main():
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh = make_mesh_from_cfg(MESH_CFG)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=MESH_CFG.tp)
    spec_tree = build_spec_tree(params, metas, MESH_CFG)
    storage = tree_to_storage(params, spec_tree, MESH_CFG)
    plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2),) * (cfg.num_groups + 1),
        host_device=CompressionPolicy(round_to=2),
    )
    reqs = _requests(cfg)

    with mesh:
        for int8 in (False, True):
            p = dataclasses.replace(plan, int8_kv=True) if int8 else plan
            static = generate_static(
                cfg, MESH_CFG, mesh, spec_tree, storage, reqs, plan=p
            )
            cont = ServeEngine(
                cfg, MESH_CFG, mesh, spec_tree, storage, plan=p,
                max_slots=2, cache_capacity=40,
            ).run(reqs)
            paged = ServeEngine(
                cfg, MESH_CFG, mesh, spec_tree, storage, plan=p,
                max_slots=2, cache_capacity=40, paged=True, page_size=PAGE,
            )
            results = paged.run(reqs)
            for r in reqs:
                assert results[r.rid].tokens == static[r.rid], (
                    "paged vs static diverged", int8, r.rid,
                    results[r.rid].tokens, static[r.rid],
                )
                assert results[r.rid].tokens == cont[r.rid].tokens, (
                    "paged vs contiguous diverged", int8, r.rid,
                )
            audit = paged.pages.audit()
            assert audit["live"] == 0
            assert audit["allocs"] == audit["releases"]
            print(f"int8_kv={int8}: {len(reqs)} paged streams (2 greedy "
                  f"+ 2 sampled) bit-exact vs contiguous + static on "
                  f"tp=2 (peak {audit['peak']} pages)")

        # all requests resident at once: measured peak == analytic
        # page-granular model with 2 shared pages stored once
        allres = ServeEngine(
            cfg, MESH_CFG, mesh, spec_tree, storage, plan=plan,
            max_slots=len(reqs), cache_capacity=40,
            paged=True, page_size=PAGE,
        )
        allres.run(reqs)
        analytic = serve_paged_kv_bytes(
            cfg, page_size=PAGE,
            requests=[(len(r.prompt_ids), GEN) for r in reqs],
            shared_prefix_len=2 * PAGE,
        )
        res = allres.kv_residency()
        assert res["pages_peak"] == analytic["pages"], (res, analytic)
        assert res["bytes_per_page"] == analytic["bytes_per_page"]
        assert res["kv_bytes_peak"] == analytic["kv_bytes_resident"]
        print(f"shared-prefix residency: peak {res['pages_peak']} pages == "
              f"analytic ({analytic['shared_pages']} shared + "
              f"{analytic['private_pages']} private), "
              f"{res['bytes_per_page']} B/page")

    print("scenario_paged_serve OK")


if __name__ == "__main__":
    main()
