"""Paper reproduction: A²DTWP vs oracle vs 32-bit baseline on the paper's
three networks (reduced scale, synthetic ImageNet-200-like data).

Reproduces the paper's §V methodology end-to-end on CPU:
  * trains each network under three policies — `baseline` (fp32),
    `oracle:<rt>` (best fixed format, ADT only), `awp` (A²DTWP) —
  * tracks top-5 validation error vs *modeled wall-time* (compute time is
    identical across policies by construction; transfer time is
    bytes / link-bandwidth, the paper's own Table II accounting),
  * reports the AWP precision trajectory (8→16→24→32 per layer/block) and
    the weight-motion byte reduction (~2.9× in the paper).

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/awp_cnn_repro.py --net alexnet --steps 150
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import SyntheticImageNet
from repro.dist.spec import DIST, LeafSpec, MeshCfg
from repro.plan import PrecisionPlan
from repro.launch.mesh import make_mesh_from_cfg
from repro.models.cnn import ALEXNET, RESNET34, VGG_A, init_cnn, reduced_cnn
from repro.optim.sgd import SGDConfig, init_momentum, lr_at
from repro.train.cnn_step import (
    build_cnn_spec_tree,
    cnn_to_storage,
    make_cnn_eval,
    make_cnn_train_step,
)
from repro.train.loop import Trainer

NETS = {"alexnet": ALEXNET, "vgg": VGG_A, "resnet": RESNET34}

# modeled link bandwidth for the transfer-time account (paper: PCIe 8 GT/s
# x8 ≈ 7.9 GB/s); compute time per batch is measured-identical across
# policies so only the transfer term differs — §V-G methodology.
LINK_BW = 7.9e9


def run_policy(policy, cfg, data, mesh_cfg, mesh, steps, batch, lr0, seed=0,
               grad_round_to=None, grad_mode="nearest"):
    params, metas, groups_info = init_cnn(cfg, jax.random.PRNGKey(seed))
    spec_tree = build_cnn_spec_tree(params, metas, mesh_cfg)
    storage = cnn_to_storage(params, spec_tree, mesh_cfg)
    groups, num_groups = groups_info

    # per-group compressed element counts (for wire-byte accounting)
    elems = [0] * num_groups
    def count(name, leafs):
        for k, s in leafs.items():
            if isinstance(s, LeafSpec) and s.kind == DIST:
                elems[groups[name]] += s.s_loc * mesh_cfg.dshards
    for name, leafs in spec_tree["layers"].items():
        count(name, leafs)

    opt = SGDConfig(lr=lr0, momentum=0.9, weight_decay=5e-4,
                    lr_decay_every=0)

    # T is tuned by the paper's own procedure (§V-A): monitor a short run,
    # observe the mean per-batch l2-norm change rate around the first
    # val-error drop, and use that as the threshold.
    t_thresh = tune_threshold(cfg, data, mesh_cfg, mesh, batch, lr0)
    # one plan per policy: the schedule source + formats are plan fields,
    # the grad reduce-scatter entry (incl. stochastic rounding) rides along
    rt0 = 4
    if policy.startswith("oracle:"):
        rt0 = int(policy.split(":")[1])
    plan = PrecisionPlan.build(
        num_groups, round_to=rt0,
        grad_round_to=grad_round_to, grad_mode=grad_mode,
        schedule="awp" if policy == "awp" else "static",
        awp_threshold=t_thresh, awp_interval=10,
    )

    def builder(round_tos):
        return make_cnn_train_step(
            cfg, mesh_cfg, mesh, spec_tree, groups_info, opt, {},
            plan=plan.with_round_tos(round_tos),
        )

    trainer = Trainer(
        builder, num_groups, plan=plan,
        dist_elems_per_group=elems, gather_axis_size=mesh_cfg.dshards,
    )
    evaluator_cache = {}

    def evaluate(storage, rts):
        if rts not in evaluator_cache:
            evaluator_cache[rts] = make_cnn_eval(
                cfg, mesh_cfg, mesh, spec_tree, groups_info,
                plan=plan.with_round_tos(rts),
            )
        imgs, labels = data.validation(256)
        return float(evaluator_cache[rts](storage, imgs, labels))

    mom = init_momentum(storage)
    curve = []
    for step in range(steps):
        imgs, labels = data.batch(batch, step)
        lr = lr_at(opt, step)
        storage, mom, _ = trainer.run_step(
            storage, mom, {"images": imgs, "labels": labels}, lr,
            jax.random.PRNGKey(1000 + step),
        )
        if step % 10 == 9 or step == steps - 1:
            err = evaluate(storage, trainer.current_round_tos())
            # modeled elapsed: Σ (compute_const + wire/bw); compute_const
            # cancels in the normalized comparison, we use measured wall
            # minus first-step compile + modeled transfer
            xfer_s = sum(r.wire_bytes for r in trainer.records) / LINK_BW
            curve.append(
                {"step": step + 1, "top5_err": err, "modeled_xfer_s": xfer_s}
            )
    s = trainer.summary()
    s["curve"] = curve
    s["policy"] = policy
    return s


_T_CACHE = {}


def tune_threshold(cfg, data, mesh_cfg, mesh, batch, lr0, monitor_steps=25):
    """Paper §V-A: measure the average l2-norm change rate over a short
    monitoring window and use it as T."""
    key = (cfg.name, batch)
    if key in _T_CACHE:
        return _T_CACHE[key]
    params, metas, groups_info = init_cnn(cfg, jax.random.PRNGKey(7))
    spec_tree = build_cnn_spec_tree(params, metas, mesh_cfg)
    storage = cnn_to_storage(params, spec_tree, mesh_cfg)
    _, num_groups = groups_info
    opt = SGDConfig(lr=lr0, momentum=0.9, weight_decay=5e-4)
    step = make_cnn_train_step(
        cfg, mesh_cfg, mesh, spec_tree, groups_info, opt, {},
        plan=PrecisionPlan.build(num_groups, round_to=4),
    )
    mom = init_momentum(storage)
    deltas = []
    prev = None
    for i in range(monitor_steps):
        imgs, labels = data.batch(batch, 10_000 + i)
        storage, mom, m = step(
            storage, mom, {"images": imgs, "labels": labels}, lr0,
            jax.random.PRNGKey(i),
        )
        norms = np.sqrt(np.asarray(m["group_norms_sq"], np.float64))
        if prev is not None:
            deltas.append(np.mean((norms - prev) / np.maximum(prev, 1e-12)))
        prev = norms
    # mean change rate over the later half of the window (post warm-up)
    t = float(np.mean(deltas[len(deltas) // 2:]))
    _T_CACHE[key] = t
    print(f"   tuned T = {t:.2e} (paper procedure §V-A)")
    return t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", choices=sorted(NETS), default="alexnet")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--devices", type=int, default=0,
                    help="data-parallel fake devices (0 = single)")
    ap.add_argument("--grad-round-to", type=int, default=None,
                    help="compress the gradient reduce-scatter (dp>1)")
    ap.add_argument("--grad-mode", default="nearest",
                    choices=["truncate", "nearest", "stochastic"],
                    help="gradient rounding; 'stochastic' exercises the "
                         "plumbed PRNG key (paper beyond-§III)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    cfg = reduced_cnn(NETS[args.net], num_classes=20, in_hw=32)
    data = SyntheticImageNet(num_classes=20, hw=32)
    # mini-nets have small weight tensors: compress everything >= 1 KiB
    if args.devices > 1:
        mesh_cfg = MeshCfg(tp=1, dp=args.devices, compress_min_size=256)
        mesh = make_mesh_from_cfg(mesh_cfg)
    else:
        mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=256)
        mesh = None

    results = {}
    ctx = mesh if mesh is not None else _null()
    with ctx:
        for policy in ("baseline", "oracle:2", "awp"):
            print(f"== {cfg.name} / {policy} ==", flush=True)
            r = run_policy(
                policy, cfg, data, mesh_cfg, mesh,
                args.steps, args.batch, args.lr,
                grad_round_to=args.grad_round_to, grad_mode=args.grad_mode,
            )
            results[policy] = r
            print(
                f"   final loss {r['final_loss']:.3f}  "
                f"top5err {r['curve'][-1]['top5_err']:.3f}  "
                f"wire reduction {r['wire_reduction']*100:.1f}%  "
                f"recompiles {r['recompiles']}"
            )
            if policy == "awp":
                print(f"   AWP bits history: {r['bits_history']}")

    base_err = results["baseline"]["curve"][-1]["top5_err"]
    awp_err = results["awp"]["curve"][-1]["top5_err"]
    print(
        f"\nvalidation-error parity: baseline {base_err:.3f} vs "
        f"A2DTWP {awp_err:.3f} (|Δ| = {abs(base_err-awp_err):.3f})"
    )
    print(
        f"A2DTWP weight-motion reduction: "
        f"{results['awp']['wire_reduction']*100:.1f}% "
        f"(paper reports ~2.9x ≈ 66% on VGG)"
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, default=str)


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
