"""Vocab-parallel cross-entropy (megatron-style, stable, mask-aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.env import Env


def lm_loss(
    logits_local: jnp.ndarray,  # (B, S, V_local) — vocab sharded over model
    labels: jnp.ndarray,        # (B, S) int32; negative = ignore
    env: Env,
    vocab_start,                # global index of this rank's first vocab row
    real_vocab: int,            # unpadded vocab size
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mean token NLL + token count. Works sharded (model axis) or local."""
    vloc = logits_local.shape[-1]
    gidx = vocab_start + jnp.arange(vloc)
    logits_local = jnp.where(
        (gidx < real_vocab)[None, None, :], logits_local.astype(jnp.float32), -1e30
    )

    m_loc = lax.stop_gradient(jnp.max(logits_local, axis=-1))
    if env.model_axis is not None:
        # lint: allow(RAW-COLLECTIVE): softmax-stability max — not a sum, so the uint8 plane pipeline cannot carry it; raw fp32 is its wire format (audited)
        m = lax.pmax(m_loc, env.model_axis)
    else:
        m = m_loc
    s_loc = jnp.sum(jnp.exp(logits_local - m[..., None]), axis=-1)
    # vocab-partial sums over full-sequence logits: always the psum pair
    # (the logits entry already gathered any sequence shards)
    s = env.psum_exit(s_loc)  # psum fwd / identity bwd
    lse = jnp.log(s) + m

    local_ids = labels - vocab_start
    in_range = (local_ids >= 0) & (local_ids < vloc)
    safe = jnp.clip(local_ids, 0, vloc - 1)
    tgt_partial = jnp.take_along_axis(logits_local, safe[..., None], axis=-1)[..., 0]
    tgt_partial = jnp.where(in_range, tgt_partial, 0.0)
    tgt = env.psum_exit(tgt_partial)

    valid = (labels >= 0).astype(jnp.float32)
    nll = (lse - tgt) * valid
    count = jnp.sum(valid)
    return jnp.sum(nll), count
