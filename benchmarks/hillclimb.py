import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: runs the three chosen (arch x shape) pairs
through ladders of optimizations, recording the roofline after each change.

  H1 llama-3.2-vision-90b x train_4k   (worst memory term)
  H2 arctic-480b          x decode_32k (most collective-bound)
  H3 qwen3-14b            x train_4k   (most representative of the paper's
                                        technique: the ADT wire-format ladder)

Usage: PYTHONPATH=src python -m benchmarks.hillclimb [--out results/hillclimb.json]
"""
import argparse
import json
import traceback

from repro.launch.dryrun import run_one

LADDERS = {
    "H3_qwen3-14b_train_4k_paper_ladder": [
        # paper-faithful baseline: fp32 everything, uncompressed gathers
        ("baseline_fp32_rt4", "qwen3-14b", "train_4k", 4, {}),
        # the paper's technique at AWP steady states
        ("adt_rt2_bf16wire", "qwen3-14b", "train_4k", 2, {}),
        ("adt_rt1_8bitwire", "qwen3-14b", "train_4k", 1, {}),
        # beyond-paper: compress the gradient path too (paper §VI notes
        # gradient compression is orthogonal/combinable)
        ("adt_rt2_gradrt2", "qwen3-14b", "train_4k", 2, {"grad_round_to": 2}),
        # beyond-paper: bf16 activations (shrinks the dominant TP psum)
        ("adt_rt2_bf16act", "qwen3-14b", "train_4k", 2, {"train_dtype": "bf16"}),
        ("adt_rt2_bf16act_gradrt2", "qwen3-14b", "train_4k", 2,
         {"train_dtype": "bf16", "grad_round_to": 2}),
    ],
    "H1_llama-vision-90b_train_4k_memory_ladder": [
        ("baseline_fp32", "llama-3.2-vision-90b", "train_4k", 2, {}),
        ("bf16_act", "llama-3.2-vision-90b", "train_4k", 2,
         {"train_dtype": "bf16"}),
        ("bf16_act_accum4", "llama-3.2-vision-90b", "train_4k", 2,
         {"train_dtype": "bf16", "accum": 4}),
        ("bf16_act_accum16", "llama-3.2-vision-90b", "train_4k", 2,
         {"train_dtype": "bf16", "accum": 16}),
    ],
    "H2_arctic-480b_decode_32k_collective_ladder": [
        ("baseline_rt2_gather_per_step", "arctic-480b", "decode_32k", 2, {}),
        ("weight_stationary", "arctic-480b", "decode_32k", 2,
         {"weight_stationary": True}),
        ("weight_stationary_int8kv", "arctic-480b", "decode_32k", 2,
         {"weight_stationary": True, "int8_kv": True}),
        # H2 continuation: keep the resident copy in bf16 (ADT residency)
        ("ws_int8kv_bf16resident", "arctic-480b", "decode_32k", 2,
         {"weight_stationary": True, "int8_kv": True, "resident_bf16": True}),
    ],
    "H4_xlstm-1.3b_train_4k_chunkwise_ladder": [
        # the worst memory term in the whole table: sequential mLSTM scan
        ("baseline_sequential_scan", "xlstm-1.3b", "train_4k", 2, {}),
        # chunkwise-parallel mLSTM: state materialized once per chunk
        ("chunkwise_64", "xlstm-1.3b", "train_4k", 2, {"mlstm_chunk": 64}),
        ("chunkwise_128", "xlstm-1.3b", "train_4k", 2, {"mlstm_chunk": 128}),
    ],
    # ablation: the masked-rectangle attention baseline (useful-flops story)
    "A1_qwen3-14b_prefill_32k_causal_skip_ablation": [
        ("masked_rectangle", "qwen3-14b", "prefill_32k", 2,
         {"causal_skip": False}),
        ("triangular_exact", "qwen3-14b", "prefill_32k", 2,
         {"causal_skip": True}),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/hillclimb.json")
    ap.add_argument("--ladder", default=None, choices=[*LADDERS, None])
    args = ap.parse_args()
    ladders = {args.ladder: LADDERS[args.ladder]} if args.ladder else LADDERS

    out = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            out = json.load(f)
    for lname, steps in ladders.items():
        out.setdefault(lname, {})
        for tag, arch, shape, rt, opts in steps:
            if tag in out[lname]:
                continue
            print(f"== {lname} :: {tag} ==", flush=True)
            try:
                r = run_one(arch, shape, False, rt, opts=opts, verbose=False)
            except Exception as e:
                traceback.print_exc()
                r = {"error": repr(e)}
            out[lname][tag] = r
            with open(args.out, "w") as f:
                json.dump(out, f, indent=2, default=str)
            if "roofline" in r:
                rf = r["roofline"]
                print(
                    f"   c={rf['compute_s']:.3f}s m={rf['memory_s']:.3f}s "
                    f"x={rf['collective_s']:.3f}s dom={rf['dominant']} "
                    f"useful={rf['useful_ratio']:.2f} "
                    f"temp={r['memory']['temp_bytes']/1e9:.1f}GB",
                    flush=True,
                )
    print("hillclimb done ->", args.out)


if __name__ == "__main__":
    main()
