"""Pallas TPU kernel: paged decode attention over a block page table.

Decode-time attention for the block-paged KV cache: K/V live in a page
pool ``(P, page, Kv, hd)`` and each slot owns a row of the page table
``(B, n_pages)`` mapping logical page ``j`` to a physical pool row. The
kernel walks the table with a scalar-prefetch index map — the grid is
``(B, n_pages)`` and the K/V BlockSpec picks block ``table[b, j]`` —
so only the pages a slot actually owns move from HBM to VMEM. That is
the data-motion win: resident bytes and gathered bytes scale with the
tokens written, not with ``max_slots * capacity``.

The online softmax ``(m, l, acc)`` carry persists in VMEM scratch
across the sequential ``j`` steps of one batch row (initialised at
``j == 0``, output written at the last ``j``), the same running-rescale
algebra as :mod:`repro.kernels.flash_prefill`.

Bit-compatibility contract: :func:`paged_attend_ref` replays the exact
page walk through the shared :func:`_page_update` helper, so kernel and
oracle agree bitwise under ``interpret=True``. Dispatch mirrors
``kernels/bitpack.py``: ``resolve_interpret`` compiles on a real TPU
and interprets elsewhere. The serve engine's CPU path uses the dense
``attend_decode_paged`` reference in ``models/attention.py`` (bit-exact
vs the contiguous engine); this kernel is the TPU fast path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bitpack import resolve_interpret

NEG_INF = -1e30  # matches models.attention: exp() underflows to exact 0.0


def _page_valid(j, page: int, length):
    """(page,) bool — which rows of logical page ``j`` hold live tokens.

    Shared kernel/oracle. 2D iota then squeeze: TPU requires >=2D iota.
    """
    offs = jax.lax.broadcasted_iota(jnp.int32, (page, 1), 0)[:, 0]
    return (j * page + offs) < length


def _page_update(q, k_pg, v_pg, valid, m, l, acc):
    """One page of the online-softmax walk.

    ``q (Kv, G, hd)``; ``k_pg/v_pg (page, Kv, hd)``; ``valid (page,)``
    bool; carry ``m/l (Kv, G)`` and ``acc (Kv, G, hd)`` in fp32. Shared
    VERBATIM by kernel body and oracle — bitwise parity by construction.
    """
    s = jnp.einsum(
        "kgh,pkh->kgp", q, k_pg, preferred_element_type=jnp.float32
    ) * (q.shape[-1] ** -0.5)
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "kgp,pkh->kgh", p, v_pg.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return m_new, l_new, acc_new


def _paged_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    valid = _page_valid(j, page, len_ref[b])
    m, l, acc = _page_update(
        q_ref[0], k_ref[0], v_ref[0], valid,
        m_ref[...], l_ref[...], acc_ref[...],
    )
    m_ref[...] = m
    l_ref[...] = l
    acc_ref[...] = acc

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attend(
    q: jnp.ndarray,        # (B, Kv, G, hd) — one decode step of queries
    k_pool: jnp.ndarray,   # (P, page, Kv, hd) — shared page pool
    v_pool: jnp.ndarray,
    page_table: jnp.ndarray,  # (B, n_pages) int32 physical page ids
    lengths: jnp.ndarray,     # (B,) int32 live tokens per slot
    *,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Paged decode attention; returns ``(B, Kv, G, hd)``.

    Every table entry must be a valid pool row (point unused entries at
    a ballast page); rows past ``lengths[b]`` are masked to ``NEG_INF``
    so their softmax weight is exactly 0.0.
    """
    B, Kv, G, hd = q.shape
    P, page = k_pool.shape[0], k_pool.shape[1]
    n_pages = page_table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, Kv, G, hd), lambda b, j, table, lens: (b, 0, 0, 0)),
            pl.BlockSpec(
                (1, page, Kv, hd),
                lambda b, j, table, lens: (table[b, j], 0, 0, 0),
            ),
            pl.BlockSpec(
                (1, page, Kv, hd),
                lambda b, j, table, lens: (table[b, j], 0, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, Kv, G, hd), lambda b, j, table, lens: (b, 0, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((Kv, G), jnp.float32),
            pltpu.VMEM((Kv, G), jnp.float32),
            pltpu.VMEM((Kv, G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, page=page),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Kv, G, hd), q.dtype),
        interpret=resolve_interpret(interpret),
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pool, v_pool)


@jax.jit
def paged_attend_ref(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    page_table: jnp.ndarray,
    lengths: jnp.ndarray,
) -> jnp.ndarray:
    """Pure-JAX oracle: replays the kernel's page walk through the shared
    :func:`_page_update` helper (bitwise-parity reference).

    As in ``flash_prefill_ref``, the walk is a jitted ``fori_loop`` with
    ``dynamic_slice`` page gathers so XLA compiles the per-page einsums
    in the same context as the interpreted kernel — an unrolled eager
    replay differs by ~1 ulp.
    """
    B, Kv, G, hd = q.shape
    page = k_pool.shape[1]
    n_pages = page_table.shape[1]
    out = []
    for b in range(B):
        q_b = q[b]
        m0 = jnp.full((Kv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((Kv, G), jnp.float32)
        a0 = jnp.zeros((Kv, G, hd), jnp.float32)

        def body(j, carry, b=b, q_b=q_b):
            m, l, acc = carry
            pid = page_table[b, j]
            k_pg = jax.lax.dynamic_slice(
                k_pool, (pid, 0, 0, 0), (1, page, Kv, hd)
            )[0]
            v_pg = jax.lax.dynamic_slice(
                v_pool, (pid, 0, 0, 0), (1, page, Kv, hd)
            )[0]
            valid = _page_valid(j, page, lengths[b])
            return _page_update(q_b, k_pg, v_pg, valid, m, l, acc)

        m, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, a0))
        out.append(
            (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        )
    return jnp.stack(out, axis=0)
