"""Tokenized record shards with tiered (progressive) per-record
compression — the training-ingest twin of the transport's wire formats.

The paper's thesis is that training time is dominated by data motion;
the ingest path is the largest unpriced byte stream in a training loop.
This module gives it the same treatment the weight gathers got:

  * records are stored as MSB-first **byte planes**
    (:mod:`repro.utils.planes` — the host-side twin of the transport's
    plane decomposition), so a reader can stop after the most
    significant ``quality`` planes of every float payload — the
    record-level tiered layout of Progressive Compressed Records
    (Kuchnik et al.): one file serves every fidelity, lower tiers read
    fewer bytes;
  * integer payloads (token ids, labels) are *lossless by construction*:
    all-zero most-significant planes are trimmed at write time (a
    vocab-65k id costs 2 bytes, not 4 — the ``token_wire_width``
    adaptation applied to disk) and the remaining planes are always
    read in full regardless of ``quality``;
  * each plane is optionally zlib-compressed; the manifest records every
    stored plane size, so byte accounting is *manifest arithmetic* — the
    analytic ingest model (:func:`repro.roofline.analysis.train_ingest_bytes`)
    and the reader's measured counter derive from the same numbers and
    cannot drift;
  * iteration order is **deterministic and resumable**: epoch ``e`` of a
    reader seeded ``s`` visits a permutation drawn from
    ``SeedSequence([s, e])`` (the collision-free scheme
    ``SyntheticImageNet`` uses per step), and
    :meth:`ShardReader.state` is a small JSON-serializable dict — a
    restored reader replays the exact record (and therefore batch)
    stream, which the resume-determinism tests pin bit-exactly.

On-disk layout (``manifest.json`` + ``shard_*.bin``)::

    out_dir/
      manifest.json        # format/meta + per-record plane-size index
      shard_00000.bin      # records back to back, planes back to back
      shard_00001.bin ...

A record is a ``{field: np.ndarray}`` dict. Per field the shard stores
``lead_skip`` (trimmed zero MSB planes), the per-plane stored sizes, and
the codec — enough to read any tier of any record with one seek.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Iterable, Iterator

import numpy as np

from repro.utils.planes import lead_zero_planes, plane_join, plane_split

MANIFEST = "manifest.json"
VALID_CODECS = ("raw", "zlib")
# float dtypes degrade gracefully under plane truncation; everything else
# (ids, labels, masks) must round-trip exactly and ignores ``quality``
_FLOAT_KINDS = ("f",)


def _is_tiered(dtype: np.dtype) -> bool:
    return dtype.kind in _FLOAT_KINDS


def _encode(plane: np.ndarray, codec: str) -> bytes:
    b = plane.tobytes()
    return zlib.compress(b, 6) if codec == "zlib" else b


def _decode(buf: bytes, codec: str) -> np.ndarray:
    b = zlib.decompress(buf) if codec == "zlib" else buf
    return np.frombuffer(b, np.uint8)


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------


class ShardWriter:
    """Write records into ``records_per_shard``-sized shard files.

    ``meta`` is free-form run metadata (vocab size, sequence length,
    generator seed) persisted verbatim in the manifest — the launcher
    validates it against the model config before training.
    """

    def __init__(
        self,
        out_dir: str,
        *,
        kind: str,
        meta: dict | None = None,
        codec: str = "zlib",
        records_per_shard: int = 64,
    ):
        if codec not in VALID_CODECS:
            raise ValueError(f"codec must be in {VALID_CODECS}")
        if records_per_shard < 1:
            raise ValueError("records_per_shard must be >= 1")
        os.makedirs(out_dir, exist_ok=True)
        self.out_dir = out_dir
        self.kind = kind
        self.meta = dict(meta or {})
        self.codec = codec
        self.records_per_shard = records_per_shard
        self._shards: list[dict] = []
        self._cur_file = None
        self._cur_records: list[dict] = []
        self._cur_off = 0
        self._closed = False

    # ------------------------------------------------------------------
    def _open_shard(self):
        name = f"shard_{len(self._shards):05d}.bin"
        self._shards.append({"file": name, "records": []})
        self._cur_file = open(os.path.join(self.out_dir, name), "wb")
        self._cur_records = self._shards[-1]["records"]
        self._cur_off = 0

    def append(self, record: dict) -> None:
        if self._closed:
            raise ValueError("writer is closed")
        if self._cur_file is None or (
            len(self._cur_records) >= self.records_per_shard
        ):
            if self._cur_file is not None:
                self._cur_file.close()
            self._open_shard()
        fields = {}
        for name in sorted(record):
            arr = np.asarray(record[name])
            planes = plane_split(arr)
            skip = 0
            if not _is_tiered(arr.dtype):
                skip = lead_zero_planes(planes)
                planes = planes[skip:]
            sizes = []
            for p in planes:
                buf = _encode(p, self.codec)
                self._cur_file.write(buf)
                sizes.append(len(buf))
            fields[name] = {
                "dtype": arr.dtype.str,
                "shape": list(arr.shape),
                "lead_skip": skip,
                "plane_sizes": sizes,
            }
        rec = {"offset": self._cur_off, "fields": fields}
        self._cur_off += sum(
            s for f in fields.values() for s in f["plane_sizes"]
        )
        self._cur_records.append(rec)

    def close(self) -> dict:
        """Flush, write the manifest, return it."""
        if self._closed:
            raise ValueError("writer is closed")
        self._closed = True
        if self._cur_file is not None:
            self._cur_file.close()
        manifest = {
            "version": 1,
            "kind": self.kind,
            "codec": self.codec,
            "meta": self.meta,
            "records_per_shard": self.records_per_shard,
            "shards": self._shards,
        }
        tmp = os.path.join(self.out_dir, MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(self.out_dir, MANIFEST))
        return manifest


# ---------------------------------------------------------------------------
# reader
# ---------------------------------------------------------------------------


def _epoch_order(seed: int, epoch: int, n: int) -> np.ndarray:
    """Deterministic epoch permutation: ``SeedSequence([seed, epoch])``
    entropy words (both mapped bijectively to non-negative ints, the
    ``_step_rng`` scheme) — distinct (seed, epoch) pairs shuffle
    independently and identically across processes/restarts."""
    ent = [int(np.uint64(np.int64(seed))), int(np.uint64(np.int64(epoch)))]
    return np.random.default_rng(ent).permutation(n)


@dataclasses.dataclass
class _RecordRef:
    shard: int
    offset: int
    fields: dict


class ShardReader:
    """Deterministic, resumable, tier-aware reader over a shard dir.

    ``quality`` — float payloads read only their ``quality`` most
    significant planes (1..4 for fp32; the PCR knob); integer payloads
    always read every stored plane (lossless floor). ``seed`` drives the
    epoch permutations. :meth:`state` / :meth:`load_state` round-trip
    the full iteration position through a JSON-serializable dict.
    """

    def __init__(self, path: str, *, quality: int = 4, seed: int = 0):
        if quality < 1:
            raise ValueError("quality must be >= 1")
        self.path = path
        self.quality = int(quality)
        self.seed = int(seed)
        with open(os.path.join(path, MANIFEST)) as f:
            self.manifest = json.load(f)
        self.kind = self.manifest["kind"]
        self.codec = self.manifest["codec"]
        self.meta = self.manifest.get("meta", {})
        self._refs: list[_RecordRef] = []
        for si, sh in enumerate(self.manifest["shards"]):
            for rec in sh["records"]:
                self._refs.append(
                    _RecordRef(si, rec["offset"], rec["fields"])
                )
        if not self._refs:
            raise ValueError(f"no records under {path!r}")
        self._files: dict[int, object] = {}
        self.epoch = 0
        self.pos = 0
        self._order = _epoch_order(self.seed, 0, len(self._refs))
        self.bytes_read = 0  # measured ingest counter (stored bytes)

    # -- geometry ------------------------------------------------------
    @property
    def num_records(self) -> int:
        return len(self._refs)

    def _planes_kept(self, field: dict) -> int:
        """Stored planes a ``quality``-tier read consumes for one field
        — the single formula shared by the read path and the analytic
        byte accounting (so measured == analytic by construction)."""
        stored = len(field["plane_sizes"])
        if not _is_tiered(np.dtype(field["dtype"])):
            return stored
        # stored plane i is logical plane lead_skip + i; keep logical
        # planes [0, quality)
        return max(0, min(stored, self.quality - field["lead_skip"]))

    def record_stored_bytes(self, rid: int) -> int:
        """Stored bytes a read of record ``rid`` moves at this quality
        (pure manifest arithmetic — no file I/O)."""
        ref = self._refs[rid]
        return sum(
            sum(f["plane_sizes"][: self._planes_kept(f)])
            for f in ref.fields.values()
        )

    def planned_bytes(self, count: int) -> int:
        """Stored bytes the next ``count`` records will read, from the
        current position — the analytic ingest model's shard-read term
        (epoch wrap included). Does not advance the reader."""
        total = 0
        epoch, pos, order = self.epoch, self.pos, self._order
        for _ in range(count):
            if pos >= len(order):
                epoch += 1
                pos = 0
                order = _epoch_order(self.seed, epoch, len(self._refs))
            total += self.record_stored_bytes(int(order[pos]))
            pos += 1
        return total

    # -- state ---------------------------------------------------------
    def state(self) -> dict:
        """JSON-serializable iteration state: a restored reader replays
        the exact record stream from here."""
        return {
            "seed": self.seed,
            "epoch": self.epoch,
            "pos": self.pos,
            "quality": self.quality,
        }

    def load_state(self, state: dict) -> "ShardReader":
        self.seed = int(state["seed"])
        self.epoch = int(state["epoch"])
        self.pos = int(state["pos"])
        self.quality = int(state["quality"])
        self._order = _epoch_order(self.seed, self.epoch, len(self._refs))
        return self

    # -- reading -------------------------------------------------------
    def _file(self, shard: int):
        f = self._files.get(shard)
        if f is None:
            name = self.manifest["shards"][shard]["file"]
            f = open(os.path.join(self.path, name), "rb")
            self._files[shard] = f
        return f

    def read_record(self, rid: int) -> tuple[dict, int]:
        """Record ``rid`` at this quality -> ``(arrays, stored_bytes)``."""
        ref = self._refs[rid]
        f = self._file(ref.shard)
        out = {}
        nbytes = 0
        off = ref.offset
        for name in sorted(ref.fields):
            fld = ref.fields[name]
            keep = self._planes_kept(fld)
            planes = []
            for i, sz in enumerate(fld["plane_sizes"]):
                if i < keep:
                    f.seek(off)
                    buf = f.read(sz)
                    planes.append(_decode(buf, self.codec))
                    nbytes += sz
                off += sz
            dtype = np.dtype(fld["dtype"])
            n = int(np.prod(fld["shape"])) if fld["shape"] else 1
            stack = (
                np.stack(planes)
                if planes
                else np.zeros((0, n), np.uint8)
            )
            out[name] = plane_join(
                stack, dtype, tuple(fld["shape"]),
                lead_skip=fld["lead_skip"],
            )
        self.bytes_read += nbytes
        return out, nbytes

    def next_record(self) -> tuple[dict, int]:
        """The next record in deterministic order (epoch wrap rolls the
        permutation forward) -> ``(arrays, stored_bytes)``."""
        if self.pos >= len(self._order):
            self.epoch += 1
            self.pos = 0
            self._order = _epoch_order(
                self.seed, self.epoch, len(self._refs)
            )
        rid = int(self._order[self.pos])
        self.pos += 1
        return self.read_record(rid)

    def __iter__(self) -> Iterator[tuple[dict, int]]:
        while True:
            yield self.next_record()

    def close(self):
        for f in self._files.values():
            f.close()
        self._files.clear()


# ---------------------------------------------------------------------------
# batching
# ---------------------------------------------------------------------------


def batches(reader: ShardReader, batch_size: int):
    """Group records into training batches.

    Yields ``(host_batch, stored_bytes, state_after)`` where
    ``host_batch`` is a dict of stacked numpy arrays, ``stored_bytes``
    the shard bytes this batch read, and ``state_after`` the reader
    state *after* drawing the batch — the value a checkpoint written
    after the corresponding train step must persist so a restored run
    resumes at the next batch boundary (prefetch depth notwithstanding).

    LM shards store the token stream ONCE per record (``stream`` of
    ``seq+1`` ids); the tokens/labels views are sliced on device after
    staging — moving ``seq+1`` ids instead of ``2*seq`` is the data
    pipeline's own little data-motion win.
    """
    if batch_size < 1:
        raise ValueError("batch_size must be >= 1")
    while True:
        recs, nbytes = [], 0
        for _ in range(batch_size):
            r, b = reader.next_record()
            recs.append(r)
            nbytes += b
        batch = {
            k: np.stack([r[k] for r in recs]) for k in sorted(recs[0])
        }
        yield batch, nbytes, reader.state()


# ---------------------------------------------------------------------------
# synthetic -> shards (tests + CI need no downloads)
# ---------------------------------------------------------------------------


def write_lm_shards(
    out_dir: str,
    *,
    vocab: int,
    seq: int,
    num_records: int,
    seed: int = 0,
    codec: str = "zlib",
    records_per_shard: int = 64,
) -> dict:
    """Tokenize the synthetic k-gram LM stream into shards: one record
    per sequence, the ``seq+1``-long stream stored once (tokens/labels
    are device-side views)."""
    from repro.data.pipeline import synthetic_lm_batch

    w = ShardWriter(
        out_dir, kind="lm", codec=codec,
        records_per_shard=records_per_shard,
        meta={"vocab": int(vocab), "seq": int(seq), "seed": int(seed)},
    )
    for step in range(num_records):
        toks, labels = synthetic_lm_batch(vocab, 1, seq, step, seed=seed)
        stream = np.concatenate(
            [np.asarray(toks[0]), np.asarray(labels[0, -1:])]
        ).astype(np.int32)
        w.append({"stream": stream})
    return w.close()


def write_feature_shards(
    out_dir: str,
    *,
    dim: int,
    vocab: int,
    seq: int,
    num_records: int,
    seed: int = 0,
    codec: str = "zlib",
    records_per_shard: int = 64,
) -> dict:
    """Frame-embedding records (audio/encoder family): float features
    carry the tiered planes the quality knob trades off, labels stay
    lossless."""
    from repro.data.pipeline import synthetic_feature_batch

    w = ShardWriter(
        out_dir, kind="feature", codec=codec,
        records_per_shard=records_per_shard,
        meta={
            "dim": int(dim), "vocab": int(vocab), "seq": int(seq),
            "seed": int(seed),
        },
    )
    for step in range(num_records):
        feats, labels = synthetic_feature_batch(
            dim, vocab, 1, seq, step, seed=seed
        )
        w.append({
            "features": np.asarray(feats[0], np.float32),
            "labels": np.asarray(labels[0], np.int32),
        })
    return w.close()
