"""Dense channel mixers: SwiGLU (llama-family) and GeLU MLP (hubert)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.env import Env


def swiglu(x: jnp.ndarray, w: dict, env: Env) -> jnp.ndarray:
    """Column-parallel gate/up, row-parallel down (one model-axis psum)."""
    xin = env.enter(x)
    g = jax.nn.silu(xin @ w["w_gate"])
    u = xin @ w["w_up"]
    return env.exit((g * u) @ w["w_down"])


def gelu_mlp(x: jnp.ndarray, w: dict, env: Env) -> jnp.ndarray:
    xin = env.enter(x)
    h = jax.nn.gelu(xin @ w["w_up"], approximate=True)
    return env.exit(h @ w["w_down"])
