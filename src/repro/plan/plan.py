"""PrecisionPlan — the single declarative object that owns every
precision decision in the framework.

The paper's contribution is *one algorithm* that adapts the
data-representation format of every tensor crossing the wire; before
this module the configuration surface mirroring it was shattered across
``round_tos`` tuples, ``grad_round_to``, ``act_policy`` kwargs,
``env_kw`` dicts, ``seq_parallel`` flags and AWP CLI options. A
:class:`PrecisionPlan` gathers all of them into one validated,
serializable value:

  * **per-traffic-class policies** — one
    :class:`~repro.transport.CompressionPolicy` entry per class of wire
    traffic (see :data:`TRAFFIC_CLASSES` and docs/plan.md):

      | entry | carrier |
      |---|---|
      | ``weights``     | per-precision-group forward weight gathers (FSDP axes) |
      | ``gradients``   | the backward reduce-scatter of weight gradients |
      | ``activations`` | TP-region psums / activation cotangents |
      | ``seq_boundary``| the sequence-parallel ``seq_gather``/``seq_scatter`` pair |
      | ``host_device`` | paper §III host→device staging (accounting entry) |
      | ``kv_migration``| fleet fabric: prefill→decode KV page parcels |
      | ``weight_publish`` | fleet fabric: trainer→replica checkpoint parcels |

    ``kv_migration`` defaults to the ``host_device`` chain (it is the
    same class of traffic crossing a replica boundary instead of the
    PCIe bus); ``weight_publish`` defaults to the first weights entry
    (published planes reuse the checkpoint wire tiers).

    ``gradients`` is described by its *forward* fields (``round_to``,
    ``mode``) and folded into the weight policies' grad fields when the
    plan is resolved; ``seq_boundary`` defaults to ``activations``;
    ``host_device`` defaults to the weight entries.

  * **a schedule source** — ``static`` (the paper's oracle: the plan's
    formats are final) or ``awp`` (Algorithm 1 widens the weight
    entries at runtime; threshold / interval / initial bits live here).

  * **execution layout** — ``seq_parallel``, ``chunks`` (double-buffered
    weight-gather blocks), compute ``dtype``, ``int8_kv``,
    ``accum_steps``, plus whitelisted ``Env`` overrides.

Every consumer derives from the plan: the step factories
(``plan=`` on ``make_train_step`` / ``make_prefill_step`` /
``make_decode_step`` / ``make_cnn_train_step``), the ``Env``
(:meth:`PrecisionPlan.make_env` is the one plan→Env constructor),
the trainer's schedule + wire log, checkpoints (the plan is persisted
next to the AWP state), and the roofline analyzers
(:meth:`PrecisionPlan.wire_table` is the per-entry byte account whose
numbers come from the same ``CompressionPolicy`` formulas the HLO
analyzers charge compiled collectives with).

Invalid plans raise :class:`ValueError` at *construction* — never at
trace time.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping

import jax.numpy as jnp

from repro.core.awp import AWPConfig
from repro.transport import CompressionPolicy, policy_for
from repro.transport.policy import FP32_BYTES

TRAFFIC_CLASSES = (
    "weights", "gradients", "activations", "seq_boundary", "host_device",
    "kv_migration", "weight_publish",
)
VALID_SCHEDULES = ("static", "awp")
VALID_DTYPES = ("f32", "bf16")
# Env knobs a plan may override beyond the fields it owns outright
ENV_OVERRIDE_KEYS = ("attn_chunk", "causal_skip", "mlstm_chunk")

_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def policy_uses_rng(p: CompressionPolicy) -> bool:
    """True when materializing/synchronizing under this policy packs
    planes with stochastic rounding *at its current widths* (which needs
    a PRNG key). The single definition shared by the step factories'
    key-threading decision."""
    return (p.mode == "stochastic" and p.round_to < FP32_BYTES) or (
        p.grad_mode == "stochastic" and p.grad_round_to < FP32_BYTES
    )


def _pol_configured_rng(p: CompressionPolicy) -> bool:
    """True when a stochastic mode is *configured* on either direction,
    regardless of the current widths. This is deliberately
    width-independent: under an AWP schedule ``with_round_tos`` swaps
    widths at runtime, and the step-function signature (trailing PRNG
    key) must not flip with them."""
    return p.mode == "stochastic" or p.grad_mode == "stochastic"


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Who decides the weight formats at runtime.

    ``static`` — the plan's weight entries are final (the paper's
    *oracle* policy; a uniform rt=4 plan is the fp32 baseline).
    ``awp`` — Algorithm 1 monitors Σw² per group and widens the weight
    entries; the controller hyper-parameters live here so one JSON file
    describes the whole run.
    """

    source: str = "static"
    awp_threshold: float = -2e-3
    awp_interval: int = 100
    awp_initial_bits: int = 8

    def __post_init__(self):
        if self.source not in VALID_SCHEDULES:
            raise ValueError(
                f"schedule source must be in {VALID_SCHEDULES}, "
                f"got {self.source!r}"
            )
        if self.awp_interval <= 0:
            raise ValueError("awp_interval must be positive")
        if self.awp_initial_bits % 8 or not (8 <= self.awp_initial_bits <= 32):
            raise ValueError("awp_initial_bits must be 8/16/24/32")

    def awp_config(self) -> AWPConfig:
        return AWPConfig(
            threshold=self.awp_threshold,
            interval=self.awp_interval,
            initial_bits=self.awp_initial_bits,
        )


def _coerce_policy(v) -> CompressionPolicy | None:
    if v is None or isinstance(v, CompressionPolicy):
        return v
    if isinstance(v, Mapping):
        return CompressionPolicy(**v)
    return policy_for(v)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling contract (the serve engine's PRNG surface).

    ``temperature == 0`` is greedy — the engine's fast path, byte-
    identical to the pre-sampling argmax pack. A positive temperature
    samples from the temperature-scaled softmax restricted to the
    ``top_k`` highest-probability ids (0 = unrestricted) and the
    smallest prefix of the sorted distribution whose *preceding*
    cumulative mass stays below ``top_p``.

    Determinism: the sampled id for the n-th emitted token of a request
    (0-based; the prefill's first token is n=0) is a pure function of
    ``(logits, seed, n)`` — the key is
    ``jax.random.fold_in(jax.random.PRNGKey(seed), n)`` — so streams
    are bit-reproducible under arrival-order permutations, slot reuse,
    and any batch companions, exactly like the greedy contract.
    """

    temperature: float = 0.0
    top_p: float = 1.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "temperature", float(self.temperature))
        object.__setattr__(self, "top_p", float(self.top_p))
        if self.temperature < 0.0:
            raise ValueError("temperature must be >= 0")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        if not isinstance(self.top_k, int) or self.top_k < 0:
            raise ValueError("top_k must be an int >= 0")
        if not isinstance(self.seed, int) or self.seed < 0:
            raise ValueError("seed must be a non-negative int")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """Declarative precision + layout plan (see module docstring)."""

    weights: tuple[CompressionPolicy, ...] = (CompressionPolicy(),)
    gradients: CompressionPolicy | None = None
    activations: CompressionPolicy | None = None
    seq_boundary: CompressionPolicy | None = None
    host_device: CompressionPolicy | None = None
    kv_migration: CompressionPolicy | None = None
    weight_publish: CompressionPolicy | None = None
    schedule: Schedule = dataclasses.field(default_factory=Schedule)
    # --- execution layout ------------------------------------------------
    seq_parallel: bool = False
    chunks: int = 1
    dtype: str = "f32"
    int8_kv: bool = False
    accum_steps: int = 1
    env_overrides: tuple[tuple[str, Any], ...] = ()
    # --- serving ---------------------------------------------------------
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams
    )
    spec_draft: str = ""
    spec_k: int = 4

    # ------------------------------------------------------------------
    def __post_init__(self):
        ws = self.weights
        if isinstance(ws, CompressionPolicy):
            ws = (ws,)
        ws = tuple(_coerce_policy(w) for w in ws)
        if not ws or any(w is None for w in ws):
            raise ValueError("plan needs at least one weights entry")
        object.__setattr__(self, "weights", ws)
        for name in ("gradients", "activations", "seq_boundary",
                     "host_device", "kv_migration", "weight_publish"):
            object.__setattr__(
                self, name, _coerce_policy(getattr(self, name))
            )
        if isinstance(self.schedule, Mapping):
            object.__setattr__(self, "schedule", Schedule(**self.schedule))
        if not isinstance(self.schedule, Schedule):
            raise ValueError("schedule must be a Schedule")
        if not isinstance(self.chunks, int) or self.chunks < 1:
            raise ValueError("chunks must be an int >= 1")
        if self.dtype not in VALID_DTYPES:
            raise ValueError(f"dtype must be in {VALID_DTYPES}")
        if not isinstance(self.accum_steps, int) or self.accum_steps < 1:
            raise ValueError("accum_steps must be an int >= 1")
        if isinstance(self.env_overrides, Mapping):
            object.__setattr__(
                self, "env_overrides",
                tuple(sorted(self.env_overrides.items())),
            )
        for k, _ in self.env_overrides:
            if k not in ENV_OVERRIDE_KEYS:
                raise ValueError(
                    f"unknown env override {k!r} (allowed: "
                    f"{ENV_OVERRIDE_KEYS})"
                )
        if isinstance(self.sampling, Mapping):
            object.__setattr__(
                self, "sampling", SamplingParams(**self.sampling)
            )
        if not isinstance(self.sampling, SamplingParams):
            raise ValueError("sampling must be a SamplingParams")
        if not isinstance(self.spec_draft, str):
            raise ValueError("spec_draft must be a draft name string")
        if not isinstance(self.spec_k, int) or self.spec_k < 1:
            raise ValueError("spec_k must be an int >= 1")
        # activation-path stochastic rounding has no PRNG plumbing (the
        # collectives sit inside TP-region custom VJPs): reject early
        for name in ("activations", "seq_boundary"):
            p = getattr(self, name)
            if p is not None and _pol_configured_rng(p):
                raise ValueError(
                    f"{name} policy cannot use stochastic rounding "
                    "(no PRNG path through the activation collectives); "
                    "use mode='nearest'"
                )
        # fleet fabric parcels are deterministic byte movements (KV
        # migration is lossless, weight publish reuses checkpoint
        # tiers): stochastic rounding has no PRNG path there either
        for name in ("kv_migration", "weight_publish"):
            p = getattr(self, name)
            if p is not None and _pol_configured_rng(p):
                raise ValueError(
                    f"{name} policy cannot use stochastic rounding "
                    "(fabric parcels are deterministic byte planes); "
                    "use mode='truncate' or 'nearest'"
                )

    # -- resolution ------------------------------------------------------
    @property
    def num_weight_groups(self) -> int:
        return len(self.weights)

    @property
    def round_tos(self) -> tuple[int, ...]:
        return tuple(w.round_to for w in self.weights)

    def broadcast(self, num_groups: int) -> "PrecisionPlan":
        """Expand a single weights entry to ``num_groups`` groups (a
        plan JSON need not know the architecture's group count)."""
        if len(self.weights) == num_groups:
            return self
        if len(self.weights) == 1:
            return dataclasses.replace(
                self, weights=self.weights * num_groups
            )
        raise ValueError(
            f"plan has {len(self.weights)} weight entries, "
            f"model needs {num_groups}"
        )

    def with_round_tos(self, round_tos) -> "PrecisionPlan":
        """Same plan with the weight formats replaced — how the AWP
        schedule materializes each widening as a new (cacheable) plan."""
        rts = tuple(int(r) for r in round_tos)
        ws = self.weights
        if len(ws) == 1 and len(rts) > 1:
            ws = ws * len(rts)
        if len(ws) != len(rts):
            raise ValueError(f"{len(rts)} round_tos for {len(ws)} entries")
        return dataclasses.replace(
            self,
            weights=tuple(
                dataclasses.replace(w, round_to=rt)
                for w, rt in zip(ws, rts)
            ),
        )

    def weight_policies(self) -> tuple[CompressionPolicy, ...]:
        """The fully-resolved per-group policies the transport runs:
        weight entries with the ``gradients`` entry folded into their
        grad fields and the plan's ``chunks`` applied."""
        out = []
        for w in self.weights:
            if self.gradients is not None:
                w = dataclasses.replace(
                    w,
                    grad_round_to=self.gradients.round_to,
                    grad_mode=self.gradients.mode,
                )
            if self.chunks != w.chunks:
                w = dataclasses.replace(w, chunks=self.chunks)
            out.append(w)
        return tuple(out)

    def seq_policy(self) -> CompressionPolicy | None:
        return (
            self.seq_boundary
            if self.seq_boundary is not None
            else self.activations
        )

    def host_device_policies(self) -> tuple[CompressionPolicy, ...]:
        if self.host_device is not None:
            return (self.host_device,) * len(self.weights)
        return self.weights

    def kv_migration_policy(self) -> CompressionPolicy:
        """Policy pricing prefill→decode KV page parcels on the fleet
        fabric. Defaults to the ``host_device`` chain: migrated pages
        are the same staged-bytes class crossing a replica boundary."""
        if self.kv_migration is not None:
            return self.kv_migration
        return self.host_device_policies()[0]

    def weight_publish_policy(self) -> CompressionPolicy:
        """Policy pricing trainer→replica weight parcels. Defaults to
        the first weights entry (published planes ride the checkpoint
        wire tiers at the same widths the gathers use)."""
        if self.weight_publish is not None:
            return self.weight_publish
        return self.weights[0]

    @property
    def compute_dtype(self):
        return _DTYPES[self.dtype]

    @property
    def needs_rng(self) -> bool:
        """True when the step functions must be fed a PRNG key (a
        stochastic mode is configured on the weight/gradient path).

        Width-independent on purpose: ``with_round_tos`` must never flip
        the step signature, or an AWP widening would break the caller's
        key-passing convention mid-run. A policy that is stochastic but
        currently uncompressed simply ignores its key."""
        return any(_pol_configured_rng(p) for p in self.weight_policies())

    def awp_config(self) -> AWPConfig | None:
        if self.schedule.source != "awp":
            return None
        return self.schedule.awp_config()

    # -- the one plan -> Env constructor ---------------------------------
    def make_env(self, mesh_cfg, *, seq_parallel: bool | None = None):
        """Build the execution :class:`~repro.models.env.Env` — the
        single replacement for the three env-kwarg merging helpers the
        train / serve / cnn steps used to carry."""
        from repro.models.env import Env

        return Env(
            model_axis=mesh_cfg.model_axis if mesh_cfg.tp > 1 else None,
            fsdp_axes=mesh_cfg.fsdp_axes if mesh_cfg.dshards > 1 else None,
            tp=mesh_cfg.tp,
            dtype=self.compute_dtype,
            act_policy=self.activations,
            seq_policy=self.seq_boundary,
            seq_parallel=(
                self.seq_parallel if seq_parallel is None else seq_parallel
            ),
            int8_kv=self.int8_kv,
            **dict(self.env_overrides),
        )

    # -- per-entry wire accounting ---------------------------------------
    def wire_table(
        self,
        dist_elems_per_group,
        gather_axis_size: int,
        *,
        training: bool = True,
        tp: int = 1,
        act_elems: int = 0,
        act_collectives: int = 0,
    ) -> dict:
        """Per-traffic-class wire bytes of ONE step — the plan as the
        unit of cost accounting.

        Every number comes from the corresponding
        :class:`~repro.transport.CompressionPolicy` formula
        (``all_gather_wire_bytes`` / ``reduce_scatter_wire_bytes`` /
        ``all_reduce_wire_bytes`` / ``seq_pair_wire_bytes`` /
        ``host_device_bytes``) so the table cannot drift from what the
        HLO analyzers charge compiled collectives.

        ``dist_elems_per_group`` — global compressed element count per
        precision group (see ``repro.dist.spec.dist_elems_per_group``).
        ``gather_axis_size`` — FSDP shards; ``<= 1`` selects the paper's
        host→device staging model instead of the gather entries.
        ``act_elems`` × ``act_collectives`` — gathered activation element
        count and number of TP-region boundaries per step (optional; the
        activation entries report 0 when unknown).
        """
        pols = self.weight_policies()
        elems = list(dist_elems_per_group)
        if len(elems) != len(pols):
            raise ValueError(
                f"{len(elems)} group element counts for {len(pols)} "
                "weight entries"
            )
        n = int(gather_axis_size)
        table = {k: 0 for k in TRAFFIC_CLASSES}
        if n > 1:
            for pol, e in zip(pols, elems):
                table["weights"] += pol.all_gather_wire_bytes(e // n, n)
                if training:
                    table["gradients"] += pol.reduce_scatter_wire_bytes(
                        e // n, n
                    )
        else:
            for pol, e in zip(self.host_device_policies(), elems):
                table["host_device"] += pol.host_device_bytes(e)
        if act_collectives and act_elems and tp > 1:
            act = self.activations or CompressionPolicy()
            seq = self.seq_policy() or CompressionPolicy()
            if self.seq_parallel:
                table["seq_boundary"] = act_collectives * seq.seq_pair_wire_bytes(
                    act_elems, tp
                )
            else:
                table["activations"] = act_collectives * act.all_reduce_wire_bytes(
                    act_elems, tp
                )
        table["total"] = sum(table[k] for k in TRAFFIC_CLASSES)
        return table

    # -- serialization ---------------------------------------------------
    def to_json_dict(self) -> dict:
        def pol(p):
            return None if p is None else dataclasses.asdict(p)

        return {
            "version": 1,
            "weights": [pol(w) for w in self.weights],
            "gradients": pol(self.gradients),
            "activations": pol(self.activations),
            "seq_boundary": pol(self.seq_boundary),
            "host_device": pol(self.host_device),
            "kv_migration": pol(self.kv_migration),
            "weight_publish": pol(self.weight_publish),
            "schedule": dataclasses.asdict(self.schedule),
            "seq_parallel": self.seq_parallel,
            "chunks": self.chunks,
            "dtype": self.dtype,
            "int8_kv": self.int8_kv,
            "accum_steps": self.accum_steps,
            "env_overrides": dict(self.env_overrides),
            "sampling": dataclasses.asdict(self.sampling),
            "spec_draft": self.spec_draft,
            "spec_k": self.spec_k,
        }

    @classmethod
    def from_json_dict(cls, d: Mapping) -> "PrecisionPlan":
        d = dict(d)
        version = d.pop("version", 1)
        if version != 1:
            raise ValueError(f"unknown plan version {version!r}")
        ws = d.pop("weights", None)
        if ws is None:
            raise ValueError("plan JSON needs a 'weights' entry")
        if isinstance(ws, Mapping):
            ws = [ws]
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown plan fields {sorted(unknown)}")
        return cls(weights=tuple(ws), **d)

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_json_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "PrecisionPlan":
        return cls.from_json_dict(json.loads(text))

    def to_file(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def from_file(cls, path: str) -> "PrecisionPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- builder sugar ---------------------------------------------------
    @classmethod
    def build(
        cls,
        num_groups: int = 1,
        round_to: int = 4,
        *,
        mode: str = "truncate",
        impl: str = "auto",
        grad_round_to: int | None = None,
        grad_mode: str = "nearest",
        act_round_to: int = 4,
        act_mode: str = "nearest",
        seq_parallel: bool = False,
        chunks: int = 1,
        dtype: str = "f32",
        int8_kv: bool = False,
        accum_steps: int = 1,
        schedule: str = "static",
        awp_threshold: float = -2e-3,
        awp_interval: int = 100,
        awp_initial_bits: int = 8,
        env_overrides=(),
    ) -> "PrecisionPlan":
        """The CLI flag → plan builder both launchers use: every legacy
        knob maps onto exactly one plan field."""
        gradients = None
        if grad_round_to is not None and (
            grad_round_to != 4 or grad_mode != "nearest"
        ):
            gradients = CompressionPolicy(
                round_to=int(grad_round_to), mode=grad_mode, impl=impl
            )
        activations = None
        if act_round_to < FP32_BYTES:
            activations = CompressionPolicy(
                round_to=int(act_round_to),
                grad_round_to=int(act_round_to),
                mode=act_mode,
                grad_mode=act_mode,
                impl=impl,
            )
        return cls(
            weights=(CompressionPolicy(
                round_to=int(round_to), mode=mode, impl=impl
            ),) * num_groups,
            gradients=gradients,
            activations=activations,
            schedule=Schedule(
                source=schedule,
                awp_threshold=awp_threshold,
                awp_interval=awp_interval,
                awp_initial_bits=awp_initial_bits,
            ),
            seq_parallel=seq_parallel,
            chunks=chunks,
            dtype=dtype,
            int8_kv=int8_kv,
            accum_steps=accum_steps,
            env_overrides=env_overrides,
        )
