"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: RMSNorm → two branches:
  y-branch: linear → GeLU
  x-branch: linear → temporal conv1d(width 4) → RG-LRU
merge: y ⊙ h → down projection.

RG-LRU recurrence (elementwise over the recurrence width r):
  r_t = σ(x_t W_a + b_a)          (recurrence gate)
  i_t = σ(x_t W_i + b_i)          (input gate)
  log a_t = −c · softplus(Λ) · r_t            (c = 8)
  h_t = a_t · h_{t−1} + √(1 − a_t²) · (i_t ⊙ x̃_t)

The recurrence is a linear scan → ``lax.associative_scan`` (log-depth) for
train/prefill and an O(1) state update for decode. Elementwise over the
channel dim ⇒ the recurrence TP-shards over the model axis cleanly; the
gates read the *block input* (model-replicated) so their weights are
column-parallel (deviation from Griffin's block-diagonal gates, noted in
DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.env import Env
from repro.models.layers import rms_norm

_C = 8.0


def _conv1d(x: jnp.ndarray, conv_w: jnp.ndarray, conv_b: jnp.ndarray,
            conv_state: jnp.ndarray | None, mode: str):
    """Causal depthwise temporal conv. x: (B,S,r); conv_w: (W,r).

    Returns (y, new_conv_state (B, W-1, r))."""
    B, S, r = x.shape
    W = conv_w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, r), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)  # (B, S+W-1, r)
    y = sum(xp[:, i : i + S] * conv_w[i][None, None, :] for i in range(W))
    y = y + conv_b[None, None, :]
    new_state = xp[:, -(W - 1):] if W > 1 else conv_state
    return y, new_state


def rglru_block(x, w, cfg, env: Env, *, mode="train", state=None):
    """x: (B,S,d) -> (y, state'). state = (h (B,r_l), conv (B,W-1,r_l)).

    w keys: ln, w_x (d,r_l), w_y (d,r_l), conv_w (W,r_l), conv_b (r_l,),
    w_a (d,r_l), b_a, w_i (d,r_l), b_i, lam (r_l,), w_down (r_l, d).

    Under ``env.seq_parallel`` the incoming ``x`` is a sequence shard;
    ``env.enter`` gathers the full sequence (the linear recurrence scans
    over time) and ``env.exit`` reduce-scatters the partial outputs."""
    xn = rms_norm(x, w["ln"], cfg.norm_eps)
    xin = env.enter(xn)
    B, S = xin.shape[:2]

    yb = jax.nn.gelu(xin @ w["w_y"], approximate=True)
    xb = xin @ w["w_x"]
    h_prev, conv_state = state if state is not None else (None, None)
    xb, conv_state = _conv1d(xb, w["conv_w"], w["conv_b"], conv_state, mode)

    r_gate = jax.nn.sigmoid(xin @ w["w_a"] + w["b_a"])
    i_gate = jax.nn.sigmoid(xin @ w["w_i"] + w["b_i"])
    log_a = -_C * jax.nn.softplus(w["lam"])[None, None, :] * r_gate  # (B,S,r)
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_gate * xb)

    if mode == "decode":
        if S != 1:
            raise ValueError(f"decode expects a single token, got S={S}")
        if h_prev is None:
            h_prev = jnp.zeros((B, a.shape[-1]), x.dtype)
        h = a[:, 0] * h_prev + gated_x[:, 0]
        hs = h[:, None]
        new_state = (h, conv_state)
    else:
        if h_prev is not None:
            # fold carried state into the first step
            gated_x = gated_x.at[:, 0].add(a[:, 0] * h_prev)

        def op(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        _, hs = lax.associative_scan(op, (a, gated_x), axis=1)
        new_state = (hs[:, -1], conv_state)

    y = env.exit((yb[:, : hs.shape[1]] * hs) @ w["w_down"])
    return y, new_state
