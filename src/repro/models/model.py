"""Model assembly: embedding → scanned layer groups → head / loss.

Every architecture family routes through ``run_group`` — the per-group
layer scan whose body materializes that layer's weights (via the caller's
``mat_fn``: identity single-device, compressed FSDP gather distributed) and
applies the pattern's blocks. The same code path serves train, prefill and
decode; caches/states are stacked per group and scanned alongside params.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.attention import (
    KVCache,
    PagedKVCache,
    PagedQuantKVCache,
    QuantKVCache,
    check_cache_geometry,
    init_cache,
    init_paged_cache,
    mha,
)
from repro.models.env import Env
from repro.models.layers import embed_lookup_vp, rms_norm
from repro.models.loss import lm_loss
from repro.models.mlp import gelu_mlp, swiglu
from repro.models.moe import moe_block
from repro.models.rglru import rglru_block
from repro.models.init import eff_vocab


def _channel_mix(x, w, cfg: ModelConfig, env: Env):
    """Post-attention channel mixer -> (delta, aux_loss)."""
    if "mix" not in w:
        return jnp.zeros_like(x), 0.0
    wm = w["mix"]
    xn = rms_norm(x, wm["ln"], cfg.norm_eps)
    if cfg.num_experts:
        y, aux = moe_block(xn, wm, cfg, env)
        return y, aux
    if cfg.arch_type == "audio":
        return gelu_mlp(xn, wm, env), 0.0
    return swiglu(xn, wm, env), 0.0


def apply_block(
    kind: str,
    x: jnp.ndarray,
    w: dict,
    cfg: ModelConfig,
    env: Env,
    *,
    mode: str,
    cache: Any = None,
    img_kv: Optional[jnp.ndarray] = None,
    window_override: Optional[int] = None,
    pos_offset=0,
    page_table: Optional[jnp.ndarray] = None,
):
    """One block of the pattern. Returns (x', cache', aux)."""
    aux = 0.0
    if kind in ("attn", "local", "cross"):
        wa = w["attn"]
        window = cfg.sliding_window if kind == "local" else (
            cfg.sliding_window if cfg.sliding_window else None
        )
        if window_override is not None and kind != "cross":
            window = window_override if window is None else min(window, window_override)
        xn = rms_norm(x, wa["ln"], cfg.norm_eps)
        if kind == "cross":
            y, cache = mha(
                xn, wa, cfg, env, mode=mode, cache=cache,
                kv_ext=img_kv, is_cross=True, pos_offset=pos_offset,
            )
        else:
            y, cache = mha(
                xn, wa, cfg, env, mode=mode, cache=cache,
                window=window, pos_offset=pos_offset,
                page_table=page_table,
            )
        x = x + y
        dy, aux = _channel_mix(x, w, cfg, env)
        x = x + dy
    elif kind == "mlstm":
        y, cache = ssm.mlstm_block(x, w["mlstm"], cfg, env, mode=mode, state=cache)
        x = x + y
    elif kind == "slstm":
        y, cache = ssm.slstm_block(x, w["slstm"], cfg, env, mode=mode, state=cache)
        x = x + y
    elif kind == "rglru":
        y, cache = rglru_block(x, w["rglru"], cfg, env, mode=mode, state=cache)
        x = x + y
        dy, _ = _channel_mix(x, w, cfg, env)
        x = x + dy
    else:
        raise ValueError(kind)
    return x, cache, aux


def run_group(
    x: jnp.ndarray,
    group_params: dict,      # {p<i>: stacked (R, ...) param trees}
    cfg: ModelConfig,
    env: Env,
    *,
    mode: str,
    mat_fn: Callable[[str, dict], dict],  # (pattern key, rep storage) -> weights
    caches: Any = None,      # {p<i>: stacked cache trees} or None
    img_kv: Optional[jnp.ndarray] = None,
    window_override: Optional[int] = None,
    pos_offset=0,
    page_table: Optional[jnp.ndarray] = None,
):
    """Scan the group's pattern repetitions. Returns (x, caches', aux)."""
    pat = cfg.pattern

    def body(carry, per_rep):
        xc, aux_acc = carry
        p_rep, c_rep = per_rep
        new_caches = {}
        for pi, kind in enumerate(pat):
            w = mat_fn(f"p{pi}", p_rep[f"p{pi}"])
            c_in = c_rep[f"p{pi}"] if c_rep is not None else None
            xc, c_out, aux = apply_block(
                kind, xc, w, cfg, env, mode=mode, cache=c_in,
                img_kv=img_kv, window_override=window_override,
                pos_offset=pos_offset, page_table=page_table,
            )
            new_caches[f"p{pi}"] = c_out
            aux_acc = aux_acc + aux
        return (xc, aux_acc), new_caches

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body)

    xs = (group_params, caches)
    if cfg.scan_layers:
        # scan needs a uniform xs tree; when caches is None build a None-free
        # placeholder by scanning params only
        if caches is None:
            def body_nc(carry, p_rep):
                return body(carry, (p_rep, None))[0], None

            if cfg.remat and mode == "train":
                body_nc = jax.checkpoint(body_nc)
            (x, aux), _ = lax.scan(body_nc, (x, 0.0), group_params)
            return x, None, aux
        (x, aux), new_caches = lax.scan(body, (x, 0.0), xs)
        return x, new_caches, aux

    # unrolled path (smoke tests / tiny models)
    reps = jax.tree_util.tree_leaves(group_params)[0].shape[0]
    aux_total = 0.0
    out_caches = []
    for rep in range(reps):
        p_rep = jax.tree_util.tree_map(lambda a: a[rep], group_params)
        c_rep = (
            jax.tree_util.tree_map(lambda a: a[rep], caches)
            if caches is not None
            else None
        )
        (x, aux_total), c_out = body((x, aux_total), (p_rep, c_rep))
        out_caches.append(c_out)
    if caches is not None:
        out_caches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, 0), *out_caches
        )
    else:
        out_caches = None
    return x, out_caches, aux_total


# ---------------------------------------------------------------------------
# end-to-end forwards
# ---------------------------------------------------------------------------


def _embed(params, batch, cfg: ModelConfig, env: Env, mat_top):
    """Token/feature embedding in the env's activation layout: under
    ``env.seq_parallel`` the result is a sequence shard — the
    vocab-parallel psum becomes a reduce-scatter (via ``env.exit`` inside
    ``embed_lookup_vp``, halving its wire bytes) and the replicated
    feature stub is sliced."""
    if cfg.embed_is_input_stub:
        w = mat_top("embed_in")
        return env.seq_shard(batch["features"] @ w)
    table = mat_top("embed")  # (V_local, d)
    V = eff_vocab(cfg, env.tp)
    vloc = V // env.tp if env.tp > 1 else V
    vocab_start = env.model_rank() * vloc
    return embed_lookup_vp(batch["tokens"], table, vocab_start, env)


def _img_kv(params, batch, cfg: ModelConfig, env: Env, mat_top):
    if not cfg.num_image_tokens:
        return None
    proj = mat_top("img_proj")
    return batch["image_features"] @ proj  # (B, N, d)


def _logits(x, params, cfg: ModelConfig, env: Env, mat_top):
    """Final norm + vocab-parallel logits entry. Under ``env.seq_parallel``
    the final norm runs on the sequence shard and ``env.enter`` gathers
    the full sequence into the vocab-sharded matmul, so the output layout
    matches the replicated path exactly."""
    x = rms_norm(x, mat_top("final_norm"), cfg.norm_eps)
    if cfg.tie_embeddings:
        table = mat_top("embed")
        logits = env.enter(x) @ table.T
    else:
        head = mat_top("head")
        logits = env.enter(x) @ head
    return logits  # (B, S, V_local) — vocab-sharded when tp > 1


def forward_loss(
    params,
    batch: dict,
    cfg: ModelConfig,
    env: Env,
    *,
    mat_group: Callable[[int, dict], dict],  # (group_idx, rep storage) -> weights
    mat_top: Callable[[str], Any],
):
    """Training forward: mean LM/frame NLL + MoE aux. Returns (loss, metrics)."""
    x = _embed(params, batch, cfg, env, mat_top).astype(env.dtype)
    img_kv = _img_kv(params, batch, cfg, env, mat_top)
    aux_total = 0.0
    for g, gp in enumerate(params["groups"]):
        x, _, aux = run_group(
            x, gp, cfg, env, mode="train",
            mat_fn=functools.partial(mat_group, g), img_kv=img_kv,
        )
        aux_total = aux_total + aux
    logits = _logits(x, params, cfg, env, mat_top)
    V = eff_vocab(cfg, env.tp)
    vloc = logits.shape[-1]
    vocab_start = env.model_rank() * vloc if env.tp > 1 else 0
    nll_sum, count = lm_loss(
        logits, batch["labels"], env, vocab_start, cfg.vocab_size
    )
    # mean over *global* tokens happens in the train step (psum of both)
    loss_local = nll_sum
    metrics = {"nll_sum": nll_sum, "token_count": count, "aux": aux_total}
    return loss_local, metrics


def forward_prefill(params, batch, cfg, env, *, mat_group, mat_top,
                    cache_capacity, window_override=None):
    """Prefill: returns (last-token logits, caches per group).

    ``batch["last"]`` (scalar int32, optional) marks the last *real*
    token when the prompt is right-padded to a page-bucket length: the
    logits are read there instead of at ``S - 1``. Padding is causal-
    safe for pure-attention patterns only (the serve engine gates
    bucketing accordingly); ``last`` requires the replicated layout
    (no ``seq_parallel``), since an arbitrary position cannot be
    gathered off one sequence shard."""
    x = _embed(params, batch, cfg, env, mat_top).astype(env.dtype)
    img_kv = _img_kv(params, batch, cfg, env, mat_top)
    B, S = x.shape[:2]
    caches = init_caches(cfg, env, B, cache_capacity, env.dtype,
                         context=S, window_override=window_override)
    new_caches = []
    for g, gp in enumerate(params["groups"]):
        x, c, _ = run_group(
            x, gp, cfg, env, mode="prefill",
            mat_fn=functools.partial(mat_group, g),
            caches=caches[g], img_kv=img_kv,
        )
        new_caches.append(c)
    if env.seq_parallel_active:
        if "last" in batch:
            raise ValueError(
                "batch['last'] (bucketed prefill) requires the replicated "
                "layout: disable seq_parallel for padded prompts"
            )
        # gather only each shard's LAST token (B, tp, d) — the global last
        # token is the final rank's — instead of the full residual stream;
        # the logits entry then runs replicated (a (B,1,d) slice can't shard)
        x = env.seq_unshard(x[:, -1:])
        env = env.without_seq_parallel()
        x_last = x[:, -1:]
    elif "last" in batch:
        x_last = lax.dynamic_slice_in_dim(x, batch["last"], 1, axis=1)
    else:
        x_last = x[:, -1:]
    logits = _logits(x_last, params, cfg, env, mat_top)
    return logits, new_caches


def forward_decode(params, batch, caches, cfg, env, *, mat_group, mat_top,
                   window_override=None):
    """One-token decode step. batch['tokens']: (B, 1). Returns (logits, caches').

    Decode has no sequence dim to shard: ``seq_parallel`` envs fall back
    to the replicated psum layout for this path (caches are full-sequence
    either way, so prefill-under-seq-parallel hands off transparently)."""
    env = env.without_seq_parallel()
    x = _embed(params, batch, cfg, env, mat_top).astype(env.dtype)
    pos = batch["pos"]  # () int32 — tokens absorbed so far
    page_table = batch.get("page_table")  # (B, n_pages) — paged engine only
    new_caches = []
    for g, gp in enumerate(params["groups"]):
        x, c, _ = run_group(
            x, gp, cfg, env, mode="decode",
            mat_fn=functools.partial(mat_group, g),
            caches=caches[g], window_override=window_override,
            pos_offset=pos, page_table=page_table,
        )
        new_caches.append(c)
    logits = _logits(x, params, cfg, env, mat_top)
    return logits, new_caches


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def _block_cache(kind, cfg: ModelConfig, env: Env, batch, capacity, dtype,
                 per_slot: bool = False, context=None, window_override=None):
    hd = cfg.head_dim
    if kind in ("attn", "local"):
        kv_l = env.heads_local(cfg.num_kv_heads)
        cap = capacity
        if kind == "local" and cfg.sliding_window:
            cap = min(capacity, cfg.sliding_window)
        # the same window selection apply_block will use at runtime, so
        # the construction-time geometry guard sees the real mask
        window = cfg.sliding_window if cfg.sliding_window else None
        if window_override is not None:
            window = (
                window_override if window is None
                else min(window, window_override)
            )
        kv_dtype = jnp.int8 if env.int8_kv else dtype
        return init_cache(batch, cap, kv_l, hd, kv_dtype, per_slot=per_slot,
                          window=window, context=context)
    if kind == "cross":
        kv_l = env.heads_local(cfg.num_kv_heads)
        return init_cache(batch, max(cfg.num_image_tokens, 1), kv_l, hd, dtype)
    if kind == "mlstm":
        dv = int(cfg.mlstm_proj_factor * cfg.d_model)
        dv_l = env.ff_local(dv)
        dk = dv // cfg.num_heads
        return ssm.init_mlstm_state(batch, cfg.num_heads, dk, dv_l // cfg.num_heads, dtype)
    if kind == "slstm":
        return ssm.init_slstm_state(batch, cfg.d_model, dtype)
    if kind == "rglru":
        r = cfg.lru_dim or cfg.d_model
        r_l = env.ff_local(r)
        h = jnp.zeros((batch, r_l), dtype)
        conv = jnp.zeros((batch, cfg.conv1d_width - 1, r_l), dtype)
        return (h, conv)
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, env: Env, batch: int, capacity: int, dtype,
                per_slot: bool = False, *, context=None,
                window_override=None):
    """Stacked caches per group: groups[g][p<i>] leading dim = repetitions.

    ``per_slot=True`` builds the serve engine's slotted layout: KV caches
    carry a ``(reps, batch)`` position vector so every request (slot)
    tracks its own absorbed-token count independently.

    ``context`` (tokens the caches will absorb, when known) arms the
    construction-time :func:`~repro.models.attention.check_cache_geometry`
    guard with the effective window (``sliding_window`` merged with
    ``window_override`` exactly as ``apply_block`` merges them)."""
    pat = cfg.pattern
    reps = cfg.layers_per_group // len(pat)
    groups = []
    for g in range(cfg.num_groups):
        entry = {}
        for pi, kind in enumerate(pat):
            one = _block_cache(kind, cfg, env, batch, capacity, dtype,
                               per_slot=per_slot, context=context,
                               window_override=window_override)
            entry[f"p{pi}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), one
            )
        groups.append(entry)
    return groups


def init_paged_caches(cfg: ModelConfig, env: Env, batch: int, num_pages: int,
                      page_size: int, dtype):
    """Paged twin of ``init_caches(per_slot=True)``: every plain "attn"
    block gets a shared page pool (:func:`init_paged_cache`) instead of a
    per-slot contiguous array; recurrent/state kinds keep their slotted
    layout (their state is O(1) per slot — nothing to page). Sliding
    ("local") and cross blocks have no paged variant: rings and static
    image KV stay contiguous."""
    pat = cfg.pattern
    reps = cfg.layers_per_group // len(pat)
    groups = []
    for g in range(cfg.num_groups):
        entry = {}
        for pi, kind in enumerate(pat):
            if kind == "attn":
                kv_l = env.heads_local(cfg.num_kv_heads)
                kv_dtype = jnp.int8 if env.int8_kv else dtype
                one = init_paged_cache(
                    batch, num_pages, page_size, kv_l, cfg.head_dim, kv_dtype
                )
            elif kind in ("local", "cross"):
                raise ValueError(
                    f"{kind!r} blocks have no paged layout (sliding-window "
                    "rings and image KV stay contiguous)"
                )
            else:
                one = _block_cache(kind, cfg, env, batch, 1, dtype,
                                   per_slot=True)
            entry[f"p{pi}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), one
            )
        groups.append(entry)
    return groups
