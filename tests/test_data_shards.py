"""Tiered record shards + prefetcher invariants.

Property-tested (real hypothesis, or the in-repo stub on offline
containers):

  * write→read round-trips are **bitwise** at full quality for arbitrary
    dtypes/shapes/codecs — including the lead-trimmed lossless integer
    path and special float values;
  * the quality knob reads exactly the manifest's priced byte planes:
    a quality-q float comes back as its q most-significant-plane
    truncation, integers ignore quality entirely;
  * iteration is deterministic in (seed, epoch) and resumable through a
    JSON round-trip of ``ShardReader.state()`` — the batch stream
    replays bit-exactly from any boundary;
  * measured bytes (reader counter, prefetcher h2d log) equal the pure
    manifest/policy arithmetic (``planned_bytes``,
    ``token_host_bytes``) — the same pin the train-I/O scenario applies
    end-to-end.
"""
import json
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.pipeline import synthetic_lm_batch
from repro.data.prefetch import Prefetcher, staged_ids_per_batch
from repro.data.shards import (
    ShardReader, ShardWriter, batches, write_feature_shards,
    write_lm_shards,
)
from repro.transport import CompressionPolicy
from repro.utils.planes import lead_zero_planes, plane_join, plane_split

DTYPES = ["<f4", "<i4", "<u1", "<i8", "<f8", "<u2"]


def _arr(seed: int, dtype: str, n: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    dt = np.dtype(dtype)
    if dt.kind == "f":
        a = rng.normal(0, 1e3, n).astype(dt)
        # salt in specials: truncation must preserve them bitwise too
        if n:
            a[rng.integers(0, n)] = np.inf
        if n > 1:
            a[rng.integers(0, n)] = 0.0
        return a
    hi = min(int(np.iinfo(dt).max), 1 << 20)
    return rng.integers(0, hi, n).astype(dt)


# ---------------------------------------------------------------------------
# planes codec
# ---------------------------------------------------------------------------


@settings(max_examples=30)
@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from(DTYPES),
    st.integers(1, 257),
)
def test_plane_split_join_bitwise(seed, dtype, n):
    a = _arr(seed, dtype, n)
    planes = plane_split(a)
    assert planes.shape == (a.dtype.itemsize, n)
    b = plane_join(planes, a.dtype, a.shape)
    np.testing.assert_array_equal(a.view(np.uint8), b.view(np.uint8))


@settings(max_examples=20)
@given(st.integers(0, 2**31 - 1), st.integers(1, 127))
def test_lead_trim_lossless(seed, n):
    """Trimming all-zero MSB planes + zero-fill on join is identity."""
    a = _arr(seed, "<i4", n) % 4096  # fits 2 bytes -> 2 trimmed planes
    planes = plane_split(a)
    skip = lead_zero_planes(planes)
    assert skip >= 2
    b = plane_join(planes[skip:], a.dtype, a.shape, lead_skip=skip)
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# shard round-trips
# ---------------------------------------------------------------------------


@settings(max_examples=15)
@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from(DTYPES),
    st.sampled_from(["raw", "zlib"]),
    st.integers(1, 65),
)
def test_shard_roundtrip_bitwise(seed, dtype, codec, n):
    # tempfile, not a pytest fixture: fixtures don't compose with @given
    # (neither real hypothesis' function-scope health check nor the stub)
    with tempfile.TemporaryDirectory() as out:
        recs = [
            {"x": _arr(seed + i, dtype, n).reshape(shape)}
            for i, shape in enumerate([(n,), (1, n), (n, 1)])
        ]
        w = ShardWriter(out, kind="t", codec=codec, records_per_shard=2)
        for r in recs:
            w.append(r)
        w.close()
        # quality counts MSB planes per float field: full fidelity for
        # the widest dtype here (f8) is 8 planes, not fp32's 4
        rd = ShardReader(out, quality=8)
        for i, r in enumerate(recs):
            got, nbytes = rd.read_record(i)
            np.testing.assert_array_equal(
                got["x"].view(np.uint8), r["x"].view(np.uint8)
            )
            assert nbytes == rd.record_stored_bytes(i)
        rd.close()


@settings(max_examples=15)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(1, 100))
def test_quality_tier_is_plane_truncation(seed, q, n):
    """A quality-q float read == keeping the q MSB planes, zeroing the
    rest; integer fields are bitwise regardless of q."""
    with tempfile.TemporaryDirectory() as out:
        f = _arr(seed, "<f4", n)
        i = _arr(seed + 1, "<i4", n)
        w = ShardWriter(out, kind="t", codec="raw")
        w.append({"f": f, "i": i})
        w.close()
        rd = ShardReader(out, quality=q)
        got, _ = rd.read_record(0)
        planes = plane_split(f)
        want = plane_join(planes[:q], f.dtype, f.shape)
        np.testing.assert_array_equal(
            got["f"].view(np.uint8), want.view(np.uint8)
        )
        np.testing.assert_array_equal(got["i"], i)
        rd.close()


def test_quality_bytes_monotonic(tmp_path):
    out = str(tmp_path / "mono")
    write_feature_shards(out, dim=8, vocab=64, seq=8, num_records=6)
    sizes = []
    for q in (1, 2, 3, 4):
        rd = ShardReader(out, quality=q)
        sizes.append(sum(rd.record_stored_bytes(i) for i in range(6)))
        rd.close()
    assert sizes == sorted(sizes) and sizes[0] < sizes[-1]


# ---------------------------------------------------------------------------
# deterministic, resumable iteration
# ---------------------------------------------------------------------------


@settings(max_examples=10)
@given(st.integers(-2**31, 2**31 - 1), st.integers(0, 17))
def test_resume_replays_exact_stream(seed, k):
    """Serialize state after k records (through JSON — the checkpoint
    carrier), restore into a fresh reader: identical continuation,
    including across the epoch wrap."""
    with tempfile.TemporaryDirectory() as out:
        write_lm_shards(out, vocab=256, seq=8, num_records=7)
        a = ShardReader(out, seed=seed)
        for _ in range(k):
            a.next_record()
        state = json.loads(json.dumps(a.state()))
        b = ShardReader(out, seed=0).load_state(state)
        for _ in range(10):  # 7 records -> crosses epochs
            ra, _ = a.next_record()
            rb, _ = b.next_record()
            np.testing.assert_array_equal(ra["stream"], rb["stream"])
        assert a.state() == b.state()
        a.close(), b.close()


def test_epoch_orders_differ_and_are_seed_stable(tmp_path):
    out = str(tmp_path / "ep")
    write_lm_shards(out, vocab=64, seq=4, num_records=32)
    a, b = ShardReader(out, seed=5), ShardReader(out, seed=5)
    ordA = [a.next_record()[0]["stream"][0] for _ in range(64)]
    ordB = [b.next_record()[0]["stream"][0] for _ in range(64)]
    assert ordA == ordB  # same seed: identical across epochs
    assert ordA[:32] != ordA[32:]  # epochs reshuffle
    c = ShardReader(out, seed=6)
    ordC = [c.next_record()[0]["stream"][0] for _ in range(32)]
    assert ordC != ordA[:32]  # different seed: different order
    for r in (a, b, c):
        r.close()


def test_planned_bytes_equals_measured(tmp_path):
    out = str(tmp_path / "pb")
    write_lm_shards(out, vocab=1 << 17, seq=16, num_records=9)
    rd = ShardReader(out, seed=3)
    for _ in range(4):
        rd.next_record()
    planned = rd.planned_bytes(12)  # wraps the 9-record epoch
    before = rd.bytes_read
    for _ in range(12):
        rd.next_record()
    assert rd.bytes_read - before == planned
    rd.close()


def test_batches_state_after_is_resume_boundary(tmp_path):
    out = str(tmp_path / "ba")
    write_lm_shards(out, vocab=128, seq=8, num_records=12)
    rd = ShardReader(out, seed=1)
    it = batches(rd, 4)
    b0, _, s0 = next(it)
    b1, _, _ = next(it)
    rd2 = ShardReader(out, seed=0).load_state(s0)
    b1r, _, _ = next(batches(rd2, 4))
    np.testing.assert_array_equal(b1["stream"], b1r["stream"])
    rd.close(), rd2.close()


# ---------------------------------------------------------------------------
# prefetcher
# ---------------------------------------------------------------------------


def test_prefetcher_lm_matches_generator_and_policy_bytes(tmp_path):
    """End of the ingest pipe == the generator it tokenized: shard write
    + tiered read + plane staging + device unpack reproduce
    synthetic_lm_batch bit-exactly, and the measured h2d bytes equal the
    policy formula at the compressed token width."""
    vocab, seq, n = 300, 12, 8
    out = str(tmp_path / "pf")
    write_lm_shards(out, vocab=vocab, seq=seq, num_records=n, seed=4)
    rd = ShardReader(out, seed=9)
    order = [int(r) for r in np.random.default_rng(
        [np.uint64(9), np.uint64(0)]).permutation(n)]
    plan_policy = CompressionPolicy(round_to=1)  # floor: vocab 300 -> 2B
    pf = Prefetcher(batches(rd, 2), kind="lm", vocab=vocab, plan=plan_policy)
    width = plan_policy.token_wire_width(vocab)
    assert width == 2
    for bi in range(n // 2):
        batch, log = pf.next()
        assert log["host_device"] == plan_policy.token_host_bytes(
            staged_ids_per_batch("lm", 2, seq), vocab
        )
        for row in range(2):
            rid = order[bi * 2 + row]
            t, l = synthetic_lm_batch(vocab, 1, seq, rid, seed=4)
            np.testing.assert_array_equal(
                np.asarray(batch["tokens"][row]), np.asarray(t[0])
            )
            np.testing.assert_array_equal(
                np.asarray(batch["labels"][row]), np.asarray(l[0])
            )
        assert log["data_state"]["pos"] == (bi + 1) * 2
    pf.close()
    rd.close()


def test_prefetcher_feature_floats_raw(tmp_path):
    out = str(tmp_path / "pff")
    dim, vocab, seq = 6, 40, 5
    write_feature_shards(out, dim=dim, vocab=vocab, seq=seq, num_records=4)
    rd = ShardReader(out, seed=0)
    pol = CompressionPolicy(round_to=1)
    pf = Prefetcher(batches(rd, 2), kind="feature", vocab=vocab, plan=pol)
    batch, log = pf.next()
    want = pol.token_host_bytes(
        staged_ids_per_batch("feature", 2, seq), vocab
    ) + 2 * seq * dim * 4  # labels packed + features raw fp32
    assert log["host_device"] == want
    assert batch["features"].shape == (2, seq, dim)
    pf.close()
    rd.close()


def test_prefetcher_finite_iterator_stops(tmp_path):
    out = str(tmp_path / "fin")
    write_lm_shards(out, vocab=64, seq=4, num_records=4)
    rd = ShardReader(out)

    def two_batches():
        it = batches(rd, 2)
        for _ in range(2):
            yield next(it)

    pf = Prefetcher(two_batches(), kind="lm", vocab=64)
    pf.next(), pf.next()
    with pytest.raises(StopIteration):
        pf.next()
    pf.close()
    rd.close()


def test_prefetcher_propagates_worker_error():
    def boom():
        raise RuntimeError("shard corrupted")
        yield  # pragma: no cover

    pf = Prefetcher(boom(), kind="lm", vocab=64)
    with pytest.raises(RuntimeError, match="shard corrupted"):
        pf.next()
    pf.close()


def test_reader_rejects_bad_args(tmp_path):
    with pytest.raises(ValueError):
        ShardWriter(str(tmp_path / "x"), kind="t", codec="lz4")
    out = str(tmp_path / "ok")
    write_lm_shards(out, vocab=16, seq=4, num_records=2)
    with pytest.raises(ValueError):
        ShardReader(out, quality=0)
