"""Small pytree / padding helpers used across the framework."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def tree_size_bytes(tree: Any) -> int:
    """Total byte size of all array leaves (works on ShapeDtypeStruct too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        total += math.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
    return total


def tree_count_params(tree: Any) -> int:
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(tree))


def round_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


def pad_to(x: jnp.ndarray, size: int, axis: int = 0) -> jnp.ndarray:
    """Zero-pad ``x`` along ``axis`` up to ``size``."""
    cur = x.shape[axis]
    if cur == size:
        return x
    if cur > size:
        raise ValueError(f"cannot pad axis {axis} from {cur} down to {size}")
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, size - cur)
    return jnp.pad(x, pads)


def flatten_dict(d: dict, prefix: str = "") -> dict:
    """{'a': {'b': x}} -> {'a/b': x}."""
    out = {}
    for k, v in d.items():
        key = f"{prefix}/{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten_dict(v, key))
        else:
            out[key] = v
    return out


def unflatten_dict(d: dict) -> dict:
    out: dict = {}
    for k, v in d.items():
        parts = k.split("/")
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out
