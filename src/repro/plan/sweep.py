"""Roofline sweep that picks the weight-gather chunk count for a plan.

The chunked gather (``CompressionPolicy.chunks > 1``, see
docs/transport.md §"Chunked double-buffered gather") splits a flat FSDP
shard into independent pack → all-gather → unpack block pipelines so the
wire time of block *k* overlaps the pack/unpack of block *k±1*. More
chunks buy more overlap but pay a per-collective launch latency, so
there is an interior optimum. This helper models the pipeline with the
same hardware constants as :mod:`repro.roofline.analysis` and returns
the argmin — the ``plan``-selected chunk count the launchers use for
``--chunks auto``.
"""
from __future__ import annotations

from repro.transport import CompressionPolicy, policy_for

# TPU v5e-class constants, kept in sync with repro.roofline.analysis
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link
COLLECTIVE_LATENCY = 5e-6   # s per collective launch (dispatch + sync)

CHUNK_CANDIDATES = (1, 2, 4, 8, 16)


def modeled_gather_time(
    s_loc: int, axis_size: int, policy: CompressionPolicy, chunks: int
) -> float:
    """Modeled seconds for one chunked compressed all-gather of an
    ``s_loc``-element fp32 shard over ``axis_size`` devices.

    Per block: pack touches the fp32 read + plane write, unpack the
    gathered planes + fp32 write (HBM term); the plane all-gather pays
    the policy's ring wire bytes (ICI term) plus a launch latency.
    Blocks double-buffer: total ≈ first pack + (chunks-1) overlapped
    stages + last unpack.
    """
    n = max(int(axis_size), 1)
    blk = s_loc / chunks
    pack_s = blk * (4 + policy.round_to) / HBM_BW
    unpack_s = n * blk * (policy.round_to + 4) / HBM_BW
    wire_s = (
        policy.all_gather_wire_bytes(max(int(blk), 1), n) / ICI_BW
        + COLLECTIVE_LATENCY
    )
    # fill (first pack) + steady state (wire overlaps neighbouring
    # pack/unpack) + drain (last unpack); chunks=1 degenerates to the
    # unoverlapped pack + wire + unpack sum
    stage = max(pack_s + unpack_s, wire_s)
    return pack_s + stage * (chunks - 1) + wire_s + unpack_s


def sweep_chunks(
    s_loc: int,
    axis_size: int,
    policy=2,
    candidates=CHUNK_CANDIDATES,
) -> dict[int, float]:
    """Modeled gather time per candidate chunk count (only candidates
    that divide ``s_loc`` — the transport falls back to the unchunked
    pipeline otherwise, so a non-dividing pick would be a silent no-op)."""
    pol = policy_for(policy)
    out = {}
    for c in candidates:
        if c >= 1 and s_loc % c == 0:
            out[c] = modeled_gather_time(s_loc, axis_size, pol, c)
    return out


def pick_chunks(
    s_loc: int,
    axis_size: int,
    policy=2,
    candidates=CHUNK_CANDIDATES,
) -> int:
    """The plan-selected chunk count: argmin of :func:`sweep_chunks`
    (1 when nothing divides, or when the gather is degenerate)."""
    if s_loc <= 0 or axis_size <= 1:
        return 1
    table = sweep_chunks(s_loc, axis_size, policy, candidates)
    if not table:
        return 1
    return min(table, key=table.get)
