"""Parameter initialization + metadata for every architecture family.

``init_params(cfg, key, tp)`` returns ``(params, metas)`` — two pytrees of
identical structure. Shapes are *global logical* (TP slicing happens in
``repro.dist``); head counts and vocab are padded up to TP divisibility
with zero-initialised padding (exactness argument in DESIGN.md §3).

``param_shapes(cfg, tp)`` produces the same structure as
``ShapeDtypeStruct``s with **zero allocation** — that is what the
production-size dry-runs lower against.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.meta import (
    ParamMeta, REPLICATED_BIG, REPLICATED_SMALL, SEQ_NORM,
)
from repro.utils.trees import round_up


def eff_heads(cfg: ModelConfig, tp: int) -> int:
    return round_up(cfg.num_heads, tp) if tp > 1 else cfg.num_heads


def eff_kv_heads(cfg: ModelConfig, tp: int) -> int:
    kv = cfg.num_kv_heads
    if tp > 1 and kv > tp and kv % tp:
        return round_up(kv, tp)
    return kv


def eff_vocab(cfg: ModelConfig, tp: int) -> int:
    return round_up(cfg.vocab_size, tp) if tp > 1 else cfg.vocab_size


class Maker:
    """Creates either concrete initialised arrays (key given) or
    ShapeDtypeStructs (key None) with one code path."""

    def __init__(self, key, num_layers: int):
        self.key = key
        self.num_layers = num_layers
        self._n = 0

    def fold(self, tag: int) -> "Maker":
        if self.key is None:
            return Maker(None, self.num_layers)
        return Maker(jax.random.fold_in(self.key, tag), self.num_layers)

    def _next_key(self):
        self._n += 1
        return jax.random.fold_in(self.key, self._n)

    def normal(self, shape, scale=0.02):
        if self.key is None:
            return jax.ShapeDtypeStruct(shape, jnp.float32)
        return scale * jax.random.normal(self._next_key(), shape, jnp.float32)

    def out_proj(self, shape):
        """Residual-branch output projection: 1/sqrt(2L)-scaled init."""
        return self.normal(shape, 0.02 / math.sqrt(2 * max(self.num_layers, 1)))

    def ones(self, shape):
        if self.key is None:
            return jax.ShapeDtypeStruct(shape, jnp.float32)
        return jnp.ones(shape, jnp.float32)

    def zeros(self, shape):
        if self.key is None:
            return jax.ShapeDtypeStruct(shape, jnp.float32)
        return jnp.zeros(shape, jnp.float32)

    def const(self, values: np.ndarray):
        if self.key is None:
            return jax.ShapeDtypeStruct(values.shape, jnp.float32)
        return jnp.asarray(values, jnp.float32)

    def masked_heads(self, w, real_heads, padded_heads, hd, dim):
        """Zero the padded head rows/cols so padding is mathematically inert."""
        if self.key is None or real_heads == padded_heads:
            return w
        n_real = real_heads * hd
        idx = np.arange(w.shape[dim])
        mask = jnp.asarray((idx < n_real).astype(np.float32))
        return w * (mask[None, :] if dim == 1 else mask[:, None])


def _attn_params(mk: Maker, cfg: ModelConfig, tp: int, is_cross: bool):
    d, hd = cfg.d_model, cfg.head_dim
    H, Kv = eff_heads(cfg, tp), eff_kv_heads(cfg, tp)
    p = {
        "wq": mk.masked_heads(mk.normal((d, H * hd)), cfg.num_heads, H, hd, 1),
        "wk": mk.normal((d, Kv * hd)),
        "wv": mk.normal((d, Kv * hd)),
        "wo": mk.masked_heads(mk.out_proj((H * hd, d)), cfg.num_heads, H, hd, 0),
        "ln": mk.ones((d,)),
    }
    m = {
        "wq": ParamMeta(tp_dim=1, tp_units=H),
        "wk": ParamMeta(tp_dim=1, tp_units=Kv),
        "wv": ParamMeta(tp_dim=1, tp_units=Kv),
        "wo": ParamMeta(tp_dim=0, tp_units=H),
        "ln": SEQ_NORM,
    }
    if cfg.qkv_bias:
        p["bq"] = mk.zeros((H * hd,))
        p["bk"] = mk.zeros((Kv * hd,))
        p["bv"] = mk.zeros((Kv * hd,))
        m["bq"] = ParamMeta(tp_dim=0, tp_units=H, compress=False)
        m["bk"] = ParamMeta(tp_dim=0, tp_units=Kv, compress=False)
        m["bv"] = ParamMeta(tp_dim=0, tp_units=Kv, compress=False)
    if cfg.qk_norm:
        p["q_norm"] = mk.ones((hd,))
        p["k_norm"] = mk.ones((hd,))
        m["q_norm"] = ParamMeta(tp_dim=None, compress=False, grad_sync_model=True)
        m["k_norm"] = ParamMeta(tp_dim=None, compress=False, grad_sync_model=True)
    if is_cross:
        p["gate"] = mk.zeros(())
        m["gate"] = ParamMeta(tp_dim=None, compress=False, grad_sync_model=True)
    return p, m


def _mlp_params(mk: Maker, cfg: ModelConfig, audio: bool):
    d, ff = cfg.d_model, cfg.d_ff
    if audio:
        p = {
            "ln": mk.ones((d,)),
            "w_up": mk.normal((d, ff)),
            "w_down": mk.out_proj((ff, d)),
        }
        m = {
            "ln": SEQ_NORM,
            "w_up": ParamMeta(tp_dim=1),
            "w_down": ParamMeta(tp_dim=0),
        }
        return p, m
    p = {
        "ln": mk.ones((d,)),
        "w_gate": mk.normal((d, ff)),
        "w_up": mk.normal((d, ff)),
        "w_down": mk.out_proj((ff, d)),
    }
    m = {
        "ln": SEQ_NORM,
        "w_gate": ParamMeta(tp_dim=1),
        "w_up": ParamMeta(tp_dim=1),
        "w_down": ParamMeta(tp_dim=0),
    }
    return p, m


def _moe_params(mk: Maker, cfg: ModelConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    if cfg.moe_impl == "ep":
        gate_meta = ParamMeta(tp_dim=0, tp_units=E)
        down_meta = ParamMeta(tp_dim=0, tp_units=E)
    else:
        gate_meta = ParamMeta(tp_dim=2)
        down_meta = ParamMeta(tp_dim=1)
    p = {
        "ln": mk.ones((d,)),
        "router": mk.normal((d, E)),
        "w_gate": mk.normal((E, d, ff)),
        "w_up": mk.normal((E, d, ff)),
        "w_down": mk.out_proj((E, ff, d)),
    }
    m = {
        "ln": SEQ_NORM,
        "router": ParamMeta(
            tp_dim=None, compress=d * E >= 65536, grad_sync_model=True
        ),
        "w_gate": gate_meta,
        "w_up": gate_meta,
        "w_down": down_meta,
    }
    if cfg.moe_dense_ff:
        dff = cfg.moe_dense_ff
        p["dense_gate"] = mk.normal((d, dff))
        p["dense_up"] = mk.normal((d, dff))
        p["dense_down"] = mk.out_proj((dff, d))
        m["dense_gate"] = ParamMeta(tp_dim=1)
        m["dense_up"] = ParamMeta(tp_dim=1)
        m["dense_down"] = ParamMeta(tp_dim=0)
    return p, m


def _mlstm_params(mk: Maker, cfg: ModelConfig):
    d = cfg.d_model
    dv = int(cfg.mlstm_proj_factor * d)
    H = cfg.num_heads
    p = {
        "ln": mk.ones((d,)),
        "wq": mk.normal((d, dv)),
        "wk": mk.normal((d, dv)),
        "wv": mk.normal((d, dv)),
        "wi": mk.normal((d, H)),
        "wf": mk.normal((d, H)),
        "wog": mk.normal((d, dv)),
        "w_down": mk.out_proj((dv, d)),
    }
    m = {
        "ln": SEQ_NORM,
        "wq": ParamMeta(tp_dim=None, grad_sync_model=True),  # full keys on every rank
        "wk": ParamMeta(tp_dim=None, grad_sync_model=True),
        "wv": ParamMeta(tp_dim=1),
        "wi": ParamMeta(tp_dim=None, compress=False, grad_sync_model=True),
        "wf": ParamMeta(tp_dim=None, compress=False, grad_sync_model=True),
        "wog": ParamMeta(tp_dim=1),
        "w_down": ParamMeta(tp_dim=0),
    }
    return p, m


def _slstm_params(mk: Maker, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    p = {
        "ln": mk.ones((d,)),
        "w_in": mk.normal((d, 4 * d)),
        "r": mk.normal((H, dh, 4 * dh)),
        "b": mk.zeros((4 * d,)),
        "w_out": mk.out_proj((d, d)),
    }
    m = {
        "ln": REPLICATED_SMALL,
        "w_in": REPLICATED_BIG,
        "r": REPLICATED_BIG,
        "b": REPLICATED_SMALL,
        "w_out": REPLICATED_BIG,
    }
    return p, m


def _rglru_params(mk: Maker, cfg: ModelConfig):
    d = cfg.d_model
    r = cfg.lru_dim or d
    W = cfg.conv1d_width
    # Λ init so that a ∈ (0.9, 0.999) at r_gate ≈ 0.5 (Griffin appendix)
    lam0 = np.log(
        np.expm1(-np.log(np.random.default_rng(0).uniform(0.9, 0.999, r)) / (0.5 * 8.0))
    ).astype(np.float32)
    p = {
        "ln": mk.ones((d,)),
        "w_x": mk.normal((d, r)),
        "w_y": mk.normal((d, r)),
        "conv_w": mk.normal((W, r)),
        "conv_b": mk.zeros((r,)),
        "w_a": mk.normal((d, r)),
        "b_a": mk.zeros((r,)),
        "w_i": mk.normal((d, r)),
        "b_i": mk.zeros((r,)),
        "lam": mk.const(lam0),
        "w_down": mk.out_proj((r, d)),
    }
    m = {
        "ln": SEQ_NORM,
        "w_x": ParamMeta(tp_dim=1),
        "w_y": ParamMeta(tp_dim=1),
        "conv_w": ParamMeta(tp_dim=1, compress=False),
        "conv_b": ParamMeta(tp_dim=0, compress=False),
        "w_a": ParamMeta(tp_dim=1),
        "b_a": ParamMeta(tp_dim=0, compress=False),
        "w_i": ParamMeta(tp_dim=1),
        "b_i": ParamMeta(tp_dim=0, compress=False),
        "lam": ParamMeta(tp_dim=0, compress=False),
        "w_down": ParamMeta(tp_dim=0),
    }
    return p, m


def _block_params(mk: Maker, kind: str, cfg: ModelConfig, tp: int):
    """(params, metas) for one block of the given pattern kind."""
    if kind in ("attn", "local", "cross"):
        pa, ma = _attn_params(mk, cfg, tp, is_cross=(kind == "cross"))
        if cfg.num_experts and kind != "cross":
            pc, mc = _moe_params(mk.fold(1), cfg)
        elif cfg.d_ff and kind != "cross":
            pc, mc = _mlp_params(mk.fold(1), cfg, audio=cfg.arch_type == "audio")
        else:
            pc, mc = {}, {}
        p, m = {"attn": pa}, {"attn": ma}
        if pc:
            p["mix"], m["mix"] = pc, mc
        return p, m
    if kind == "mlstm":
        p, m = _mlstm_params(mk, cfg)
    elif kind == "slstm":
        p, m = _slstm_params(mk, cfg)
    elif kind == "rglru":
        pr, mr = _rglru_params(mk, cfg)
        pc, mc = _mlp_params(mk.fold(1), cfg, audio=False)
        return {"rglru": pr, "mix": pc}, {"rglru": mr, "mix": mc}
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return {kind: p}, {kind: m}


def _is_sds(x):
    return isinstance(x, jax.ShapeDtypeStruct)


def _stack(xs):
    """Stack leaves; works for both arrays and ShapeDtypeStructs."""
    first = xs[0]
    if _is_sds(first):
        return jax.ShapeDtypeStruct((len(xs),) + tuple(first.shape), first.dtype)
    return jnp.stack(xs, axis=0)


def init_params(cfg: ModelConfig, key, tp: int = 1):
    """Global-logical (params, metas). Layers stacked per precision group:
    group g holds, per pattern position, arrays with leading dim R_g
    (= pattern repetitions inside the group). key=None -> abstract shapes."""
    pat = cfg.pattern
    reps_per_group = cfg.layers_per_group // len(pat)
    base = Maker(key, cfg.num_layers)
    groups_p, groups_m = [], []
    for g in range(cfg.num_groups):
        layer_p, layer_m = {}, {}
        for pi, kind in enumerate(pat):
            stack_p, meta = [], None
            for rrep in range(reps_per_group):
                mk = base.fold(1 + g * 10000 + pi * 100 + rrep)
                p, meta = _block_params(mk, kind, cfg, tp)
                stack_p.append(p)
            layer_p[f"p{pi}"] = jax.tree_util.tree_map(
                lambda *xs: _stack(list(xs)), *stack_p,
                is_leaf=lambda x: _is_sds(x),
            )
            layer_m[f"p{pi}"] = meta
        groups_p.append(layer_p)
        groups_m.append(layer_m)

    d = cfg.d_model
    V = eff_vocab(cfg, tp)
    mk = base.fold(999_001)
    top_p, top_m = {}, {}
    if cfg.embed_is_input_stub:
        top_p["embed_in"] = mk.normal((cfg.vision_dim, d))
        top_m["embed_in"] = REPLICATED_BIG
    else:
        top_p["embed"] = mk.normal((V, d))
        top_m["embed"] = ParamMeta(tp_dim=0, tp_units=V)
    if not cfg.tie_embeddings:
        top_p["head"] = mk.normal((d, V))
        top_m["head"] = ParamMeta(tp_dim=1, tp_units=V)
    if cfg.num_image_tokens:
        top_p["img_proj"] = mk.normal((cfg.vision_dim, d))
        top_m["img_proj"] = REPLICATED_BIG
    top_p["final_norm"] = mk.ones((d,))
    top_m["final_norm"] = SEQ_NORM

    params = {"groups": groups_p, **top_p}
    metas = {"groups": groups_m, **top_m}
    return params, metas


def param_shapes(cfg: ModelConfig, tp: int = 1):
    """Abstract (ShapeDtypeStruct) params + metas, zero allocation."""
    return init_params(cfg, None, tp)
