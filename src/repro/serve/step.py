"""Distributed serving steps: prefill (build caches) and one-token decode.

Weights flow through the same ADT-compressed gathers as training — serving
models the paper's "send weights to accelerators" motion at inference
load time / per step, and decode roofline shows where int8 KV (beyond-
paper) pays off. A :class:`~repro.plan.PrecisionPlan` drives every
precision choice: the per-group weight entries, the activation policy
compressing the TP-axis collectives, ``int8_kv`` (resident KV state),
``seq_parallel`` for prefill, and the chunked weight gather.

Serving is deterministic: a plan whose *forward* weight path uses
stochastic rounding is rejected here (there is no per-request PRNG key);
its gradient fields are simply unused.

``plan=`` is the only configuration entry point; the pre-plan
``round_tos``/``env_kw`` legacy signatures (and their deprecation
shims) are gone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.shard import shard_map
from repro.dist.spec import (
    LeafSpec,
    MeshCfg,
    placed_leaf,
    placed_leaf_pspec,
    tree_partition_specs,
)
from repro.models import model as M
from repro.plan import PrecisionPlan
from repro.train.step import (
    batch_pspecs,
    check_seq_parallel,
    make_mat_fns,
    resolve_plan,
)
from repro.transport.policy import FP32_BYTES

def _serve_plan(cfg, plan, *, caller):
    """Shared plan validation for the serve factories: required plan=,
    group broadcast, and the deterministic-forward constraint."""
    plan = resolve_plan(cfg, plan=plan, caller=caller)
    for pol in plan.weight_policies():
        if pol.mode == "stochastic" and pol.round_to < FP32_BYTES:
            raise ValueError(
                f"{caller}: stochastic forward rounding is not supported "
                "in serving steps (deterministic, no PRNG key); use "
                "mode='nearest'"
            )
    return plan


def cache_pspecs(cfg: ModelConfig, mesh_cfg: MeshCfg, shard_batch: bool,
                 int8_kv: bool = False, per_slot: bool = False,
                 paged: bool = False):
    """PartitionSpec tree matching model.init_caches structure.

    ``per_slot=True`` matches the engine's slotted layout
    (``init_caches(per_slot=True)``): KV positions are ``(R, B)`` vectors
    sharded like the batch dim instead of replicated scalars.

    ``paged=True`` matches ``model.init_paged_caches``: attn blocks hold
    a page *pool* ``(R, P, page, Kv_l, hd)`` — kv heads stay rank-local
    on the model axis, but the pool has no batch dim to dp-shard (every
    shard must see every page, so paged serving forces
    ``shard_batch=False``)."""
    if mesh_cfg.tp == 1 and mesh_cfg.dshards == 1:
        none = lambda *a: P()
        dp = mo = None
    else:
        dp = (
            mesh_cfg.fsdp_axes
            if len(mesh_cfg.fsdp_axes) > 1
            else mesh_cfg.fsdp_axes[0]
        ) if (mesh_cfg.dshards > 1 and shard_batch) else None
        mo = mesh_cfg.model_axis if mesh_cfg.tp > 1 else None
    if paged and dp is not None:
        raise ValueError("paged caches cannot shard the batch dim: the "
                         "page pool is slot-global")
    pos_spec = P(None, dp) if per_slot else P(None)
    pat = cfg.pattern
    groups = []
    for g in range(cfg.num_groups):
        entry = {}
        for pi, kind in enumerate(pat):
            if paged and kind == "attn":
                # Paged(Quant)KVCache: pool (R,P,page,Kv_l,hd), pos (R,B)
                kv = P(None, None, None, mo, None)
                if int8_kv:
                    sc = P(None, None, None, mo)
                    entry[f"p{pi}"] = M.PagedQuantKVCache(
                        kv, kv, sc, sc, P(None, None)
                    )
                else:
                    entry[f"p{pi}"] = M.PagedKVCache(kv, kv, P(None, None))
            elif kind in ("attn", "local", "cross"):
                # KVCache(k, v, pos): (R,B,C,Kv_l,hd) — kv heads are rank-local
                kv = P(None, dp, None, mo, None)
                if int8_kv and kind != "cross":
                    sc = P(None, dp, None, mo)
                    entry[f"p{pi}"] = M.QuantKVCache(kv, kv, sc, sc, pos_spec)
                else:
                    entry[f"p{pi}"] = M.KVCache(kv, kv, pos_spec)
            elif kind == "mlstm":
                entry[f"p{pi}"] = jax.tree_util.tree_unflatten(
                    jax.tree_util.tree_structure(
                        M.ssm.MLSTMState(0, 0, 0)
                    ),
                    [P(None, dp, None, None, mo), P(None, dp, None, None), P(None, dp, None)],
                )
            elif kind == "slstm":
                entry[f"p{pi}"] = jax.tree_util.tree_unflatten(
                    jax.tree_util.tree_structure(
                        M.ssm.SLSTMState(0, 0, 0, 0)
                    ),
                    [P(None, dp, None)] * 4,
                )
            elif kind == "rglru":
                entry[f"p{pi}"] = (P(None, dp, mo), P(None, dp, None, mo))
            else:
                raise ValueError(kind)
        groups.append(entry)
    return groups


def global_cache_shapes(
    cfg: ModelConfig,
    mesh_cfg: MeshCfg,
    batch: int,
    capacity: int,
    dtype=jnp.float32,
    *,
    shard_batch: bool = True,
    per_slot: bool = False,
    int8_kv: bool | None = None,
    paged_pages: int | None = None,
    page_size: int | None = None,
):
    """Global ShapeDtypeStruct tree for decode-step cache inputs (zero alloc).

    Local cache shapes come from ``model.init_caches`` under eval_shape; any
    dim mapped to the model axis in ``cache_pspecs`` is scaled by tp to get
    the global (pre-shard_map) shape. ``per_slot=True`` selects the serve
    engine's slotted layout (per-request KV position vectors);
    ``paged_pages`` + ``page_size`` select ``model.init_paged_caches``
    (``capacity`` is then ignored for attn blocks).

    ``int8_kv`` quantizes the attention KV leaves only; recurrent state
    leaves keep ``dtype``. The legacy spelling (``dtype=jnp.int8``) is
    still honored when ``int8_kv`` is unset."""
    from repro.models.env import Env

    if int8_kv is None:  # legacy spelling: every leaf follows dtype
        int8_kv = dtype == jnp.int8
        state_dtype = dtype
    else:
        state_dtype = jnp.float32 if dtype == jnp.int8 else dtype
    env = Env(tp=mesh_cfg.tp, int8_kv=int8_kv)
    paged = paged_pages is not None
    if paged:
        local = jax.eval_shape(
            lambda: M.init_paged_caches(cfg, env, batch, paged_pages,
                                        page_size, state_dtype)
        )
    else:
        local = jax.eval_shape(
            lambda: M.init_caches(cfg, env, batch, capacity, state_dtype,
                                  per_slot=per_slot)
        )
    cspecs = cache_pspecs(cfg, mesh_cfg, shard_batch, int8_kv=int8_kv,
                          per_slot=per_slot, paged=paged)

    def fix(sds, spec):
        shape = list(sds.shape)
        for i, ax in enumerate(tuple(spec)):
            if ax == mesh_cfg.model_axis:
                shape[i] *= mesh_cfg.tp
        return jax.ShapeDtypeStruct(tuple(shape), sds.dtype)

    return jax.tree_util.tree_map(
        fix, local, cspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _logits_dp(mesh_cfg: MeshCfg, shard_batch: bool):
    if mesh_cfg.dshards <= 1 or not shard_batch:
        return None
    return (
        mesh_cfg.fsdp_axes
        if len(mesh_cfg.fsdp_axes) > 1
        else mesh_cfg.fsdp_axes[0]
    )


def make_prefill_step(
    cfg: ModelConfig,
    mesh_cfg: MeshCfg,
    mesh,
    spec_tree,
    batch_shapes: dict | None = None,
    *,
    plan: PrecisionPlan | None = None,
    cache_capacity: int,
    shard_batch: bool = True,
    window_override=None,
):
    plan = _serve_plan(cfg, plan, caller="make_prefill_step")
    if batch_shapes is None:
        raise TypeError("make_prefill_step: batch_shapes required")
    env = plan.make_env(mesh_cfg)
    if env.seq_parallel and mesh_cfg.tp > 1:
        check_seq_parallel(batch_shapes, mesh_cfg)
    mat_group, mat_top_factory = make_mat_fns(
        spec_tree, mesh_cfg, plan.weight_policies(), plan.compute_dtype
    )

    def step(storage, batch):
        return M.forward_prefill(
            storage, batch, cfg, env,
            mat_group=mat_group, mat_top=mat_top_factory(storage),
            cache_capacity=cache_capacity, window_override=window_override,
        )

    if mesh is None:
        return jax.jit(step)

    pspecs = tree_partition_specs(spec_tree, mesh_cfg)
    bspecs = batch_pspecs(batch_shapes, mesh_cfg, shard_batch)
    cspecs = cache_pspecs(cfg, mesh_cfg, shard_batch, int8_kv=plan.int8_kv)
    mo = mesh_cfg.model_axis if mesh_cfg.tp > 1 else None
    dp = _logits_dp(mesh_cfg, shard_batch)
    logits_spec = P(dp, None, mo)  # (B, 1, V_local): batch+vocab sharded
    sharded = shard_map(
        step, mesh=mesh, in_specs=(pspecs, bspecs),
        out_specs=(logits_spec, cspecs),
    )
    return jax.jit(sharded)


def make_place_step(
    cfg: ModelConfig,
    mesh_cfg: MeshCfg,
    mesh,
    spec_tree,
    *,
    plan: PrecisionPlan | None = None,
    resident_dtype=None,
):
    """Weight-stationary serving (§Perf): run every ADT-compressed gather
    ONCE, emitting per-TP-rank resident weights. Decode steps built with
    ``weight_stationary=True`` then contain no weight collectives at all.

    Returns (place_fn, placed_pspecs): ``placed = place_fn(storage)``."""
    plan = _serve_plan(cfg, plan, caller="make_place_step")
    policies = plan.weight_policies()

    def _walk(storage_sub, spec_sub, g):
        pol = policies[g]
        return jax.tree_util.tree_map(
            lambda x, s: placed_leaf(x, s, mesh_cfg, pol, resident_dtype),
            storage_sub, spec_sub,
            is_leaf=lambda x: isinstance(x, LeafSpec),
        )

    def place(storage):
        groups = [
            _walk(gp, gs, g)
            for g, (gp, gs) in enumerate(
                zip(storage["groups"], spec_tree["groups"])
            )
        ]
        top = {
            k: placed_leaf(storage[k], spec_tree[k], mesh_cfg, policies[-1],
                           resident_dtype)
            for k in storage
            if k != "groups"
        }
        return {"groups": groups, **top}

    if mesh is None:
        return jax.jit(place), None

    pspecs = tree_partition_specs(spec_tree, mesh_cfg)
    placed_specs = jax.tree_util.tree_map(
        lambda s: placed_leaf_pspec(s, mesh_cfg),
        spec_tree, is_leaf=lambda x: isinstance(x, LeafSpec),
    )
    sharded = shard_map(
        place, mesh=mesh, in_specs=(pspecs,), out_specs=placed_specs
    )
    return jax.jit(sharded), placed_specs


def make_decode_step(
    cfg: ModelConfig,
    mesh_cfg: MeshCfg,
    mesh,
    spec_tree,
    batch_shapes: dict | None = None,
    *,
    plan: PrecisionPlan | None = None,
    shard_batch: bool = True,
    window_override=None,
    weight_stationary: bool = False,
    slot_caches: bool = False,
    paged: bool = False,
):
    plan = _serve_plan(cfg, plan, caller="make_decode_step")
    if batch_shapes is None:
        raise TypeError("make_decode_step: batch_shapes required")
    # seq_parallel is part of the plan for launcher symmetry but decode
    # has no sequence dim to shard: forward_decode drops the flag (model.py)
    env = plan.make_env(mesh_cfg)
    mat_group, mat_top_factory = make_mat_fns(
        spec_tree, mesh_cfg, plan.weight_policies(), plan.compute_dtype,
        placed=weight_stationary,
    )

    def step(storage, caches, batch):
        return M.forward_decode(
            storage, batch, caches, cfg, env,
            mat_group=mat_group, mat_top=mat_top_factory(storage),
            window_override=window_override,
        )

    if mesh is None:
        return jax.jit(step)

    if weight_stationary:
        pspecs = jax.tree_util.tree_map(
            lambda s: placed_leaf_pspec(s, mesh_cfg),
            spec_tree, is_leaf=lambda x: isinstance(x, LeafSpec),
        )
    else:
        pspecs = tree_partition_specs(spec_tree, mesh_cfg)
    bspecs = batch_pspecs(batch_shapes, mesh_cfg, shard_batch)
    cspecs = cache_pspecs(cfg, mesh_cfg, shard_batch, int8_kv=plan.int8_kv,
                          per_slot=slot_caches, paged=paged)
    mo = mesh_cfg.model_axis if mesh_cfg.tp > 1 else None
    dp = _logits_dp(mesh_cfg, shard_batch)
    logits_spec = P(dp, None, mo)
    sharded = shard_map(
        step, mesh=mesh, in_specs=(pspecs, cspecs, bspecs),
        out_specs=(logits_spec, cspecs),
    )
    return jax.jit(sharded, donate_argnums=(1,))


def make_verify_step(
    cfg: ModelConfig,
    mesh_cfg: MeshCfg,
    mesh,
    spec_tree,
    *,
    plan: PrecisionPlan | None = None,
    n_slots: int,
    block: int,
    shard_batch: bool = True,
    weight_stationary: bool = False,
    paged: bool = False,
    table_width: int = 0,
):
    """The k-token verify variant of the decode step (speculative
    decoding): the SAME program family as :func:`make_decode_step`,
    compiled once at ``tokens (n_slots, block)`` with
    ``block = spec_k + 1``, so one batched target forward scores the
    carried last-emitted token plus all k draft proposals. The
    multi-token cache branches (models/attention.py) scatter block
    position j at ``pos + j``; the engine rolls back rejected positions
    by re-stamping ``pos`` (:func:`repro.serve.spec.rollback_caches`)."""
    dshapes = {
        "tokens": jax.ShapeDtypeStruct((n_slots, block), jnp.int32),
        "pos": jax.ShapeDtypeStruct((n_slots,), jnp.int32),
    }
    if paged:
        dshapes["page_table"] = jax.ShapeDtypeStruct(
            (n_slots, table_width), jnp.int32
        )
    return make_decode_step(
        cfg, mesh_cfg, mesh, spec_tree, dshapes, plan=plan,
        shard_batch=shard_batch, weight_stationary=weight_stationary,
        slot_caches=True, paged=paged,
    )
