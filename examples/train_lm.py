"""End-to-end LM training driver with A²DTWP (multi-device capable).

Presets:
  cpu-demo : ~4M-param qwen3-family model, 200 steps, 1 device  (default)
  8dev     : same model, 2x4 (data x model) mesh over 8 fake host devices
             (set XLA_FLAGS=--xla_force_host_platform_device_count=8)
  100m     : ~100M-param config, few hundred steps — sized for a real
             accelerator host; lowers + runs on CPU too, just slowly.

Logs loss, AWP format trajectory, wire bytes, and writes a checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py --preset cpu-demo
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import save_checkpoint
from repro.configs.registry import get_config, reduced
from repro.data.pipeline import synthetic_lm_batch
from repro.dist.spec import (
    MeshCfg, build_spec_tree, dist_elems_per_group, tree_to_storage,
)
from repro.launch.mesh import make_mesh_from_cfg
from repro.models.init import init_params
from repro.optim.sgd import SGDConfig, init_momentum
from repro.plan import PrecisionPlan
from repro.train.loop import Trainer
from repro.train.step import make_train_step


def build_preset(name: str):
    if name == "cpu-demo":
        cfg = reduced(get_config("qwen3-1.7b"), layers=4)
        return cfg, MeshCfg(tp=1, dp=1, compress_min_size=4096), 8, 128, 200
    if name == "8dev":
        cfg = reduced(get_config("qwen3-1.7b"), layers=4)
        return cfg, MeshCfg(tp=2, dp=4, compress_min_size=4096), 16, 128, 200
    if name == "100m":
        cfg = dataclasses.replace(
            get_config("qwen3-1.7b"),
            num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32768,
            num_precision_groups=4, scan_layers=True, remat=True,
        )
        return cfg, MeshCfg(tp=1, dp=1), 8, 512, 300
    raise SystemExit(f"unknown preset {name}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-demo",
                    choices=["cpu-demo", "8dev", "100m"])
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--policy", default="awp",
                    help="awp | baseline | oracle:<rt>")
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt.npz")
    args = ap.parse_args()

    cfg, mesh_cfg, B, S, steps = build_preset(args.preset)
    if args.steps:
        steps = args.steps
    mesh = make_mesh_from_cfg(mesh_cfg)

    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    n_params = sum(
        np.prod(l.shape) for l in jax.tree_util.tree_leaves(params)
    )
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)  "
          f"mesh: {mesh_cfg.shape if mesh is not None else 'single'}  "
          f"batch: {B}x{S}")

    batch_shapes = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    opt = SGDConfig(lr=args.lr, momentum=0.9, weight_decay=1e-4)
    nrt = cfg.num_groups + 1

    if args.policy == "awp":
        plan = PrecisionPlan.build(
            nrt, schedule="awp", awp_threshold=1e-3, awp_interval=25,
        )
    elif args.policy == "baseline":
        plan = PrecisionPlan.build(nrt, round_to=4)
    elif args.policy.startswith("oracle:"):
        plan = PrecisionPlan.build(nrt, round_to=int(args.policy.split(":")[1]))
    else:
        raise SystemExit(f"unknown --policy {args.policy}")

    def builder(round_tos):
        return make_train_step(
            cfg, mesh_cfg, mesh, spec_tree, opt, batch_shapes,
            plan=plan.with_round_tos(round_tos),
        )

    trainer = Trainer(
        builder, nrt, plan=plan,
        dist_elems_per_group=dist_elems_per_group(spec_tree, mesh_cfg, nrt),
        gather_axis_size=max(mesh_cfg.dshards, 1),
    )
    mom = init_momentum(storage)

    ctx = mesh if mesh is not None else _null()
    t0 = time.time()
    with ctx:
        for step in range(steps):
            tokens, labels = synthetic_lm_batch(cfg.vocab_size, B, S, step)
            storage, mom, _ = trainer.run_step(
                storage, mom, {"tokens": tokens, "labels": labels}, args.lr
            )
            if step % 25 == 24:
                r = trainer.records[-1]
                print(f"step {step+1:4d}  loss {r.loss:.4f}  "
                      f"rts {r.round_tos}  "
                      f"wire {r.wire_bytes/1e6:.1f}MB  "
                      f"{(time.time()-t0)/(step+1):.2f}s/step")
    s = trainer.summary()
    print(f"\nfinal loss {s['final_loss']:.4f}  "
          f"wire reduction {s['wire_reduction']*100:.1f}%  "
          f"recompiles {s['recompiles']}")
    print(f"AWP history: {s['bits_history']}")
    save_checkpoint(args.ckpt, storage, mom, trainer.controller, steps,
                    plan=plan)
    print(f"checkpoint -> {args.ckpt}")


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
