"""Synthetic data pipelines (offline container: no ImageNet download).

* ``SyntheticImageNet`` — class prototypes + noise + random shift; an
  ImageNet-200-shaped classification task whose top-5 validation error
  decreases with training, so the paper's time-to-error methodology
  (§V-A) is reproducible end-to-end.
* ``synthetic_lm`` — token stream with a k-gram generating rule so an LM
  actually has signal to learn.

Both are deterministic in their seed, cheap, and sharded by slicing the
global batch (the train steps shard over the data axis themselves).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def _step_rng(seed: int, step: int) -> np.random.Generator:
    """Collision-free per-(seed, step) stream: both ints map bijectively
    to non-negative entropy words (the previous ``abs(seed·p + step) + 1``
    mix folded pairs symmetric about zero onto the same stream, repeating
    batches). SeedSequence mixes the words, so distinct pairs — including
    the validation set's ``step=-1`` — get independent streams."""
    ent = [int(np.uint64(np.int64(seed))), int(np.uint64(np.int64(step)))]
    return np.random.default_rng(ent)


@dataclasses.dataclass
class SyntheticImageNet:
    num_classes: int = 200
    hw: int = 32
    channels: int = 3
    noise: float = 0.35
    seed: int = 0

    def __post_init__(self):
        # uint64 view: bijective and non-negative (negative seeds raise in
        # default_rng); identical stream to before for seed >= 0
        rng = np.random.default_rng(int(np.uint64(np.int64(self.seed))))
        self.prototypes = rng.normal(
            0, 1, (self.num_classes, self.hw, self.hw, self.channels)
        ).astype(np.float32)

    def batch(self, batch_size: int, step: int):
        rng = _step_rng(self.seed, step)
        labels = rng.integers(0, self.num_classes, batch_size)
        base = self.prototypes[labels]
        shift = rng.integers(-2, 3, (batch_size, 2))
        imgs = np.stack(
            [
                np.roll(np.roll(b, s[0], axis=0), s[1], axis=1)
                for b, s in zip(base, shift)
            ]
        )
        imgs = imgs + self.noise * rng.normal(0, 1, imgs.shape)
        return (
            jnp.asarray(imgs, jnp.float32),
            jnp.asarray(labels, jnp.int32),
        )

    def validation(self, size: int = 512):
        return self.batch(size, step=-1)


def synthetic_lm_batch(
    vocab: int, batch: int, seq: int, step: int, *, seed: int = 0, order: int = 3
):
    """Deterministic k-gram stream: next = (a·t1 + b·t2 + c·t3) mod vocab,
    with per-sequence offsets — learnable but not trivial."""
    # collision-free per-(seed, step) stream (the old ``seed·p + step``
    # affine mix aliased pairs like (0, 7_777_777) and (1, 0) onto the
    # same stream, repeating batches across runs with different seeds)
    rng = _step_rng(seed, step)
    coef = np.array([3, 5, 7])
    toks = rng.integers(0, vocab, (batch, order + seq + 1))
    for t in range(order, order + seq + 1):
        nxt = (toks[:, t - 3] * coef[0] + toks[:, t - 2] * coef[1]
               + toks[:, t - 1] * coef[2] + toks[:, 0]) % vocab
        # mix generated structure with 10% noise tokens
        noise = rng.random(batch) < 0.1
        toks[:, t] = np.where(noise, toks[:, t], nxt)
    stream = toks[:, order:]
    tokens = stream[:, :-1]
    labels = stream[:, 1:]
    return (
        jnp.asarray(tokens, jnp.int32),
        jnp.asarray(labels, jnp.int32),
    )


def synthetic_feature_batch(dim: int, vocab: int, batch: int, seq: int,
                            step: int, *, seed: int = 0):
    """Frame embeddings + frame labels for the audio (encoder) family."""
    # same collision-free SeedSequence scheme as synthetic_lm_batch (the
    # old ``seed·13 + step`` mix aliased e.g. (0, 13) and (1, 0)); the
    # codebook depends on the seed alone, via the bijective uint64 view
    # so negative seeds work
    rng = _step_rng(seed, step)
    labels = rng.integers(0, vocab, (batch, seq))
    codebook = np.random.default_rng(
        int(np.uint64(np.int64(seed)))
    ).normal(0, 1, (vocab, dim))
    feats = codebook[labels] + 0.5 * rng.normal(0, 1, (batch, seq, dim))
    return (
        jnp.asarray(feats, jnp.float32),
        jnp.asarray(labels, jnp.int32),
    )
