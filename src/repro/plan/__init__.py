"""repro.plan — the declarative PrecisionPlan API (see docs/plan.md).

One validated, serializable object owns every precision knob: the
per-traffic-class :class:`~repro.transport.CompressionPolicy` entries,
the schedule source (static oracle vs AWP dynamic), and the execution
layout (``seq_parallel`` / ``chunks`` / compute dtype / ``int8_kv`` /
``accum_steps``). Step factories take ``plan=``, ``Env`` is built from
the plan, launchers load ``--plan plan.json``, checkpoints persist it,
and the roofline analyzers account wire bytes per plan entry.
"""
from repro.plan.plan import (
    ENV_OVERRIDE_KEYS,
    TRAFFIC_CLASSES,
    PrecisionPlan,
    SamplingParams,
    Schedule,
    policy_uses_rng,
)
from repro.plan.sweep import (
    CHUNK_CANDIDATES,
    modeled_gather_time,
    pick_chunks,
    sweep_chunks,
)

__all__ = [
    "CHUNK_CANDIDATES",
    "ENV_OVERRIDE_KEYS",
    "PrecisionPlan",
    "SamplingParams",
    "Schedule",
    "TRAFFIC_CLASSES",
    "modeled_gather_time",
    "policy_uses_rng",
    "pick_chunks",
    "sweep_chunks",
]
