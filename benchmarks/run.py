"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Mapping to the paper:

  table2_3_profile       — per-kernel cost profile (Bitpack / Bitunpack /
                           l2-norm measured on CPU; transfer terms modeled
                           bytes/bandwidth, as Tables II/III)
  fig2_bitpack_kernel    — SIMD-Bitpack throughput (Pallas interpret vs
                           jnp oracle) over VGG-sized weight arrays
  fig3_convergence       — time-to-validation-error, baseline vs oracle vs
                           A²DTWP on the reduced AlexNet (§V-B, Fig. 3)
  fig4_normalized_time   — normalized execution time of oracle/A²DTWP vs
                           the fp32 baseline across batch sizes (Fig. 4)
  compression_ratio      — weight-motion bytes per format (the ~2.94x
                           CPU→GPU reduction of Table II)
  roofline_table         — §Roofline terms per (arch x shape) read from
                           results/dryrun_*.json (produced by the dry-run)

Keep each entry fast: the full harness must finish in a few minutes on one
CPU core.
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def row(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return 1e6 * statistics.median(ts)


# ---------------------------------------------------------------------------


def table2_3_profile():
    """Tables II/III: per-batch component profile for VGG-sized weights."""
    from repro.kernels import ops
    from repro.transport import pack_planes, unpack_planes

    n = 20_000_000  # ~VGG-A conv+fc weight count (paper: ~133M at full fc)
    w = jnp.asarray(np.random.default_rng(0).normal(0, 1, n), jnp.float32)
    pack = jax.jit(lambda x: pack_planes(x, 2, impl="ref"))
    unpack = jax.jit(lambda p: unpack_planes(p, impl="ref"))
    us_pack = _time(pack, w, iters=5)
    us_unpack = _time(unpack, pack(w), iters=5)
    us_norm = _time(lambda x: ops.l2norm_sq(x, impl="ref"), w, iters=5)
    row("table2.bitpack_20M_weights", us_pack, "paper_x86=19.71ms_on_133M")
    row("table2.bitunpack_20M_weights", us_unpack, "paper_x86=4.51ms")
    row("table2.awp_l2norm_20M_weights", us_norm, "paper_x86=3.88ms")
    # modeled transfer at PCIe3 x8 (paper x86 system)
    bw = 7.9e9
    fp32_us = n * 4 / bw * 1e6
    rt2_us = n * 2 / bw * 1e6
    row("table2.transfer_fp32_modeled", fp32_us, "paper=153.93ms_on_133M")
    row(
        "table2.transfer_rt2_modeled", rt2_us,
        f"reduction={fp32_us/rt2_us:.2f}x_paper=2.94x",
    )


def fig2_bitpack_kernel():
    """Pallas bitpack/bitunpack vs jnp oracle through the transport
    dispatch (kernels compiled on TPU, interpret on CPU)."""
    from repro.kernels.bitpack import resolve_interpret
    from repro.transport import pack_planes

    mode = "pallas_interp" if resolve_interpret(None) else "pallas"
    w = jnp.asarray(
        np.random.default_rng(1).normal(0, 1, (4096, 128)), jnp.float32
    ).reshape(-1)
    for rt in (1, 2, 3):
        fp = jax.jit(lambda x, rt=rt: pack_planes(x, rt, impl="pallas"))
        fr = jax.jit(lambda x, rt=rt: pack_planes(x, rt, impl="ref"))
        us_p = _time(fp, w, iters=5)
        us_r = _time(fr, w, iters=5)
        row(f"fig2.bitpack_rt{rt}_{mode}", us_p, f"ref_us={us_r:.1f}")


def fig3_convergence(steps=140):
    """Fig 3: top-5 val-error vs modeled elapsed time (reduced AlexNet)."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "examples"))
    from awp_cnn_repro import NETS, run_policy, LINK_BW
    from repro.data.pipeline import SyntheticImageNet
    from repro.dist.spec import MeshCfg
    from repro.models.cnn import reduced_cnn

    cfg = reduced_cnn(NETS["alexnet"], num_classes=20, in_hw=32)
    data = SyntheticImageNet(num_classes=20, hw=32)
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=256)
    for policy in ("baseline", "oracle:2", "awp"):
        t0 = time.perf_counter()
        r = run_policy(policy, cfg, data, mesh_cfg, None, steps, 64, 0.05)
        err = r["curve"][-1]["top5_err"]
        xfer = r["curve"][-1]["modeled_xfer_s"]
        row(
            f"fig3.alexnet_{policy.replace(':', '')}",
            1e6 * (time.perf_counter() - t0) / steps,
            f"top5err={err:.3f}_modeled_xfer_s={xfer:.3f}",
        )


def fig4_normalized_time():
    """Fig 4: normalized execution time vs baseline across batch sizes.

    Modeled per the paper's own account: batch time = compute (equal across
    policies) + weight transfer (bytes/bw). Compute time measured once."""
    from repro.models.cnn import ALEXNET, VGG_A, RESNET34, reduced_cnn, init_cnn, cnn_loss

    bw = 7.9e9
    for name, full in (("alexnet", ALEXNET), ("vgg", VGG_A), ("resnet", RESNET34)):
        cfg = reduced_cnn(full, num_classes=20, in_hw=32)
        params, metas, _ = init_cnn(cfg, jax.random.PRNGKey(0))
        wbytes = sum(
            int(np.prod(v["w"].shape)) * 4 for v in params["layers"].values()
        )
        for batch in (16, 32, 64):
            imgs = jnp.zeros((batch, 32, 32, 3), jnp.float32)
            labels = jnp.zeros((batch,), jnp.int32)
            lossf = jax.jit(
                lambda lp, i, l: cnn_loss(lp, i, l, cfg, train=False)
            )
            us_compute = _time(lossf, params["layers"], imgs, labels, iters=5)
            t_fp32 = us_compute + wbytes / bw * 1e6
            t_rt2 = us_compute + wbytes / 2 / bw * 1e6
            row(
                f"fig4.{name}_b{batch}_oracle2_norm_time",
                t_rt2,
                f"normalized={t_rt2/t_fp32:.3f}_fp32_us={t_fp32:.0f}",
            )


def compression_ratio():
    from repro.core.formats import TransferFormat
    from repro.transport import CompressionPolicy

    for rt in (1, 2, 3, 4):
        f = TransferFormat(rt)
        pol = CompressionPolicy(round_to=rt)
        # the format table and the transport accounting must agree
        assert f.compression_ratio == 1.0 / pol.wire_fraction
        row(
            f"compression.{f.name}", 0.0,
            f"ratio={f.compression_ratio:.2f}x_bits={f.bits}"
            f"_wire_frac={pol.wire_fraction:.2f}",
        )


def roofline_table():
    """§Roofline terms from the dry-run JSONs (if present)."""
    for mesh_name, path in (
        ("16x16", "results/dryrun_single_pod.json"),
        ("2x16x16", "results/dryrun_multi_pod.json"),
    ):
        if not os.path.exists(path):
            continue
        with open(path) as f:
            results = json.load(f)
        for r in results:
            tag = f"roofline.{mesh_name}.{r['arch']}.{r['shape']}"
            if "skipped" in r:
                row(tag, 0.0, "skipped=" + r["skipped"].split(":")[0])
                continue
            if "error" in r:
                row(tag, 0.0, "ERROR")
                continue
            rf = r["roofline"]
            row(
                tag,
                1e6 * max(rf["compute_s"], rf["memory_s"], rf["collective_s"]),
                f"dom={rf['dominant']}_c={rf['compute_s']:.3f}"
                f"_m={rf['memory_s']:.3f}_x={rf['collective_s']:.3f}"
                f"_useful={rf['useful_ratio']:.2f}",
            )


def main() -> None:
    print("name,us_per_call,derived")
    table2_3_profile()
    fig2_bitpack_kernel()
    compression_ratio()
    fig4_normalized_time()
    fig3_convergence(steps=int(os.environ.get("BENCH_FIG3_STEPS", "140")))
    roofline_table()
    print(f"# {len(ROWS)} rows", file=sys.stderr)


if __name__ == "__main__":
    main()
