"""Production mesh construction.

Built as functions (never module-level constants) so importing this module
never touches jax device state — only launch/dryrun.py sets the 512-device
XLA host-platform flag, and only in its own process.
"""
from __future__ import annotations

import jax

from repro.dist.spec import MeshCfg

SINGLE_POD = MeshCfg(tp=16, dp=16, pods=1)
MULTI_POD = MeshCfg(tp=16, dp=16, pods=2)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_cfg_for(*, multi_pod: bool = False) -> MeshCfg:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_mesh_from_cfg(mesh_cfg: MeshCfg):
    """Arbitrary-geometry mesh (tests use small ones, e.g. 2x2x2)."""
    if mesh_cfg.tp == 1 and mesh_cfg.dshards == 1:
        return None
    return jax.make_mesh(mesh_cfg.shape, mesh_cfg.axis_names)
