"""Compressed collectives — the heart of ADT on a TPU mesh (DESIGN.md §2).

:func:`compressed_all_gather` is the TPU analogue of the paper's
CPU→GPU weight send: the fp32 master shard is bitpacked to ``round_to``
byte planes, the *planes* are all-gathered over the FSDP axes (moving
``round_to/4`` of the fp32 bytes), and every device bitunpacks back to
fp32.  Its custom VJP is an uncompressed ``psum_scatter`` — the paper
deliberately leaves the gradient path (GPU→CPU) uncompressed, and so does
our faithful mode.

:func:`compressed_psum_scatter` is the beyond-paper counterpart for the
gradient path (paper §VI notes gradient-compression work is "orthogonal
and combinable"): every device packs the chunk destined for each peer,
an ``all_to_all`` moves the packed planes, and the receiver unpacks and
reduces locally.  Wire bytes shrink by the same ``round_to/4`` factor.
"""
from __future__ import annotations

import functools
from typing import Hashable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ref

AxisNames = Hashable | Sequence[Hashable]


def _axis_size(axis_names: AxisNames) -> int:
    if isinstance(axis_names, (tuple, list)):
        size = 1
        for a in axis_names:
            size *= lax.axis_size(a)
        return size
    return lax.axis_size(axis_names)


# ---------------------------------------------------------------------------
# Weight path: compressed all-gather (paper-faithful)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def compressed_all_gather(
    w_local: jnp.ndarray,
    axis_names: AxisNames,
    round_to: int,
    grad_round_to: int = 4,
) -> jnp.ndarray:
    """All-gather a flat fp32 shard ``(S_loc,)`` -> ``(S,)`` in ``round_to`` bytes.

    ``grad_round_to=4`` keeps the backward reduce-scatter uncompressed
    (paper-faithful). Values < 4 compress the gradient path too
    (beyond-paper, via :func:`compressed_psum_scatter`).
    """
    return _cag_fwd(w_local, axis_names, round_to, grad_round_to)[0]


def _cag_fwd(w_local, axis_names, round_to, grad_round_to):
    if round_to == 4:
        w_full = lax.all_gather(w_local, axis_names, axis=0, tiled=True)
        return w_full, None
    planes = ref.bitpack_ref(w_local, round_to)  # (round_to, S_loc)
    planes_g = lax.all_gather(planes, axis_names, axis=1, tiled=True)
    w_full = ref.bitunpack_ref(planes_g)  # (S,)
    return w_full, None


def _cag_bwd(axis_names, round_to, grad_round_to, _, g):
    if grad_round_to == 4:
        return (lax.psum_scatter(g, axis_names, scatter_dimension=0, tiled=True),)
    return (compressed_psum_scatter(g, axis_names, grad_round_to),)


compressed_all_gather.defvjp(_cag_fwd, _cag_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantize_ste(w: jnp.ndarray, round_to: int) -> jnp.ndarray:
    """Single-device ADT format truncation with a straight-through VJP
    (the master fp32 copy receives the full-precision gradient)."""
    return ref.quantize_ref(w, round_to)


def _q_fwd(w, round_to):
    return ref.quantize_ref(w, round_to), None


def _q_bwd(round_to, _, g):
    return (g,)


quantize_ste.defvjp(_q_fwd, _q_bwd)


# ---------------------------------------------------------------------------
# Gradient path: compressed reduce-scatter (beyond-paper)
# ---------------------------------------------------------------------------


def compressed_psum_scatter(
    g: jnp.ndarray, axis_names: AxisNames, round_to: int
) -> jnp.ndarray:
    """Reduce-scatter a flat fp32 ``(S,)`` -> ``(S_loc,)`` in ``round_to`` bytes.

    Decomposed as pack → ``all_to_all`` of byte planes → unpack → local sum,
    which keeps every wire transfer compressed while the reduction itself is
    done in fp32 on-device. Rounding uses *nearest* (not the paper's
    truncation) because gradient sums are bias-sensitive.
    """
    if round_to == 4:
        return lax.psum_scatter(g, axis_names, scatter_dimension=0, tiled=True)
    size = _axis_size(axis_names)
    s = g.shape[0]
    if s % size:
        raise ValueError(f"flat size {s} not divisible by axis size {size}")
    chunks = g.reshape(size, s // size)
    planes = ref.bitpack_ref(chunks, round_to, mode="nearest")
    # (round_to, size, S_loc): exchange the `size` dim
    planes_x = lax.all_to_all(
        planes, axis_names, split_axis=1, concat_axis=1, tiled=False
    )
    # after all_to_all over possibly-multiple axes the exchanged dim stays `size`
    contribs = ref.bitunpack_ref(planes_x)  # (size, S_loc)
    return jnp.sum(contribs, axis=0)


# ---------------------------------------------------------------------------
# Collective byte accounting (used by benchmarks and the roofline model)
# ---------------------------------------------------------------------------


def all_gather_wire_bytes(s_local: int, axis_size: int, round_to: int) -> int:
    """Bytes received per device for one compressed all-gather.

    Ring/bidirectional all-gather delivers every remote shard once:
    ``(axis_size - 1) * S_loc * round_to`` bytes in, vs ``* 4`` for fp32.
    """
    return (axis_size - 1) * s_local * round_to


def psum_scatter_wire_bytes(s_local: int, axis_size: int, round_to: int) -> int:
    """Bytes received per device for one (compressed) reduce-scatter."""
    return (axis_size - 1) * s_local * round_to
