"""Execution environment threaded through every model function.

Carries the mesh-axis names (None = single device: every collective helper
degrades to identity), the TP degree, compute dtype, and the performance
levers toggled during §Perf hillclimbing. ``act_policy`` is the
activation-group :class:`~repro.transport.CompressionPolicy`: when set,
every TP-region psum and sequence-parallel collective issued through this
env rides the compressed transport (packed byte planes) instead of
fp32/compute-dtype collectives.

``seq_parallel`` switches the activation layout contract between blocks
(docs/collectives.md §"Sequence-parallel layout"):

  * ``False`` (Megatron TP): activations between blocks are model-axis
    *replicated*; :meth:`enter`/:meth:`exit` are the f/g psum pair.
  * ``True``: activations between blocks are *sequence-sharded*
    ``(B, S/tp, d)`` — norms and residual adds run on shards, and
    :meth:`enter`/:meth:`exit` become the transport-backed
    ``seq_gather``/``seq_scatter`` boundary pair (all-gather into the
    TP-region matmuls, reduce-scatter of the partial outputs).

Tensors that are *not* sequence-sharded under either layout (vocab-partial
loss sums, cross-attention image KV) must use :meth:`psum_enter`/
:meth:`psum_exit`, which stay the TP-region pair regardless of the flag.
One-token decode has no sequence dim to shard: ``forward_decode`` runs
under :meth:`without_seq_parallel`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
from jax import lax

from repro.core.collectives import (
    seq_gather,
    seq_merge,
    seq_scatter,
    seq_split,
    tp_region_enter,
    tp_region_exit,
)


@dataclasses.dataclass(frozen=True)
class Env:
    model_axis: str | None = None           # TP axis name
    fsdp_axes: tuple[str, ...] | None = None  # weight-gather axes
    tp: int = 1
    dtype: Any = jnp.float32                # compute dtype (bf16 = beyond-paper)
    attn_chunk: int = 1024                  # flash-chunk size (q and kv)
    causal_skip: bool = True                # skip fully-masked kv chunks
    seq_parallel: bool = False              # sequence-parallel activations
    int8_kv: bool = False                   # int8 KV cache (decode, §Perf)
    mlstm_chunk: int = 0                    # chunkwise mLSTM (0 = sequential)
    act_policy: Any = None                  # activation CompressionPolicy
    seq_policy: Any = None                  # seq-boundary policy (None = act)

    # ------------------------------------------------------------------
    @property
    def _seq_pol(self):
        """Policy of the sequence-parallel boundary pair: the plan's
        ``seq_boundary`` traffic class, defaulting to the activation
        (TP-region) policy when unset."""
        return self.seq_policy if self.seq_policy is not None else self.act_policy

    # ------------------------------------------------------------------
    @property
    def seq_parallel_active(self) -> bool:
        """True when activations between blocks are sequence-sharded."""
        return self.seq_parallel and self.model_axis is not None

    def without_seq_parallel(self) -> "Env":
        """Same env in the replicated-activation layout (decode steps,
        post-gather logits entries)."""
        if not self.seq_parallel:
            return self
        return dataclasses.replace(self, seq_parallel=False)

    # ------------------------------------------------------------------
    def enter(self, x, axis: int = 1):
        """TP-region enter. seq_parallel: all-gather sequence shards into
        the region (compressed fwd, reduce-scatter bwd); else Megatron 'f'
        (identity fwd / model-axis psum bwd)."""
        if self.model_axis is None:
            return x
        if self.seq_parallel:
            return seq_gather(x, self.model_axis, self._seq_pol, axis)
        return tp_region_enter(x, self.model_axis, self.act_policy)

    def exit(self, x, axis: int = 1):
        """TP-region exit. seq_parallel: reduce-scatter the partial
        outputs back onto sequence shards (all-gather bwd); else Megatron
        'g' (model-axis psum fwd / identity bwd)."""
        if self.model_axis is None:
            return x
        if self.seq_parallel:
            return seq_scatter(x, self.model_axis, self._seq_pol, axis)
        return tp_region_exit(x, self.model_axis, self.act_policy)

    def psum_enter(self, x):
        """Megatron 'f' regardless of ``seq_parallel`` — for tensors that
        are never sequence-sharded (cross-attn image KV, vocab-partial
        loss sums)."""
        if self.model_axis is None:
            return x
        return tp_region_enter(x, self.model_axis, self.act_policy)

    def psum_exit(self, x):
        """Megatron 'g' regardless of ``seq_parallel`` (see psum_enter)."""
        if self.model_axis is None:
            return x
        return tp_region_exit(x, self.model_axis, self.act_policy)

    def seq_gather(self, x, axis: int = 1):
        """Sequence-parallel enter: all-gather sequence shards (identity
        when there is no model axis)."""
        if self.model_axis is None:
            return x
        return seq_gather(x, self.model_axis, self._seq_pol, axis)

    def seq_scatter(self, x, axis: int = 1):
        """Sequence-parallel exit: reduce-scatter along the sequence dim
        (identity when there is no model axis)."""
        if self.model_axis is None:
            return x
        return seq_scatter(x, self.model_axis, self._seq_pol, axis)

    def seq_shard(self, x, axis: int = 1):
        """Replicated activation -> this rank's sequence shard (identity
        unless seq-parallel is active). Fwd slice / bwd all-gather."""
        if not self.seq_parallel_active:
            return x
        return seq_split(x, self.model_axis, axis)

    def seq_unshard(self, x, axis: int = 1):
        """Sequence shard -> full *replicated* sequence (identity unless
        seq-parallel is active): fwd all-gather / bwd slice. For regions
        whose compute is replicated over the model axis — sLSTM
        recurrences, the prefill gather before the last-token logits —
        where ``seq_gather``'s reduce-scatter transpose would
        double-count (see core.collectives.seq_merge)."""
        if not self.seq_parallel_active:
            return x
        return seq_merge(x, self.model_axis, axis)

    def model_rank(self):
        if self.model_axis is None:
            return 0
        return lax.axis_index(self.model_axis)

    def heads_local(self, heads: int) -> int:
        """Local head count when sharding `heads` over the model axis
        (replicated up when heads < tp, see DESIGN.md kv-replication note)."""
        return max(1, heads // self.tp)

    def ff_local(self, ff: int) -> int:
        return max(1, ff // self.tp)
