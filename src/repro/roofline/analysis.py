"""Three-term roofline model from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / peak_FLOP/s            (per chip)
  memory term     = HLO_bytes_accessed / HBM_bw        (per chip)
  collective term = wire_bytes / link_bw               (per chip)

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device after SPMD
partitioning). Wire bytes are parsed from the compiled HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
is charged its ring-algorithm wire traffic. Compressed-transport
collectives (uint8 byte planes — weight gathers, gradient reduce-scatters,
TP-axis activation pipelines) are charged at their true packed width and
reported separately as the plane-wire split (see
:mod:`repro.roofline.hlo_cost`).

Sequence-parallel steps (``Env.seq_parallel``) trade each block's
enter/exit psum pair for an ag + rs boundary pair
(``CompressionPolicy.seq_pair_wire_bytes`` — same ring volume at equal
width, docs/collectives.md): the activation all-reduce entries disappear
from these reports and reappear under all-gather / reduce-scatter /
all-to-all, packed-plane when an activation policy compresses.

The serving path has its own wire model:
:func:`serve_host_device_bytes` prices the continuous-batching engine's
host<->device token staging (the plan's ``host_device`` traffic class)
from the same ``CompressionPolicy`` formulas the engine's measured log
uses, so logged and analytic bytes are pinned equal.

Hardware constants (TPU v5e class, per chip): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

from repro.transport import ring_wire_bytes

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link (we charge one link direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLL_KINDS = (
    "all-gather-start", "all-gather",
    "all-reduce-start", "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute-start", "collective-permute",
)


def shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.groups()
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    """Participant count per replica group from HLO text."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 2  # conservative default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    wire_bytes: dict
    total_wire_bytes: int

    def to_dict(self):
        return {
            "counts": self.counts,
            "wire_bytes": self.wire_bytes,
            "total_wire_bytes": self.total_wire_bytes,
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Per-device wire bytes by collective kind (ring algorithm model)."""
    counts: dict[str, int] = {}
    wire: dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result shape = first shape token; op kind after " = <shape> "
        m = re.match(r"%?[\w.\-]+ = ([\w\[\],{}\/ ]*?)(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(-start)?\(", stripped)
        if not m:
            continue
        kind = m.group(2)
        out_match = _SHAPE_RE.search(stripped)
        out_bytes = shape_bytes(out_match.group(0)) if out_match else 0
        # operand shapes: inside the call parens
        paren = stripped[stripped.index("(") + 1 :]
        operand_bytes = sum(
            shape_bytes(sm.group(0)) for sm in _SHAPE_RE.finditer(paren)
        )
        n = _group_size(stripped)
        # ring model, shared with the transport policy accounting so the
        # analytical and measured byte counts cannot drift; all-gather and
        # all-to-all are charged on their output size per the formula's
        # contract (matches hlo_cost.py)
        payload = (
            out_bytes if kind in ("all-gather", "all-to-all") else operand_bytes
        )
        bytes_on_wire = int(ring_wire_bytes(kind, payload, n))
        counts[kind] = counts.get(kind, 0) + 1
        wire[kind] = wire.get(kind, 0) + bytes_on_wire
    return CollectiveStats(counts, wire, sum(wire.values()))


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    collectives: dict

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_from_compiled(
    compiled, model_flops_per_device: float, act_bytes: int = 4,
    *, seq_parallel: bool = False, plan=None, plan_geometry: dict | None = None,
) -> Roofline:
    """While-trip-aware roofline (see repro.roofline.hlo_cost for why raw
    cost_analysis cannot be used with scanned layer stacks).

    ``act_bytes``: wire width of *uncompressed* activation all-reduces.
    The CPU emulation backend promotes every sub-f32 collective to f32
    and cancels the down-casts (excess-precision pass), so a bf16 compute
    dtype cannot be observed in the emulated HLO; on TPU these psums run
    natively in the compute dtype. All all-reduces in this framework's
    step functions are activation psums (weight grads go through
    reduce-scatter), so they are charged at ``act_bytes`` analytically
    when < 4.

    A compressing activation policy needs no parameter here: it replaces
    TP psums with packed-plane reduce-scatter + all-gather pipelines
    whose u8 wire bytes appear *exactly* in the HLO (the CPU backend
    cannot promote u8). The plane-wire split is always reported in
    ``collectives`` and can be checked against
    ``CompressionPolicy.all_reduce_wire_bytes``.

    ``seq_parallel``: the step was built with ``Env.seq_parallel`` — the
    block-boundary wire is then an ag + rs pair per TP region instead of
    the 2× all-reduce decomposition
    (``CompressionPolicy.seq_pair_wire_bytes``). Compressed boundaries
    are u8 planes and need no correction; *uncompressed* boundaries put
    raw-dtype all-gather / reduce-scatter legs on the wire, and the CPU
    backend promotes the reducing half to f32 exactly like psums, so the
    same analytical ``act_bytes`` correction is applied to the non-plane
    reduce-scatter residue. (Caveat: only pass ``seq_parallel=True`` for
    steps whose weight-gradient reduce-scatters are compressed — an
    uncompressed f32 grad reduce-scatter is indistinguishable from an
    activation one in HLO text and would be wrongly scaled.)

    ``plan`` + ``plan_geometry`` (``dist_elems_per_group``,
    ``gather_axis_size``, optional ``training``): break the wire down by
    :class:`~repro.plan.PrecisionPlan` traffic class — the per-entry
    numbers come from the plan's ``CompressionPolicy`` formulas and the
    measured packed-plane residue (see
    :func:`repro.roofline.hlo_cost.plan_wire_split`); the table lands in
    ``collectives["per_plan_entry"]``."""
    from repro.roofline.hlo_cost import analyze_hlo, plan_wire_split

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    c = analyze_hlo(compiled.as_text())
    if act_bytes < 4 and "all-reduce" in c.wire:
        # scales only the raw-dtype psums: a compressing act_policy turns
        # TP psums into u8 all_to_all + all-gather plane pipelines (never
        # a u8 all-reduce), which are already exact in the HLO — the
        # all-reduce entries remaining here are the uncompressed
        # residue (no divisible split axis, grad syncs, loss scalars)
        c.wire["all-reduce"] *= act_bytes / 4.0
    if seq_parallel and act_bytes < 4 and "reduce-scatter" in c.wire:
        # seq-parallel exits are psum_scatters: promoted to f32 on the
        # CPU backend like psums; plane (u8) scatters stay exact
        raw_rs = c.wire["reduce-scatter"] - c.plane_wire.get(
            "reduce-scatter", 0
        )
        c.wire["reduce-scatter"] -= raw_rs * (1.0 - act_bytes / 4.0)
    flops = max(c.flops, raw_flops)
    hbm = max(c.bytes, raw_bytes)
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = c.wire_total / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops_per_device / flops if flops else 0.0
    per_plan_entry = None
    if plan is not None:
        per_plan_entry = plan_wire_split(c, plan, **(plan_geometry or {}))
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        wire_bytes=float(c.wire_total),
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        dominant=dominant,
        model_flops=model_flops_per_device,
        useful_ratio=useful,
        collectives={
            "counts": c.coll_counts,
            "wire_bytes": c.wire,
            # packed-plane (compressed transport) share of wire_bytes:
            # weight gathers, grad reduce-scatters, TP activation planes
            "plane_wire_bytes": c.plane_wire,
            "plane_wire_total": c.plane_wire_total,
            # wire bytes by PrecisionPlan traffic class (plan-driven runs)
            "per_plan_entry": per_plan_entry,
            "raw_cost_analysis": {"flops": raw_flops, "bytes": raw_bytes},
        },
    )


def serve_host_device_bytes(
    plan_or_policy,
    vocab_size: int,
    *,
    n_slots: int,
    prompt_lens,
    decode_steps: int,
    page_table_entries: int = 0,
) -> dict:
    """Analytic serve-wire model: host<->device staging bytes of one
    continuous-batching engine run (the serving twin of
    :meth:`~repro.plan.PrecisionPlan.wire_table`).

    Every term derives from
    :meth:`~repro.transport.CompressionPolicy.token_host_bytes` — the
    same formula the engine's measured ``step_log`` packing uses — so
    ``ServeEngine.wire_summary()["host_device"]`` must equal this
    table's ``total`` for the run's observed geometry
    (``tests/test_serve_engine.py`` pins it):

      * ``prompt_h2d``     — each admitted prompt (one ``prompt_lens``
        entry per admission) staged once, h2d;
      * ``first_token_d2h``— one sampled id per admission (the prefill
        logits' argmax) returning d2h;
      * ``decode_token_io``— per decode step the engine stages the full
        slot batch both ways (next-step feed h2d + sampled ids d2h),
        retired-slot ballast included — the honest cost of the
        fixed-shape batch;
      * ``page_table_h2d`` — paged engines re-stage the host page table
        (``page_table_entries`` = slots x table width, raw int32 — no
        token packing) every decode step; zero entries for the
        contiguous layout keeps the model backward compatible.
    """
    pol = plan_or_policy
    if hasattr(pol, "host_device_policies"):  # a PrecisionPlan
        pol = pol.host_device_policies()[0]
    prompt_lens = list(prompt_lens)
    admissions = len(prompt_lens)
    tok = pol.token_host_bytes
    table = {
        "prompt_h2d": tok(sum(prompt_lens), vocab_size),
        "first_token_d2h": tok(admissions, vocab_size),
        "decode_token_io": 2 * tok(n_slots, vocab_size) * int(decode_steps),
        "page_table_h2d": 4 * int(page_table_entries) * int(decode_steps),
        "token_width": pol.token_wire_width(vocab_size),
    }
    table["total"] = (
        table["prompt_h2d"] + table["first_token_d2h"]
        + table["decode_token_io"] + table["page_table_h2d"]
    )
    return table


def serve_spec_decode_bytes(
    plan_or_policy,
    vocab_size: int,
    *,
    n_slots: int,
    prompt_lens,
    spec_rounds: int,
    spec_k: int,
    page_table_entries: int = 0,
) -> dict:
    """Analytic serve-wire model for the **speculative** engine — the
    fourth measured==analytic pin (after the training collectives, the
    plain serve model, and the fleet migration fabric). Same
    ``token_host_bytes`` arithmetic as :func:`serve_host_device_bytes`,
    reshaped by the draft/verify protocol (``T = spec_k + 1``):

      * ``prompt_h2d``     — each admitted prompt staged once, h2d; the
        draft model prefills from the SAME staged device tokens on the
        local-admission path, so the prompt crosses the boundary once
        (migration admissions re-stage it for the draft — callers add
        one extra ``prompt_h2d``-shaped term per migrated prompt);
      * ``first_token_d2h``— one sampled id per admission, d2h;
      * ``draft_h2d``      — per round the draft runs ``T`` micro decode
        steps, each feeding the full slot batch one token h2d
        (``k`` sampled proposals + the absorb-only final step);
      * ``draft_d2h``      — per round ``k`` proposal batches return d2h
        (the absorb step samples nothing);
      * ``verify_token_io``— per round the target stages the ``(B, T)``
        verify block h2d and the ``T`` verified ids per slot d2h;
      * ``page_table_h2d`` — paged engines re-stage the (spec-widened)
        host table every verify step, raw int32.
    """
    pol = plan_or_policy
    if hasattr(pol, "host_device_policies"):  # a PrecisionPlan
        pol = pol.host_device_policies()[0]
    prompt_lens = list(prompt_lens)
    admissions = len(prompt_lens)
    tok = pol.token_host_bytes
    rounds, k = int(spec_rounds), int(spec_k)
    T = k + 1
    table = {
        "prompt_h2d": tok(sum(prompt_lens), vocab_size),
        "first_token_d2h": tok(admissions, vocab_size),
        "draft_h2d": rounds * tok(n_slots * T, vocab_size),
        "draft_d2h": rounds * tok(n_slots * k, vocab_size),
        "verify_token_io": 2 * rounds * tok(n_slots * T, vocab_size),
        "page_table_h2d": 4 * int(page_table_entries) * rounds,
        "token_width": pol.token_wire_width(vocab_size),
    }
    table["total"] = (
        table["prompt_h2d"] + table["first_token_d2h"]
        + table["draft_h2d"] + table["draft_d2h"]
        + table["verify_token_io"] + table["page_table_h2d"]
    )
    return table


def train_ingest_bytes(
    plan_or_policy,
    vocab_size: int,
    *,
    kind: str,
    batch: int,
    seq: int,
    steps: int,
    dim: int = 0,
    reader=None,
) -> dict:
    """Analytic training-ingest model: the byte cost of feeding ``steps``
    batches from the tiered shard pipeline (the training twin of
    :func:`serve_host_device_bytes`). Two terms, matching the measured
    per-step ``StepRecord.io_by_entry``:

      * ``shard_read`` — stored bytes the reader moves off disk. Pure
        manifest arithmetic (:meth:`~repro.data.shards.ShardReader.planned_bytes`
        from the reader's *current* position — order matters because
        per-record compressed plane sizes differ), so it prices the
        actual tier the reader's ``quality`` knob selects. 0 when no
        ``reader`` is passed (inline synthetic data reads no shards).
      * ``ingest_h2d`` — bytes staged across the host→device boundary at
        the plan's ``host_device``
        :class:`~repro.transport.CompressionPolicy`: integer ids packed
        to ``token_wire_width`` planes
        (:func:`~repro.data.prefetch.staged_ids_per_batch` ids per batch
        — LM stages the ``seq+1`` stream once, not tokens+labels
        separately) plus raw fp32 feature payloads
        (``batch·seq·dim·4``; lossy staging of training inputs would
        change the optimization problem).

    ``tests/scenarios/scenario_train_io.py`` pins both terms equal to
    the prefetcher's measured log."""
    from repro.data.prefetch import staged_ids_per_batch

    pol = plan_or_policy
    if pol is None:
        from repro.transport import CompressionPolicy

        pol = CompressionPolicy()
    elif hasattr(pol, "host_device_policies"):  # a PrecisionPlan
        pol = pol.host_device_policies()[0]
    steps = int(steps)
    ids = staged_ids_per_batch(kind, batch, seq) * steps
    float_bytes = 0
    if kind == "feature":
        float_bytes = 4 * batch * seq * int(dim) * steps
    table = {
        "shard_read": (
            reader.planned_bytes(batch * steps) if reader is not None else 0
        ),
        "ingest_h2d": pol.token_host_bytes(ids, vocab_size) + float_bytes,
        "token_width": pol.token_wire_width(vocab_size),
    }
    table["total"] = table["shard_read"] + table["ingest_h2d"]
    return table


def train_checkpoint_bytes(
    storage_like,
    opt_like=None,
    *,
    spec_tree=None,
    round_tos=None,
    residuals: bool = True,
) -> dict:
    """Analytic byte model of one width-aware sharded checkpoint — must
    equal :func:`repro.checkpoint.sharded.manifest_bytes` of the written
    directory (and the summed ``os.path.getsize`` of its ``.bin`` files;
    the train-I/O tests pin all three equal).

    Walks the same :func:`~repro.checkpoint.sharded.assign_widths` the
    writer uses: a compressible fp32 leaf in a group at ``round_to=rt``
    costs ``elems·rt`` wire bytes (+ ``elems·(4-rt)`` residual bytes
    when ``residuals``); every other storage leaf and the whole
    optimizer tree cost full width. No compression estimate is needed —
    checkpoint shards store raw planes, so the model is exact."""
    import numpy as np

    from repro.checkpoint.sharded import assign_widths, leaf_entries

    widths: dict[str, int] = {}
    if round_tos is not None and spec_tree is not None:
        widths = assign_widths(storage_like, spec_tree, round_tos)
    wire = residual = 0
    for tree, use_widths in ((storage_like, True), (opt_like, False)):
        if tree is None:
            continue
        for kpath, leaf in leaf_entries(tree):
            n = int(math.prod(leaf.shape)) if len(leaf.shape) else 1
            full = np.dtype(leaf.dtype).itemsize
            w = widths.get(kpath, full) if use_widths else full
            wire += n * w
            if residuals and w < full:
                residual += n * (full - w)
    return {"wire": wire, "residual": residual, "total": wire + residual}


def serve_paged_kv_bytes(
    cfg,
    *,
    page_size: int,
    requests,
    shared_prefix_len: int = 0,
    int8_kv: bool = False,
    dtype_bytes: int = 4,
) -> dict:
    """Analytic page-granular KV residency for the paged serve engine:
    the peak-resident byte model ``ServeEngine.kv_residency()`` must
    reproduce when every request is resident at once (the shared-prefix
    test pins measured == analytic).

    ``requests`` is an iterable of ``(prompt_len, max_new_tokens)``;
    ``shared_prefix_len`` tokens are common to ALL requests, so their
    whole pages (``shared_prefix_len // page_size``) are stored once and
    refcounted instead of per-request. Per page, every attention layer
    holds K + V — ``2 * page_size * num_kv_heads * head_dim`` elements
    at ``dtype_bytes`` (1 for int8 KV, which then adds two fp32 scale
    planes of ``page_size * num_kv_heads`` each).
    """
    reqs = list(requests)
    layers = cfg.num_groups * cfg.layers_per_group
    attn_frac = sum(1 for k in cfg.pattern if k == "attn") / len(cfg.pattern)
    attn_layers = int(layers * attn_frac)
    kv_elems = page_size * cfg.num_kv_heads * cfg.head_dim
    per_layer = 2 * kv_elems * (1 if int8_kv else dtype_bytes)
    if int8_kv:
        per_layer += 2 * page_size * cfg.num_kv_heads * 4  # fp32 scales
    bytes_per_page = per_layer * attn_layers
    shared_pages = shared_prefix_len // page_size
    private_pages = sum(
        -(-(s + g) // page_size) - shared_pages for s, g in reqs
    )
    pages = shared_pages + private_pages
    return {
        "bytes_per_page": bytes_per_page,
        "shared_pages": shared_pages,
        "private_pages": private_pages,
        "pages": pages,
        "kv_bytes_resident": pages * bytes_per_page,
    }


def fleet_migration_bytes(
    plan_or_policy,
    cfg,
    *,
    page_size: int,
    migrated_pages: int,
    int8_kv: bool = False,
    dtype_bytes: int = 4,
    publish_wire_bytes: int = 0,
    publish_installs: int = 0,
) -> dict:
    """Analytic fleet-fabric model: inter-replica parcel bytes of a
    disaggregated serving run — the third measured==analytic pin after
    the serve staging log and the checkpoint manifest. Must equal the
    :class:`~repro.transport.FabricChannel` hop log EXACTLY
    (``tests/scenarios/scenario_fleet.py`` pins both classes).

      * ``kv_migration`` — every migrated page ships each attention
        layer's K + V plane-packed at the ``kv_migration`` policy's
        :meth:`~repro.transport.CompressionPolicy.kv_wire_width` —
        the same :func:`serve_paged_kv_bytes` geometry, priced at wire
        width instead of resident width (int8 pools ship 1
        byte/element under a compressing policy, their fp32 scale
        planes always 4; an uncompressed policy pads everything to
        raw fp32 words). ``migrated_pages`` is the run's total new
        (non-shared-prefix) prompt pages — the router counts them.
      * ``weight_publish`` — each rolling-refresh install moves one
        checkpoint-tier parcel (``publish_wire_bytes``, already exact
        via :func:`train_checkpoint_bytes` /
        ``WeightParcel.manifest_meta``) across the fabric;
        ``publish_installs`` counts replica installs (join + refresh).
    """
    pol = plan_or_policy
    if hasattr(pol, "kv_migration_policy"):  # a PrecisionPlan
        pol = pol.kv_migration_policy()
    layers = cfg.num_groups * cfg.layers_per_group
    attn_frac = sum(1 for k in cfg.pattern if k == "attn") / len(cfg.pattern)
    attn_layers = int(layers * attn_frac)
    kv_elems = page_size * cfg.num_kv_heads * cfg.head_dim
    kv_width = pol.kv_wire_width(1 if int8_kv else dtype_bytes)
    per_layer = 2 * kv_elems * kv_width
    if int8_kv:
        # fp32 scale planes ride at full width under every policy
        per_layer += 2 * page_size * cfg.num_kv_heads * pol.kv_wire_width(4)
    page_wire_bytes = per_layer * attn_layers
    table = {
        "page_wire_bytes": page_wire_bytes,
        "kv_width": kv_width,
        "migrated_pages": int(migrated_pages),
        "kv_migration": page_wire_bytes * int(migrated_pages),
        "weight_publish": int(publish_wire_bytes) * int(publish_installs),
    }
    table["total"] = table["kv_migration"] + table["weight_publish"]
    return table


def model_flops_estimate(cfg, shape, chips: int) -> float:
    """6·N_active·D per device (decode: D = new tokens = batch)."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / chips
