"""Pallas TPU kernel: ADT Bitunpack — uint8 byte planes -> fp32.

Mirror of :mod:`repro.kernels.bitpack` (paper Algorithm 5): merge the kept
byte planes back into a uint32 word, zero-fill the discarded low bytes, and
bitcast to IEEE-754 fp32.  Like the paper's CUDA Bitunpack this is
embarrassingly parallel; on TPU each grid step processes one
``(round_to, BLOCK_ROWS, 128)`` VMEM block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitpack import BLOCK_ROWS, LANES, resolve_interpret

_SHIFTS = (24, 16, 8, 0)


def _bitunpack_kernel(planes_ref, out_ref, *, round_to: int):
    u = jnp.zeros(out_ref.shape, jnp.uint32)
    for k in range(round_to):
        u = u | (planes_ref[k, :, :].astype(jnp.uint32) << jnp.uint32(_SHIFTS[k]))
    out_ref[...] = jax.lax.bitcast_convert_type(u, jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def bitunpack_2d(
    planes: jnp.ndarray,
    *,
    interpret: bool | None = None,
    block_rows: int = BLOCK_ROWS,
) -> jnp.ndarray:
    """Unpack ``(round_to, rows, 128)`` u8 planes to ``(rows, 128)`` fp32."""
    round_to, rows, lanes = planes.shape
    if lanes != LANES:
        raise ValueError(f"last dim must be {LANES}, got {lanes}")
    if rows % block_rows:
        raise ValueError(f"rows ({rows}) must be a multiple of {block_rows}")
    grid = (rows // block_rows,)
    interpret = resolve_interpret(interpret)
    return pl.pallas_call(
        functools.partial(_bitunpack_kernel, round_to=round_to),
        grid=grid,
        in_specs=[
            pl.BlockSpec((round_to, block_rows, LANES), lambda i: (0, i, 0))
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(planes)
