"""Fleet workers: dedicated prefill and decode roles (`repro.fleet`).

Prefill/decode disaggregation splits the serve engine's two compiled
programs across processes: a :class:`PrefillWorker` owns the prefill
programs (one per page-bucketed prompt length), computes a request's KV
pages and first greedy token, and exports the freshly written pool
pages as host arrays; a :class:`DecodeReplica` wraps one paged
:class:`~repro.serve.engine.ServeEngine` driven through its streaming
surface (``admit_pages`` / ``decode_tick``), installing migrated pages
shipped through the :class:`~repro.transport.FabricChannel`.

Bit-exactness: the worker compiles the *same* prefill parametrization
as the engine's local path (page-rounded capacity, replicated layout,
true-last-token gather), and its page export replicates the engine's
``pool_write`` slicing math, so a migrated admission is
indistinguishable — bit for bit — from a local one. The fleet is
restricted to pure-attention causal archs (the same family where the
engine's prompt bucketing and prefix sharing are causal-safe); anything
else is rejected with a typed :class:`~repro.fleet.errors.ReplicaError`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet.errors import ReplicaError
from repro.models import model as M
from repro.serve.sampling import sample_tokens
from repro.serve.step import global_cache_shapes, make_prefill_step
from repro.transport import (
    pack_tokens,
    pack_tokens_host,
    stage,
    unpack_kv_pages,
    unpack_tokens,
    unpack_tokens_host,
)


def check_fleet_arch(cfg) -> None:
    """The fleet serves pure-attention causal token models only — the
    family where paged prompt bucketing, prefix sharing and therefore
    migrated prefill are causal-safe and slot-independent."""
    if not cfg.causal:
        raise ReplicaError(f"{cfg.name} is encoder-only: nothing to serve")
    if cfg.num_image_tokens or cfg.embed_is_input_stub:
        raise ReplicaError(
            f"{cfg.name}: fleet serving stages token payloads only"
        )
    if cfg.num_experts or any(k != "attn" for k in cfg.pattern):
        raise ReplicaError(
            f"{cfg.name}: fleet serving needs a pure-attention pattern "
            "(MoE capacity dispatch and recurrent state couple "
            "positions, breaking migrated-prefill equivalence)"
        )
    if cfg.sliding_window:
        raise ReplicaError(
            f"{cfg.name}: paged fleet serving keeps the full context "
            "resident — sliding-window archs stay on the static path"
        )


class PrefillWorker:
    """Dedicated prefill role: compiles the engine's paged prefill
    parametrization once per page bucket and exports prompt KV pages
    ready for migration.

    ``cache_capacity`` / ``page_size`` must match the decode fleet's
    geometry — the exported segment uses the same page-rounded prefill
    capacity and the same ``pool_write`` slicing as the engine's local
    insert, which is what makes migrated admission bit-exact.
    ``step_log`` records the worker's own host<->device staging (prompt
    h2d + first-token d2h), one record per prefill.
    """

    def __init__(self, name, cfg, mesh_cfg, mesh, spec_tree, *,
                 plan, cache_capacity: int, page_size: int = 64):
        check_fleet_arch(cfg)
        self.name = str(name)
        self.cfg = cfg
        self.mesh_cfg = mesh_cfg
        self.mesh = mesh
        self.spec_tree = spec_tree
        self.plan = plan.broadcast(cfg.num_groups + 1)
        self.cache_capacity = int(cache_capacity)
        self.page_size = int(page_size)
        if self.page_size < 1:
            raise ReplicaError(f"worker {self.name}: page_size must be >= 1")
        self._table_width = -(-self.cache_capacity // self.page_size)
        # page-rounded prefill capacity, the engine's paged parametrization
        self._cap_pre = self._table_width * self.page_size
        self.host_policy = self.plan.host_device_policies()[0]
        self.token_width = self.host_policy.token_wire_width(cfg.vocab_size)
        self._prefill_cache: dict[int, object] = {}
        self._unpack = jax.jit(unpack_tokens)
        vocab, width = cfg.vocab_size, self.token_width

        def sample_pack(logits):
            tok = jnp.argmax(
                logits[:, -1, :vocab], axis=-1
            ).astype(jnp.int32)
            return tok, pack_tokens(tok, width)

        self._sample = jax.jit(sample_pack)

        def sample_rng_pack(logits, temp, top_p, top_k, seed, step):
            tok = sample_tokens(
                logits[:, -1], vocab, temp, top_p, top_k, seed, step
            )
            return tok, pack_tokens(tok, width)

        self._sample_rng = jax.jit(sample_rng_pack)
        # minimal pool-shape tree (batch 1, one page): per-leaf dtypes
        # the export must land in — identical to the decode pool's
        self._pool_shapes = global_cache_shapes(
            cfg, mesh_cfg, 1, self.cache_capacity, self.plan.compute_dtype,
            shard_batch=False, per_slot=True, int8_kv=self.plan.int8_kv,
            paged_pages=1, page_size=self.page_size,
        )
        self.step_log: list[dict] = []

    def _prefill(self, prompt_len: int):
        if prompt_len not in self._prefill_cache:
            # batch["last"] (true last-token gather for padded prompts)
            # needs the replicated layout — same fallback as the engine
            wplan = dataclasses.replace(self.plan, seq_parallel=False)
            bshapes = {
                "tokens": jax.ShapeDtypeStruct((1, prompt_len), jnp.int32),
                "last": jax.ShapeDtypeStruct((), jnp.int32),
            }
            self._prefill_cache[prompt_len] = make_prefill_step(
                self.cfg, self.mesh_cfg, self.mesh, self.spec_tree, bshapes,
                plan=wplan, cache_capacity=self._cap_pre, shard_batch=False,
            )
        return self._prefill_cache[prompt_len]

    def prefill(self, storage, req, *, n_hits: int = 0):
        """Run one request's prefill under ``storage`` and export its
        new prompt pages.

        ``n_hits`` whole-prompt prefix pages are already resident at
        the destination (shared-prefix interning) and are skipped —
        the parcel only ships pages ``[n_hits:prompt_pages)``. Returns
        ``(pages, first)``: the export pytree (per group, per cache
        node, ``{"k", "v"(, scales)}`` arrays shaped
        ``(R, n_new, page, ...)`` in pool dtype) and the prompt's first
        token id, sampled under the request's own
        :class:`~repro.plan.SamplingParams` key fold (greedy requests
        keep the argmax fast path) — migrated admissions stay bit-exact
        against local ones.
        """
        S = len(req.prompt_ids)
        page = self.page_size
        prompt_pages = -(-S // page)
        if not 0 <= int(n_hits) <= S // page:
            raise ReplicaError(
                f"worker {self.name}: n_hits={n_hits} outside the "
                f"whole-prompt page range [0, {S // page}]"
            )
        if S + req.max_new > self.cache_capacity:
            raise ReplicaError(
                f"worker {self.name}: request {req.rid} needs "
                f"{S + req.max_new} positions, capacity is "
                f"{self.cache_capacity}"
            )
        rec = {"rid": req.rid, "prompt_len": S, "host_device": 0}
        planes = pack_tokens_host(
            np.asarray(req.prompt_ids, np.int32)[None, :], self.token_width
        )  # (w, 1, S) — h2d prompt staging (true length, no pads)
        rec["host_device"] += planes.nbytes
        tokens_dev = self._unpack(stage(planes))
        Spad = prompt_pages * page  # pure-attn: always page-bucketed
        if Spad > S:
            tokens_dev = jnp.pad(tokens_dev, ((0, 0), (0, Spad - S)))
        pbatch = {"tokens": tokens_dev,
                  "last": jnp.asarray(S - 1, jnp.int32)}
        logits, pcaches = self._prefill(Spad)(storage, pbatch)
        s = req.sampling
        if s.greedy:
            _, tok_planes = self._sample(logits)  # byte-identical path
        else:
            # same key-fold the engine's local admission uses — migrated
            # streams stay bit-exact against local ones
            _, tok_planes = self._sample_rng(
                logits,
                np.asarray([s.temperature], np.float32),
                np.asarray([s.top_p], np.float32),
                np.asarray([s.top_k], np.int32),
                np.asarray([s.seed], np.uint32),
                np.zeros((1,), np.int32),
            )
        tok_planes = np.asarray(tok_planes)  # (w, 1) — d2h first id
        rec["host_device"] += tok_planes.nbytes
        first = int(unpack_tokens_host(tok_planes)[0])
        pages = self._export(pcaches, int(n_hits), prompt_pages - int(n_hits))
        self.step_log.append(rec)
        return pages, first

    def _export(self, pcaches, n_hits: int, n_new: int):
        """Slice the prefill cache's freshly written positions into pool
        pages — the host-side twin of the engine's ``pool_write``
        (``dynamic_slice_in_dim(s[:, 0], start, n_new*page, axis=1)``
        then reshape to ``(R, n_new, page, ...)`` at pool dtype)."""
        page = self.page_size
        start, stop = n_hits * page, (n_hits + n_new) * page

        def leaf(src, like):
            arr = np.asarray(src)[:, 0]  # (R, cap_pre, ...)
            seg = arr[:, start:stop]
            seg = seg.reshape(arr.shape[0], n_new, page, *arr.shape[2:])
            return seg.astype(like.dtype)

        out = []
        for pg, sg in zip(self._pool_shapes, pcaches):
            gd = {}
            for key, pn in pg.items():
                attrs = ("k", "v")
                if isinstance(pn, M.PagedQuantKVCache):
                    attrs = ("k", "v", "k_scale", "v_scale")
                elif not isinstance(pn, M.PagedKVCache):
                    raise ReplicaError(
                        f"worker {self.name}: cache node {key!r} is not "
                        "a paged pool — fleet archs are pure-attention"
                    )
                sn = sg[key]
                gd[key] = {a: leaf(getattr(sn, a), getattr(pn, a))
                           for a in attrs}
            out.append(gd)
        return out


class DecodeReplica:
    """Decode role: one paged engine driven through its streaming
    surface. ``version`` is the installed weight-publish sequence
    number (``None`` until the router's first install)."""

    def __init__(self, name, engine):
        check_fleet_arch(engine.cfg)
        if not engine.paged:
            raise ReplicaError(
                f"replica {name}: fleet serving needs the paged engine "
                "(paged=True)"
            )
        self.name = str(name)
        self.engine = engine
        self.version: int | None = None
        self.draining = False
        engine.begin_stream()

    def probe(self, req):
        """Admission probe: ``(ok, resident prefix-page hits)``."""
        return self.engine.can_admit(req)

    def admit_parcel(self, req, parcel) -> None:
        """Install a migration parcel (routing metadata rides in
        ``parcel.meta``: skipped prefix pages + the worker's first
        token)."""
        self.engine.admit_pages(
            req, unpack_kv_pages(parcel),
            n_hits=parcel.meta["n_hits"], first_tok=parcel.meta["first"],
            wire_bytes=parcel.nbytes,
        )

    def tick(self) -> None:
        self.engine.decode_tick()

    def install(self, storage, version: int) -> None:
        """Hot-swap to a published weight version. The router only
        installs while the replica is idle (versioned-at-admission);
        this guard keeps that contract typed."""
        if self.engine.active_slots:
            raise ReplicaError(
                f"replica {self.name}: weight install with "
                f"{self.engine.active_slots} slots in flight"
            )
        self.engine.swap_weights(storage)
        self.version = int(version)
