"""Inter-replica fabric: the metered channel for fleet parcel traffic.

The serving fleet (``repro.fleet``) moves two new classes of bytes
between replicas, and both ride the same adaptive byte-plane
representation as every other wire class:

  * ``kv_migration`` — prefill→decode hand-off of paged KV. A prefill
    worker's freshly written pool pages are plane-split
    (:mod:`repro.utils.planes`, MSB-first) and shipped at
    :meth:`~repro.transport.CompressionPolicy.kv_wire_width` bytes per
    element: an uncompressed policy pads every element to raw fp32-width
    words (the staging analogue of raw int32 token ids), a compressing
    policy drops exactly the pad planes — never a resident byte, so the
    destination pool is BIT-EXACT vs local prefill (int8 pools ship 1
    byte/element, bf16 pools 2, fp32 leaves — including int8-KV scale
    rows — always 4).
  * ``weight_publish`` — trainer→replica checkpoint parcels. Leaves are
    encoded with the *same* tier codec as the on-disk sharded
    checkpointer (:func:`repro.checkpoint.sharded.encode_leaf` at the
    AWP controller's current widths), so a published parcel is
    byte-identical to a ``save_sharded`` directory: wire tiers only when
    the publish policy compresses (replicas restore at the transport's
    truncation), wire + residual when uncompressed (bitwise fp32).

:class:`FabricChannel` is the accounting boundary: every parcel crosses
via :meth:`FabricChannel.send`, which appends one per-hop log record —
the measured side of the ``fleet_migration_bytes`` analytic pin (the
third measured==analytic instance after the serve engine's staging pin
and the checkpoint manifest pin). Like ``hostdev.stage``, the channel
exists so fleet code has exactly one priced way to move replica-boundary
bytes (the UNPRICED-TRANSFER lint names this module for that reason).

This module is host-side numpy only (parcels are host byte strings;
staging a parcel's pages onto a device goes through the engine's normal
metered paths).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.transport.policy import CompressionPolicy
from repro.utils.planes import plane_join, plane_split

#: the two PrecisionPlan traffic classes priced on the fabric
FABRIC_CLASSES = ("kv_migration", "weight_publish")


class FabricError(Exception):
    """Fabric parcel / channel misuse (typed — survives ``-O``)."""


# ---------------------------------------------------------------------------
# KV page parcels (prefill -> decode migration)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVPageParcel:
    """Plane-packed paged-KV payload: one ``(wire, info)`` entry per
    cache pool leaf, plus free-form routing ``meta`` (request id, page
    count, prompt position — metadata, not priced wire bytes)."""

    entries: tuple[tuple[bytes, dict], ...]
    treedef: object
    meta: dict

    @property
    def nbytes(self) -> int:
        return sum(len(wire) for wire, _ in self.entries)


def pack_kv_pages(
    pages, policy: CompressionPolicy, *, meta: dict | None = None
) -> KVPageParcel:
    """Pack a pytree of extracted KV pages into a parcel.

    Every leaf is plane-split and shipped at
    ``policy.kv_wire_width(itemsize)`` bytes per element: widths above
    the leaf's own itemsize prepend all-zero MSB pad planes (the
    uncompressed fp32-word framing), widths never go below it — the
    parcel is lossless by construction.
    """
    leaves, treedef = jax.tree_util.tree_flatten(pages)
    entries = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        it = arr.dtype.itemsize
        width = policy.kv_wire_width(it)
        planes = plane_split(arr)
        if width > it:
            planes = np.concatenate(
                [np.zeros((width - it, planes.shape[1]), np.uint8), planes]
            )
        entries.append((
            planes.tobytes(),
            # str(dtype) (not .str) so extension dtypes such as the
            # KV pool's bfloat16 survive the trip — ml_dtypes registers
            # the names with numpy
            {"dtype": str(arr.dtype), "shape": list(arr.shape),
             "width": int(width)},
        ))
    return KVPageParcel(
        entries=tuple(entries), treedef=treedef, meta=dict(meta or {})
    )


def unpack_kv_pages(parcel: KVPageParcel):
    """Inverse of :func:`pack_kv_pages` — bitwise lossless: drop the pad
    planes, rejoin the leaf's own planes."""
    leaves = []
    for wire, e in parcel.entries:
        dtype = np.dtype(e["dtype"])
        shape = tuple(e["shape"])
        n = int(np.prod(shape)) if shape else 1
        width = int(e["width"])
        if len(wire) != width * n:
            raise FabricError(
                f"KV parcel leaf carries {len(wire)} bytes, expected "
                f"{width}x{n} (width x elements)"
            )
        planes = np.frombuffer(wire, np.uint8).reshape(width, n)
        leaves.append(plane_join(planes[width - dtype.itemsize:], dtype, shape))
    return jax.tree_util.tree_unflatten(parcel.treedef, leaves)


# ---------------------------------------------------------------------------
# weight parcels (trainer -> replica publish)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WeightParcel:
    """Tier-encoded storage tree: ``(wire, res, info)`` per leaf in
    canonical ``leaf_entries`` order, the in-memory twin of a
    ``save_sharded`` directory. ``version`` is the publish sequence
    number replicas key their hot-swap on."""

    entries: tuple[tuple[bytes, bytes | None, dict], ...]
    treedef: object
    version: int
    step: int
    residuals: bool

    @property
    def nbytes(self) -> int:
        return sum(
            len(wire) + (len(res) if res is not None else 0)
            for wire, res, _ in self.entries
        )

    def manifest_meta(self) -> dict:
        """Manifest-shaped view so ``checkpoint.sharded.manifest_bytes``
        prices a parcel exactly like an on-disk checkpoint."""
        return {"trees": {"storage": [info for _, _, info in self.entries]}}


def pack_weight_parcel(
    storage,
    *,
    spec_tree,
    round_tos,
    policy: CompressionPolicy,
    version: int,
    step: int = 0,
) -> WeightParcel:
    """Encode ``storage`` at the controller's current ``round_tos``
    widths using the checkpoint tier codec.

    A compressing ``weight_publish`` policy ships wire tiers only
    (replicas restore at the transport's truncation — the width-priced
    serving hand-off); an uncompressed policy ships wire + residual
    (bitwise fp32).
    """
    from repro.checkpoint.sharded import assign_widths, encode_leaf, leaf_entries

    widths = assign_widths(storage, spec_tree, round_tos)
    residuals = not policy.compresses
    leaves, treedef = jax.tree_util.tree_flatten(storage)
    entries = []
    for kpath, leaf in leaf_entries(storage):
        arr = np.asarray(leaf)
        wire, res, info = encode_leaf(
            arr, widths.get(kpath, arr.dtype.itemsize), residuals
        )
        info["path"] = kpath
        entries.append((wire, res, info))
    if len(entries) != len(leaves):
        raise FabricError(
            f"weight parcel leaf walk disagrees with tree_flatten "
            f"({len(entries)} vs {len(leaves)} leaves)"
        )
    return WeightParcel(
        entries=tuple(entries), treedef=treedef,
        version=int(version), step=int(step), residuals=residuals,
    )


def unpack_weight_parcel(parcel: WeightParcel, storage_like):
    """Decode a parcel against a structure-matching target tree.

    Residual-bearing parcels restore bitwise; wire-only parcels restore
    at the transport's truncation (quality="wire"), exactly like loading
    a ``residuals=False`` checkpoint export."""
    from repro.checkpoint.sharded import decode_leaf, leaf_entries

    want = leaf_entries(storage_like)
    if len(want) != len(parcel.entries):
        raise FabricError(
            f"weight parcel holds {len(parcel.entries)} leaves, restore "
            f"target has {len(want)}"
        )
    quality = "exact" if parcel.residuals else "wire"
    arrs = []
    for (wire, res, info), (kpath, leaf) in zip(parcel.entries, want):
        if info["path"] != kpath:
            raise FabricError(
                f"weight parcel structure mismatch at {kpath}: parcel "
                f"has {info['path']}"
            )
        if tuple(info["shape"]) != tuple(np.shape(leaf)):
            raise FabricError(
                f"weight parcel shape mismatch at {kpath}: parcel "
                f"{tuple(info['shape'])} vs target {tuple(np.shape(leaf))}"
            )
        arrs.append(decode_leaf(wire, info, quality, res, where="parcel"))
    treedef = jax.tree_util.tree_structure(storage_like)
    return jax.tree_util.tree_unflatten(treedef, arrs)


# ---------------------------------------------------------------------------
# the channel (per-hop measured log)
# ---------------------------------------------------------------------------


class FabricChannel:
    """The one priced way to move a parcel between replicas.

    Each :meth:`send` appends ``{"cls", "src", "dst", "bytes"}`` to the
    hop log — the measured side that ``roofline.fleet_migration_bytes``
    must equal EXACTLY (the fleet scenario pins it). The channel itself
    is a host-side accounting boundary: parcels are byte strings, and
    the caller hands the returned parcel to the destination replica.
    """

    def __init__(self):
        self.hops: list[dict] = []

    def send(self, parcel, *, cls: str, src: str, dst: str):
        if cls not in FABRIC_CLASSES:
            raise FabricError(
                f"unknown fabric traffic class {cls!r} "
                f"(valid: {FABRIC_CLASSES})"
            )
        nbytes = getattr(parcel, "nbytes", None)
        if nbytes is None:
            raise FabricError(
                f"fabric parcels must expose .nbytes, got {type(parcel)}"
            )
        self.hops.append({
            "cls": cls, "src": str(src), "dst": str(dst),
            "bytes": int(nbytes),
        })
        return parcel

    def wire_summary(self) -> dict:
        """Per-class measured totals + hop counts."""
        out = {cls: 0 for cls in FABRIC_CLASSES}
        counts = {cls: 0 for cls in FABRIC_CLASSES}
        for h in self.hops:
            out[h["cls"]] += h["bytes"]
            counts[h["cls"]] += 1
        out["hops"] = dict(counts)
        out["total"] = sum(out[cls] for cls in FABRIC_CLASSES)
        return out
