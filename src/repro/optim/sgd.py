"""Momentum SGD — the paper's optimizer (§IV-B: momentum 0.9, weight decay
5e-4, exponential LR decay). Operates directly on the flat storage shards;
the update is elementwise so layout is irrelevant."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 1e-2
    momentum: float = 0.9
    weight_decay: float = 5e-4
    # paper §IV-B: LR decays by 0.16 every `decay_every` batches
    lr_decay_rate: float = 0.16
    lr_decay_every: int = 0  # 0 = no decay


def lr_at(cfg: SGDConfig, step: int) -> float:
    if not cfg.lr_decay_every:
        return cfg.lr
    return cfg.lr * (cfg.lr_decay_rate ** (step // cfg.lr_decay_every))


def init_momentum(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def sgd_update(params, grads, momentum, wd_mask, cfg: SGDConfig, lr):
    """One momentum-SGD step. ``wd_mask``: pytree of {0,1} floats selecting
    which leaves get weight decay (matrices yes, norms/biases no)."""

    def upd(p, g, m, wd):
        g = g + cfg.weight_decay * wd * p
        m = cfg.momentum * m + g
        return p - lr * m, m

    out = jax.tree_util.tree_map(upd, params, grads, momentum, wd_mask)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, new_m
