"""mLSTM / sLSTM unit tests: chunkwise-vs-sequential equivalence, decode
continuation, state shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


def _inputs(B=2, S=64, H=2, dk=8, dv=8, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, dv)), jnp.float32)
    i = jnp.asarray(rng.normal(0, 0.5, (B, S, H)), jnp.float32)
    f = jax.nn.log_sigmoid(jnp.asarray(rng.normal(1, 0.5, (B, S, H)), jnp.float32))
    return q, k, v, i, f


def _sequential(q, k, v, i, f, st):
    def body(s, inp):
        s, h = ssm._mlstm_step(s, inp)
        return s, h

    st, hs = jax.lax.scan(
        body, st,
        (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
         v.transpose(1, 0, 2, 3), i.transpose(1, 0, 2), f.transpose(1, 0, 2)),
    )
    return hs.transpose(1, 0, 2, 3), st


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunkwise_matches_sequential(chunk):
    q, k, v, i, f = _inputs()
    st0 = ssm.init_mlstm_state(2, 2, 8, 8, jnp.float32)
    h1, s1 = _sequential(q, k, v, i, f, st0)
    h2, s2 = ssm.mlstm_chunkwise(q, k, v, i, f, st0, chunk)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1.C), np.asarray(s2.C), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s1.n), np.asarray(s2.n), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s1.m), np.asarray(s2.m), rtol=1e-4)


def test_chunkwise_state_continues_decode():
    """Train chunkwise, then decode one step == sequential throughout."""
    q, k, v, i, f = _inputs(S=32)
    st0 = ssm.init_mlstm_state(2, 2, 8, 8, jnp.float32)
    _, s_seq = _sequential(q, k, v, i, f, st0)
    _, s_chk = ssm.mlstm_chunkwise(q, k, v, i, f, st0, 8)
    qd, kd, vd, idd, fd = _inputs(S=1, seed=7)
    s1, h1 = ssm._mlstm_step(s_seq, (qd[:, 0], kd[:, 0], vd[:, 0], idd[:, 0], fd[:, 0]))
    s2, h2 = ssm._mlstm_step(s_chk, (qd[:, 0], kd[:, 0], vd[:, 0], idd[:, 0], fd[:, 0]))
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3, atol=1e-5)


def test_chunkwise_nonzero_initial_state():
    q, k, v, i, f = _inputs(S=16, seed=3)
    rng = np.random.default_rng(9)
    st0 = ssm.MLSTMState(
        jnp.asarray(rng.normal(0, 1, (2, 2, 8, 8)), jnp.float32),
        jnp.asarray(rng.normal(0, 1, (2, 2, 8)), jnp.float32),
        jnp.asarray(rng.normal(0, 0.5, (2, 2)), jnp.float32),
    )
    h1, s1 = _sequential(q, k, v, i, f, st0)
    h2, s2 = ssm.mlstm_chunkwise(q, k, v, i, f, st0, 8)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3, atol=1e-5)


def test_slstm_decode_matches_scan():
    from repro.configs.registry import get_config, reduced
    from repro.models.env import Env

    cfg = reduced(get_config("xlstm-1.3b"))
    env = Env()
    rng = np.random.default_rng(0)
    d = cfg.d_model
    w = {
        "ln": jnp.ones((d,)),
        "w_in": jnp.asarray(rng.normal(0, 0.05, (d, 4 * d)), jnp.float32),
        "r": jnp.asarray(
            rng.normal(0, 0.05, (cfg.num_heads, d // cfg.num_heads,
                                 4 * (d // cfg.num_heads))), jnp.float32),
        "b": jnp.zeros((4 * d,)),
        "w_out": jnp.asarray(rng.normal(0, 0.05, (d, d)), jnp.float32),
    }
    x = jnp.asarray(rng.normal(0, 1, (2, 6, d)), jnp.float32)
    y_full, st_full = ssm.slstm_block(x, w, cfg, env, mode="train")
    st = None
    ys = []
    for t in range(6):
        y, st = ssm.slstm_block(x[:, t:t+1], w, cfg, env, mode="decode", state=st)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(y_dec), rtol=2e-4, atol=2e-5
    )
    np.testing.assert_allclose(np.asarray(st_full.c), np.asarray(st.c), rtol=2e-4, atol=1e-5)
