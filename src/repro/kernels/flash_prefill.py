"""Pallas TPU kernel: online-softmax flash prefill attention.

The fused form of :func:`repro.models.attention.attend_tiled`: one grid
cell per ``(batch, head, q-block)`` runs the ``(m, l, acc)`` running
rescale over k-blocks *inside* the kernel, so the ``(Sq, Sk)`` score
matrix never round-trips through HBM — scores, softmax weights and the
weighted value sum live entirely in VMEM. That is the paper's thesis
applied to attention itself: the data motion (score traffic) shrinks,
the FLOPs stay identical.

Bit-compatibility contract (mirrors :mod:`repro.kernels.bitpack`):
:func:`flash_prefill_ref` is the pure-JAX oracle that replays the exact
tile schedule through the shared :func:`_flash_tile` update, so under
``interpret=True`` kernel and oracle agree *bitwise*
(``tests/test_kernels.py``). Dispatch follows ``resolve_interpret``:
compiled on a real TPU, interpreted elsewhere. The serving engine's CPU
reference path keeps using ``attend_tiled`` (the bit-exactness pin vs
``generate_static``); this kernel is the TPU fast path.

GQA layout: ``q (B, H, Sq, hd)`` attends ``k/v (B, Kv, Sk, hd)`` with
``G = H // Kv`` query heads sharing each kv head (the k/v BlockSpec
index map walks ``h // G``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitpack import resolve_interpret

NEG_INF = -1e30  # matches models.attention: exp() underflows to exact 0.0
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_tile(q, k, v, mask, m, l, acc):
    """One (block_q, block_k) online-softmax tile update.

    ``q (bq, hd)``, ``k/v (bk, hd)``, ``mask (bq, bk)`` bool,
    carry ``m/l (bq,)`` and ``acc (bq, hd)`` in fp32 — the same
    max/rescale algebra as ``attention._attend_tile``/``_combine``,
    fused into a single update. Shared VERBATIM by the kernel body and
    the oracle: bitwise parity under interpret mode is by construction.
    """
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (q.shape[-1] ** -0.5)
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[:, None] + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return m_new, l_new, acc_new


def _tile_mask(q_pos, j, block_q, block_k, causal):
    """(bq, bk) validity mask for k-block ``j`` (shared kernel/oracle)."""
    k_pos = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    if not causal:
        return jnp.ones((block_q, block_k), bool)
    return q_pos >= k_pos


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *,
                  block_k: int, seq_k: int, causal: bool, q_offset: int):
    qi = pl.program_id(2)
    bq, hd = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0]
    q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, block_k), 0
    )
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        k_blk = k_ref[0, 0, pl.ds(j * block_k, block_k)]
        v_blk = v_ref[0, 0, pl.ds(j * block_k, block_k)]
        mask = _tile_mask(q_pos, j, bq, block_k, causal)
        return _flash_tile(q, k_blk, v_blk, mask, m, l, acc)

    m, l, acc = jax.lax.fori_loop(0, seq_k // block_k, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def _resolve_blocks(Sq, Sk, block_q, block_k):
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q or Sk % block_k:
        raise ValueError(
            f"Sq={Sq}/Sk={Sk} must divide into blocks ({block_q}, {block_k})"
        )
    return block_q, block_k


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_prefill(
    q: jnp.ndarray,  # (B, H, Sq, hd)
    k: jnp.ndarray,  # (B, Kv, Sk, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused flash prefill attention; returns ``(B, H, Sq, hd)``.

    ``q_offset`` is the absolute position of ``q[..., 0, :]`` relative to
    ``k[..., 0, :]`` (prefill continuation), as in ``attend_tiled``. The
    full k/v sequence of one kv head is staged per grid cell, so the
    VMEM working set is ``O(Sk * hd)`` — prefill-sized sequences, not
    training contexts.
    """
    B, H, Sq, hd = q.shape
    Kv, Sk = k.shape[1], k.shape[2]
    if H % Kv:
        raise ValueError(f"H={H} not a multiple of Kv={Kv}")
    G = H // Kv
    block_q, block_k = _resolve_blocks(Sq, Sk, block_q, block_k)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, block_k=block_k, seq_k=Sk,
            causal=causal, q_offset=q_offset,
        ),
        grid=(B, H, Sq // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, hd), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Sk, hd), lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        interpret=resolve_interpret(interpret),
    )(q, k, v)


@functools.partial(
    jax.jit, static_argnames=("causal", "q_offset", "block_q", "block_k")
)
def flash_prefill_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jnp.ndarray:
    """Pure-JAX oracle: replays the kernel's exact tile schedule through
    the shared :func:`_flash_tile` update (bitwise-parity reference).

    The structure mirrors the kernel op-for-op — a ``fori_loop`` over
    k-blocks sliced with ``dynamic_slice``, under jit — because XLA's
    matmul accumulation order depends on that compilation context; an
    unrolled eager replay lands ~1 ulp away.
    """
    B, H, Sq, hd = q.shape
    Kv, Sk = k.shape[1], k.shape[2]
    G = H // Kv
    block_q, block_k = _resolve_blocks(Sq, Sk, block_q, block_k)
    out = jnp.zeros_like(q)
    for b in range(B):
        for h in range(H):
            k_head = jax.lax.dynamic_slice(k, (b, h // G, 0, 0), (1, 1, Sk, hd))[0, 0]
            v_head = jax.lax.dynamic_slice(v, (b, h // G, 0, 0), (1, 1, Sk, hd))[0, 0]
            for i in range(Sq // block_q):
                q_blk = jax.lax.dynamic_slice(
                    q, (b, h, i * block_q, 0), (1, 1, block_q, hd)
                )[0, 0]
                q_pos = q_offset + i * block_q + jax.lax.broadcasted_iota(
                    jnp.int32, (block_q, block_k), 0
                )
                m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
                l0 = jnp.zeros((block_q,), jnp.float32)
                a0 = jnp.zeros((block_q, hd), jnp.float32)

                def body(j, carry, q_blk=q_blk, q_pos=q_pos,
                         k_head=k_head, v_head=v_head):
                    m, l, acc = carry
                    k_blk = jax.lax.dynamic_slice(
                        k_head, (j * block_k, 0), (block_k, hd)
                    )
                    v_blk = jax.lax.dynamic_slice(
                        v_head, (j * block_k, 0), (block_k, hd)
                    )
                    mask = _tile_mask(q_pos, j, block_q, block_k, causal)
                    return _flash_tile(q_blk, k_blk, v_blk, mask, m, l, acc)

                m, l, acc = jax.lax.fori_loop(
                    0, Sk // block_k, body, (m0, l0, a0)
                )
                o = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(q.dtype)
                out = jax.lax.dynamic_update_slice(
                    out, o[None, None], (b, h, i * block_q, 0)
                )
    return out
