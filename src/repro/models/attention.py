"""GQA attention: flash-style tiled softmax, sliding windows, KV caches.

One implementation covers every assigned flavour:

  * causal / bidirectional (hubert) / cross (llama-vision),
  * GQA with kv-head replication when kv < TP degree,
  * qk-norm (qwen3), qkv-bias (qwen2.5), partial rotary (chatglm3),
  * sliding-window (mixtral SWA, recurrentgemma local, long_500k variant),
  * prefill (tiled, O(S·chunk) memory) and single-token decode with either a
    linear or ring-buffer KV cache.

The prefill path unrolls over q chunks with *exact* kv ranges (triangular /
banded), so HLO_FLOPs ≈ useful FLOPs — the masked-full-rectangle variant is
kept (``causal_skip=False``) as the §Perf baseline ablation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.env import Env
from repro.models.layers import apply_rope, head_rms_norm

NEG_INF = -1e30


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVCache:
    """Uniform-length KV cache. ``pos`` = number of tokens already absorbed.

    Capacity ``C = k.shape[1]``. When ``C < context`` the cache is used as a
    ring buffer (sliding-window decode)."""

    k: jnp.ndarray  # (B, C, Kv_local, head_dim)
    v: jnp.ndarray
    pos: jnp.ndarray  # () int32

    def tree_flatten(self):
        return (self.k, self.v, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantKVCache:
    """int8 KV cache with per-(slot, head) fp scales (beyond-paper §Perf:
    decode shapes are HBM-bound on cache reads; int8 quarters the traffic
    vs fp32, halves vs bf16)."""

    k: jnp.ndarray        # (B, C, Kv_local, head_dim) int8
    v: jnp.ndarray
    k_scale: jnp.ndarray  # (B, C, Kv_local) f32
    v_scale: jnp.ndarray
    pos: jnp.ndarray      # () int32

    def tree_flatten(self):
        return (self.k, self.v, self.k_scale, self.v_scale, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVCache:
    """Block-paged KV cache: a shared page *pool* instead of per-slot
    contiguous arrays. K/V for all slots live in ``(P, page, Kv_local,
    head_dim)`` pools; which pool rows a slot owns is decided by the
    host-side page table (``(B, n_pages)`` int32, staged into each decode
    step as ``batch["page_table"]`` — it is scheduler state, not cache
    state, so it does NOT travel in this pytree). The last pool row is
    the **trash page**: retired slots' ballast writes and unused table
    entries point there, so resident bytes track tokens actually written,
    not ``max_slots * capacity``.

    ``pos`` is the per-slot absorbed-token count, exactly as in the
    slotted :class:`KVCache` layout."""

    k: jnp.ndarray    # (P, page, Kv_local, head_dim) — row P-1 is trash
    v: jnp.ndarray
    pos: jnp.ndarray  # (B,) int32

    def tree_flatten(self):
        return (self.k, self.v, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def page_size(self) -> int:
        return self.k.shape[1]

    @property
    def num_pages(self) -> int:
        """Pool rows including the trailing trash page."""
        return self.k.shape[0]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedQuantKVCache:
    """int8 variant of :class:`PagedKVCache`: codes pools plus per-(page
    row, offset, head) fp32 scale pools."""

    k: jnp.ndarray        # (P, page, Kv_local, head_dim) int8
    v: jnp.ndarray
    k_scale: jnp.ndarray  # (P, page, Kv_local) f32
    v_scale: jnp.ndarray
    pos: jnp.ndarray      # (B,) int32

    def tree_flatten(self):
        return (self.k, self.v, self.k_scale, self.v_scale, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def page_size(self) -> int:
        return self.k.shape[1]

    @property
    def num_pages(self) -> int:
        return self.k.shape[0]


def _quantize_kv(x):
    """(B, S, Kv, hd) fp -> (int8 values, (B, S, Kv) scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def check_cache_geometry(capacity: int, window: Optional[int], context: int,
                         *, label: str = ""):
    """Guard against a KV cache that silently drops or evicts live tokens.

    ``mha``'s rule: a cache rings iff ``window is not None and capacity
    <= window``; a linear cache must hold the whole ``context``. Raised
    here (shared by ``init_cache``/``init_caches`` construction and the
    serve engine's per-request admission check) so the train-side
    windowed ring caches get the same guard as the serve path."""
    if context <= capacity:
        return
    ring = window is not None and capacity <= window
    if not ring:
        hint = (
            " (no sliding window)" if window is None else
            f" (window={window} does not ring: capacity "
            f"{capacity} > window — shrink the cache capacity to the "
            "window)"
        )
        raise ValueError(
            f"{label}context {context} exceeds cache capacity "
            f"{capacity}{hint}"
        )
    if capacity < window:
        # a wrapping ring narrower than the window evicts tokens the
        # attention mask still wants — streams would silently diverge
        raise ValueError(
            f"{label}context {context} wraps a ring cache of "
            f"{capacity} slots that is smaller than window={window}: "
            "live tokens would be evicted — set the cache capacity == "
            "window"
        )
    # capacity == window rings faithfully (wrapping IS window eviction)


def init_cache(batch: int, capacity: int, kv_heads: int, head_dim: int, dtype,
               per_slot: bool = False, *, window: Optional[int] = None,
               context: Optional[int] = None):
    """``per_slot=True`` gives the cache a ``(batch,)`` position vector —
    the serve engine's slotted layout where every request sits at its own
    sequence offset. Scalar ``pos`` (the default) keeps the historical
    uniform-batch semantics byte-for-byte.

    ``context`` (when known) is the number of tokens this cache will be
    asked to absorb: construction then runs :func:`check_cache_geometry`
    against ``window`` so a silently-evicting geometry fails loudly at
    build time instead of corrupting streams."""
    if context is not None:
        check_cache_geometry(capacity, window, context)
    pos = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    if dtype == jnp.int8:
        z = jnp.zeros((batch, capacity, kv_heads, head_dim), jnp.int8)
        sc = jnp.zeros((batch, capacity, kv_heads), jnp.float32)
        return QuantKVCache(z, z, sc, sc, pos)
    zeros = jnp.zeros((batch, capacity, kv_heads, head_dim), dtype)
    return KVCache(zeros, zeros, pos)


def init_paged_cache(batch: int, num_pages: int, page_size: int,
                     kv_heads: int, head_dim: int, dtype):
    """Paged pool + per-slot positions. ``num_pages`` counts *allocatable*
    pages; one extra trash row (index ``num_pages``) is appended for
    ballast writes and unused page-table entries."""
    P = num_pages + 1
    pos = jnp.zeros((batch,), jnp.int32)
    if dtype == jnp.int8:
        z = jnp.zeros((P, page_size, kv_heads, head_dim), jnp.int8)
        sc = jnp.zeros((P, page_size, kv_heads), jnp.float32)
        return PagedQuantKVCache(z, z, sc, sc, pos)
    zeros = jnp.zeros((P, page_size, kv_heads, head_dim), dtype)
    return PagedKVCache(zeros, zeros, pos)


# ---------------------------------------------------------------------------
# core softmax-attention tiles
# ---------------------------------------------------------------------------


def _attend_tile(q, k, v, mask):
    """Dense tile: q (B,Kv,G,Sq,hd), k/v (B,Sk,Kv,hd), mask (Sq,Sk) or None.

    Returns (scores_max, sumexp, acc) suitable for online combination.
    Scores/softmax accumulate in fp32 regardless of compute dtype."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum(
        "bkgqh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32
    ) * scale
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum(
        "bkgqs,bskh->bkgqh", p, v, preferred_element_type=jnp.float32
    )
    return m, l, acc


def _combine(m1, l1, a1, m2, l2, a2):
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    return m, l1 * c1 + l2 * c2, a1 * c1[..., None] + a2 * c2[..., None]


def attend_tiled(
    q: jnp.ndarray,  # (B, Sq, Kv, G, hd)
    k: jnp.ndarray,  # (B, Sk, Kv, hd)
    v: jnp.ndarray,
    *,
    causal: bool,
    window: Optional[int],
    q_offset: int = 0,
    chunk: int = 1024,
    causal_skip: bool = True,
) -> jnp.ndarray:
    """Flash-style tiled attention; returns (B, Sq, Kv, G, hd).

    ``q_offset``: absolute position of q[0] relative to k[0] (prefill
    continuation). q chunks are unrolled with exact kv ranges so that masked
    work is *not* lowered (unless causal_skip=False, the §Perf baseline)."""
    B, Sq, Kv, G, hd = q.shape
    Sk = k.shape[1]
    cq = min(chunk, Sq)
    if Sq % cq:
        raise ValueError(f"Sq={Sq} not divisible by chunk={cq}")
    # kv ranges are tiled in cq-sized blocks: pad kv up to a multiple and
    # mask the tail, otherwise a short kv (cross-attn image tokens with
    # Sk < cq, or Sk % cq != 0) is silently truncated to floor(Sk/cq)
    # whole blocks — zero attention output for Sk < cq
    sk_pad = ((Sk + cq - 1) // cq) * cq if Sk else 0
    if sk_pad != Sk:
        padw = [(0, 0)] * k.ndim
        padw[1] = (0, sk_pad - Sk)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    nq = Sq // cq
    outs = []
    for i in range(nq):
        q_i = q[:, i * cq : (i + 1) * cq].transpose(0, 2, 3, 1, 4)  # B,Kv,G,cq,hd
        q_pos_lo = q_offset + i * cq
        # exact kv range for this q chunk
        k_hi = min(Sk, q_pos_lo + cq) if (causal and causal_skip) else Sk
        k_lo = 0
        if window is not None and causal_skip:
            k_lo = max(0, q_pos_lo - window + 1)
        # align to chunk for tidy inner tiling
        k_lo = (k_lo // cq) * cq
        k_hi = min(sk_pad, ((k_hi + cq - 1) // cq) * cq)
        nk = (k_hi - k_lo) // cq if k_hi > k_lo else 0
        if nk == 0:
            outs.append(jnp.zeros((B, cq, Kv, G, hd), q.dtype))
            continue

        q_pos = q_pos_lo + jnp.arange(cq)

        def kv_block(j):
            lo = k_lo + j * cq
            kc = lax.dynamic_slice_in_dim(k, lo, cq, axis=1)
            vc = lax.dynamic_slice_in_dim(v, lo, cq, axis=1)
            k_pos = lo + jnp.arange(cq)
            mask = jnp.ones((cq, cq), bool)
            if sk_pad != Sk:
                mask &= k_pos[None, :] < Sk
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            return kc, vc, mask

        def body(carry, j):
            m, l, acc = carry
            kc, vc, mask = kv_block(j)
            m2, l2, a2 = _attend_tile(q_i, kc, vc, mask)
            return _combine(m, l, acc, m2, l2, a2), None

        m0 = jnp.full((B, Kv, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Kv, G, cq, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nk))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        outs.append(out.transpose(0, 3, 1, 2, 4))  # B,cq,Kv,G,hd
    return jnp.concatenate(outs, axis=1)


def _attend_decode_multi(q, cache, *, ring: bool, window: Optional[int]):
    """T-token block attention (the speculative-decoding verify step)
    over the already updated per-slot cache: block token j sits at
    absolute position ``pos - T + j`` and attends exactly its own
    prefix, including the block's earlier tokens. Every op reduces
    along the slot axis only, mirroring :func:`attend_decode`, so a
    T-block is bitwise the T successive single-token steps."""
    B, T, Kv, G, hd = q.shape
    if ring or window is not None or not jnp.ndim(cache.pos):
        raise ValueError(
            "multi-token decode (speculative verify) needs per-slot "
            "linear caches (no ring/window)"
        )
    C = cache.capacity
    slots = jnp.arange(C)
    tpos = (cache.pos - T)[:, None] + jnp.arange(T)  # (B, T) abs positions
    valid = slots[None, None, :] <= tpos[:, :, None]  # (B, T, C)
    vmask = valid[:, None, None]  # (B, 1, 1, T, C)
    scale = hd**-0.5
    quant = isinstance(cache, QuantKVCache)
    s = jnp.einsum(
        "btkgh,bskh->bkgts", q, cache.k, preferred_element_type=jnp.float32
    ) * scale
    if quant:
        s = s * cache.k_scale.transpose(0, 2, 1)[:, :, None, None, :]
    s = jnp.where(vmask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if quant:
        p = p * cache.v_scale.transpose(0, 2, 1)[:, :, None, None, :]
    return jnp.einsum(
        "bkgts,bskh->btkgh", p, cache.v, preferred_element_type=jnp.float32
    ).astype(q.dtype)


def attend_decode(
    q: jnp.ndarray,  # (B, T, Kv, G, hd) — T=1 outside speculative verify
    cache,
    *,
    ring: bool,
    window: Optional[int],
) -> jnp.ndarray:
    """Single-token attention over the (already updated) cache; handles
    both fp (KVCache) and int8 (QuantKVCache) layouts. ``T > 1``
    (the speculative verify block) dispatches to
    :func:`_attend_decode_multi`; the T=1 path below is unchanged.

    ``cache.pos`` may be a scalar (uniform batch — the historical path,
    kept bit-for-bit) or a ``(B,)`` vector (per-slot positions from the
    continuous-batching serve engine): the validity mask then becomes
    per-request, so every slot attends exactly its own prefix."""
    B, T, Kv, G, hd = q.shape
    if T > 1:
        return _attend_decode_multi(q, cache, ring=ring, window=window)
    C = cache.capacity
    pos = cache.pos - 1  # absolute position of the current token
    slots = jnp.arange(C)
    if jnp.ndim(pos):
        pos_b = pos[:, None]  # (B, 1)
        if ring:
            slot_pos = pos_b - jnp.mod(pos_b - slots[None, :], C)
        else:
            slot_pos = jnp.broadcast_to(slots[None, :], (B, C))
        valid = (slot_pos >= 0) & (slot_pos <= pos_b)
        if window is not None:
            valid &= (pos_b - slot_pos) < window
        vmask = valid[:, None, None, :]  # (B, 1, 1, C)
    else:
        if ring:
            # slot j currently holds absolute position: pos - ((pos-j) mod C)
            slot_pos = pos - jnp.mod(pos - slots, C)
        else:
            slot_pos = slots
        valid = (slot_pos >= 0) & (slot_pos <= pos)
        if window is not None:
            valid &= (pos - slot_pos) < window
        vmask = valid[None, None, None, :]
    scale = hd**-0.5
    qh = q[:, 0]  # B,Kv,G,hd
    quant = isinstance(cache, QuantKVCache)
    s = jnp.einsum(
        "bkgh,bskh->bkgs", qh, cache.k, preferred_element_type=jnp.float32
    ) * scale
    if quant:
        # scores were computed against int8 codes: apply per-slot scales
        s = s * cache.k_scale.transpose(0, 2, 1)[:, :, None, :]
    s = jnp.where(vmask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if quant:
        p = p * cache.v_scale.transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum(
        "bkgs,bskh->bkgh", p, cache.v, preferred_element_type=jnp.float32
    ).astype(q.dtype)
    return out[:, None]


def attend_decode_paged(
    q: jnp.ndarray,  # (B, 1, Kv, G, hd)
    cache,           # PagedKVCache | PagedQuantKVCache (already updated)
    page_table: jnp.ndarray,  # (B, n_pages) int32
    *,
    window: Optional[int] = None,
    impl: Optional[str] = None,
) -> jnp.ndarray:
    """Single-token attention over the paged pool.

    ``impl=None`` dispatches like ``kernels.bitpack.resolve_interpret``:
    the fused page-walking Pallas kernel on a real TPU (fp caches, no
    window), the dense reference elsewhere. ``impl="dense"`` gathers the
    slot's pages into a contiguous per-slot view and runs the *exact*
    ``attend_decode`` ops — positions past ``pos`` mask to ``NEG_INF``
    so their softmax weight is exactly 0.0, which keeps paged streams
    bit-identical to the contiguous engine layout."""
    quant = isinstance(cache, PagedQuantKVCache)
    if impl is None:
        impl = (
            "pallas"
            if jax.default_backend() == "tpu" and not quant
            and window is None and q.shape[1] == 1
            else "dense"
        )
    if impl == "pallas":
        from repro.kernels.paged_attention import paged_attend

        out = paged_attend(q[:, 0], cache.k, cache.v, page_table, cache.pos)
        return out[:, None]
    B = q.shape[0]
    n_pages = page_table.shape[1]
    cap = n_pages * cache.page_size
    gk = cache.k[page_table].reshape(B, cap, *cache.k.shape[2:])
    gv = cache.v[page_table].reshape(B, cap, *cache.v.shape[2:])
    if quant:
        gks = cache.k_scale[page_table].reshape(B, cap, -1)
        gvs = cache.v_scale[page_table].reshape(B, cap, -1)
        dense = QuantKVCache(gk, gv, gks, gvs, cache.pos)
    else:
        dense = KVCache(gk, gv, cache.pos)
    return attend_decode(q, dense, ring=False, window=window)


def _paged_write(cache, k, v, page_table):
    """Scatter the decoded token block into each slot's pages.

    ``k/v (B, T, Kv, hd)`` — T=1 is the ordinary decode step (path kept
    bit-for-bit), T=k+1 the speculative verify block. Logical page
    ``pos // page`` is clamped to the table width: retired-ballast
    slots (table all-trash, ``pos`` still advancing) then keep writing
    into the trash page, and under speculative decoding the engine
    widens the table so a verify block near end-of-capacity clamps
    into unallocated (trash) entries, never a live page."""
    B = page_table.shape[0]
    page = cache.page_size
    pos = cache.pos  # (B,) tokens absorbed BEFORE this block
    T = k.shape[1]
    if T == 1:
        pi = jnp.minimum(pos // page, page_table.shape[1] - 1)
        phys = page_table[jnp.arange(B), pi]  # (B,)
        off = jnp.mod(pos, page)
        if isinstance(cache, PagedQuantKVCache):
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            return PagedQuantKVCache(
                cache.k.at[phys, off].set(kq[:, 0]),
                cache.v.at[phys, off].set(vq[:, 0]),
                cache.k_scale.at[phys, off].set(ks[:, 0]),
                cache.v_scale.at[phys, off].set(vs[:, 0]),
                pos + 1,
            )
        return PagedKVCache(
            cache.k.at[phys, off].set(k[:, 0].astype(cache.k.dtype)),
            cache.v.at[phys, off].set(v[:, 0].astype(cache.v.dtype)),
            pos + 1,
        )
    tpos = pos[:, None] + jnp.arange(T)  # (B, T) absolute positions
    pi = jnp.minimum(tpos // page, page_table.shape[1] - 1)
    phys = page_table[jnp.arange(B)[:, None], pi]  # (B, T)
    off = jnp.mod(tpos, page)
    if isinstance(cache, PagedQuantKVCache):
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        return PagedQuantKVCache(
            cache.k.at[phys, off].set(kq),
            cache.v.at[phys, off].set(vq),
            cache.k_scale.at[phys, off].set(ks),
            cache.v_scale.at[phys, off].set(vs),
            pos + T,
        )
    return PagedKVCache(
        cache.k.at[phys, off].set(k.astype(cache.k.dtype)),
        cache.v.at[phys, off].set(v.astype(cache.v.dtype)),
        pos + T,
    )


def _flash_prefill_viable(causal, window, is_cross, pos_offset, qg, k):
    """The fused flash kernel handles the plain causal prefill shape on a
    real TPU; everything else (CPU tests — the bit-exactness pins — and
    windows/cross/per-slot offsets/untiled lengths) keeps ``attend_tiled``."""
    if jax.default_backend() != "tpu":
        return False
    if not causal or window is not None or is_cross:
        return False
    if jnp.ndim(pos_offset):
        return False
    B, Sq, Kv, G, hd = qg.shape
    Sk = k.shape[1]
    if hd % 128:
        return False
    return Sq % 128 == 0 and Sk % 128 == 0


def _flash_prefill_call(qg, k, v, *, q_offset):
    """(B,S,Kv,G,hd) q / (B,Sk,Kv,hd) kv -> fused kernel layouts and back."""
    from repro.kernels.flash_prefill import flash_prefill

    B, Sq, Kv, G, hd = qg.shape
    qf = qg.transpose(0, 2, 3, 1, 4).reshape(B, Kv * G, Sq, hd)
    kf = k.transpose(0, 2, 1, 3)
    vf = v.transpose(0, 2, 1, 3)
    out = flash_prefill(qf, kf, vf, causal=True, q_offset=q_offset)
    return out.reshape(B, Kv, G, Sq, hd).transpose(0, 3, 1, 2, 4)


# ---------------------------------------------------------------------------
# full attention layer (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------


def mha(
    x: jnp.ndarray,  # (B, S, d) — model-axis replicated
    w: dict,
    cfg,
    env: Env,
    *,
    mode: str = "train",  # train | prefill | decode
    cache: Optional[KVCache] = None,
    window: Optional[int] = None,
    kv_ext: Optional[jnp.ndarray] = None,  # cross-attn source (B, N, d)
    is_cross: bool = False,
    pos_offset=0,
    page_table: Optional[jnp.ndarray] = None,  # (B, n_pages) — paged decode
) -> tuple[jnp.ndarray, Optional[KVCache]]:
    """One attention layer. Returns (out (B,S,d), updated cache).

    Under ``env.seq_parallel`` the incoming ``x`` is a sequence shard;
    ``env.enter`` all-gathers it, so every shape below derives from the
    gathered ``xin`` (full sequence), and ``env.exit`` reduce-scatters
    the output back onto shards."""
    hd = cfg.head_dim
    # head counts from the (TP-local, possibly padded) weights themselves
    Hq_l = w["wq"].shape[1] // hd
    Kv_l = w["wk"].shape[1] // hd
    G = Hq_l // Kv_l
    is_cross = is_cross or (kv_ext is not None)

    xin = env.enter(x)
    B, S, _ = xin.shape
    q = xin @ w["wq"]
    if cfg.qkv_bias:
        q = q + w["bq"]
    q = q.reshape(B, S, Hq_l, hd)

    # image KV are replicated (never sequence-sharded): always the psum pair
    kv_src = env.psum_enter(kv_ext) if is_cross else xin
    if is_cross and mode == "decode":
        k = v = None  # cross KV live in the cache, computed at prefill
    else:
        k = kv_src @ w["wk"]
        v = kv_src @ w["wv"]
        if cfg.qkv_bias:
            k = k + w["bk"]
            v = v + w["bv"]
        Skv = kv_src.shape[1]
        k = k.reshape(B, Skv, Kv_l, hd)
        v = v.reshape(B, Skv, Kv_l, hd)

    if cfg.qk_norm:
        q = head_rms_norm(q, w["q_norm"], cfg.norm_eps)
        if k is not None:
            k = head_rms_norm(k, w["k_norm"], cfg.norm_eps)

    if not is_cross:
        if jnp.ndim(pos_offset):  # (B,) per-slot offsets (serve engine)
            q_pos = pos_offset[:, None] + jnp.arange(S)
            k_pos = pos_offset[:, None] + jnp.arange(k.shape[1])
        else:
            q_pos = pos_offset + jnp.arange(S)
            k_pos = pos_offset + jnp.arange(k.shape[1])
        q = apply_rope(q, q_pos, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
        k = apply_rope(k, k_pos, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)

    qg = q.reshape(B, S, Kv_l, G, hd)
    new_cache = cache
    paged = isinstance(cache, (PagedKVCache, PagedQuantKVCache))

    if paged and mode != "decode":
        raise ValueError(
            "paged caches are decode-only: prefill runs on contiguous "
            "caches and the serve engine scatters them into pages"
        )
    if mode == "decode" and paged:
        if page_table is None:
            raise ValueError(
                "paged decode needs a page_table (S=1 ordinary decode, "
                "S=k+1 the speculative verify block)"
            )
        if window is not None:
            raise ValueError(
                "paged KV keeps the full context: sliding-window decode "
                "stays on the contiguous ring layout"
            )
        new_cache = _paged_write(cache, k, v, page_table)
        out = attend_decode_paged(qg, new_cache, page_table)
    elif mode == "decode" and not is_cross:
        if cache is None:
            raise ValueError("decode needs a KV cache")
        C = cache.capacity
        ring = window is not None and C <= window
        per_slot = jnp.ndim(cache.pos) > 0
        if S != 1 and (not per_slot or window is not None):
            raise ValueError(
                f"multi-token decode (S={S}) needs per-slot linear "
                "caches (no ring/window)"
            )
        idx = jnp.mod(cache.pos, C) if ring else cache.pos
        if per_slot and S > 1:
            # speculative verify block: scatter all S tokens at
            # (slot, pos + j); mode="drop" skips past-capacity writes
            # (ballast slots and block tails past the stop position,
            # both never attended)
            bi2 = jnp.arange(B)[:, None]
            idx2 = cache.pos[:, None] + jnp.arange(S)  # (B, S)
            if isinstance(cache, QuantKVCache):
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                new_cache = QuantKVCache(
                    cache.k.at[bi2, idx2].set(kq, mode="drop"),
                    cache.v.at[bi2, idx2].set(vq, mode="drop"),
                    cache.k_scale.at[bi2, idx2].set(ks, mode="drop"),
                    cache.v_scale.at[bi2, idx2].set(vs, mode="drop"),
                    cache.pos + S,
                )
            else:
                new_cache = KVCache(
                    cache.k.at[bi2, idx2].set(
                        k.astype(cache.k.dtype), mode="drop"
                    ),
                    cache.v.at[bi2, idx2].set(
                        v.astype(cache.v.dtype), mode="drop"
                    ),
                    cache.pos + S,
                )
        elif per_slot:
            # per-request write positions (continuous batching): a batched
            # scatter at (slot, idx[slot]); mode="drop" silently skips
            # requests whose linear cache is already full (a retired slot
            # the engine keeps decoding as ballast)
            bi = jnp.arange(B)
            if isinstance(cache, QuantKVCache):
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                kc = cache.k.at[bi, idx].set(kq[:, 0], mode="drop")
                vc = cache.v.at[bi, idx].set(vq[:, 0], mode="drop")
                ksc = cache.k_scale.at[bi, idx].set(ks[:, 0], mode="drop")
                vsc = cache.v_scale.at[bi, idx].set(vs[:, 0], mode="drop")
                new_cache = QuantKVCache(kc, vc, ksc, vsc, cache.pos + 1)
            else:
                kc = cache.k.at[bi, idx].set(
                    k[:, 0].astype(cache.k.dtype), mode="drop"
                )
                vc = cache.v.at[bi, idx].set(
                    v[:, 0].astype(cache.v.dtype), mode="drop"
                )
                new_cache = KVCache(kc, vc, cache.pos + 1)
        elif isinstance(cache, QuantKVCache):
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            kc = lax.dynamic_update_slice(cache.k, kq, (0, idx, 0, 0))
            vc = lax.dynamic_update_slice(cache.v, vq, (0, idx, 0, 0))
            ksc = lax.dynamic_update_slice(cache.k_scale, ks, (0, idx, 0))
            vsc = lax.dynamic_update_slice(cache.v_scale, vs, (0, idx, 0))
            new_cache = QuantKVCache(kc, vc, ksc, vsc, cache.pos + 1)
        else:
            kc = lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, idx, 0, 0)
            )
            vc = lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, idx, 0, 0)
            )
            new_cache = KVCache(kc, vc, cache.pos + 1)
        out = attend_decode(qg, new_cache, ring=ring, window=window)
    elif mode == "decode" and is_cross:
        # cross-attention during decode: attend to static image KV
        out = _cross_decode(qg, cache)
        new_cache = cache
    else:
        causal = cfg.causal and not is_cross
        q_off = int(pos_offset) if isinstance(pos_offset, int) else 0
        if _flash_prefill_viable(causal, window, is_cross, pos_offset, qg, k):
            out = _flash_prefill_call(qg, k, v, q_offset=q_off)
        else:
            out = attend_tiled(
                qg, k, v,
                causal=causal,
                window=window,
                q_offset=q_off,
                chunk=min(env.attn_chunk, S),
                causal_skip=env.causal_skip,
            )
        if mode == "prefill":
            if is_cross:
                new_cache = KVCache(k, v, jnp.asarray(k.shape[1], jnp.int32))
            else:
                if cache is None:
                    raise ValueError("prefill needs a pre-allocated KV cache")
                C = cache.capacity
                pos = jnp.asarray(S, jnp.int32)
                # C < S keeps the trailing window, ROLLED so absolute
                # position p sits at slot p % C — the layout the ring
                # decode formula (attend_decode) and the ring write index
                # (idx = pos % C above) both assume
                if isinstance(cache, QuantKVCache):
                    ks, kv_sc = _quantize_kv(k if C >= S else k[:, S - C:])
                    vs, vv_sc = _quantize_kv(v if C >= S else v[:, S - C:])
                    if C >= S:
                        kc = lax.dynamic_update_slice(cache.k, ks, (0, 0, 0, 0))
                        vc = lax.dynamic_update_slice(cache.v, vs, (0, 0, 0, 0))
                        ksc = lax.dynamic_update_slice(cache.k_scale, kv_sc, (0, 0, 0))
                        vsc = lax.dynamic_update_slice(cache.v_scale, vv_sc, (0, 0, 0))
                    else:
                        r = S % C
                        kc = jnp.roll(ks, r, axis=1)
                        vc = jnp.roll(vs, r, axis=1)
                        ksc = jnp.roll(kv_sc, r, axis=1)
                        vsc = jnp.roll(vv_sc, r, axis=1)
                    new_cache = QuantKVCache(kc, vc, ksc, vsc, pos)
                else:
                    kc, vc = cache.k, cache.v
                    if C >= S:
                        kc = lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, 0, 0, 0))
                        vc = lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, 0, 0, 0))
                    else:
                        kc = jnp.roll(k[:, S - C :], S % C, axis=1).astype(kc.dtype)
                        vc = jnp.roll(v[:, S - C :], S % C, axis=1).astype(vc.dtype)
                    new_cache = KVCache(kc, vc, pos)

    out = out.reshape(B, S, Hq_l * hd)
    y = out @ w["wo"]
    if is_cross and "gate" in w:
        y = jnp.tanh(w["gate"]) * y
    return env.exit(y), new_cache


def _cross_decode(qg, cache: KVCache):
    """Decode-time gated cross attention over the static image KV."""
    B, S, Kv, G, hd = qg.shape
    scale = hd**-0.5
    s = jnp.einsum(
        "bqkgh,bskh->bkgqs", qg, cache.k, preferred_element_type=jnp.float32
    ) * scale
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskh->bkgqh", p, cache.v, preferred_element_type=jnp.float32
    ).astype(qg.dtype)
    return out.transpose(0, 3, 1, 2, 4)
