"""jaxpr communication walker: find every wire-moving equation statically.

``collect_comm_eqns`` descends a traced (closed) jaxpr through every
sub-jaxpr carrier — ``pjit`` bodies, ``shard_map`` (which also binds the
mesh axis sizes), ``scan`` (whose ``length`` multiplies everything
inside), ``while``/``cond`` (data-dependent control flow: collectives
under either are recorded and later rejected — their static trip/branch
counts are unknowable, so their wire bytes are unpriceable), ``remat``
replays and custom-derivative bodies — and returns one :class:`CommEqn`
per communication primitive it finds. No device is touched; this is
pure metadata over the trace.

The walker is deliberately dumb: it records *what the program does*
(primitive, axes, operand/result avals, static trip multiplier) and
nothing about what the plan *intended*. Attribution and byte pinning
live in :mod:`repro.audit.audit`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

# Primitives that move bytes between devices (or across the host/device
# boundary, for device_put). ``psum2`` is the rep-checking spelling of
# ``psum``; ``reduce_scatter`` is what ``lax.psum_scatter`` traces to.
COMM_PRIMS = frozenset({
    "all_gather",
    "all_to_all",
    "psum",
    "psum2",
    "pmax",
    "pmin",
    "reduce_scatter",
    "ppermute",
    "device_put",
})
# Zero-wire replication bookkeeping emitted by rep-checking shard_map.
_IGNORED = frozenset({"pbroadcast", "pvary"})


class JaxprWalkError(ValueError):
    """The jaxpr contains a communication eqn the walker cannot price
    (unknown axis name, positional psum axis, ...)."""


@dataclasses.dataclass(frozen=True)
class CommEqn:
    """One communication equation found in the trace.

    ``mult`` is the static execution multiplier (product of enclosing
    ``scan`` lengths); ``in_ctrl`` marks eqns under ``while``/``cond``
    bodies whose trip count is not static. Shapes/dtypes are the
    operand → result avals of the primitive itself: for packed-plane
    pipelines the leading dim of a ``uint8`` aval is the plane count
    (the wire width the transport chose).
    """

    prim: str
    axes: tuple[str, ...]
    group_size: int
    in_shape: tuple[int, ...]
    in_dtype: str
    out_shape: tuple[int, ...]
    out_dtype: str
    mult: int
    path: str
    in_ctrl: bool = False
    axis_index_groups: bool = False

    # -- aval-derived byte views (per execution, before ``mult``) -------
    @property
    def in_bytes(self) -> int:
        return math.prod(self.in_shape) * _itemsize(self.in_dtype)

    @property
    def out_bytes(self) -> int:
        return math.prod(self.out_shape) * _itemsize(self.out_dtype)

    @property
    def is_packed(self) -> bool:
        """A uint8 plane pipeline (the transport's compressed format):
        planes are packed with the width as the leading dim."""
        return (
            self.in_dtype == "uint8"
            and len(self.in_shape) >= 1
            and self.prim in ("all_gather", "all_to_all", "reduce_scatter")
        )

    @property
    def plane_width(self) -> int | None:
        """Wire bytes/element the packed pipeline actually used."""
        return self.in_shape[0] if self.is_packed else None

    @property
    def payload_elems(self) -> int:
        """Logical (pre-packing) element count of the collective's
        payload: *output* elements for gather-like ops, *input* elements
        for reduce-like ops — matching the ring formula's payload
        convention (:func:`repro.transport.ring_wire_bytes`)."""
        if self.prim in ("all_gather",):
            total, shape = math.prod(self.out_shape), self.out_shape
        else:
            total, shape = math.prod(self.in_shape), self.in_shape
        if self.is_packed:
            return total // shape[0]
        return total

    def describe(self) -> str:
        ax = ",".join(self.axes) or "-"
        mult = f" x{self.mult}" if self.mult != 1 else ""
        return (
            f"{self.prim}[{ax}|n={self.group_size}] "
            f"{self.in_dtype}{list(self.in_shape)} -> "
            f"{self.out_dtype}{list(self.out_shape)}{mult} @{self.path}"
        )


_ITEMSIZE = {
    "uint8": 1, "int8": 1, "bool": 1,
    "bfloat16": 2, "float16": 2, "uint16": 2, "int16": 2,
    "float32": 4, "int32": 4, "uint32": 4,
    "float64": 8, "int64": 8, "uint64": 8,
}


def _itemsize(dtype_name: str) -> int:
    try:
        return _ITEMSIZE[dtype_name]
    except KeyError as e:
        raise JaxprWalkError(f"unknown dtype {dtype_name!r}") from e


def _axis_names(eqn) -> tuple[str, ...]:
    p = eqn.params
    raw: Any
    if eqn.primitive.name in ("psum", "psum2", "pmax", "pmin"):
        raw = p.get("axes")
    else:
        raw = p.get("axis_name")
    if raw is None:
        raise JaxprWalkError(
            f"{eqn.primitive.name}: no axis parameter in {sorted(p)}"
        )
    if not isinstance(raw, (tuple, list)):
        raw = (raw,)
    names = []
    for a in raw:
        if not isinstance(a, str):
            raise JaxprWalkError(
                f"{eqn.primitive.name}: positional axis {a!r} in a "
                "shard_map body (only named mesh axes are priceable)"
            )
        names.append(a)
    return tuple(names)


def _sub_jaxprs(params):
    """Yield every jaxpr-valued entry of an eqn's params (open or
    closed, scalar or sequence) — the generic recursion surface that
    covers pjit / scan / while / cond / remat / custom-vjp bodies."""
    for key, v in params.items():
        items = v if isinstance(v, (list, tuple)) else (v,)
        for item in items:
            if hasattr(item, "eqns"):
                yield key, item
            elif hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                yield key, item.jaxpr


def _record(eqn, axis_sizes, mult, in_ctrl, path, out):
    name = eqn.primitive.name
    if name == "device_put":
        for iv, ov in zip(eqn.invars, eqn.outvars):
            out.append(CommEqn(
                prim=name, axes=(), group_size=1,
                in_shape=tuple(iv.aval.shape), in_dtype=iv.aval.dtype.name,
                out_shape=tuple(ov.aval.shape), out_dtype=ov.aval.dtype.name,
                mult=mult, path=path, in_ctrl=in_ctrl,
            ))
        return
    axes = _axis_names(eqn)
    group = 1
    for a in axes:
        if a not in axis_sizes:
            raise JaxprWalkError(
                f"{name}: axis {a!r} not bound by any enclosing "
                f"shard_map mesh (known: {sorted(axis_sizes)})"
            )
        group *= int(axis_sizes[a])
    aig = eqn.params.get("axis_index_groups") is not None
    # psum is multiple-results: one CommEqn per operand/result pair so
    # attribution can match shapes leaf-by-leaf
    for iv, ov in zip(eqn.invars, eqn.outvars):
        if not hasattr(iv.aval, "shape"):  # pragma: no cover - tokens
            continue
        out.append(CommEqn(
            prim="psum" if name == "psum2" else name,
            axes=axes, group_size=group,
            in_shape=tuple(iv.aval.shape), in_dtype=iv.aval.dtype.name,
            out_shape=tuple(ov.aval.shape), out_dtype=ov.aval.dtype.name,
            mult=mult, path=path, in_ctrl=in_ctrl,
            axis_index_groups=aig,
        ))


def _walk(jaxpr, axis_sizes, mult, in_ctrl, path, out):
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in _IGNORED:
            continue
        if name == "shard_map":
            mesh = eqn.params["mesh"]
            inner = dict(axis_sizes)
            inner.update(
                (str(k), int(v)) for k, v in dict(mesh.shape).items()
            )
            for key, sub in _sub_jaxprs(eqn.params):
                _walk(sub, inner, mult, in_ctrl, f"{path}/shard_map", out)
            continue
        if name in COMM_PRIMS:
            _record(eqn, axis_sizes, mult, in_ctrl, path, out)
            continue
        child_mult = mult
        child_ctrl = in_ctrl
        if name == "scan":
            child_mult = mult * int(eqn.params.get("length", 1))
        elif name in ("while", "cond"):
            child_ctrl = True
        for key, sub in _sub_jaxprs(eqn.params):
            _walk(sub, axis_sizes, child_mult, child_ctrl,
                  f"{path}/{name}", out)


def collect_comm_eqns(jaxpr_like) -> list[CommEqn]:
    """All communication eqns of a (closed) jaxpr, in trace order."""
    jaxpr = getattr(jaxpr_like, "jaxpr", jaxpr_like)
    out: list[CommEqn] = []
    _walk(jaxpr, {}, 1, False, "", out)
    return out
