"""Subprocess scenario: the PrecisionPlan drives train/serve/roofline
end-to-end on an 8-device mesh.

  * chunks>1: the plan-selected double-buffered weight gather is
    BIT-exact vs chunks=1 (losses, norms and updated storage identical),
    in train and prefill.
  * grad_mode="stochastic": the plumbed PRNG key reaches the backward
    gradient pack — training descends, same key reproduces bit-exactly,
    different keys give different updates.
  * CNN repro eval: stochastic vs nearest gradient rounding on the
    paper's DP CNN setting — both descend to comparable loss/error.
  * plan JSON file -> step factory round-trip (the launchers' --plan path).
  * roofline per-plan-entry report: the compiled HLO's packed-plane
    all-gather / all-to-all wire equals the plan's analytic weights /
    gradients entries (the CompressionPolicy formulas).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.dist.spec import (
    MeshCfg, build_spec_tree, dist_elems_per_group, tree_to_storage,
)
from repro.launch.mesh import make_mesh_from_cfg
from repro.models.init import init_params
from repro.optim.sgd import SGDConfig, init_momentum
from repro.plan import PrecisionPlan, pick_chunks
from repro.roofline.hlo_cost import analyze_hlo, plan_wire_split
from repro.serve.step import make_prefill_step
from repro.train.step import make_train_step
from repro.transport import CompressionPolicy

MESH_CFG = MeshCfg(tp=2, dp=4)
OPT = SGDConfig(lr=0.05, momentum=0.9, weight_decay=0.0)


def _setup(cfg, mesh_cfg):
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    spec = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec, mesh_cfg)
    return spec, storage


def _batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }


def run_chunked_bit_exact(mesh):
    """chunks>1 (incl. the sweep-selected count) == chunks=1, bitwise."""
    cfg = reduced(get_config("qwen3-1.7b"))
    nrt = cfg.num_groups + 1
    B, S = 8, 32
    batch = _batch(cfg, B, S)
    bsh = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    spec, _ = _setup(cfg, MESH_CFG)

    # sweep-selected chunk count for a representative shard (the
    # ROADMAP's "pick block sizes from a roofline sweep")
    elems = dist_elems_per_group(spec, MESH_CFG, nrt)
    s_loc = max(elems) // max(MESH_CFG.dshards, 1)
    auto = pick_chunks(s_loc, MESH_CFG.dshards, 2)
    results = {}
    for chunks in (1, 2, auto):
        if chunks in results:
            continue
        plan = PrecisionPlan.build(nrt, round_to=2, chunks=chunks)
        _, storage = _setup(cfg, MESH_CFG)
        step = make_train_step(cfg, MESH_CFG, mesh, spec, OPT, bsh, plan=plan)
        st, mom, met = step(storage, init_momentum(storage), batch, 0.05)
        st2, _, met2 = step(st, mom, _batch(cfg, B, S, 1), 0.05)
        results[chunks] = (
            float(met["loss"]), float(met2["loss"]),
            [np.asarray(x) for x in jax.tree_util.tree_leaves(st2)],
        )
    l1, l1b, leaves1 = results[1]
    for chunks, (lc, lcb, leaves) in results.items():
        assert lc == l1 and lcb == l1b, (chunks, lc, l1)
        for a, b in zip(leaves, leaves1):
            np.testing.assert_array_equal(a, b)
    print(f"  chunked gather bit-exact (chunks 1 == 2 == auto({auto})) OK")

    # prefill path too (serve weight gathers)
    sb = {"tokens": bsh["tokens"]}
    logits = {}
    for chunks in (1, 4):
        _, storage = _setup(cfg, MESH_CFG)
        pre = make_prefill_step(
            cfg, MESH_CFG, mesh, spec, sb,
            plan=PrecisionPlan.build(nrt, round_to=2, chunks=chunks),
            cache_capacity=S + 2,
        )
        lg, _ = pre(storage, {"tokens": batch["tokens"]})
        logits[chunks] = np.asarray(lg)
    np.testing.assert_array_equal(logits[1], logits[4])
    print("  chunked prefill bit-exact OK")


def run_stochastic_grads(mesh):
    """grad_mode='stochastic' end-to-end: descends, reproducible per key."""
    cfg = reduced(get_config("qwen3-1.7b"))
    nrt = cfg.num_groups + 1
    B, S = 8, 32
    batch = _batch(cfg, B, S)
    bsh = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    spec, _ = _setup(cfg, MESH_CFG)
    plan = PrecisionPlan.build(
        nrt, round_to=2, grad_round_to=2, grad_mode="stochastic"
    )
    assert plan.needs_rng
    step = make_train_step(cfg, MESH_CFG, mesh, spec, OPT, bsh, plan=plan)

    _, st = _setup(cfg, MESH_CFG)
    mom = init_momentum(st)
    losses = []
    for i in range(4):
        st, mom, m = step(st, mom, batch, 0.05, jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses

    # same key -> bit-identical step; different key -> different update
    def one(key):
        _, st0 = _setup(cfg, MESH_CFG)
        s, _, m = step(st0, init_momentum(st0), batch, 0.05, key)
        return np.concatenate([
            np.asarray(x).ravel() for x in jax.tree_util.tree_leaves(s)
        ]), float(m["loss"])

    va, la = one(jax.random.PRNGKey(7))
    vb, lb = one(jax.random.PRNGKey(7))
    vc, lc = one(jax.random.PRNGKey(8))
    np.testing.assert_array_equal(va, vb)
    assert np.any(va != vc), "different keys must give different updates"
    # nearest twin stays close: stochastic rounding is noise around it
    plan_n = PrecisionPlan.build(nrt, round_to=2, grad_round_to=2)
    step_n = make_train_step(cfg, MESH_CFG, mesh, spec, OPT, bsh, plan=plan_n)
    _, st0 = _setup(cfg, MESH_CFG)
    _, _, mn = step_n(st0, init_momentum(st0), batch, 0.05)
    assert abs(la - float(mn["loss"])) < 0.05 + 0.05 * abs(la)
    print(f"  stochastic grads: descends {losses}, reproducible, "
          f"keyed OK")


def run_cnn_stochastic_vs_nearest(mesh_unused):
    """Paper CNN repro: stochastic vs nearest gradient rounding both
    train; the §V-style eval stays comparable (DP grad reduce-scatter)."""
    from repro.data.pipeline import SyntheticImageNet
    from repro.models.cnn import ALEXNET, init_cnn, reduced_cnn
    from repro.train.cnn_step import (
        build_cnn_spec_tree, cnn_to_storage, make_cnn_eval,
        make_cnn_train_step,
    )

    cfg = reduced_cnn(ALEXNET, num_classes=10, in_hw=32)
    data = SyntheticImageNet(num_classes=10, hw=32, noise=0.1)
    mesh_cfg = MeshCfg(tp=1, dp=4, compress_min_size=256)
    mesh = make_mesh_from_cfg(mesh_cfg)

    def train(grad_mode, steps=20):
        params, metas, gi = init_cnn(cfg, jax.random.PRNGKey(0))
        spec = build_cnn_spec_tree(params, metas, mesh_cfg)
        st = cnn_to_storage(params, spec, mesh_cfg)
        _, ng = gi
        plan = PrecisionPlan.build(
            ng, round_to=2, grad_round_to=2, grad_mode=grad_mode,
        )
        with mesh:
            step = make_cnn_train_step(
                cfg, mesh_cfg, mesh, spec, gi,
                SGDConfig(lr=0.05, momentum=0.9, weight_decay=5e-4), {},
                plan=plan,
            )
            mom = init_momentum(st)
            losses = []
            for i in range(steps):
                imgs, labels = data.batch(64, i)
                st, mom, m = step(
                    st, mom, {"images": imgs, "labels": labels}, 0.05,
                    jax.random.PRNGKey(i),
                )
                losses.append(float(m["loss"]))
            ev = make_cnn_eval(cfg, mesh_cfg, mesh, spec, gi, plan=plan)
            imgs, labels = data.validation(128)
            err = float(ev(st, imgs, labels))
        return losses, err

    ln, en = train("nearest")
    ls, es = train("stochastic")
    assert np.isfinite(ln).all() and np.isfinite(ls).all()
    assert ln[-1] < ln[0] and ls[-1] < ls[0], (ln, ls)
    assert abs(ls[-1] - ln[-1]) < 0.2 + 0.1 * abs(ln[-1]), (ln[-1], ls[-1])
    assert abs(es - en) < 0.25, (en, es)
    print(f"  CNN grad rounding: nearest loss {ln[-1]:.3f} err {en:.3f} | "
          f"stochastic loss {ls[-1]:.3f} err {es:.3f} OK")


def run_plan_json_drive(mesh):
    """--plan path: JSON file -> factory -> training step (launcher route)."""
    cfg = reduced(get_config("qwen3-1.7b"))
    nrt = cfg.num_groups + 1
    B, S = 8, 32
    batch = _batch(cfg, B, S)
    bsh = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    spec, storage = _setup(cfg, MESH_CFG)
    plan = PrecisionPlan.build(
        1, round_to=2, grad_round_to=2, grad_mode="stochastic",
        act_round_to=2, chunks=2, schedule="awp",
    )
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "plan.json")
        plan.to_file(path)
        loaded = PrecisionPlan.from_file(path).broadcast(nrt)
    step = make_train_step(cfg, MESH_CFG, mesh, spec, OPT, bsh, plan=loaded)
    st, mom, m = step(storage, init_momentum(storage), batch, 0.05,
                      jax.random.PRNGKey(0))
    assert np.isfinite(float(m["loss"]))
    print(f"  plan.json -> train step (awp/stochastic/chunked/act2) OK")


def run_roofline_per_entry(mesh):
    """Compiled-HLO plane wire == the plan's analytic weights/gradients
    entries (the CompressionPolicy formulas): the plan is the unit of
    cost accounting, and the measured and analytic sides agree."""
    cfg = reduced(get_config("qwen3-1.7b"))
    nrt = cfg.num_groups + 1
    B, S = 8, 32
    bsh = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    spec, storage = _setup(cfg, MESH_CFG)
    plan = PrecisionPlan.build(nrt, round_to=2, grad_round_to=2)
    step = make_train_step(cfg, MESH_CFG, mesh, spec, OPT, bsh, plan=plan)
    mom = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), storage
    )
    batch = _batch(cfg, B, S)
    with mesh:
        compiled = step.lower(
            storage, mom, batch, jax.ShapeDtypeStruct((), jnp.float32)
        ).compile()
    cost = analyze_hlo(compiled.as_text())
    elems = dist_elems_per_group(spec, MESH_CFG, nrt)
    split = plan_wire_split(
        cost, plan, elems, MESH_CFG.dshards, training=True
    )
    # no activation policy: every packed plane belongs to the weight
    # gathers (u8 all-gather) or the gradient reduce-scatters (u8
    # all-to-all) — measured == analytic per entry
    ag = cost.plane_wire.get("all-gather", 0)
    a2a = cost.plane_wire.get("all-to-all", 0)
    np.testing.assert_allclose(ag, split["weights"], rtol=1e-3)
    np.testing.assert_allclose(a2a, split["gradients"], rtol=1e-3)
    # no act policy and no remat on the reduced config: every plane byte
    # is attributed, the residue is ~0
    assert split["plane_residue"] <= max(
        1e-3 * cost.plane_wire_total, 64
    ), split
    assert split["measured_plane_wire"] == round(cost.plane_wire_total)
    print(f"  per-plan-entry roofline: weights {split['weights']/1e6:.2f}MB "
          f"== plane-ag, gradients {split['gradients']/1e6:.2f}MB == "
          f"plane-a2a OK")


def main():
    mesh = make_mesh_from_cfg(MESH_CFG)
    with mesh:
        run_chunked_bit_exact(mesh)
        run_stochastic_grads(mesh)
        run_plan_json_drive(mesh)
        run_roofline_per_entry(mesh)
    run_cnn_stochastic_vs_nearest(None)
    print("scenario_plan OK")


if __name__ == "__main__":
    main()
