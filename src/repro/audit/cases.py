"""Build auditable (step, abstract args) combos from the registry.

Mirrors ``launch/dryrun.py``'s combo builder but at audit scale: reduced
configs, tiny shapes, and a mesh sized from the MeshCfg (no 512-device
host flag). Tracing is abstract — no arrays are ever materialized.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.configs.registry import get_config, reduced
from repro.configs.shapes import applicable, input_specs
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.launch.mesh import make_mesh_from_cfg
from repro.models.init import param_shapes
from repro.optim.sgd import SGDConfig
from repro.plan import PrecisionPlan
from repro.serve.step import (
    global_cache_shapes,
    make_decode_step,
    make_place_step,
    make_prefill_step,
)
from repro.train.step import make_train_step

#: the plan points the acceptance sweep pins (AWP twice: the initial
#: 8-bit widths and a heterogeneous mid-run widening — per-group rt
#: 1/2/4 exercises mixed-format inventories in one trace)
PLAN_NAMES = ("rt4", "rt2", "awp", "awp_widened")


def parse_mesh(spec: str) -> MeshCfg:
    """``"dpxtp"`` (launcher convention: ``2x1`` = fsdp-2, ``1x2`` =
    tp-2) or ``"podsxdpxtp"`` for the multi-pod hierarchy."""
    parts = [int(p) for p in spec.split("x")]
    if len(parts) == 2:
        return MeshCfg(dp=parts[0], tp=parts[1])
    if len(parts) == 3:
        return MeshCfg(pods=parts[0], dp=parts[1], tp=parts[2])
    raise ValueError(f"mesh spec {spec!r} (want dpxtp or podsxdpxtp)")


def make_plan(name: str, num_entries: int, *,
              seq_parallel: bool = False) -> PrecisionPlan:
    if name == "rt4":
        plan = PrecisionPlan.build(1, round_to=4, seq_parallel=seq_parallel)
    elif name == "rt2":
        plan = PrecisionPlan.build(
            1, round_to=2, grad_round_to=2, act_round_to=2,
            seq_parallel=seq_parallel,
        )
    elif name in ("awp", "awp_widened"):
        # awp_initial_bits=8 -> every group starts at rt=1; the widened
        # variant is a mid-run controller step materialized via
        # with_round_tos (how the trainer rebuilds the step)
        plan = PrecisionPlan.build(
            1, round_to=1, grad_round_to=2, act_round_to=2,
            schedule="awp", seq_parallel=seq_parallel,
        )
        if name == "awp_widened":
            plan = plan.broadcast(num_entries).with_round_tos(
                tuple(itertools.islice(
                    itertools.cycle((1, 2, 4)), num_entries
                ))
            )
    else:
        raise ValueError(f"unknown plan name {name!r} (want {PLAN_NAMES})")
    return plan.broadcast(num_entries)


@dataclasses.dataclass
class AuditCase:
    """Everything ``audit_step`` needs for one registry combo."""

    arch: str
    kind: str
    mesh_cfg: MeshCfg
    mesh: object
    plan: PrecisionPlan
    spec_tree: dict
    step: object
    args: tuple


def _sds_tree(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def build_case(
    arch: str,
    kind: str,
    mesh_cfg: MeshCfg,
    plan: PrecisionPlan,
    *,
    seq_len: int = 32,
    global_batch: int = 4,
    cfg: ModelConfig | None = None,
) -> AuditCase | None:
    """One auditable combo, or None when the combo does not apply
    (e.g. decode on an encoder-only arch)."""
    cfg = reduced(get_config(arch)) if cfg is None else cfg
    shape = InputShape(f"audit_{kind}", seq_len, global_batch,
                       "train" if kind == "place" else kind)
    if kind != "place":
        ok, _ = applicable(cfg, shape)
        if not ok:
            return None
    plan = plan.broadcast(cfg.num_groups + 1)
    mesh = make_mesh_from_cfg(mesh_cfg)
    storage_abs, metas = param_shapes(cfg, tp=mesh_cfg.tp)
    spec_tree = build_spec_tree(storage_abs, metas, mesh_cfg)
    storage = tree_to_storage(storage_abs, spec_tree, mesh_cfg)
    shard_batch = shape.global_batch >= mesh_cfg.dshards

    if kind == "place":
        step, _ = make_place_step(cfg, mesh_cfg, mesh, spec_tree, plan=plan)
        return AuditCase(arch, kind, mesh_cfg, mesh, plan, spec_tree,
                         step, (storage,))

    batch = input_specs(cfg, shape)
    if kind == "train":
        step = make_train_step(
            cfg, mesh_cfg, mesh, spec_tree, SGDConfig(), batch, plan=plan
        )
        args = (storage, _sds_tree(storage), batch,
                jax.ShapeDtypeStruct((), jnp.float32))
        if plan.needs_rng:
            args = args + (jax.ShapeDtypeStruct((2,), jnp.uint32),)
        return AuditCase(arch, kind, mesh_cfg, mesh, plan, spec_tree,
                         step, args)

    if kind == "prefill":
        step = make_prefill_step(
            cfg, mesh_cfg, mesh, spec_tree, batch, plan=plan,
            cache_capacity=shape.seq_len, shard_batch=shard_batch,
        )
        return AuditCase(arch, kind, mesh_cfg, mesh, plan, spec_tree,
                         step, (storage, batch))

    if kind == "decode":
        capacity = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
        cache_dtype = jnp.int8 if plan.int8_kv else jnp.bfloat16
        caches = global_cache_shapes(
            cfg, mesh_cfg, shape.global_batch, capacity, cache_dtype,
            shard_batch=shard_batch,
        )
        step = make_decode_step(
            cfg, mesh_cfg, mesh, spec_tree, batch, plan=plan,
            shard_batch=shard_batch,
        )
        return AuditCase(arch, kind, mesh_cfg, mesh, plan, spec_tree,
                         step, (storage, caches, batch))

    raise ValueError(f"unknown kind {kind!r}")
