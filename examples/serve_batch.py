"""Continuous-batching serving demo: the request queue, slotted KV cache
and host<->device staged tokens, end-to-end on CPU.

Mixed-length prompts are admitted into a small pool of KV slots as they
free up (prefill/decode interleave); every request's stream is bit-exact
against the static one-shot reference path, and the engine's measured
``host_device`` wire log matches the analytic roofline serve model.

Run:  PYTHONPATH=src python examples/serve_batch.py --arch qwen3-1.7b \
          --requests 6 --gen 16 --max-slots 2
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.models.init import init_params
from repro.plan import PrecisionPlan
from repro.roofline.analysis import serve_host_device_bytes
from repro.serve.engine import Request, ServeEngine, generate_static
from repro.transport import CompressionPolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=2)
    ap.add_argument("--round-to", type=int, default=2,
                    help="ADT wire format for weight placement + the "
                         "host_device staging entry")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    if cfg.num_image_tokens:
        raise SystemExit(
            f"{args.arch} has image inputs — the engine stages token "
            "payloads only; serve it via "
            "`python -m repro.launch.serve ... --static`"
        )
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=4096)

    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=args.round_to),)
        * (cfg.num_groups + 1),
        host_device=CompressionPolicy(round_to=args.round_to),
    )

    rng = np.random.default_rng(0)
    lens = [24 + 8 * (i % 3) for i in range(args.requests)]  # mixed lengths
    requests = [
        Request(
            rid=i,
            prompt_ids=tuple(int(t) for t in rng.integers(0, cfg.vocab_size, S)),
            max_new=args.gen,
        )
        for i, S in enumerate(lens)
    ]

    engine = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
        max_slots=args.max_slots, cache_capacity=max(lens) + args.gen,
    )
    t0 = time.time()
    results = engine.run(requests)
    wall = time.time() - t0

    total_new = sum(len(r.tokens) for r in results.values())
    s = engine.wire_summary()
    print(f"arch={cfg.name}  requests={args.requests}  prompts={lens}  "
          f"slots={args.max_slots}")
    print(f"engine: {s['steps']} steps in {wall:.2f}s "
          f"({total_new / max(wall, 1e-9):.1f} tok/s on CPU, incl. compile)")

    analytic = serve_host_device_bytes(
        plan, cfg.vocab_size, n_slots=args.max_slots,
        prompt_lens=lens, decode_steps=s["decode_steps"],
    )
    print(f"host_device wire: measured {s['host_device']} B == analytic "
          f"{analytic['total']} B at {analytic['token_width']} B/token "
          f"({4 / analytic['token_width']:.1f}x motion reduction vs int32)")
    assert s["host_device"] == analytic["total"]

    if cfg.num_experts:
        # MoE: grouped static prefill changes capacity pressure vs the
        # engine's batch-of-1 prefills — reference per request
        ref = {}
        for r in requests:
            ref.update(generate_static(
                cfg, mesh_cfg, None, spec_tree, storage, [r], plan=plan
            ))
        kind = "per-request static"
    else:
        ref = generate_static(
            cfg, mesh_cfg, None, spec_tree, storage, requests, plan=plan
        )
        kind = "static batching"
    exact = all(results[r.rid].tokens == ref[r.rid] for r in requests)
    print(f"continuous vs {kind}: "
          f"{'BIT-EXACT' if exact else 'DIVERGED'}")
    print("sample generations (token ids):")
    for r in requests[: min(args.requests, 4)]:
        gr = results[r.rid]
        print(f"  req{r.rid} (admitted step {gr.admitted_step}, finished "
              f"{gr.finished_step}): {gr.tokens[:12]}")


if __name__ == "__main__":
    main()
