"""xlstm-1.3b [ssm] — alternating mLSTM / sLSTM blocks  [arXiv:2405.04517].

Attention-free: O(1) decode state => long_500k runs natively. d_ff=0 per
the assignment: the blocks carry their own up/down projections
(mlstm_proj_factor). AWP/ADT applies unchanged — it compresses the weight
gathers, not attention (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    mlstm_proj_factor=2.0,
    num_precision_groups=4,
)
