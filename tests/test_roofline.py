"""HLO cost-analyzer tests: while-trip multiplication, dot flops,
collective wire accounting — on tiny compiled programs with known costs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import HloModule, analyze_hlo


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    txt = _compiled_text(lambda a, b: a @ b, a, b)
    c = analyze_hlo(txt)
    assert c.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_while_trip_multiplication():
    a = jnp.zeros((32, 32), jnp.float32)

    def scanned(a):
        def body(x, _):
            return x @ a, None
        x, _ = jax.lax.scan(body, a, None, length=10)
        return x

    txt = _compiled_text(scanned, a)
    c = analyze_hlo(txt)
    # 10 trips x 2*32^3 flops
    assert c.flops == pytest.approx(10 * 2 * 32**3, rel=0.05)


def test_batch_dot_flops():
    a = jnp.zeros((4, 16, 24), jnp.float32)
    b = jnp.zeros((4, 24, 8), jnp.float32)
    txt = _compiled_text(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    c = analyze_hlo(txt)
    assert c.flops == pytest.approx(2 * 4 * 16 * 24 * 8, rel=0.01)


def test_nested_while():
    a = jnp.zeros((16, 16), jnp.float32)

    def inner(x):
        def body(y, _):
            return y @ a, None
        y, _ = jax.lax.scan(body, x, None, length=3)
        return y

    def outer(a):
        def body(x, _):
            return inner(x), None
        x, _ = jax.lax.scan(body, a, None, length=5)
        return x

    c = analyze_hlo(_compiled_text(outer, a))
    assert c.flops == pytest.approx(15 * 2 * 16**3, rel=0.05)


def test_collective_wire_bytes():
    import os
    import subprocess
    import sys

    # needs >1 device: run in a subprocess with 4 host devices
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.dist.shard import shard_map
from repro.roofline.hlo_cost import analyze_hlo
from repro.transport import CompressionPolicy
mesh = Mesh(np.array(jax.devices()).reshape(4), ("d",))
def f(x):
    return jax.lax.all_gather(x, "d", axis=0, tiled=True)
sm = shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P(None))
x = jnp.zeros((4096,), jnp.float32)
txt = jax.jit(sm).lower(x).compile().as_text()
c = analyze_hlo(txt)
# expected bytes come from the SAME policy accounting the trainer logs:
# fp32 (round_to=4), 1024-element local shard, 4 devices -> 3*1024*4
want = CompressionPolicy(round_to=4).all_gather_wire_bytes(1024, 4)
assert want == 12288, want
assert abs(c.wire.get("all-gather", 0) - want) < 1, (c.wire, want)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    ) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=300,
    )
    assert proc.returncode == 0 and "OK" in proc.stdout, proc.stderr[-2000:]


def test_compressed_tp_wire_shrinks_by_packing_ratio():
    """The TP-axis all-reduce, routed through the compressed transport,
    must shrink the HLO-derived wire bytes by exactly round_to/4, and the
    plane-wire split must match the policy's all_reduce_wire_bytes."""
    import os
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.dist.shard import shard_map
from repro.roofline.hlo_cost import analyze_hlo
from repro.core.collectives import tp_region_exit
from repro.transport import CompressionPolicy
mesh = Mesh(np.array(jax.devices()).reshape(4), ("model",))
S = 4096
x = jnp.zeros((S,), jnp.float32)
def wire(pol):
    f = shard_map(lambda v: tp_region_exit(v, "model", pol), mesh=mesh,
                  in_specs=P(None), out_specs=P(None))
    return analyze_hlo(jax.jit(f).lower(x).compile().as_text())
c4 = wire(None)
pol = CompressionPolicy(round_to=2, grad_round_to=2, mode="nearest")
c2 = wire(pol)
# uncompressed: one f32 ring all-reduce, no planes
want4 = CompressionPolicy(round_to=4).all_reduce_wire_bytes(S, 4)
assert abs(c4.wire_total - want4) < 2, (c4.wire, want4)
assert c4.plane_wire_total == 0, c4.plane_wire
# compressed: rs+ag of u8 planes, all of it plane wire, exactly rt/4
want2 = pol.all_reduce_wire_bytes(S, 4)
assert abs(c2.wire_total - want2) < 2, (c2.wire, want2)
assert abs(c2.plane_wire_total - c2.wire_total) < 2, c2.plane_wire
assert abs(c2.wire_total / c4.wire_total - pol.wire_fraction) < 0.01
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    ) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=300,
    )
    assert proc.returncode == 0 and "OK" in proc.stdout, proc.stderr[-2000:]


def test_seq_parallel_block_wire():
    """The seq-parallel boundary pair (ag + rs of packed planes) per TP
    region: strictly fewer wire bytes than the uncompressed 2x-all-reduce
    psum pair (by the packing ratio), exactly the policy's
    seq_pair_wire_bytes model, volume-identical to the compressed psum
    decomposition at equal width (Megatron-SP invariant), and it removes
    the activation all-reduce entries from the report entirely."""
    import os
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.dist.shard import shard_map
from repro.roofline.hlo_cost import analyze_hlo
from repro.core.collectives import (
    tp_region_enter, tp_region_exit, seq_gather, seq_scatter,
)
from repro.transport import CompressionPolicy

mesh = Mesh(np.array(jax.devices()).reshape(4), ("model",))
B, S, d, ff, n = 2, 64, 16, 32, 4
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(0, 1, (B, S, d)), jnp.float32)
w1 = jnp.asarray(rng.normal(0, .1, (d, ff)), jnp.float32)
w2 = jnp.asarray(rng.normal(0, .1, (ff, d)), jnp.float32)

def wire(fn, in_specs, out_specs):
    f = shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return analyze_hlo(jax.jit(f).lower(x, w1, w2).compile().as_text())

def psum_block(pol):
    def lossfn(x, w1, w2):
        xin = tp_region_enter(x, "model", pol)
        y = tp_region_exit(jax.nn.relu(xin @ w1) @ w2, "model", pol)
        return jnp.sum(y ** 2) / n
    def g(x, w1, w2):
        l, gx = jax.value_and_grad(lossfn)(x, w1, w2)
        return jax.lax.psum(l, "model"), gx
    return wire(g, (P(None, None, None), P(None, "model"), P("model", None)),
                (P(), P(None, None, None)))

def sp_block(pol):
    def lossfn(x_shard, w1, w2):
        xin = seq_gather(x_shard, "model", pol)
        return jnp.sum(seq_scatter(jax.nn.relu(xin @ w1) @ w2, "model", pol) ** 2)
    def g(x, w1, w2):
        l, gx = jax.value_and_grad(lossfn)(x, w1, w2)
        return jax.lax.psum(l, "model"), gx
    return wire(g, (P(None, "model", None), P(None, "model"), P("model", None)),
                (P(), P(None, "model", None)))

pol2 = CompressionPolicy(round_to=2, grad_round_to=2, mode="nearest")
c_psum_f32, c_psum_rt2 = psum_block(None), psum_block(pol2)
c_sp_rt2, c_sp_f32 = sp_block(pol2), sp_block(None)
P_elems = B * S * d
scalar_slack = 16  # the loss-scalar psum per program

# 1) policy model is exact: fwd pair + cotangent pair of packed planes
want = pol2.seq_pair_wire_bytes(P_elems, n) + pol2.seq_pair_wire_bytes(
    P_elems, n, grad=True)
assert abs(c_sp_rt2.plane_wire_total - want) < 1, (c_sp_rt2.plane_wire, want)
assert abs(c_sp_rt2.wire_total - want) < scalar_slack

# 2) strictly fewer than the uncompressed psum pair, by the packing ratio
assert c_sp_rt2.wire_total < c_psum_f32.wire_total
ratio = c_sp_rt2.wire_total / c_psum_f32.wire_total
assert abs(ratio - pol2.wire_fraction) < 0.01, ratio

# 3) volume conservation at equal width (Megatron-SP / HyPar):
#    seq pair == all-reduce decomposition, compressed and uncompressed
assert abs(c_sp_rt2.wire_total - c_psum_rt2.wire_total) < scalar_slack
assert abs(c_sp_f32.wire_total - c_psum_f32.wire_total) < scalar_slack

# 4) activation all-reduces vanish under the seq layout (scalar residue
#    only); the psum layout keeps the full 2x-AR pair
assert c_sp_f32.wire.get("all-reduce", 0) < scalar_slack, c_sp_f32.wire
want_ar = CompressionPolicy(round_to=4).all_reduce_wire_bytes(P_elems, n) * 2
assert abs(c_psum_f32.wire.get("all-reduce", 0) - want_ar) < scalar_slack
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    ) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=300,
    )
    assert proc.returncode == 0 and "OK" in proc.stdout, (
        proc.stdout[-2000:], proc.stderr[-2000:]
    )


def test_shape_parsing():
    from repro.roofline.hlo_cost import _type_bytes

    assert _type_bytes("f32[16,4096,2048]{2,1,0}") == 16 * 4096 * 2048 * 4
    assert _type_bytes("u8[2,262144]{0,1}") == 2 * 262144
    assert _type_bytes("(f32[8], s32[2])") == 32 + 8
    assert _type_bytes("pred[]") == 1
