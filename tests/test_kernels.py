"""Per-kernel allclose tests: Pallas (interpret) vs pure-jnp oracle.

Sweeps shapes / round_to / value distributions, plus hypothesis property
tests on the pack/unpack invariants.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.bitpack import bitpack_2d
from repro.kernels.bitunpack import bitunpack_2d
from repro.kernels.l2norm import l2norm_sq_2d

SHAPES_2D = [(256, 128), (512, 128), (1024, 128)]
ROUND_TOS = [1, 2, 3, 4]


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("round_to", ROUND_TOS)
def test_bitpack_kernel_matches_ref(shape, round_to):
    w = _rand(shape, seed=round_to)
    got = bitpack_2d(w, round_to, interpret=True)
    want = ref.bitpack_ref(w, round_to)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("round_to", ROUND_TOS)
def test_bitunpack_kernel_matches_ref(shape, round_to):
    w = _rand(shape, seed=17 + round_to, scale=3.0)
    planes = ref.bitpack_ref(w, round_to)
    got = bitunpack_2d(planes, interpret=True)
    want = ref.bitunpack_ref(planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(512, 128), (2048, 128)])
def test_l2norm_kernel_matches_ref(shape):
    w = _rand(shape, seed=3, scale=0.1)
    got = l2norm_sq_2d(w, interpret=True)
    want = ref.l2norm_sq_ref(w)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


@pytest.mark.parametrize(
    "shape", [(7,), (130,), (64, 33), (3, 5, 7), (1,), (40000,)]
)
@pytest.mark.parametrize("round_to", ROUND_TOS)
def test_ops_quantize_arbitrary_shapes(shape, round_to):
    w = _rand(shape, seed=round_to * 11, scale=2.0)
    got = ops.quantize(w, round_to)
    want = ref.quantize_ref(w, round_to)
    assert got.shape == w.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_round_to_4_is_identity():
    w = _rand((1000,), seed=5)
    np.testing.assert_array_equal(np.asarray(ops.quantize(w, 4)), np.asarray(w))


def test_round_to_2_is_bfloat16_truncation():
    """Paper's 16-bit format (1s+8e+7m) is exactly bf16 round-toward-zero."""
    w = _rand((4096,), seed=9, scale=10.0)
    q = np.asarray(ops.quantize(w, 2))
    # truncation: uint32 view with low 16 bits cleared
    u = np.asarray(w).view(np.uint32) & np.uint32(0xFFFF0000)
    np.testing.assert_array_equal(q.view(np.uint32), u)


@given(
    st.lists(
        st.floats(
            allow_nan=False, allow_infinity=False, width=32
        ),
        min_size=1,
        max_size=64,
    ),
    st.sampled_from(ROUND_TOS),
)
@settings(max_examples=60, deadline=None)
def test_property_truncation_invariants(vals, round_to):
    """Truncation: |q| <= |w|, sign preserved, idempotent, error < 2^(drop) ulp."""
    w = jnp.asarray(np.asarray(vals, np.float32))
    q = np.asarray(ref.quantize_ref(w, round_to))
    wn = np.asarray(w)
    # sign preserved (zero maps to +/-0)
    assert np.all((q >= 0) == (wn >= 0) | (q == 0))
    # magnitude never increases under truncation toward zero
    assert np.all(np.abs(q) <= np.abs(wn))
    # idempotent
    q2 = np.asarray(ref.quantize_ref(jnp.asarray(q), round_to))
    np.testing.assert_array_equal(q, q2)
    # relative error bound: dropping d mantissa bits -> rel err < 2^-(kept mantissa)
    kept_mantissa = max(0, 8 * round_to - 9)
    finite = np.abs(wn) > 1e-30
    if kept_mantissa > 0 and finite.any():
        rel = np.abs(q[finite] - wn[finite]) / np.abs(wn[finite])
        assert np.all(rel <= 2.0 ** (-kept_mantissa) + 1e-12)


@given(st.integers(1, 4), st.integers(1, 5000))
@settings(max_examples=30, deadline=None)
def test_property_pack_unpack_roundtrip_on_packed_values(round_to, n):
    """Values already representable in round_to bytes survive exactly."""
    rng = np.random.default_rng(n)
    w = rng.normal(0, 1, (n,)).astype(np.float32)
    w = np.asarray(ref.quantize_ref(jnp.asarray(w), round_to))
    q = np.asarray(ref.quantize_ref(jnp.asarray(w), round_to))
    np.testing.assert_array_equal(w, q)


def test_nearest_mode_reduces_bias():
    # truncation is round-toward-zero: |q| <= |w| always, so the magnitude
    # error is systematically negative; round-to-nearest should center it.
    w = _rand((20000,), seed=21, scale=1.0)
    mag_trunc = np.mean(
        np.abs(np.asarray(ref.quantize_ref(w, 2))) - np.abs(np.asarray(w))
    )
    mag_near = np.mean(
        np.abs(np.asarray(ref.quantize_ref(w, 2, mode="nearest")))
        - np.abs(np.asarray(w))
    )
    assert mag_trunc < 0
    assert abs(mag_near) < abs(mag_trunc)


def test_stochastic_mode_unbiased():
    key = jax.random.PRNGKey(0)
    w = jnp.full((50000,), 1.0 + 1e-4, jnp.float32)
    q = ref.quantize_ref(w, 2, mode="stochastic", key=key)
    # expectation of stochastic rounding equals the input
    assert abs(float(jnp.mean(q)) - float(jnp.mean(w))) < 1e-5


def test_special_values_survive():
    w = jnp.asarray([0.0, -0.0, 1.0, -1.0, np.inf, -np.inf], jnp.float32)
    for rt in ROUND_TOS:
        q = np.asarray(ref.quantize_ref(w, rt))
        if rt > 1:
            # 16+ bits keep sign + full exponent + some mantissa:
            # zeros, +/-1 and infinities survive exactly.
            np.testing.assert_array_equal(q[:4], np.asarray(w)[:4])
            assert np.isinf(q[4]) and q[4] > 0
            assert np.isinf(q[5]) and q[5] < 0
        else:
            # 8-bit (sign + 7 exponent bits) loses the exponent LSB: it can
            # represent zero exactly but not 1.0 or inf — as in the paper,
            # 8-bit is only useful very early in training.
            np.testing.assert_array_equal(q[:2], np.asarray(w)[:2])


# ---------------------------------------------------------------------------
# attention kernels: flash prefill + paged decode (interpret-mode parity)
# ---------------------------------------------------------------------------

from repro.kernels.flash_prefill import flash_prefill, flash_prefill_ref
from repro.kernels.paged_attention import paged_attend, paged_attend_ref


def _qkv(B, H, Kv, Sq, Sk, hd, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (B, H, Sq, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (B, Kv, Sk, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (B, Kv, Sk, hd)).astype(np.float32))
    return q, k, v


def _dense_softmax_attn(q, k, v, causal, q_offset):
    # plain softmax reference (not the kernel's schedule): allclose only
    B, H, Sq, hd = q.shape
    Kv, Sk = k.shape[1], k.shape[2]
    g = H // Kv
    kh = jnp.repeat(k, g, axis=1)
    vh = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kh) * hd ** -0.5
    if causal:
        qpos = q_offset + jnp.arange(Sq)[:, None]
        mask = qpos >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vh)


@pytest.mark.parametrize("shape", [(1, 2, 1, 256, 256, 128),
                                   (2, 4, 2, 128, 384, 128)])
def test_flash_prefill_kernel_matches_ref_bitwise(shape):
    B, H, Kv, Sq, Sk, hd = shape
    off = Sk - Sq  # chunked prefill: q tile ends the kv sequence
    q, k, v = _qkv(B, H, Kv, Sq, Sk, hd, seed=5)
    got = flash_prefill(q, k, v, causal=True, q_offset=off, interpret=True)
    want = flash_prefill_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(_dense_softmax_attn(q, k, v, True, off)),
        atol=2e-5,
    )


def test_flash_prefill_q_offset_parity():
    # chunked prefill: the q tile sits at the END of the kv sequence
    q, k, v = _qkv(1, 2, 2, 128, 256, 128, seed=9)
    got = flash_prefill(q, k, v, causal=True, q_offset=128, interpret=True)
    want = flash_prefill_ref(q, k, v, causal=True, q_offset=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _paged_setup(B, Kv, G, page, n_pages, num_phys, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(
        rng.normal(0, 1, (B, Kv, G, 128)).astype(np.float32)
    )
    pool_shape = (num_phys, page, Kv, 128)
    k_pool = jnp.asarray(rng.normal(0, 1, pool_shape).astype(np.float32))
    v_pool = jnp.asarray(rng.normal(0, 1, pool_shape).astype(np.float32))
    # distinct physical pages per (slot, logical) entry
    perm = rng.permutation(num_phys)[: B * n_pages]
    table = jnp.asarray(perm.reshape(B, n_pages).astype(np.int32))
    lengths = jnp.asarray(
        rng.integers(1, page * n_pages + 1, (B,)).astype(np.int32)
    )
    return q, k_pool, v_pool, table, lengths


def test_paged_attend_kernel_matches_ref_bitwise():
    q, kp, vp, table, lengths = _paged_setup(3, 2, 2, 8, 4, 16, seed=11)
    got = paged_attend(q, kp, vp, table, lengths, interpret=True)
    want = paged_attend_ref(q, kp, vp, table, lengths)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_attend_matches_dense_softmax():
    q, kp, vp, table, lengths = _paged_setup(2, 2, 4, 8, 4, 12, seed=13)
    out = np.asarray(paged_attend(q, kp, vp, table, lengths, interpret=True))
    B, Kv, G, hd = q.shape
    page, n_pages = kp.shape[1], table.shape[1]
    for b in range(B):
        L = int(lengths[b])
        k = np.asarray(kp)[np.asarray(table)[b]].reshape(-1, Kv, hd)[:L]
        v = np.asarray(vp)[np.asarray(table)[b]].reshape(-1, Kv, hd)[:L]
        s = np.einsum("kgh,pkh->kgp", np.asarray(q)[b], k) * hd ** -0.5
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        want = np.einsum("kgp,pkh->kgh", p, v)
        np.testing.assert_allclose(out[b], want, atol=2e-5)


def test_paged_attend_page_table_permutation_invariance():
    # scatter the same logical pages to different physical rows: the
    # output must be BITWISE identical — attention walks the table, so
    # physical placement can never leak into the math
    q, kp, vp, table, lengths = _paged_setup(2, 2, 2, 8, 3, 12, seed=17)
    base = np.asarray(paged_attend(q, kp, vp, table, lengths, interpret=True))
    rng = np.random.default_rng(23)
    perm = rng.permutation(kp.shape[0])
    inv = np.argsort(perm)
    kp2 = jnp.asarray(np.asarray(kp)[perm])
    vp2 = jnp.asarray(np.asarray(vp)[perm])
    table2 = jnp.asarray(inv[np.asarray(table)].astype(np.int32))
    moved = np.asarray(
        paged_attend(q, kp2, vp2, table2, lengths, interpret=True)
    )
    np.testing.assert_array_equal(base, moved)
