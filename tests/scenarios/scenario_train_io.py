"""End-to-end training-I/O scenario: tiered shards -> prefetcher ->
train loop -> width-aware async checkpoint -> resume.

The paper's methodology needs interruptible runs whose byte streams are
priced: this scenario pins

  * **resume determinism** — train N steps uninterrupted vs train k,
    checkpoint (data-iterator state included), restore into a FRESH
    trainer, continue: the loss stream and the final storage tree are
    bit-exact. Twice: a static plan, and an AWP plan whose controller
    widens formats mid-run (the checkpoint carries bits / counters /
    prev_norms / history across the boundary).
  * **measured == analytic, ingest** — the prefetcher's per-step
    ``shard_read`` / ``host_device`` log sums equal
    ``train_ingest_bytes`` priced from the reader's start position
    (manifest + CompressionPolicy arithmetic, no file I/O).
  * **measured == analytic, checkpoint** — the width-aware save's
    manifest totals equal ``train_checkpoint_bytes`` AND the summed
    on-disk shard file sizes; the widths recorded are the AWP
    controller's *current* formats.
  * **tiered ingest trains** — a quality-2 feature run reads strictly
    fewer shard bytes than quality-4 (priced exactly) and still
    descends.
"""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import (
    AsyncCheckpointer, ckpt_dir, load_checkpoint, load_extra,
    save_checkpoint,
)
from repro.checkpoint.sharded import manifest_bytes, read_meta
from repro.configs.registry import get_config, reduced
from repro.data.prefetch import Prefetcher
from repro.data.shards import ShardReader, batches, write_feature_shards, \
    write_lm_shards
from repro.dist.spec import (
    MeshCfg, build_spec_tree, dist_elems_per_group, tree_to_storage,
)
from repro.models.init import init_params
from repro.optim.sgd import SGDConfig, init_momentum
from repro.plan import PrecisionPlan
from repro.roofline.analysis import train_checkpoint_bytes, train_ingest_bytes
from repro.train.loop import Trainer
from repro.train.step import make_train_step

B, S, STEPS, HALF = 2, 16, 6, 3


def _setup(arch, plan):
    cfg = reduced(get_config(arch))
    mesh_cfg = MeshCfg()
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    nrt = cfg.num_groups + 1
    plan = plan.broadcast(nrt)
    opt = SGDConfig(lr=0.05, momentum=0.9, weight_decay=1e-4)
    if cfg.embed_is_input_stub:
        shapes = {
            "features": jax.ShapeDtypeStruct((B, S, cfg.vision_dim), jnp.float32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    else:
        shapes = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }

    def builder(round_tos):
        return make_train_step(
            cfg, mesh_cfg, None, spec_tree, opt, shapes,
            plan=plan.with_round_tos(round_tos),
        )

    def trainer():
        return Trainer(
            builder, nrt, plan=plan,
            dist_elems_per_group=dist_elems_per_group(spec_tree, mesh_cfg, nrt),
            gather_axis_size=1,
        )

    # host snapshot: the train steps donate their storage/opt buffers,
    # so every run must start from a FRESH device tree
    host = jax.tree_util.tree_map(np.asarray, storage)

    def fresh_storage():
        return jax.tree_util.tree_map(jnp.asarray, host)

    return cfg, spec_tree, fresh_storage, trainer


def _run(trainer, storage, mom, shard_dir, kind, vocab, plan, steps,
         data_state=None, quality=4):
    """Train ``steps`` batches off the shard pipeline; returns final
    trees, losses, the last data_state, and the summed io log."""
    reader = ShardReader(shard_dir, quality=quality, seed=0)
    if data_state is not None:
        reader.load_state(data_state)
    pf = Prefetcher(batches(reader, B), kind=kind, vocab=vocab, plan=plan)
    losses, io = [], {"shard_read": 0, "host_device": 0}
    state = None
    for _ in range(steps):
        batch, log = pf.next()
        storage, mom, m = trainer.run_step(storage, mom, batch, 0.05,
                                           io_log=log)
        losses.append(float(m["loss"]))
        state = log["data_state"]
        io = {k: io[k] + log[k] for k in io}
    pf.close()
    reader.close()
    return storage, mom, losses, state, io


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _resume_roundtrip(tmp, plan, tag):
    """Uninterrupted vs checkpoint-at-HALF + fresh-trainer resume."""
    cfg, spec_tree, fresh_storage, mk_trainer = _setup("qwen3-1.7b", plan)
    shard_dir = os.path.join(tmp, f"shards_{tag}")
    write_lm_shards(shard_dir, vocab=cfg.vocab_size, seq=S, num_records=8)

    # ingest pin: price before any reading, then compare measured sums
    rd = ShardReader(shard_dir, seed=0)
    ingest = train_ingest_bytes(plan, cfg.vocab_size, kind="lm", batch=B,
                                seq=S, steps=STEPS, reader=rd)
    rd.close()

    tr_full = mk_trainer()
    s0 = fresh_storage()
    s_full, m_full, losses_full, _, io = _run(
        tr_full, s0, init_momentum(s0), shard_dir, "lm", cfg.vocab_size,
        plan, STEPS,
    )
    assert io["shard_read"] == ingest["shard_read"], (io, ingest)
    assert io["host_device"] == ingest["ingest_h2d"], (io, ingest)
    assert tr_full.summary()["io_by_entry"]["shard_read"] == io["shard_read"]

    # interrupted half: async width-aware checkpoint at the boundary
    tr_a = mk_trainer()
    s1 = fresh_storage()
    s_half, m_half, losses_a, state, _ = _run(
        tr_a, s1, init_momentum(s1), shard_dir, "lm",
        cfg.vocab_size, plan, HALF,
    )
    ck = os.path.join(tmp, f"ck_{tag}")
    ac = AsyncCheckpointer()
    rts = tr_a.current_round_tos()
    save_checkpoint(ck, s_half, m_half, tr_a.controller, HALF, plan=plan,
                    spec_tree=spec_tree, round_tos=rts,
                    extra={"data_state": state}, async_ckpt=ac)
    ac.wait()

    # checkpoint byte pin: manifest == analytic == on-disk
    meta = read_meta(ckpt_dir(ck))
    mb = manifest_bytes(meta)
    assert mb == train_checkpoint_bytes(s_half, m_half, spec_tree=spec_tree,
                                        round_tos=rts)
    d = ckpt_dir(ck)
    assert mb["total"] == sum(
        os.path.getsize(os.path.join(d, f))
        for f in os.listdir(d) if f.endswith(".bin")
    )
    widths = {e["path"]: e["width"] for e in meta["trees"]["storage"]
              if e["tiered"]}
    assert widths, "expected width-tiered leaves in the manifest"
    assert set(widths.values()) <= set(rts)

    # fresh trainer + restored state: bit-exact continuation
    tr_b = mk_trainer()
    s_r, m_r, step = load_checkpoint(ck, s_half, m_half, tr_b.controller)
    assert step == HALF
    ds = load_extra(ck)["data_state"]
    s_res, m_res, losses_b, _, _ = _run(
        tr_b, s_r, m_r, shard_dir, "lm", cfg.vocab_size, plan,
        STEPS - HALF, data_state=ds,
    )
    assert losses_a + losses_b == losses_full, (
        tag, losses_a + losses_b, losses_full
    )
    _assert_trees_equal(s_res, s_full)
    _assert_trees_equal(m_res, m_full)
    return tr_full, tr_b


def test_resume_bit_exact_static_plan(tmp_path):
    plan = PrecisionPlan.build(3, round_to=2, schedule="static")
    _resume_roundtrip(str(tmp_path), plan, "static")


def test_resume_bit_exact_awp_plan(tmp_path):
    """AWP plan whose controller is forced to widen every 2 steps
    (threshold so high every norm delta hits): the widths change across
    the checkpoint boundary and the resumed trajectory — losses, bits
    history, final trees — is still bit-exact."""
    plan = PrecisionPlan.build(3, schedule="awp", awp_threshold=1e9,
                               awp_interval=2)
    tr_full, tr_res = _resume_roundtrip(str(tmp_path), plan, "awp")
    assert len(tr_full.controller.history) > 1, "controller never widened"
    assert tr_res.controller.history == tr_full.controller.history
    np.testing.assert_array_equal(tr_res.controller.state.bits,
                                  tr_full.controller.state.bits)


def test_quality_tier_trains_and_prices_exactly(tmp_path):
    """Feature (audio) family at ingest quality 2: float payloads read
    half their planes — strictly fewer shard bytes, priced exactly by
    the analytic model — and the truncated stream still trains."""
    plan = PrecisionPlan.build(3, round_to=2, schedule="static")
    cfg, spec_tree, fresh_storage, mk_trainer = _setup("hubert-xlarge", plan)
    shard_dir = str(tmp_path / "fshards")
    write_feature_shards(shard_dir, dim=cfg.vision_dim,
                         vocab=cfg.vocab_size, seq=S, num_records=8)
    plans = {}
    for q in (2, 4):
        rd = ShardReader(shard_dir, quality=q, seed=0)
        plans[q] = train_ingest_bytes(
            plan, cfg.vocab_size, kind="feature", batch=B, seq=S,
            steps=STEPS, dim=cfg.vision_dim, reader=rd,
        )
        rd.close()
    assert plans[2]["shard_read"] < plans[4]["shard_read"]
    assert plans[2]["ingest_h2d"] == plans[4]["ingest_h2d"]  # h2d is raw fp32

    tr = mk_trainer()
    s0 = fresh_storage()
    _, _, losses, _, io = _run(
        tr, s0, init_momentum(s0), shard_dir, "feature",
        cfg.vocab_size, plan, STEPS, quality=2,
    )
    assert io["shard_read"] == plans[2]["shard_read"]
    assert io["host_device"] == plans[2]["ingest_h2d"]
    assert losses[-1] < losses[0], "quality-2 ingest failed to descend"
