"""Execution environment threaded through every model function.

Carries the mesh-axis names (None = single device: every collective helper
degrades to identity), the TP degree, compute dtype, and the performance
levers toggled during §Perf hillclimbing. ``act_policy`` is the
activation-group :class:`~repro.transport.CompressionPolicy`: when set,
every TP-region psum and sequence-parallel collective issued through this
env rides the compressed transport (packed byte planes) instead of
fp32/compute-dtype collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
from jax import lax

from repro.core.collectives import (
    seq_gather,
    seq_scatter,
    tp_region_enter,
    tp_region_exit,
)


@dataclasses.dataclass(frozen=True)
class Env:
    model_axis: str | None = None           # TP axis name
    fsdp_axes: tuple[str, ...] | None = None  # weight-gather axes
    tp: int = 1
    dtype: Any = jnp.float32                # compute dtype (bf16 = beyond-paper)
    attn_chunk: int = 1024                  # flash-chunk size (q and kv)
    causal_skip: bool = True                # skip fully-masked kv chunks
    seq_parallel: bool = False              # sequence-parallel activations
    int8_kv: bool = False                   # int8 KV cache (decode, §Perf)
    mlstm_chunk: int = 0                    # chunkwise mLSTM (0 = sequential)
    act_policy: Any = None                  # activation CompressionPolicy

    # ------------------------------------------------------------------
    def enter(self, x):
        """Megatron 'f': identity fwd / model-axis psum bwd."""
        if self.model_axis is None:
            return x
        return tp_region_enter(x, self.model_axis, self.act_policy)

    def exit(self, x):
        """Megatron 'g': model-axis psum fwd / identity bwd."""
        if self.model_axis is None:
            return x
        return tp_region_exit(x, self.model_axis, self.act_policy)

    def seq_gather(self, x, axis: int = 1):
        """Sequence-parallel enter: all-gather sequence shards (identity
        when there is no model axis)."""
        if self.model_axis is None:
            return x
        return seq_gather(x, self.model_axis, self.act_policy, axis)

    def seq_scatter(self, x, axis: int = 1):
        """Sequence-parallel exit: reduce-scatter along the sequence dim
        (identity when there is no model axis)."""
        if self.model_axis is None:
            return x
        return seq_scatter(x, self.model_axis, self.act_policy, axis)

    def model_rank(self):
        if self.model_axis is None:
            return 0
        return lax.axis_index(self.model_axis)

    def heads_local(self, heads: int) -> int:
        """Local head count when sharding `heads` over the model axis
        (replicated up when heads < tp, see DESIGN.md kv-replication note)."""
        return max(1, heads // self.tp)

    def ff_local(self, ff: int) -> int:
        return max(1, ff // self.tp)
