"""Explicit-transpose collective pairs for manual tensor parallelism.

Megatron-style TP needs two conjugate operators around each block:

  * :func:`tp_region_enter` ("f"): forward identity on the (model-axis
    replicated) activations, backward ``psum`` of the cotangent over the
    model axis — column-parallel weights each produce a partial ``dx``.
  * :func:`tp_region_exit`  ("g"): forward ``psum`` of the partial block
    output over the model axis, backward identity.

We pin both directions down with ``custom_vjp`` instead of relying on the
AD transpose of ``lax.psum``, whose semantics for replicated inputs are a
classic source of silent double-counting.
"""
from __future__ import annotations

import functools
from typing import Hashable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

AxisNames = Hashable | Sequence[Hashable]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_region_enter(x, axis_names: AxisNames):
    return x


def _enter_fwd(x, axis_names):
    return x, jnp.zeros((0,), x.dtype)  # zero-size dtype carrier


def _enter_bwd(axis_names, marker, g):
    # cotangents are psum'd in the compute dtype: fp32-accumulated attention
    # einsums would otherwise silently upcast every backward all-reduce
    # (bf16 activation grads are standard practice; noted in DESIGN.md §7).
    # The optimization barrier stops XLA's excess-precision pass from
    # cancelling the down-cast against the CPU backend's f32 promotion —
    # on TPU the collective runs natively in the compute dtype.
    g = lax.optimization_barrier(g.astype(marker.dtype))
    return (lax.psum(g, axis_names),)


tp_region_enter.defvjp(_enter_fwd, _enter_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_region_exit(x, axis_names: AxisNames):
    return lax.psum(lax.optimization_barrier(x), axis_names)


def _exit_fwd(x, axis_names):
    x = lax.optimization_barrier(x)
    return lax.psum(x, axis_names), jnp.zeros((0,), x.dtype)


def _exit_bwd(axis_names, marker, g):
    return (g.astype(marker.dtype),)


tp_region_exit.defvjp(_exit_fwd, _exit_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def seq_gather(x, axis_names: AxisNames):
    """Sequence-parallel enter: all-gather sequence shards over the model
    axis (axis 1 == sequence), backward reduce-scatter.  Beyond-paper lever
    for shrinking the model-axis collective term (DESIGN.md §7)."""
    return lax.all_gather(x, axis_names, axis=1, tiled=True)


def _sg_fwd(x, axis_names):
    return lax.all_gather(x, axis_names, axis=1, tiled=True), None


def _sg_bwd(axis_names, _, g):
    return (lax.psum_scatter(g, axis_names, scatter_dimension=1, tiled=True),)


seq_gather.defvjp(_sg_fwd, _sg_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def seq_scatter(x, axis_names: AxisNames):
    """Sequence-parallel exit: reduce-scatter partial outputs over the model
    axis along the sequence dim, backward all-gather."""
    return lax.psum_scatter(x, axis_names, scatter_dimension=1, tiled=True)


def _ss_fwd(x, axis_names):
    return lax.psum_scatter(x, axis_names, scatter_dimension=1, tiled=True), None


def _ss_bwd(axis_names, _, g):
    return (lax.all_gather(g, axis_names, axis=1, tiled=True),)


seq_scatter.defvjp(_ss_fwd, _ss_bwd)
