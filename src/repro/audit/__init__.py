"""Static data-motion auditor (jaxpr layer).

``audit_step`` traces any step-factory product with ``jax.make_jaxpr``
under abstract inputs — no device execution — walks the jaxpr for
communication equations, attributes each one to a
:class:`~repro.plan.PrecisionPlan` traffic class via the transport's
packing structure, and pins the jaxpr-derived wire bytes against the
roofline's analytic model (``PrecisionPlan.wire_table`` geometry). The
third independent byte pin alongside measured and analytic: the traced
program itself. See docs/audit.md for the attribution catalog.
"""
from repro.audit.audit import (
    AuditError,
    AuditReport,
    ClassTotal,
    audit_step,
)
from repro.audit.jaxpr import CommEqn, JaxprWalkError, collect_comm_eqns

__all__ = [
    "AuditError",
    "AuditReport",
    "ClassTotal",
    "CommEqn",
    "JaxprWalkError",
    "audit_step",
    "collect_comm_eqns",
]
