"""Host-side byte-plane codec shared by the data shards and the sharded
checkpointer.

The device-side transport decomposes fp32 words into MSB-first uint8
byte planes (``repro.kernels.ref``: plane 0 = sign + high exponent bits).
Training I/O moves the *same* representation on the host: a record or
checkpoint leaf is stored as byte planes so readers can stop after the
most significant ``k`` planes — the progressive/tiered layout of
Progressive Compressed Records applied to our on-disk formats, and the
reason a rt=2 checkpoint leaf costs exactly 2 bytes per element.

This module is pure numpy (no jax): it runs on writer threads and in the
async checkpointer where touching the device would serialize against the
next train step.

Conventions (must stay bit-compatible with ``kernels/ref.py``):

  * plane 0 is the MOST significant byte of each element's bit pattern;
  * dropping trailing planes and zero-filling reproduces the transport's
    ``truncate`` rounding mode exactly;
  * the codec is a pure byte shuffle — every dtype (floats, ints, bool)
    round-trips bitwise when all planes are kept.
"""
from __future__ import annotations

import numpy as np


def plane_split(arr: np.ndarray) -> np.ndarray:
    """Array -> uint8 byte planes, shape ``(itemsize, arr.size)``.

    Plane 0 holds the most significant byte of every element; joining
    all ``itemsize`` planes back is bitwise lossless for any POD dtype.
    """
    a = np.ascontiguousarray(arr)
    # big-endian byte order makes byte 0 the MSB for every dtype
    be = a.astype(a.dtype.newbyteorder(">"), copy=False)
    raw = np.frombuffer(be.tobytes(), np.uint8)
    if a.dtype.itemsize == 1:
        return raw.reshape(1, -1)
    return np.ascontiguousarray(
        raw.reshape(-1, a.dtype.itemsize).T
    )


def plane_join(
    planes: np.ndarray, dtype, shape, *, total_planes: int | None = None,
    lead_skip: int = 0,
) -> np.ndarray:
    """uint8 planes ``(k, n)`` -> array of ``dtype``/``shape``.

    ``total_planes`` defaults to the dtype's itemsize; planes beyond the
    given ``k`` are zero-filled (the transport's truncate semantics —
    this is how a quality-limited reader reconstructs a float payload).
    ``lead_skip`` re-inserts that many all-zero MOST-significant planes
    (integer payloads whose high bytes were trimmed at write time).
    """
    dtype = np.dtype(dtype)
    total = dtype.itemsize if total_planes is None else int(total_planes)
    planes = np.asarray(planes, np.uint8)
    k, n = planes.shape
    full = np.zeros((total, n), np.uint8)
    full[lead_skip:lead_skip + k] = planes
    raw = np.ascontiguousarray(full.T).tobytes()
    be = np.frombuffer(raw, dtype.newbyteorder(">"))
    return be.astype(dtype, copy=False).reshape(shape)


def lead_zero_planes(planes: np.ndarray) -> int:
    """How many MOST-significant planes are entirely zero (trimmable
    losslessly — integer ids far narrower than their container dtype).
    Always leaves at least one plane."""
    k = 0
    while k < planes.shape[0] - 1 and not planes[k].any():
        k += 1
    return k
