"""Trainer-side weight publishing (`repro.fleet.publish`).

The live-refresh producer: snapshot the trainer's sharded ``storage``
tree as a :class:`~repro.transport.WeightParcel` at the width
controller's *current* ``round_tos`` (the same
:func:`repro.checkpoint.sharded.assign_widths` walk the on-disk
checkpointer uses), optionally mirroring the parcel to a real
``save_sharded`` directory — parcel bytes and directory bytes are
identical by construction, which is what lets the fleet scenario pin
``parcel.nbytes == manifest_bytes(...) == train_checkpoint_bytes(...)``
three ways.
"""
from __future__ import annotations

from repro.transport import pack_weight_parcel


class WeightPublisher:
    """Versioned publisher over one model's ``spec_tree``. Each
    :meth:`publish` stamps the next version number; the router's
    rolling refresh keys replica installs on it."""

    def __init__(self, cfg, spec_tree, *, plan):
        self.spec_tree = spec_tree
        self.plan = plan.broadcast(cfg.num_groups + 1)
        self.policy = self.plan.weight_publish_policy()
        self.next_version = 0

    def publish(self, storage, *, round_tos=None, step: int = 0,
                save_dir=None, awp=None):
        """Pack ``storage`` into a weight parcel (and optionally write
        the matching sharded checkpoint to ``save_dir``).

        ``round_tos`` defaults to the plan's static widths; pass the
        AWP controller's current widths (``trainer.current_round_tos()``
        style) for width-aware publishes."""
        rts = tuple(round_tos) if round_tos is not None else self.plan.round_tos
        parcel = pack_weight_parcel(
            storage, spec_tree=self.spec_tree, round_tos=rts,
            policy=self.policy, version=self.next_version, step=step,
        )
        if save_dir is not None:
            from repro.checkpoint.sharded import save_sharded

            save_sharded(
                save_dir, storage, None, awp, step, plan=self.plan,
                spec_tree=self.spec_tree, round_tos=rts,
                residuals=parcel.residuals,
            )
        self.next_version += 1
        return parcel
