"""Subprocess scenario: sequence-parallel activations (Env.seq_parallel)
on an 8-device host mesh.

Equivalence pins, per architecture family (attention, MoE-tp, mLSTM/sLSTM,
RG-LRU, audio encoder, vision cross-attn):

  * seq_parallel=True at round_to=4 (uncompressed seq pair) matches the
    psum-decomposition train step BIT-EXACTLY at tp=2 — norms, residuals
    and the embedding/logits entries on sequence shards reproduce the
    replicated layout's sums exactly (two-operand reductions have a
    single order).
  * seq_parallel + act_policy=rt2: every block boundary rides packed
    planes fwd AND bwd; loss stays inside the bf16-grade envelope and
    training keeps descending.
  * prefill under seq_parallel produces bit-close logits AND caches, and
    decode (which drops the flag — no sequence dim to shard) continues
    from those caches transparently.
"""
import dataclasses
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.launch.mesh import make_mesh_from_cfg
from repro.models.init import init_params
from repro.optim.sgd import SGDConfig, init_momentum
from repro.plan import PrecisionPlan
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.step import make_train_step
from repro.transport import CompressionPolicy

OPT = SGDConfig(lr=0.05, momentum=0.9, weight_decay=0.0)
B, S = 8, 32


def _plan(nrt, **kw):
    return PrecisionPlan.build(nrt, **kw)


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.embed_is_input_stub:
        b = {
            "features": jnp.asarray(
                rng.normal(0, 1, (B, S, cfg.vision_dim)), jnp.float32
            ),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
            ),
        }
    else:
        b = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
            ),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
            ),
        }
    if cfg.num_image_tokens:
        b["image_features"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_image_tokens, cfg.vision_dim)),
            jnp.float32,
        )
    return b


def _fresh_storage(cfg, spec, mesh_cfg):
    # every step is donate_argnums=(0, 1): re-init per section
    params, _ = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    return tree_to_storage(params, spec, mesh_cfg)


def run_train_equivalence(arch, mesh_cfg, mesh):
    """seq_parallel rt=4 == psum layout, bit-exact at tp=2."""
    cfg = reduced(get_config(arch))
    batch = _batch(cfg)
    bs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    nrt = cfg.num_groups + 1
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    spec = build_spec_tree(params, metas, mesh_cfg)

    st = tree_to_storage(params, spec, mesh_cfg)
    step = make_train_step(cfg, mesh_cfg, mesh, spec, OPT, bs,
                           plan=_plan(nrt))
    s_a, m_a, met_a = step(st, init_momentum(st), batch, 0.05)

    st2 = _fresh_storage(cfg, spec, mesh_cfg)
    step_sp = make_train_step(
        cfg, mesh_cfg, mesh, spec, OPT, bs, plan=_plan(nrt, seq_parallel=True)
    )
    s_b, m_b, met_b = step_sp(st2, init_momentum(st2), batch, 0.05)

    la, lb = float(met_a["loss"]), float(met_b["loss"])
    assert la == lb, (arch, la, lb)
    np.testing.assert_array_equal(
        np.asarray(met_a["group_norms_sq"]), np.asarray(met_b["group_norms_sq"])
    )
    # a second step from the updated storage stays pinned
    _, _, met_a2 = step(s_a, m_a, _batch(cfg, seed=1), 0.05)
    _, _, met_b2 = step_sp(s_b, m_b, _batch(cfg, seed=1), 0.05)
    assert float(met_a2["loss"]) == float(met_b2["loss"]), arch
    print(f"  {arch}: seq-parallel == psum bit-exact ({la:.4f})")
    return spec


def run_compressed(cfg, spec, mesh_cfg, mesh):
    """seq_parallel + act rt2: planes on every boundary, loss in envelope."""
    batch = _batch(cfg)
    bs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    nrt = cfg.num_groups + 1
    act2 = CompressionPolicy(round_to=2, grad_round_to=2, mode="nearest")

    st = _fresh_storage(cfg, spec, mesh_cfg)
    step = make_train_step(cfg, mesh_cfg, mesh, spec, OPT, bs,
                           plan=_plan(nrt))
    _, _, met_ref = step(st, init_momentum(st), batch, 0.05)
    l_ref = float(met_ref["loss"])

    st2 = _fresh_storage(cfg, spec, mesh_cfg)
    plan_c = PrecisionPlan(
        weights=_plan(nrt).weights, activations=act2, seq_parallel=True
    )
    step_c = make_train_step(
        cfg, mesh_cfg, mesh, spec, OPT, bs, plan=plan_c,
    )
    s_c, m_c, met_c = step_c(st2, init_momentum(st2), batch, 0.05)
    l_c = float(met_c["loss"])
    assert abs(l_c - l_ref) < 0.05 + 0.05 * abs(l_ref), (l_ref, l_c)
    _, _, met_c2 = step_c(s_c, m_c, batch, 0.05)
    assert float(met_c2["loss"]) < l_c + 0.05, "seq-parallel rt2 diverged"
    print(f"  act-rt2 seq-parallel: {l_ref:.4f} -> {l_c:.4f} OK")


def run_serve(cfg, spec, mesh_cfg, mesh):
    """Prefill on shards == replicated prefill (logits AND caches), and
    decode continues from seq-parallel caches."""
    Sp = 16
    nrt = cfg.num_groups + 1
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (B, Sp)),
        jnp.int32,
    )}
    bshapes = {"tokens": jax.ShapeDtypeStruct((B, Sp), jnp.int32)}
    st = _fresh_storage(cfg, spec, mesh_cfg)

    pre = make_prefill_step(
        cfg, mesh_cfg, mesh, spec, bshapes, plan=_plan(nrt),
        cache_capacity=Sp + 2,
    )
    lg_a, caches_a = pre(st, batch)
    pre_sp = make_prefill_step(
        cfg, mesh_cfg, mesh, spec, bshapes,
        plan=_plan(nrt, seq_parallel=True), cache_capacity=Sp + 2,
    )
    lg_b, caches_b = pre_sp(st, batch)
    v = cfg.vocab_size
    np.testing.assert_allclose(
        np.asarray(lg_a[..., :v]), np.asarray(lg_b[..., :v]),
        rtol=1e-5, atol=1e-5,
    )
    for xa, xb in zip(
        jax.tree_util.tree_leaves(caches_a), jax.tree_util.tree_leaves(caches_b)
    ):
        np.testing.assert_allclose(
            np.asarray(xa), np.asarray(xb), rtol=1e-5, atol=1e-6
        )

    dshapes = {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    tok = {"tokens": jnp.ones((B, 1), jnp.int32),
           "pos": jnp.asarray(Sp, jnp.int32)}
    dstep = make_decode_step(cfg, mesh_cfg, mesh, spec, dshapes,
                             plan=_plan(nrt))
    dl_a, _ = dstep(st, caches_a, tok)
    dstep_sp = make_decode_step(
        cfg, mesh_cfg, mesh, spec, dshapes,
        plan=_plan(nrt, seq_parallel=True),
    )
    dl_b, _ = dstep_sp(st, caches_b, tok)
    np.testing.assert_allclose(
        np.asarray(dl_a[..., :v]), np.asarray(dl_b[..., :v]),
        rtol=1e-5, atol=1e-5,
    )
    print("  prefill/decode under seq-parallel OK")


def run_ep_moe(mesh_cfg, mesh):
    """Expert-parallel MoE: under seq_parallel the sequence shards ARE the
    EP token split (no boundary collective). The psum layout splits the
    flat token axis instead, so per-rank routing sets — and hence
    capacity drops — differ: statistical, not bit, equivalence. Also
    covers the ep_split path itself (its _token_split/_token_merge used
    the jax>=0.5-only lax.axis_size and was dead on this pin)."""
    cfg = dataclasses.replace(
        reduced(get_config("mixtral-8x7b")), moe_impl="ep"
    )
    batch = _batch(cfg)
    bs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    nrt = cfg.num_groups + 1
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    spec = build_spec_tree(params, metas, mesh_cfg)

    st = tree_to_storage(params, spec, mesh_cfg)
    step = make_train_step(cfg, mesh_cfg, mesh, spec, OPT, bs,
                           plan=_plan(nrt))
    _, _, met_a = step(st, init_momentum(st), batch, 0.05)
    st2 = _fresh_storage(cfg, spec, mesh_cfg)
    step_sp = make_train_step(
        cfg, mesh_cfg, mesh, spec, OPT, bs, plan=_plan(nrt, seq_parallel=True)
    )
    s_b, m_b, met_b = step_sp(st2, init_momentum(st2), batch, 0.05)
    la, lb = float(met_a["loss"]), float(met_b["loss"])
    assert abs(la - lb) < 0.02 + 0.01 * abs(la), (la, lb)
    _, _, met_b2 = step_sp(s_b, m_b, batch, 0.05)
    assert float(met_b2["loss"]) < lb + 0.05, "EP seq-parallel diverged"
    print(f"  ep-moe: psum {la:.4f} vs seq-parallel {lb:.4f} OK")


def run_seq_divisibility_guard(cfg, spec, mesh_cfg, mesh):
    bad = {"tokens": jax.ShapeDtypeStruct((B, 33), jnp.int32),
           "labels": jax.ShapeDtypeStruct((B, 33), jnp.int32)}
    nrt = cfg.num_groups + 1
    try:
        make_train_step(
            cfg, mesh_cfg, mesh, spec, OPT, bad,
            plan=_plan(nrt, seq_parallel=True),
        )
    except ValueError as e:
        assert "seq_parallel" in str(e)
        print("  seq divisibility guard OK")
        return
    raise AssertionError("expected ValueError for seq % tp != 0")


def main():
    mesh_cfg = MeshCfg(tp=2, dp=4)
    mesh = make_mesh_from_cfg(mesh_cfg)
    with mesh:
        # one arch per family: attention/vocab-parallel, MoE (tp layout),
        # mLSTM+sLSTM (incl. the replicated-recurrence re-shard path),
        # RG-LRU, audio feature stub, vision cross-attention
        spec_q = run_train_equivalence("qwen3-1.7b", mesh_cfg, mesh)
        for arch in ("mixtral-8x7b", "xlstm-1.3b", "recurrentgemma-9b",
                     "hubert-xlarge", "llama-3.2-vision-90b"):
            run_train_equivalence(arch, mesh_cfg, mesh)
        run_ep_moe(mesh_cfg, mesh)
        cfg_q = reduced(get_config("qwen3-1.7b"))
        run_compressed(cfg_q, spec_q, mesh_cfg, mesh)
        run_serve(cfg_q, spec_q, mesh_cfg, mesh)
        run_seq_divisibility_guard(cfg_q, spec_q, mesh_cfg, mesh)
    print("scenario_seq_parallel OK")


if __name__ == "__main__":
    main()
