"""The unified serving request API (`repro.serve.api`).

One frozen :class:`Request` object is accepted by every submit surface
— ``ServeEngine.submit``, :func:`repro.serve.engine.generate_static`,
``FleetRouter.submit``, and both launchers — so ``--check-static``
compares *identical* request objects end to end. A request carries its
prompt ids, stop conditions, per-request
:class:`~repro.plan.SamplingParams` (the PRNG contract lives there; see
docs/serving.md §sampling), and optionally per-request image features
for vision cross-attention archs on the static path.

Deprecation shims (one release, the PR 4/PR 9 pattern): the pre-PR 10
field names ``prompt=`` / ``max_new_tokens=`` still construct a
``Request`` behind a :class:`DeprecationWarning`, read-only properties
keep old call sites compiling, and :func:`legacy_request` adapts
positional old-style construction (the ``tools/lint`` DEPRECATED-SHIM
entry for this PR).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

from repro.plan.plan import SamplingParams

__all__ = ["Request", "SamplingParams", "legacy_request"]


@dataclasses.dataclass(frozen=True, init=False)
class Request:
    """One generation request: prompt, stop conditions, sampling."""

    rid: int
    prompt_ids: tuple[int, ...]
    max_new: int
    eos_id: int | None = None
    sampling: SamplingParams = SamplingParams()
    image_features: Any = dataclasses.field(
        default=None, compare=False, repr=False
    )

    def __init__(
        self,
        rid: int,
        prompt_ids=None,
        max_new: int | None = None,
        eos_id: int | None = None,
        sampling: SamplingParams | None = None,
        image_features=None,
        *,
        prompt=None,
        max_new_tokens: int | None = None,
    ):
        if prompt is not None or max_new_tokens is not None:
            warnings.warn(
                "Request(prompt=..., max_new_tokens=...) is deprecated; "
                "use Request(prompt_ids=..., max_new=...) — the legacy "
                "field names go away next release",
                DeprecationWarning,
                stacklevel=2,
            )
            if prompt_ids is None:
                prompt_ids = prompt
            if max_new is None:
                max_new = max_new_tokens
        if prompt_ids is None:
            raise ValueError(f"request {rid}: no prompt ids")
        prompt_ids = tuple(int(t) for t in prompt_ids)
        if not prompt_ids:
            raise ValueError(f"request {rid}: empty prompt")
        if max_new is None or max_new < 1:
            raise ValueError(f"request {rid}: max_new < 1")
        if sampling is None:
            sampling = SamplingParams()
        if not isinstance(sampling, SamplingParams):
            raise ValueError(f"request {rid}: sampling must be "
                             "a SamplingParams")
        object.__setattr__(self, "rid", rid)
        object.__setattr__(self, "prompt_ids", prompt_ids)
        object.__setattr__(self, "max_new", int(max_new))
        object.__setattr__(self, "eos_id", eos_id)
        object.__setattr__(self, "sampling", sampling)
        object.__setattr__(self, "image_features", image_features)

    # -- legacy read surface (no warning: cheap, unambiguous) ----------
    @property
    def prompt(self) -> tuple[int, ...]:
        return self.prompt_ids

    @property
    def max_new_tokens(self) -> int:
        return self.max_new


def legacy_request(rid, prompt, max_new_tokens, eos_id=None) -> Request:
    """DEPRECATED positional-tuple adapter for pre-PR 10 call sites.

    Kept one release behind a warning so external drivers migrate at
    their own pace; ``tools/lint`` forbids new in-repo callers.
    """
    warnings.warn(
        "legacy_request() is deprecated; construct serve.api.Request "
        "directly (prompt_ids=, max_new=)",
        DeprecationWarning,
        stacklevel=2,
    )
    return Request(rid, tuple(prompt), int(max_new_tokens), eos_id)
