"""Pure-jnp oracles for the ADT transfer kernels.

These implement the paper's Bitpack / Bitunpack (Algorithms 2-5) semantics:
an IEEE-754 fp32 weight is viewed as a 32-bit word and only the most
significant ``round_to`` bytes are kept.  The TPU-native layout is a
struct-of-arrays *byte-plane* decomposition (see DESIGN.md §2): plane ``k``
holds byte ``k`` (MSB first) of every weight.

Rounding modes:
  * ``truncate``   — the paper's mode: drop the low bytes.
  * ``nearest``    — beyond-paper: add half-ULP of the kept format first.
  * ``stochastic`` — beyond-paper: add uniform noise in [0, ULP) first.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

VALID_ROUND_TO = (1, 2, 3, 4)

_SHIFTS = (24, 16, 8, 0)  # MSB-first byte shifts within a uint32


def _as_u32(w: jnp.ndarray) -> jnp.ndarray:
    if w.dtype != jnp.float32:
        raise ValueError(f"bitpack expects float32, got {w.dtype}")
    return jax.lax.bitcast_convert_type(w, jnp.uint32)


def _round_bits(u: jnp.ndarray, round_to: int, mode: str, key=None) -> jnp.ndarray:
    """Apply rounding to the uint32 view before truncation."""
    drop = 8 * (4 - round_to)
    if drop == 0 or mode == "truncate":
        return u
    if mode == "nearest":
        # add half of the dropped range; saturate so the exponent never
        # overflows into inf/nan territory.
        half = jnp.uint32(1 << (drop - 1))
        bumped = u + half
        return jnp.where(bumped < u, jnp.uint32(0xFFFFFFFF), bumped)
    if mode == "stochastic":
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        noise = jax.random.randint(
            key, u.shape, 0, 1 << drop, dtype=jnp.uint32
        )
        bumped = u + noise
        return jnp.where(bumped < u, jnp.uint32(0xFFFFFFFF), bumped)
    raise ValueError(f"unknown rounding mode {mode!r}")


def bitpack_ref(
    w: jnp.ndarray, round_to: int, *, mode: str = "truncate", key=None
) -> jnp.ndarray:
    """fp32 array -> uint8 byte planes, shape ``(round_to, *w.shape)``.

    Plane 0 is the most significant byte (sign + 7 exponent bits).
    """
    if round_to not in VALID_ROUND_TO:
        raise ValueError(f"round_to must be in {VALID_ROUND_TO}")
    u = _round_bits(_as_u32(w), round_to, mode, key)
    planes = [
        ((u >> jnp.uint32(_SHIFTS[k])) & jnp.uint32(0xFF)).astype(jnp.uint8)
        for k in range(round_to)
    ]
    return jnp.stack(planes, axis=0)


def bitunpack_ref(planes: jnp.ndarray) -> jnp.ndarray:
    """uint8 byte planes ``(round_to, ...)`` -> fp32 (low bytes zero-filled)."""
    round_to = planes.shape[0]
    if round_to not in VALID_ROUND_TO:
        raise ValueError(f"leading plane dim must be in {VALID_ROUND_TO}")
    u = jnp.zeros(planes.shape[1:], jnp.uint32)
    for k in range(round_to):
        u = u | (planes[k].astype(jnp.uint32) << jnp.uint32(_SHIFTS[k]))
    return jax.lax.bitcast_convert_type(u, jnp.float32)


def quantize_ref(
    w: jnp.ndarray, round_to: int, *, mode: str = "truncate", key=None
) -> jnp.ndarray:
    """pack∘unpack — the value actually seen by the compute devices."""
    return bitunpack_ref(bitpack_ref(w, round_to, mode=mode, key=key))


def l2norm_sq_ref(w: jnp.ndarray) -> jnp.ndarray:
    """Σ w² as float32 scalar (AWP's per-layer monitor quantity)."""
    wf = w.astype(jnp.float32)
    return jnp.sum(wf * wf)
