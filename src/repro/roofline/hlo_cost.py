"""While-aware HLO-text cost analyzer.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, which
under-counts every ``lax.scan``-over-layers model by the trip count; the
same applies to collectives inside scanned layer bodies. This module
re-derives the three roofline inputs by walking the post-optimization HLO:

  * flops            — dot / convolution / reduce flops, × while trips
  * traffic bytes    — per-instruction operand+output bytes (fusions are
                       charged only their boundary, approximating fused
                       memory traffic), × while trips
  * collective wire  — ring-model wire bytes per collective, × while trips

Compressed-collective accounting: the transport's pack -> collective ->
unpack pipelines put ``uint8`` byte planes on the wire (weight gathers,
gradient reduce-scatters, and — since the TP-axis compression — activation
``seq_gather``/``seq_scatter``/all-reduce decompositions). Those
collectives are charged at their true u8 width like any other, and
*additionally* recorded in ``Cost.plane_wire`` so reports and tests can
split packed-plane traffic from raw-dtype traffic (the quantity that
shrinks by ``CompressionPolicy.wire_fraction``).

Sequence-parallel steps (``Env.seq_parallel``) need no special casing
here: their block boundaries lower to the same ag + rs plane pipelines
(``CompressionPolicy.seq_pair_wire_bytes`` is the per-region model), the
activation all-reduce entries disappear from the report, and the psums
the layout *removes* (the embedding exit, EP-MoE boundaries) show up as
genuinely fewer wire bytes.

Parsing rules target the CPU/SPMD backend's textual HLO (resolved via a
per-computation symbol table; computations recurse through ``calls=``,
``body=``, ``to_apply=``).
"""
from __future__ import annotations

import dataclasses
import math
import re
from functools import lru_cache

from repro.transport import ring_wire_bytes

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_HEAD_RE = re.compile(r"^(%?[\w.\-]+) \(.*\) -> .* \{")
_INSTR_RE = re.compile(r"^\s*(%?[\w.\-]+) = (.*)$")
_ROOT_RE = re.compile(r"^\s*ROOT (%?[\w.\-]+) = (.*)$")


def _shape_dims(shape_str: str):
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


def _type_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    # subset of `wire` carried as packed u8 byte planes (compressed
    # transport pipelines); same kind keys, always <= wire[kind]
    plane_wire: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k, v in other.wire.items():
            self.wire[k] = self.wire.get(k, 0) + v * times
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * times
        for k, v in other.plane_wire.items():
            self.plane_wire[k] = self.plane_wire.get(k, 0) + v * times

    @property
    def wire_total(self) -> float:
        return sum(self.wire.values())

    @property
    def plane_wire_total(self) -> float:
        return sum(self.plane_wire.values())


class Instr:
    __slots__ = ("name", "rhs", "type_str", "op", "operands")

    def __init__(self, name, rhs):
        self.name = name
        self.rhs = rhs
        # rhs: "TYPE op(...)" — TYPE may be a tuple "(a, b)"
        if rhs.startswith("("):
            depth = 0
            for i, ch in enumerate(rhs):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            self.type_str = rhs[: i + 1]
            rest = rhs[i + 1 :].strip()
        else:
            sp = rhs.index(" ")
            self.type_str = rhs[:sp]
            rest = rhs[sp + 1 :]
        m = re.match(r"([\w\-]+)\(", rest)
        self.op = m.group(1) if m else rest.split("(")[0].strip()
        # operand names: only inside the call's balanced paren group (attrs
        # like to_apply=%region / condition=%cond come after and are NOT
        # operands — resolved separately via _attr)
        if "(" in rest:
            pstart = rest.index("(")
            depth = 0
            pend = len(rest)
            for i in range(pstart, len(rest)):
                depth += rest[i] == "("
                depth -= rest[i] == ")"
                if depth == 0:
                    pend = i
                    break
            args = rest[pstart + 1 : pend]
        else:
            args = ""
        self.operands = re.findall(r"%[\w.\-]+", args)


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        cur = None
        for line in text.splitlines():
            h = _COMP_HEAD_RE.match(line.strip()) if "{" in line else None
            if line.startswith("ENTRY"):
                m = re.match(r"ENTRY (%?[\w.\-]+)", line)
                cur = m.group(1)
                self.computations[cur] = []
                self.entry = cur
                continue
            if h and not line.startswith(" "):
                cur = h.group(1)
                self.computations[cur] = []
                continue
            if cur is None:
                continue
            s = line.strip()
            if s == "}":
                cur = None
                continue
            m = _ROOT_RE.match(line) or _INSTR_RE.match(line)
            if m and " = " in s:
                name, rhs = m.group(1), m.group(2)
                if name.startswith("ROOT"):
                    continue
                try:
                    self.computations[cur].append(Instr(name, rhs))
                except Exception:
                    pass
        # symbol tables
        self.types: dict[str, dict[str, str]] = {
            c: {i.name.lstrip("%"): i.type_str for i in instrs}
            for c, instrs in self.computations.items()
        }

    # ------------------------------------------------------------------
    def _attr(self, rhs: str, key: str):
        m = re.search(key + r"=(%?[\w.\-]+)", rhs)
        return m.group(1) if m else None

    def _group_size(self, rhs: str) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", rhs)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([0-9,]+)\}", rhs)
        if m:
            return len(m.group(1).split(","))
        return 2

    def _trip_count(self, cond_comp: str) -> int:
        """Largest integer constant in the while condition computation."""
        best = 1
        for i in self.computations.get(cond_comp, []):
            m = re.search(r"constant\((\d+)\)", i.rhs)
            if m:
                best = max(best, int(m.group(1)))
        return best

    def _operand_type(self, comp: str, ref: str) -> str | None:
        return self.types.get(comp, {}).get(ref.lstrip("%"))

    def _dot_flops(self, comp: str, instr: Instr) -> float:
        _, out_dims = _shape_dims(instr.type_str)
        out_elems = math.prod(out_dims) if out_dims else 0
        lhs_t = self._operand_type(comp, instr.operands[0]) if instr.operands else None
        k = 1
        if lhs_t:
            _, lhs_dims = _shape_dims(lhs_t)
            m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rhs)
            if m and lhs_dims:
                for d in m.group(1).split(","):
                    if d:
                        k *= lhs_dims[int(d)]
        return 2.0 * out_elems * k

    def _conv_flops(self, comp: str, instr: Instr) -> float:
        _, out_dims = _shape_dims(instr.type_str)
        out_elems = math.prod(out_dims) if out_dims else 0
        rhs_t = (
            self._operand_type(comp, instr.operands[1])
            if len(instr.operands) > 1
            else None
        )
        if not rhs_t:
            return 0.0
        _, rdims = _shape_dims(rhs_t)
        m = re.search(r"dim_labels=\w+_(\w+)->", instr.rhs)
        kernel = math.prod(rdims) if rdims else 0
        if m and rdims:
            labels = m.group(1)
            if "o" in labels:
                kernel //= max(rdims[labels.index("o")], 1)
        return 2.0 * out_elems * kernel

    # ------------------------------------------------------------------
    def comp_cost(self, comp: str, _memo=None) -> Cost:
        if _memo is None:
            _memo = {}
        if comp in _memo:
            return _memo[comp]
        total = Cost()
        _memo[comp] = total  # guards (benign) cycles
        for instr in self.computations.get(comp, []):
            op = instr.op
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "partition-id", "replica-id"):
                continue
            out_b = _type_bytes(instr.type_str)
            in_b = sum(
                _type_bytes(self._operand_type(comp, o) or "")
                for o in instr.operands
            )
            if op == "while":
                body = self._attr(instr.rhs, "body")
                cond = self._attr(instr.rhs, "condition")
                trips = self._trip_count(cond) if cond else 1
                if body:
                    total.add(self.comp_cost(body, _memo), trips)
                continue
            if op in ("fusion", "call", "map", "custom-call"):
                total.bytes += out_b + in_b
                callee = self._attr(instr.rhs, "calls") or self._attr(
                    instr.rhs, "to_apply"
                )
                if callee:
                    inner = self.comp_cost(callee, _memo)
                    # fusion internals contribute flops + collectives but
                    # their memory traffic stays in registers/cache
                    total.flops += inner.flops
                    for k, v in inner.wire.items():
                        total.wire[k] = total.wire.get(k, 0) + v
                    for k, v in inner.coll_counts.items():
                        total.coll_counts[k] = total.coll_counts.get(k, 0) + v
                    for k, v in inner.plane_wire.items():
                        total.plane_wire[k] = total.plane_wire.get(k, 0) + v
                continue
            if op == "conditional":
                # charge the max branch
                branches = re.findall(r"%[\w.\-]+_computation[\w.\-]*", instr.rhs)
                costs = [self.comp_cost(b, _memo) for b in branches]
                if costs:
                    total.add(max(costs, key=lambda c: c.flops))
                total.bytes += out_b + in_b
                continue
            total.bytes += out_b + in_b
            if op == "dot":
                total.flops += self._dot_flops(comp, instr)
            elif op == "convolution":
                total.flops += self._conv_flops(comp, instr)
            elif op in ("reduce", "reduce-window"):
                total.flops += in_b / 4.0  # ~1 flop per input element
            elif op.startswith(("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute")):
                if op.endswith("-done"):
                    # async completion half: the wire traffic was charged
                    # on the matching -start op
                    continue
                kind = op.replace("-start", "")
                n = self._group_size(instr.rhs)
                # The CPU backend promotes narrow-dtype collectives to f32
                # via wrapped-convert fusions; TPU runs them natively. Wire
                # bytes are charged at the pre-convert width.
                in_eff = self._deconverted_bytes(comp, instr, in_b)
                ratio = in_eff / in_b if in_b else 1.0
                out_eff = out_b * ratio
                # ring model shared with the transport policy accounting
                payload = (
                    out_eff if kind in ("all-gather", "all-to-all") else in_eff
                )
                w = ring_wire_bytes(kind, payload, n)
                total.wire[kind] = total.wire.get(kind, 0) + w
                total.coll_counts[kind] = total.coll_counts.get(kind, 0) + 1
                if self._is_plane_collective(comp, instr):
                    total.plane_wire[kind] = (
                        total.plane_wire.get(kind, 0) + w
                    )
        return total

    def _is_plane_collective(self, comp: str, instr: Instr) -> bool:
        """True when every operand is uint8 — the transport's packed
        byte-plane pipelines are the only u8 wire traffic in this
        framework (weights, grads, and TP-axis activations alike)."""
        if not instr.operands:
            return False
        for ref in instr.operands:
            t = self._operand_type(comp, ref)
            if t is None or not t.lstrip("(").startswith("u8["):
                return False
        return True

    def _deconverted_bytes(self, comp: str, instr: Instr, in_b: int) -> int:
        """If every operand of a collective is a (fusion-wrapped) dtype
        convert, charge the pre-convert width (CPU-backend promotion)."""
        eff = 0
        found = False
        instr_by_name = getattr(self, "_instr_idx", None)
        if instr_by_name is None:
            instr_by_name = {
                c: {i.name.lstrip("%"): i for i in instrs}
                for c, instrs in self.computations.items()
            }
            self._instr_idx = instr_by_name
        table = instr_by_name.get(comp, {})
        for ref in instr.operands:
            src = table.get(ref.lstrip("%"))
            if src is None:
                return in_b
            is_conv = (
                src.op == "convert"
                or "convert" in src.name
                or (src.op == "fusion" and "convert" in src.rhs)
            )
            if not is_conv or not src.operands:
                return in_b
            src_t = self._operand_type(comp, src.operands[0])
            if src_t is None:
                return in_b
            b = _type_bytes(src_t)
            out_t = _type_bytes(src.type_str)
            if b >= out_t:
                return in_b
            eff += b
            found = True
        return eff if found else in_b

    def entry_cost(self) -> Cost:
        if self.entry is None:
            raise ValueError("HLO module has no entry computation")
        # memo shared so fusion computations are cached, but note: while
        # bodies reached from different whiles are distinct computations in
        # HLO, so memoization over names is safe.
        return self.comp_cost(self.entry, {})


def analyze_hlo(text: str) -> Cost:
    return HloModule(text).entry_cost()


def plan_wire_split(
    cost: Cost,
    plan,
    dist_elems_per_group,
    gather_axis_size: int,
    *,
    training: bool = True,
) -> dict:
    """Split a measured :class:`Cost` by :class:`~repro.plan.PrecisionPlan`
    traffic class — the plan as the unit of cost accounting.

    The ``weights`` / ``gradients`` / ``host_device`` entries come from
    the plan's own :meth:`~repro.plan.PrecisionPlan.wire_table` (the
    ``CompressionPolicy`` formulas, so they agree with what this module
    charges the corresponding collectives). ``plane_residue`` is the
    *measured* packed-plane wire not explained by the compressed
    weight/gradient entries: the TP-axis activation / seq-boundary
    pipelines, plus remat-replayed weight gathers on configs that
    rematerialize the layer stack (the recompute repeats the forward
    plane gather, which the once-per-step analytic entry deliberately
    does not count). ``raw_wire`` is the non-plane remainder
    (uncompressed psums, grad syncs, cache shuffles). The measured
    totals ride along so reports can show analytic-vs-HLO drift."""
    table = plan.wire_table(
        dist_elems_per_group, gather_axis_size, training=training
    )
    # only the groups that actually compress ride u8 planes: an rt=4
    # entry's gather is a raw f32 collective and must not be subtracted
    # from the measured plane wire (mixed-width plans are the norm under
    # per-group AWP widening)
    plane_share = 0
    n = int(gather_axis_size)
    if n > 1:
        for pol, e in zip(plan.weight_policies(), dist_elems_per_group):
            if pol.compresses:
                plane_share += pol.all_gather_wire_bytes(e // n, n)
            if training and pol.compresses_grads:
                plane_share += pol.reduce_scatter_wire_bytes(e // n, n)
    split = {k: v for k, v in table.items()}
    split["plane_residue"] = max(
        round(cost.plane_wire_total - plane_share), 0
    )
    split["raw_wire"] = round(cost.wire_total - cost.plane_wire_total)
    split["measured_plane_wire"] = round(cost.plane_wire_total)
    split["measured_wire"] = round(cost.wire_total)
    return split
