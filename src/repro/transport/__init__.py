"""Unified compression transport layer (see docs/transport.md).

Public surface:

  * :class:`CompressionPolicy` / :func:`policy_for` — wire-format policy
    and the single source of truth for wire-byte accounting.
  * :class:`Transport` and the functional :func:`all_gather`,
    :func:`reduce_scatter`, :func:`quantize` — the pack -> collective ->
    unpack pipelines with ADT semantics and training-ready VJPs.
  * :func:`pack_planes` / :func:`unpack_planes` — kernel dispatch
    (Pallas compiled on TPU / interpret off-TPU, or the jnp oracle).
"""
from repro.transport.policy import (
    CompressionPolicy,
    policy_for,
    ring_wire_bytes,
)
from repro.transport.transport import (
    Transport,
    all_gather,
    axis_size,
    pack_planes,
    quantize,
    reduce_scatter,
    resolve_impl,
    unpack_planes,
)

__all__ = [
    "CompressionPolicy",
    "Transport",
    "all_gather",
    "axis_size",
    "pack_planes",
    "policy_for",
    "quantize",
    "reduce_scatter",
    "resolve_impl",
    "ring_wire_bytes",
    "unpack_planes",
]
