"""Subprocess scenario: the disaggregated serving fleet on a tp=2 mesh.

The headline determinism pin of `repro.fleet` (docs/fleet.md): router
token streams are BIT-EXACT vs a single paged engine and vs the static
one-shot reference —

  * under arrival-order permutations of the same request set;
  * across a mid-run replica join AND a drain-based replica leave;
  * for fp32 and int8 KV pools (migrated pages lossless both ways);
  * across a mid-run live weight refresh: post-refresh requests equal
    a fresh engine running the weights restored FROM the published
    parcel (versioned-at-admission — no in-flight request pauses);

and the fabric hop log equals `roofline.fleet_migration_bytes` EXACTLY
for both traffic classes, in every topology above.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.fleet import DecodeReplica, FleetRouter, PrefillWorker, WeightPublisher
from repro.launch.mesh import make_mesh_from_cfg
from repro.models.init import init_params
from repro.plan import PrecisionPlan
from repro.roofline.analysis import fleet_migration_bytes
from repro.serve.engine import Request, ServeEngine, generate_static
from repro.transport import CompressionPolicy, unpack_weight_parcel

MESH_CFG = MeshCfg(tp=2, dp=1)
PAGE = 8
GEN = 5
CAP = 28
SLOTS = 2


def _requests(cfg, *, rid_base=0, seed=3):
    rng = np.random.default_rng(seed)
    shared = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, PAGE))
    return [
        Request(
            rid=rid_base + i,
            prompt_ids=shared + tuple(
                int(t) for t in rng.integers(0, cfg.vocab_size, tail)
            ),
            max_new=GEN,
        )
        for i, tail in enumerate((9, 4, 12, 7, 10))
    ]


def _pin_fabric(router, plan, cfg, publish_nbytes, *, int8=False, tag=""):
    ws = router.wire_summary()
    analytic = fleet_migration_bytes(
        plan, cfg, page_size=PAGE, migrated_pages=ws["migrated_pages"],
        int8_kv=int8, publish_wire_bytes=publish_nbytes,
        publish_installs=ws["publish_installs"],
    )
    for cls in ("kv_migration", "weight_publish"):
        assert ws[cls] == analytic[cls], (tag, cls, ws, analytic)
    return ws, analytic


def main():
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh = make_mesh_from_cfg(MESH_CFG)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=MESH_CFG.tp)
    spec_tree = build_spec_tree(params, metas, MESH_CFG)
    storage = tree_to_storage(params, spec_tree, MESH_CFG)
    params1, _ = init_params(cfg, jax.random.PRNGKey(1), tp=MESH_CFG.tp)
    storage1 = tree_to_storage(params1, spec_tree, MESH_CFG)
    plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2),) * (cfg.num_groups + 1),
        host_device=CompressionPolicy(round_to=2),
    )
    reqs = _requests(cfg)

    def engine(p=plan, store=storage):
        return ServeEngine(
            cfg, MESH_CFG, mesh, spec_tree, store, plan=p,
            max_slots=SLOTS, cache_capacity=CAP, paged=True, page_size=PAGE,
        )

    def worker(name="w0", p=plan):
        return PrefillWorker(
            name, cfg, MESH_CFG, mesh, spec_tree, plan=p,
            cache_capacity=CAP, page_size=PAGE,
        )

    with mesh:
        static = generate_static(
            cfg, MESH_CFG, mesh, spec_tree, storage, reqs, plan=plan
        )
        e0, e1 = engine(), engine()
        single = e0.run(reqs)
        for r in reqs:
            assert single[r.rid].tokens == static[r.rid], ("single", r.rid)

        publisher = WeightPublisher(cfg, spec_tree, plan=plan)
        w0 = worker()

        # -- 2-replica fleet, FIFO arrival ------------------------------
        router = FleetRouter(
            [DecodeReplica("r0", e0), DecodeReplica("r1", e1)], [w0]
        )
        p0 = publisher.publish(storage)
        router.publish(p0)
        results = router.run(reqs)
        for r in reqs:
            assert results[r.rid].tokens == static[r.rid], ("fleet", r.rid)
        ws, analytic = _pin_fabric(router, plan, cfg, p0.nbytes, tag="fifo")
        assert len({m["replica"] for m in router.placements.values()}) == 2
        print(f"fleet(2r): {len(reqs)} streams bit-exact vs single + "
              f"static; kv_migration {ws['kv_migration']} B == analytic "
              f"({ws['migrated_pages']} pages x "
              f"{analytic['page_wire_bytes']} B)")

        # -- arrival-order permutation ----------------------------------
        router = FleetRouter(
            [DecodeReplica("r0", e0), DecodeReplica("r1", e1)], [w0]
        )
        router.publish(publisher.publish(storage))
        perm = router.run(list(reversed(reqs)))
        for r in reqs:
            assert perm[r.rid].tokens == static[r.rid], ("perm", r.rid)
        print("arrival permutation: reversed submission, identical streams")

        # -- replica join + drain-based leave ---------------------------
        e2 = engine()
        router = FleetRouter(
            [DecodeReplica("r0", e0), DecodeReplica("r1", e1)], [w0]
        )
        p_jl = publisher.publish(storage)
        router.publish(p_jl)
        state = {"done": False}

        def join_leave(r):
            if not state["done"] and r.ticks >= 2:
                state["done"] = True
                r.add_replica(DecodeReplica("r2", e2))
                r.remove_replica("r0")

        jl = router.run(reqs, on_tick=join_leave)
        for r in reqs:
            assert jl[r.rid].tokens == static[r.rid], ("join/leave", r.rid)
        assert state["done"] and len(router.replicas) == 2
        assert {x.name for x in router.replicas} == {"r1", "r2"}
        ws, _ = _pin_fabric(router, plan, cfg, p_jl.nbytes, tag="join")
        assert ws["publish_installs"] == 3  # r0, r1, and the joining r2
        print("join/leave: r2 joined via fabric install, r0 drained out; "
              "streams identical, fabric pin holds")

        # -- int8 KV pools ----------------------------------------------
        plan8 = dataclasses.replace(plan, int8_kv=True)
        static8 = generate_static(
            cfg, MESH_CFG, mesh, spec_tree, storage, reqs, plan=plan8
        )
        router = FleetRouter(
            [DecodeReplica("r0", engine(plan8)),
             DecodeReplica("r1", engine(plan8))],
            [worker("w8", plan8)],
        )
        pub8 = WeightPublisher(cfg, spec_tree, plan=plan8)
        p8 = pub8.publish(storage)
        router.publish(p8)
        res8 = router.run(reqs)
        for r in reqs:
            assert res8[r.rid].tokens == static8[r.rid], ("int8", r.rid)
        ws8, an8 = _pin_fabric(
            router, plan8, cfg, p8.nbytes, int8=True, tag="int8"
        )
        assert an8["kv_width"] < 4  # int8 payload genuinely narrower
        print(f"int8 KV: streams bit-exact vs static; migrated payload at "
              f"{an8['kv_width']} B/elem ({ws8['kv_migration']} B == "
              "analytic)")

        # -- mid-run live weight refresh --------------------------------
        wave_b = _requests(cfg, rid_base=len(reqs), seed=11)
        router = FleetRouter(
            [DecodeReplica("r0", e0), DecodeReplica("r1", e1)], [w0]
        )
        pub_r = WeightPublisher(cfg, spec_tree, plan=plan)
        pv0 = pub_r.publish(storage)
        router.publish(pv0)
        pv1 = pub_r.publish(storage1, step=1)
        state = {"done": False}

        def refresh(r):
            if not state["done"] and len(r.results) >= 2:
                state["done"] = True
                r.publish(pv1)
                for req in wave_b:
                    r.submit(req)

        res = router.run(reqs, on_tick=refresh)
        assert state["done"], "refresh hook never fired mid-run"
        for r in reqs:  # pre-refresh wave: still the v0 streams
            assert res[r.rid].tokens == static[r.rid], ("refresh/v0", r.rid)
        # post-refresh wave == a fresh engine running the weights
        # restored FROM the published parcel (the hot-swap contract)
        restored1 = unpack_weight_parcel(pv1, storage)
        e2.swap_weights(restored1)
        fresh1 = e2.run(wave_b)
        for r in wave_b:
            assert res[r.rid].tokens == fresh1[r.rid].tokens, (
                "refresh/v1", r.rid,
            )
        assert {m["version"] for m in router.placements.values()} == {0, 1}
        ws, _ = _pin_fabric(router, plan, cfg, pv0.nbytes, tag="refresh")
        print(f"live refresh: v0 wave untouched, v1 wave equals a fresh "
              f"engine from the published parcel "
              f"({ws['publish_installs']} rolling installs, fabric pin "
              "holds)")

    print("scenario_fleet OK")


if __name__ == "__main__":
    main()
