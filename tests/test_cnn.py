"""CNN repro stack: shapes, learning signal, A²DTWP step, AWP per layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticImageNet
from repro.dist.spec import MeshCfg
from repro.models.cnn import (
    ALEXNET, RESNET34, VGG_A, cnn_forward, init_cnn, reduced_cnn,
)
from repro.transport import CompressionPolicy
from repro.optim.sgd import SGDConfig, init_momentum
from repro.plan import PrecisionPlan
from repro.train.cnn_step import (
    build_cnn_spec_tree, cnn_to_storage, make_cnn_eval, make_cnn_train_step,
)

MESH = MeshCfg(tp=1, dp=1, compress_min_size=256)


@pytest.mark.parametrize("full", [ALEXNET, VGG_A, RESNET34])
def test_forward_shapes(full):
    cfg = reduced_cnn(full, num_classes=10, in_hw=32)
    params, metas, (groups, ng) = init_cnn(cfg, jax.random.PRNGKey(0))
    imgs = jnp.zeros((2, 32, 32, 3), jnp.float32)
    logits = cnn_forward(params["layers"], imgs, cfg, train=False)
    assert logits.shape == (2, 10)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert ng >= 3
    # resnet groups at block granularity: fewer groups than conv layers
    if full is RESNET34:
        assert cfg.awp_granularity == "block"


@pytest.mark.parametrize("rt", [2, 4])
def test_train_step_descends(rt):
    cfg = reduced_cnn(ALEXNET, num_classes=10, in_hw=32)
    data = SyntheticImageNet(num_classes=10, hw=32, noise=0.1)
    params, metas, gi = init_cnn(cfg, jax.random.PRNGKey(0))
    spec = build_cnn_spec_tree(params, metas, MESH)
    storage = cnn_to_storage(params, spec, MESH)
    _, ng = gi
    opt = SGDConfig(lr=0.05, momentum=0.9, weight_decay=5e-4)
    step = make_cnn_train_step(
        cfg, MESH, None, spec, gi, opt, {},
        plan=PrecisionPlan.build(ng, round_to=rt),
    )
    mom = init_momentum(storage)
    losses = []
    for i in range(30):
        imgs, labels = data.batch(64, i)
        storage, mom, m = step(
            storage, mom, {"images": imgs, "labels": labels}, 0.05,
            jax.random.PRNGKey(i),
        )
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], (rt, losses[0], losses[-1])
    # AWP norm vector has one entry per group and is positive
    norms = np.asarray(m["group_norms_sq"])
    assert norms.shape == (ng,)
    assert (norms > 0).all()


def test_train_step_with_act_policy_descends():
    """Activation group in the DP CNN setting: stage-boundary
    straight-through truncation — training still descends and stays
    close to the uncompressed trajectory over a few steps."""
    cfg = reduced_cnn(ALEXNET, num_classes=10, in_hw=32)
    data = SyntheticImageNet(num_classes=10, hw=32, noise=0.1)
    opt = SGDConfig(lr=0.05, momentum=0.9, weight_decay=5e-4)

    def run(act_policy):
        params, metas, gi = init_cnn(cfg, jax.random.PRNGKey(0))
        spec = build_cnn_spec_tree(params, metas, MESH)
        storage = cnn_to_storage(params, spec, MESH)
        _, ng = gi
        plan = PrecisionPlan(
            weights=(CompressionPolicy(),) * ng, activations=act_policy
        )
        step = make_cnn_train_step(
            cfg, MESH, None, spec, gi, opt, {}, plan=plan,
        )
        mom = init_momentum(storage)
        losses = []
        for i in range(8):
            imgs, labels = data.batch(64, i)
            storage, mom, m = step(
                storage, mom, {"images": imgs, "labels": labels}, 0.05,
                jax.random.PRNGKey(i),
            )
            losses.append(float(m["loss"]))
        return losses

    base = run(None)
    act2 = run(CompressionPolicy(round_to=2, mode="nearest"))
    assert np.isfinite(act2).all()
    assert act2[-1] < act2[0], act2
    # rt=2 nearest keeps ~8 mantissa bits: trajectories stay close early
    assert abs(act2[0] - base[0]) < 0.05 + 0.05 * abs(base[0])
    # act rt=4 policy is a no-op (quantize short-circuits): bit-identical
    act4 = run(CompressionPolicy(round_to=4))
    np.testing.assert_allclose(act4, base, rtol=1e-6)


def test_eval_top5():
    cfg = reduced_cnn(VGG_A, num_classes=10, in_hw=32)
    data = SyntheticImageNet(num_classes=10, hw=32)
    params, metas, gi = init_cnn(cfg, jax.random.PRNGKey(0))
    spec = build_cnn_spec_tree(params, metas, MESH)
    storage = cnn_to_storage(params, spec, MESH)
    _, ng = gi
    ev = make_cnn_eval(cfg, MESH, None, spec, gi,
                       plan=PrecisionPlan.build(ng))
    imgs, labels = data.validation(64)
    err = float(ev(storage, imgs, labels))
    assert 0.0 <= err <= 1.0
    # untrained top-5 error on 10 classes should be near 0.5
    assert 0.2 < err < 0.85
