"""Pallas TPU kernel: fused Σw² grid reduction for the AWP monitor.

The paper's profile (Tables II/III) shows the AWP l²-norm as the algorithm's
only measurable cost, so it gets a fused kernel: one pass over the weights,
accumulating a scalar across sequential grid steps (output block revisited
every step; initialised on step 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitpack import LANES, resolve_interpret

NORM_BLOCK_ROWS = 512


def _l2norm_kernel(w_ref, acc_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[0, 0] = jnp.float32(0.0)

    x = w_ref[...].astype(jnp.float32)
    acc_ref[0, 0] += jnp.sum(x * x)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def l2norm_sq_2d(
    w: jnp.ndarray,
    *,
    interpret: bool | None = None,
    block_rows: int = NORM_BLOCK_ROWS,
) -> jnp.ndarray:
    """Σw² of a ``(rows, 128)`` fp32 array -> f32 scalar."""
    rows, lanes = w.shape
    if lanes != LANES:
        raise ValueError(f"last dim must be {LANES}, got {lanes}")
    if rows % block_rows:
        raise ValueError(f"rows ({rows}) must be a multiple of {block_rows}")
    grid = (rows // block_rows,)
    interpret = resolve_interpret(interpret)
    out = pl.pallas_call(
        _l2norm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(w)
    return out[0, 0]
