"""Typed failures for the serving fleet (`repro.fleet`).

Follows the serve engine's error taxonomy (:class:`CapacityError` /
:class:`AllocatorError` / :class:`InvariantError`): fleet code raises
typed exceptions, never bare asserts — they survive ``-O`` and callers
can catch by kind. Engine-level failures (slot/page exhaustion,
allocator misuse) keep their serve types and propagate through.
"""
from __future__ import annotations


class RouterError(RuntimeError):
    """Fleet-router contract violation: no replicas/workers, duplicate
    request ids, submission before any weight publish, mismatched
    replica geometry, or a drain loop that exceeded its tick budget.
    The fleet topology or the caller's protocol is wrong; individual
    replicas are still consistent."""


class ReplicaError(RuntimeError):
    """Replica/worker contract violation: an engine the fleet cannot
    serve (contiguous layout, MoE or non-attention pattern, vision
    payloads) or a parcel that does not match the replica's geometry.
    The replica refuses the work; the router and its peers are
    unaffected."""
