"""Subprocess scenario: the transport layer's collective paths on an
8-device host mesh — Transport dispatch (both impls), chunked
double-buffered gather, multi-axis reduce-scatter, the compressed
backward path (grad_round_to < 4), the generalized (arbitrary-rank /
placed / stacked) reduce-scatter, and the activation-path
seq_gather / seq_scatter pair with compressed fwd AND bwd."""
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.shard import shard_map
from repro.kernels import ref
from repro.transport import CompressionPolicy, Transport


def main():
    devs = np.array(jax.devices())
    mesh = Mesh(devs.reshape(4, 2), ("data", "model"))
    mesh3 = Mesh(devs.reshape(2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    S = 4 * 1024
    w = jnp.asarray(rng.normal(0, 1, (S,)).astype(np.float32))

    # ---- Transport.all_gather, both impls, all round_tos --------------
    for impl in ("ref", "pallas"):
        for rt in (1, 2, 3, 4):
            pol = CompressionPolicy(round_to=rt, impl=impl)
            t = Transport("data")

            f = shard_map(
                lambda x: t.all_gather(x, pol),
                mesh=mesh, in_specs=P("data"), out_specs=P(None),
            )
            got = np.asarray(jax.jit(f)(w))
            want = np.asarray(ref.quantize_ref(w, rt))
            np.testing.assert_array_equal(
                got, want, err_msg=f"impl={impl} rt={rt}"
            )
    print("  transport gather: ref/pallas x rt{1..4} exact OK")

    # ---- chunked double-buffered gather matches unchunked -------------
    for chunks in (2, 4, 8):
        pol = CompressionPolicy(round_to=2, chunks=chunks)
        t = Transport("data")
        f = shard_map(
            lambda x: t.all_gather(x, pol),
            mesh=mesh, in_specs=P("data"), out_specs=P(None),
        )
        got = np.asarray(jax.jit(f)(w))
        np.testing.assert_array_equal(
            got, np.asarray(ref.quantize_ref(w, 2)),
            err_msg=f"chunks={chunks}",
        )
    print("  chunked gather: interleave-exact for 2/4/8 blocks OK")

    # ---- multi-axis gather + multi-axis compressed reduce-scatter -----
    t3 = Transport(("pod", "data"))
    f = shard_map(
        lambda x: t3.all_gather(x, CompressionPolicy(round_to=2)),
        mesh=mesh3, in_specs=P(("pod", "data")), out_specs=P(None),
    )
    np.testing.assert_array_equal(
        np.asarray(jax.jit(f)(w)), np.asarray(ref.quantize_ref(w, 2))
    )

    D = 4  # pod x data
    gmat = jnp.asarray(rng.normal(0, 1, (D, S)).astype(np.float32))

    def rs(g_all):
        i = jax.lax.axis_index(("pod", "data"))
        return t3.reduce_scatter(
            g_all[i], CompressionPolicy(grad_round_to=2)
        )

    f = shard_map(
        rs, mesh=mesh3, in_specs=P(None, None),
        out_specs=P(("pod", "data")),
    )
    got = np.asarray(jax.jit(f)(gmat))
    want = np.sum(np.asarray(gmat), axis=0)
    tol = np.abs(want) * 2**-7 + 4 * 2**-7  # rt=2 nearest: ~2^-8 relative
    assert np.all(np.abs(got - want) <= tol), np.max(np.abs(got - want) - tol)

    # rt=4 multi-axis is exact
    def rs4(g_all):
        i = jax.lax.axis_index(("pod", "data"))
        return t3.reduce_scatter(g_all[i], CompressionPolicy())

    f4 = shard_map(
        rs4, mesh=mesh3, in_specs=P(None, None),
        out_specs=P(("pod", "data")),
    )
    np.testing.assert_allclose(np.asarray(jax.jit(f4)(gmat)), want, rtol=1e-6)
    print("  multi-axis (pod,data) gather + reduce-scatter OK")

    # ---- compressed backward path: grad_round_to < 4 ------------------
    D = 4
    coef = jnp.asarray(rng.normal(0, 1, (D, S)).astype(np.float32))
    pol_cg = CompressionPolicy(round_to=2, grad_round_to=2)
    t = Transport("data")

    def loss_fn(w_local, coef_row):
        w_full = t.all_gather(w_local, pol_cg)
        return jnp.sum(w_full * coef_row) / D

    def per_shard(w_local, coef_shard):
        return jax.grad(loss_fn)(w_local, coef_shard[0])

    f = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P("data"), P("data", None)), out_specs=P("data"),
    )
    got = np.asarray(jax.jit(f)(w, coef)).reshape(-1)
    want_full = np.sum(np.asarray(coef), axis=0) / D
    # the cotangent rides a rt=2 nearest-rounded reduce-scatter: each of
    # the D contributions carries ~2^-8 relative format error
    tol = np.abs(want_full) * 2**-7 + D * 2**-7
    assert np.all(np.abs(got - want_full) <= tol), np.max(
        np.abs(got - want_full) - tol
    )

    # and grad_round_to=4 (paper-faithful) stays exact to fp tolerance
    pol_ex = CompressionPolicy(round_to=2, grad_round_to=4)

    def loss_ex(w_local, coef_row):
        return jnp.sum(t.all_gather(w_local, pol_ex) * coef_row) / D

    f = shard_map(
        lambda wl, cs: jax.grad(loss_ex)(wl, cs[0]),
        mesh=mesh, in_specs=(P("data"), P("data", None)),
        out_specs=P("data"),
    )
    got = np.asarray(jax.jit(f)(w, coef)).reshape(-1)
    np.testing.assert_allclose(got, want_full, rtol=1e-6)
    print("  compressed VJP (grad_round_to=2) within format tolerance OK")

    # ---- generalized reduce-scatter: placed / stacked / N-D leaves ----
    # 2-D stacked (reps, S) scattering axis 1; 3-D placed (B, S, D) with
    # non-divisible trailing dims (33, 3); 2-D with non-divisible lead.
    t = Transport("data")
    for shape, axis in [
        ((3, 1024), 1),       # stacked leaf: (reps, flat) at axis=1
        ((4, 64, 33), 1),     # placed 3-D, trailing dim not divisible
        ((64, 5, 3), 0),      # 3-D, both trailing dims non-divisible
    ]:
        garr = jnp.asarray(rng.normal(0, 1, shape).astype(np.float32))

        def rs_gen(g_all, axis=axis):
            i = jax.lax.axis_index("data")
            return t.reduce_scatter(
                g_all * (i + 1.0), CompressionPolicy(grad_round_to=2),
                axis=axis,
            )

        def rs_fp32(g_all, axis=axis):
            i = jax.lax.axis_index("data")
            return jax.lax.psum_scatter(
                g_all * (i + 1.0), "data", scatter_dimension=axis, tiled=True
            )

        out_spec = P(*["data" if d == axis else None for d in range(len(shape))])
        f = shard_map(rs_gen, mesh=mesh, in_specs=P(*[None] * len(shape)),
                      out_specs=out_spec)
        got = np.asarray(jax.jit(f)(garr))
        want = np.asarray(garr) * 10.0  # sum_{i=1..4} i
        assert got.shape == shape, (got.shape, shape)
        tol = np.abs(want) * 2**-7 + 4 * 2**-7
        assert np.all(np.abs(got - want) <= tol), (
            shape, np.max(np.abs(got - want) - tol)
        )

        # uncompressed (grad_round_to=4) must be BIT-EXACT with the fp32
        # path: the generalized transport dispatches to the identical
        # lax.psum_scatter
        f4 = shard_map(
            lambda g_all: t.reduce_scatter(
                g_all * (jax.lax.axis_index("data") + 1.0),
                CompressionPolicy(), axis=axis,
            ),
            mesh=mesh, in_specs=P(*[None] * len(shape)), out_specs=out_spec,
        )
        fr = shard_map(rs_fp32, mesh=mesh, in_specs=P(*[None] * len(shape)),
                       out_specs=out_spec)
        np.testing.assert_array_equal(
            np.asarray(jax.jit(f4)(garr)), np.asarray(jax.jit(fr)(garr)),
            err_msg=f"shape={shape}",
        )
    print("  generalized rs: 2-D/3-D placed+stacked, rt4 bit-exact OK")

    # non-divisible SCATTER dim is a trace-time error, not silent padding
    try:
        bad = shard_map(
            lambda g_all: t.reduce_scatter(
                g_all, CompressionPolicy(grad_round_to=2), axis=0
            ),
            mesh=mesh, in_specs=P(None, None), out_specs=P("data", None),
        )
        jax.jit(bad).lower(jnp.zeros((6, 3), jnp.float32))
        raise AssertionError("non-divisible scatter dim did not raise")
    except ValueError as e:
        assert "not divisible" in str(e), e
    print("  generalized rs: non-divisible scatter dim raises OK")

    # ---- compressed bwd through a stacked placed gather (axis=1) ------
    # placed_leaf-style: (reps, S_loc) gathered at axis 1; the cotangent
    # now reduce-scatters through the generalized path at rt=2.
    reps = 3
    wst = jnp.asarray(rng.normal(0, 1, (reps, S)).astype(np.float32))
    coef_st = jnp.asarray(rng.normal(0, 1, (D, reps, S)).astype(np.float32))
    pol_st = CompressionPolicy(round_to=2, grad_round_to=2)

    def loss_st(w_local, coef_row):
        w_full = t.all_gather(w_local, pol_st, axis=1)
        return jnp.sum(w_full * coef_row) / D

    f = shard_map(
        lambda wl, cs: jax.grad(loss_st)(wl, cs[0]),
        mesh=mesh, in_specs=(P(None, "data"), P("data", None, None)),
        out_specs=P(None, "data"),
    )
    got = np.asarray(jax.jit(f)(wst, coef_st))
    want_st = np.sum(np.asarray(coef_st), axis=0) / D
    # out_specs already concatenated the per-shard results along axis 1
    got_full = got.reshape(reps, S)
    tol = np.abs(want_st) * 2**-7 + D * 2**-7
    assert np.all(np.abs(got_full - want_st) <= tol), np.max(
        np.abs(got_full - want_st) - tol
    )
    print("  stacked placed gather: compressed bwd (axis=1) OK")

    # ---- seq_gather / seq_scatter: compressed fwd + bwd ----------------
    from repro.transport import seq_gather, seq_scatter

    B, seq, dm = 4, 32, 16
    xs = jnp.asarray(rng.normal(0, 1, (B, seq, dm)).astype(np.float32))
    pol_act = CompressionPolicy(round_to=2, grad_round_to=2, mode="nearest")

    def sp(x_shard, pol):
        full = seq_gather(x_shard, "model", pol)
        return seq_scatter(full, "model", pol)

    f = shard_map(
        lambda x: sp(x, pol_act), mesh=mesh,
        in_specs=P(None, "model", None), out_specs=P(None, "model", None),
    )
    got = np.asarray(jax.jit(f)(xs))
    want = 2 * np.asarray(xs)  # gather + reduce-scatter over 2 model ranks
    tol = np.abs(want) * 2**-7 + 2**-6
    assert np.all(np.abs(got - want) <= tol), np.max(np.abs(got - want) - tol)

    # grads: compressed pipeline cotangents match the uncompressed pair
    def gfn(x, pol):
        return jax.grad(lambda v: jnp.sum(sp(v, pol)))(x)

    fg = shard_map(
        lambda x: gfn(x, pol_act), mesh=mesh,
        in_specs=P(None, "model", None), out_specs=P(None, "model", None),
    )
    fg4 = shard_map(
        lambda x: gfn(x, CompressionPolicy()), mesh=mesh,
        in_specs=P(None, "model", None), out_specs=P(None, "model", None),
    )
    gc = np.asarray(jax.jit(fg)(xs))
    g4 = np.asarray(jax.jit(fg4)(xs))
    np.testing.assert_allclose(gc, g4, rtol=1e-2, atol=1e-2)

    # negative axis resolves to the data dim, not the plane dim
    fneg = shard_map(
        lambda x: seq_gather(x, "model", pol_act, -2), mesh=mesh,
        in_specs=P(None, "model", None), out_specs=P(None, None, None),
    )
    fpos = shard_map(
        lambda x: seq_gather(x, "model", pol_act, 1), mesh=mesh,
        in_specs=P(None, "model", None), out_specs=P(None, None, None),
    )
    np.testing.assert_array_equal(
        np.asarray(jax.jit(fneg)(xs)), np.asarray(jax.jit(fpos)(xs))
    )

    # bf16 activations keep their dtype through the compressed pipeline
    outb = jax.jit(
        shard_map(
            lambda x: sp(x, pol_act), mesh=mesh,
            in_specs=P(None, "model", None),
            out_specs=P(None, "model", None),
        )
    )(xs.astype(jnp.bfloat16))
    assert outb.dtype == jnp.bfloat16, outb.dtype
    print("  seq_gather/seq_scatter: compressed fwd+bwd, bf16-safe OK")

    print("scenario_transport OK")


if __name__ == "__main__":
    main()
