"""Repo maintenance tooling (not shipped with the library)."""
