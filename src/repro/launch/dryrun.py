import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

This is the proof that the distribution config is coherent without real
hardware: 512 host devices stand in for 2 pods × 256 chips. The first two
lines above MUST run before any other import (jax locks the device count
on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k [--multi-pod] [--round-to 2] [--json out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCHS, get_config, get_shape
from repro.configs.shapes import applicable, input_specs
from repro.dist.spec import (
    build_spec_tree, dist_elems_per_group, tree_to_storage,
)
from repro.launch.mesh import make_production_mesh, mesh_cfg_for
from repro.models.init import param_shapes
from repro.optim.sgd import SGDConfig
from repro.plan import PrecisionPlan
from repro.roofline.analysis import (
    model_flops_estimate,
    parse_collectives,
    roofline_from_compiled,
)
from repro.serve.step import (
    global_cache_shapes,
    make_decode_step,
    make_place_step,
    make_prefill_step,
)
from repro.train.step import make_train_step


def _sds_tree(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def plan_for_combo(cfg, shape, round_to, opts=None, plan=None):
    """(round_to, opts) -> PrecisionPlan (``plan`` wins outright).

    The legacy ``opts`` dict (§Perf levers: train_dtype, accum,
    grad_round_to, int8_kv, causal_skip, mlstm_chunk, seq_parallel) is
    plan-builder sugar; ``weight_stationary`` / ``resident_bf16`` stay
    execution options of the decode factories."""
    if plan is not None:
        return plan.broadcast(cfg.num_groups + 1)
    opts = dict(opts or {})
    env_overrides = {}
    if "causal_skip" in opts:
        env_overrides["causal_skip"] = opts["causal_skip"]
    if "mlstm_chunk" in opts:
        env_overrides["mlstm_chunk"] = opts["mlstm_chunk"]
    dtype = "bf16" if (
        shape.kind != "train" or opts.get("train_dtype") == "bf16"
    ) else "f32"
    return PrecisionPlan.build(
        cfg.num_groups + 1,
        round_to=round_to,
        grad_round_to=opts.get("grad_round_to"),
        seq_parallel=bool(opts.get("seq_parallel")),
        chunks=int(opts.get("chunks", 1)),
        dtype=dtype,
        int8_kv=bool(opts.get("int8_kv")),
        accum_steps=int(opts.get("accum", 1)),
        env_overrides=env_overrides,
    )


def build_lowerable(cfg, shape, mesh_cfg, mesh, round_to, *, opts=None,
                    plan=None, spec_tree=None):
    """Returns (jitted step, abstract args) for the combo.

    ``opts`` (all optional — §Perf levers, see :func:`plan_for_combo`)
    builds the PrecisionPlan when no explicit ``plan`` is given.
    ``spec_tree`` skips the parameter-tree walk when the caller already
    built one (run_one shares its wire-geometry tree).
    """
    opts = dict(opts or {})
    plan = plan_for_combo(cfg, shape, round_to, opts, plan)
    storage_abs, metas = param_shapes(cfg, tp=mesh_cfg.tp)
    if spec_tree is None:
        spec_tree = build_spec_tree(storage_abs, metas, mesh_cfg)
    storage = tree_to_storage(storage_abs, spec_tree, mesh_cfg)
    batch = input_specs(cfg, shape)
    shard_batch = shape.global_batch >= mesh_cfg.dshards

    if shape.kind == "train":
        step = make_train_step(
            cfg, mesh_cfg, mesh, spec_tree, SGDConfig(), batch, plan=plan
        )
        mom = _sds_tree(storage)
        lr = jax.ShapeDtypeStruct((), jnp.float32)
        args = (storage, mom, batch, lr)
        if plan.needs_rng:
            args = args + (jax.ShapeDtypeStruct((2,), jnp.uint32),)
        return step, args

    if shape.kind == "prefill":
        step = make_prefill_step(
            cfg, mesh_cfg, mesh, spec_tree, batch, plan=plan,
            cache_capacity=shape.seq_len, shard_batch=shard_batch,
        )
        return step, (storage, batch)

    # decode
    window = shape.window if shape.name == "long_500k" else None
    capacity = min(shape.seq_len, window or shape.seq_len)
    if cfg.sliding_window:
        capacity = min(capacity, cfg.sliding_window)
    cache_dtype = jnp.int8 if plan.int8_kv else jnp.bfloat16
    caches = global_cache_shapes(
        cfg, mesh_cfg, shape.global_batch, capacity,
        cache_dtype, shard_batch=shard_batch,
    )
    step = make_decode_step(
        cfg, mesh_cfg, mesh, spec_tree, batch, plan=plan,
        shard_batch=shard_batch, window_override=window,
        weight_stationary=bool(opts.get("weight_stationary")),
    )
    if opts.get("weight_stationary"):
        place, _ = make_place_step(
            cfg, mesh_cfg, mesh, spec_tree, plan=plan,
            resident_dtype=(
                jnp.bfloat16 if opts.get("resident_bf16") else None
            ),
        )
        placed = jax.eval_shape(place, storage)
        return step, (placed, caches, batch)
    return step, (storage, caches, batch)


def run_one(arch, shape_name, multi_pod, round_to, *,
            verbose=True, opts=None, plan=None):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = applicable(cfg, shape)
    if not ok:
        result = {"arch": arch, "shape": shape_name, "skipped": reason}
        if verbose:
            print(json.dumps(result, indent=2))
        return result
    mesh_cfg = mesh_cfg_for(multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_cfg.tp * mesh_cfg.dp * mesh_cfg.pods
    plan = plan_for_combo(cfg, shape, round_to, opts, plan)

    # one spec tree serves both the step build and the wire geometry:
    # the plan is also the unit of cost accounting, so the roofline gets
    # the per-group compressed element counts for its per-entry report
    storage_abs, metas = param_shapes(cfg, tp=mesh_cfg.tp)
    spec_tree = build_spec_tree(storage_abs, metas, mesh_cfg)
    t0 = time.time()
    step, args = build_lowerable(cfg, shape, mesh_cfg, mesh, round_to,
                                 opts=opts, plan=plan, spec_tree=spec_tree)
    nrt = cfg.num_groups + 1
    plan_geometry = {
        "dist_elems_per_group": dist_elems_per_group(
            spec_tree, mesh_cfg, nrt
        ),
        "gather_axis_size": max(mesh_cfg.dshards, 1),
        "training": shape.kind == "train",
    }
    with mesh:
        lowered = step.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        act_bytes = 2 if plan.dtype == "bf16" else 4
        # the seq-parallel RS correction must not rescale raw-dtype
        # *gradient* reduce-scatters (indistinguishable from activation
        # RS in HLO text): only enable it when the shape has a seq layout
        # and any grad RS rides compressed planes (prefill has no grads)
        kind = shape.kind
        sp_corr = plan.seq_parallel and (
            kind == "prefill"
            or (
                kind == "train"
                and any(p.compresses_grads for p in plan.weight_policies())
            )
        )
        rf = roofline_from_compiled(
            compiled, model_flops_estimate(cfg, shape, chips),
            act_bytes=act_bytes, seq_parallel=sp_corr,
            plan=plan, plan_geometry=plan_geometry,
        )
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "round_to": round_to,
        "opts": opts or {},
        "plan": plan.to_json_dict(),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline": rf.to_dict(),
    }
    if verbose:
        print(json.dumps(result, indent=2, default=str))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--round-to", type=int, default=2)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--bf16-train", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--grad-round-to", type=int, default=4)
    ap.add_argument("--weight-stationary", action="store_true")
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--no-causal-skip", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--chunks", type=int, default=1)
    ap.add_argument("--plan", default=None,
                    help="PrecisionPlan JSON (overrides the sugar flags)")
    args = ap.parse_args()
    plan = PrecisionPlan.from_file(args.plan) if args.plan else None
    opts = {}
    if args.bf16_train:
        opts["train_dtype"] = "bf16"
    if args.accum > 1:
        opts["accum"] = args.accum
    if args.grad_round_to != 4:
        opts["grad_round_to"] = args.grad_round_to
    if args.weight_stationary:
        opts["weight_stationary"] = True
    if args.int8_kv:
        opts["int8_kv"] = True
    if args.no_causal_skip:
        opts["causal_skip"] = False
    if args.seq_parallel:
        opts["seq_parallel"] = True
    if args.chunks > 1:
        opts["chunks"] = args.chunks

    combos = (
        [(a, s) for a in sorted(ARCHS) for s in INPUT_SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    results = []
    failures = 0
    for arch, shape in combos:
        try:
            results.append(
                run_one(arch, shape, args.multi_pod, args.round_to,
                        opts=opts, plan=plan)
            )
        except Exception as e:
            failures += 1
            traceback.print_exc()
            results.append(
                {"arch": arch, "shape": shape, "error": repr(e)}
            )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, default=str)
    print(f"\n{len(results)} combos, {failures} failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
