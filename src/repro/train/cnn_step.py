"""Data-parallel CNN train step with per-layer ADT compression — the
paper's exact setting (host master weights, per-batch compressed sends,
uncompressed gradient returns, per-layer AWP).

A :class:`~repro.plan.PrecisionPlan` (``cfg.num_groups`` weight entries —
the CNN has no top-level group) drives the per-layer formats, the
gradient reduce-scatter entry, and the activation policy (here a
straight-through stage-boundary quantize: pure DP has no TP collective
to compress). The step already takes a PRNG ``key`` (dropout), so
stochastic rounding needs no signature change: the quantization keys are
folded off the same argument.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.dist.shard import shard_map
from repro.dist.spec import (
    DIST,
    LeafSpec,
    MeshCfg,
    build_leaf_spec,
    leaf_partition_spec,
    leaf_to_storage,
    materialize_leaf,
)
from repro.models.cnn import CNNConfig, cnn_loss, topk_error
from repro.optim.sgd import SGDConfig, sgd_update
from repro.plan import PrecisionPlan, policy_uses_rng
from repro.train.step import resolve_plan
from repro.transport import policy_for
from repro.transport import transport as _T

def _act_quant_fn(act_policy):
    """Activation policy -> straight-through stage-boundary truncation
    (None when the policy keeps fp32: zero-cost identity)."""
    if act_policy is None:
        return None
    pol = policy_for(act_policy)
    if not pol.compresses:
        return None

    def aq(x):
        return _T.quantize(x.astype(jnp.float32), pol).astype(x.dtype)

    return aq


def build_cnn_spec_tree(params, metas, mesh_cfg: MeshCfg):
    return jax.tree_util.tree_map(
        lambda x, m: build_leaf_spec(x.shape, m, mesh_cfg, stacked=False),
        params, metas,
    )


def cnn_to_storage(params, spec_tree, mesh_cfg: MeshCfg):
    return jax.tree_util.tree_map(
        lambda x, s: leaf_to_storage(x, s, mesh_cfg),
        params, spec_tree, is_leaf=lambda x: not isinstance(x, (dict,)),
    )


def _mat(storage, spec_tree, mesh_cfg, groups, policies, rng=None):
    """Materialize every layer with its own AWP format (per-layer mode).

    ``rng``: stochastic-rounding key — each layer leaf gets a distinct
    fold (the CNN stacks nothing, so per-layer noise is independent,
    matching the paper's per-layer setting)."""
    by_name = {name: policies[g] for name, g in groups.items()}
    fold = itertools.count()
    out = {}
    for name, leafs in storage["layers"].items():
        pol = by_name[name]
        use_key = rng is not None and policy_uses_rng(pol)
        out[name] = {
            k: materialize_leaf(
                v, spec_tree["layers"][name][k], mesh_cfg, pol,
                key=(
                    jax.random.fold_in(rng, next(fold)) if use_key else None
                ),
            )
            for k, v in leafs.items()
        }
    return out


def make_cnn_train_step(
    cfg: CNNConfig,
    mesh_cfg: MeshCfg,
    mesh,
    spec_tree,
    groups_info,
    opt_cfg: SGDConfig | None = None,
    batch_shapes: dict | None = None,
    *,
    plan: PrecisionPlan | None = None,
):
    """Returns jit-able ``step(storage, momentum, batch, lr, key)``.

    Call: ``make_cnn_train_step(cfg, mesh_cfg, mesh, spec_tree,
    groups_info, opt_cfg, batch_shapes, plan=plan)`` — the plan has
    ``num_groups`` weight entries (per layer/block)."""
    groups, num_groups = groups_info
    plan = resolve_plan(
        cfg, plan=plan, caller="make_cnn_train_step",
        num_groups=num_groups,
    )
    if opt_cfg is None or batch_shapes is None:
        raise TypeError(
            "make_cnn_train_step: opt_cfg and batch_shapes required"
        )
    policies = plan.weight_policies()
    needs_rng = plan.needs_rng
    dp = mesh_cfg.fsdp_axes[0] if mesh_cfg.dshards > 1 else None
    aq = _act_quant_fn(plan.activations)

    def step(storage, momentum, batch, lr, key):
        # independent streams: dropout rides `key` as before, stochastic
        # rounding a folded-off branch (so enabling it never perturbs
        # the dropout pattern of an existing run)
        rngq = jax.random.fold_in(key, 0xAD7) if needs_rng else None

        def loss_fn(st):
            layers = _mat(st, spec_tree, mesh_cfg, groups, policies, rngq)
            return cnn_loss(
                layers, batch["images"], batch["labels"], cfg,
                train=True, key=key, act_quant=aq,
            ) / max(mesh_cfg.dshards, 1)

        loss, grads = jax.value_and_grad(loss_fn)(storage)

        def fix(g, s: LeafSpec):
            if s.kind != DIST and dp is not None:
                # lint: allow(RAW-COLLECTIVE): grad-sync psum for replicated CNN leaves — fp32 contract, audited as grad_sync
                g = lax.psum(g, dp)
            return g

        grads = jax.tree_util.tree_map(
            fix, grads, spec_tree, is_leaf=lambda x: isinstance(x, LeafSpec)
        )
        wd = jax.tree_util.tree_map(
            lambda s: 1.0 if s.meta.compress else 0.0,
            spec_tree, is_leaf=lambda x: isinstance(x, LeafSpec),
        )
        new_storage, new_momentum = sgd_update(
            storage, grads, momentum, wd, opt_cfg, lr
        )

        # AWP per-group Σw² (paper Algorithm 1 line 6 input)
        sums = jnp.zeros((num_groups,), jnp.float32)
        for name, leafs in new_storage["layers"].items():
            g = groups[name]
            for k, v in leafs.items():
                if spec_tree["layers"][name][k].meta.compress:
                    vf = v.astype(jnp.float32)
                    sums = sums.at[g].add(jnp.sum(vf * vf))
        if dp is not None:
            # lint: allow(RAW-COLLECTIVE): AWP Σw² + scalar loss reductions — metrics traffic, audited as metrics
            sums = lax.psum(sums, dp)
            # lint: allow(RAW-COLLECTIVE): AWP Σw² + scalar loss reductions — metrics traffic, audited as metrics
            loss = lax.psum(loss, dp)
        return new_storage, new_momentum, {"loss": loss, "group_norms_sq": sums}

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1))

    pspecs = jax.tree_util.tree_map(
        lambda s: leaf_partition_spec(s, mesh_cfg),
        spec_tree, is_leaf=lambda x: isinstance(x, LeafSpec),
    )
    bspecs = {
        "images": P(dp, None, None, None),
        "labels": P(dp),
    }
    sharded = shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, pspecs, bspecs, P(), P(None)),
        out_specs=(pspecs, pspecs, {"loss": P(), "group_norms_sq": P(None)}),
    )
    return jax.jit(sharded, donate_argnums=(0, 1))


def make_cnn_eval(
    cfg, mesh_cfg, mesh, spec_tree, groups_info, *,
    plan: PrecisionPlan | None = None,
):
    """Returns jit-able ``evaluate(storage, images, labels)`` (top-5
    error) at the plan's weight widths."""
    groups, num_groups = groups_info
    plan = resolve_plan(
        cfg, plan=plan, caller="make_cnn_eval", num_groups=num_groups,
    )
    # evaluation is deterministic: stochastic forward rounding falls back
    # to nearest (same kept bytes, no PRNG dependence)
    policies = tuple(
        pol if pol.mode != "stochastic"
        else policy_for(pol, mode="nearest")
        for pol in plan.weight_policies()
    )

    def evaluate(storage, images, labels):
        layers = _mat(storage, spec_tree, mesh_cfg, groups, policies)
        return topk_error(layers, images, labels, cfg, k=5)

    if mesh is None:
        return jax.jit(evaluate)
    pspecs = jax.tree_util.tree_map(
        lambda s: leaf_partition_spec(s, mesh_cfg),
        spec_tree, is_leaf=lambda x: isinstance(x, LeafSpec),
    )
    sharded = shard_map(
        evaluate, mesh=mesh,
        in_specs=(pspecs, P(None, None, None, None), P(None)),
        out_specs=P(),
    )
    return jax.jit(sharded)
