"""Shard-writer CLI: tokenize the synthetic generators into a shard dir.

Tests and CI need no downloads — the same deterministic generators the
inline pipeline uses are materialized once into the tiered record format
(:mod:`repro.data.shards`), after which training ingests *bytes from
disk* like a production run:

  PYTHONPATH=src python -m repro.data.write --kind lm \
      --vocab 1024 --seq 64 --records 256 --out /tmp/shards
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --reduced --data-dir /tmp/shards --steps 20
"""
from __future__ import annotations

import argparse
import json
import os

from repro.data.shards import write_feature_shards, write_lm_shards


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", choices=["lm", "feature"], default="lm")
    ap.add_argument("--out", required=True)
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--dim", type=int, default=64,
                    help="feature dim (kind=feature)")
    ap.add_argument("--records", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--codec", choices=["zlib", "raw"], default="zlib")
    ap.add_argument("--records-per-shard", type=int, default=64)
    args = ap.parse_args()

    kw = dict(
        vocab=args.vocab, seq=args.seq, num_records=args.records,
        seed=args.seed, codec=args.codec,
        records_per_shard=args.records_per_shard,
    )
    if args.kind == "lm":
        manifest = write_lm_shards(args.out, **kw)
    else:
        manifest = write_feature_shards(args.out, dim=args.dim, **kw)
    stored = sum(
        s for sh in manifest["shards"] for r in sh["records"]
        for f in r["fields"].values() for s in f["plane_sizes"]
    )
    files = [sh["file"] for sh in manifest["shards"]]
    on_disk = sum(
        os.path.getsize(os.path.join(args.out, f)) for f in files
    )
    if stored != on_disk:
        raise RuntimeError(
            f"manifest/shard byte mismatch: manifest says {stored}, "
            f"files hold {on_disk}"
        )
    print(json.dumps({
        "out": args.out, "kind": args.kind, "records": args.records,
        "shards": len(files), "stored_bytes": stored,
    }))


if __name__ == "__main__":
    main()
