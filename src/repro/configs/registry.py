"""--arch registry + reduced (smoke) variants of every assigned config."""
from __future__ import annotations

import dataclasses

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs import (
    arctic_480b,
    chatglm3_6b,
    hubert_xlarge,
    llama_3_2_vision_90b,
    mixtral_8x7b,
    qwen2_5_14b,
    qwen3_1_7b,
    qwen3_14b,
    recurrentgemma_9b,
    xlstm_1_3b,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        qwen3_14b.CONFIG,
        mixtral_8x7b.CONFIG,
        llama_3_2_vision_90b.CONFIG,
        chatglm3_6b.CONFIG,
        qwen3_1_7b.CONFIG,
        hubert_xlarge.CONFIG,
        arctic_480b.CONFIG,
        qwen2_5_14b.CONFIG,
        xlstm_1_3b.CONFIG,
        recurrentgemma_9b.CONFIG,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


def reduced(cfg: ModelConfig, *, layers: int | None = None) -> ModelConfig:
    """Smoke-test variant of the same family: <=2 pattern repeats,
    d_model<=512, <=4 experts, tiny vocab — runs a forward/train step on CPU.
    """
    pat = len(cfg.pattern)
    num_layers = layers if layers is not None else max(pat, 2 if pat == 1 else pat)
    # keep head structure but shrink widths
    num_heads = min(cfg.num_heads, 4)
    num_kv = max(1, min(cfg.num_kv_heads, num_heads))
    while num_heads % num_kv:
        num_kv -= 1
    d_model = min(cfg.d_model, 256)
    head_dim = max(8, d_model // num_heads)
    changes = dict(
        num_layers=num_layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=0 if cfg.d_ff == 0 else min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4),
        top_k=min(cfg.top_k, 2),
        moe_dense_ff=0 if cfg.moe_dense_ff == 0 else 256,
        num_image_tokens=0 if cfg.num_image_tokens == 0 else 16,
        vision_dim=0 if cfg.vision_dim == 0 else 32,
        lru_dim=0 if cfg.lru_dim == 0 else d_model,
        sliding_window=None if cfg.sliding_window is None else 32,
        num_precision_groups=min(cfg.num_precision_groups, 2),
        scan_layers=False,
        remat=False,
    )
    if cfg.block_pattern:
        # shrink pattern to at most one repetition of a short cycle
        if len(cfg.block_pattern) > 4:
            base = tuple(dict.fromkeys(cfg.block_pattern))  # unique kinds
            changes["block_pattern"] = base
            changes["num_layers"] = len(base) * 2
        else:
            changes["num_layers"] = len(cfg.block_pattern) * 2
    if cfg.embed_is_input_stub:
        changes["vision_dim"] = 32
    return dataclasses.replace(cfg, **changes)
