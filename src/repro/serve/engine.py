"""Continuous-batching serve engine (`repro.serve.engine`).

The production serving loop the ROADMAP left open: a request queue is
drained through a **slotted KV cache** — ``max_slots`` resident requests
decode together as one fixed-shape batch, and whenever a slot frees up
(stop condition hit) the scheduler admits the next queued prompt
*between decode steps* (prefill/decode interleave). Every slot carries
its own absolute position (``init_caches(per_slot=True)`` →
``(reps, slots)`` KV position vectors), so mixed prompt lengths and
staggered admissions coexist in one compiled decode program.

Data-motion story (the paper's host<->device boundary, finally exercised
by serving traffic): prompts enter and sampled ids leave through the
plan's ``host_device`` :class:`~repro.transport.CompressionPolicy` entry
— token ids are staged as lossless byte planes
(:mod:`repro.transport.hostdev`) at
:meth:`~repro.transport.CompressionPolicy.token_wire_width` bytes each,
and the engine logs the **measured** staged bytes per step
(:attr:`ServeEngine.step_log`, the serving twin of the trainer's
``StepRecord.wire_by_entry``). The analytic mirror lives in
:func:`repro.roofline.analysis.serve_host_device_bytes`; the two are
pinned equal by ``tests/test_serve_engine.py``.

Determinism contract: sampling is greedy and slots are independent, so
every request's token stream is a pure function of its prompt — byte
for byte the same regardless of arrival order, slot assignment, or what
else shares the batch, and bit-exact against the static one-shot
reference (:func:`generate_static`). Caveat for MoE archs: the capacity
dispatch ranks the *whole* batch's tokens per expert, so decode couples
slots once a single expert can be offered more than ``capacity`` tokens
— keep ``max_slots * top_k <= 8`` (the dispatch capacity floor) for a
drop-free, companion-independent decode, and note the batched static
reference prefills requests *together* while the engine prefills one at
a time, which changes MoE prefill capacity pressure: compare MoE archs
against per-request (batch-of-1) references. Vision cross-attention
archs are rejected (image payloads are not token-stageable; the static
launcher path still serves them).

Engine compilation surface: ONE decode program (fixed ``(slots, 1)``
shape) plus one prefill program per distinct prompt length — bucket
arrival lengths if that set is unbounded.

``paged=True`` swaps the contiguous slotted layout for the **block-paged
KV cache**: fixed-size pages in a slot-global pool, a host-side per-slot
page table staged each decode step, a refcounted :class:`PageAllocator`
(``SlotManager``'s page-granular twin), shared-prefix page interning
(a common system prompt is resident ONCE, copy-on-write), and prompt
bucketing to page granularity so one prefill program serves a whole
bucket. Streams stay bit-exact vs the contiguous engine and
``generate_static``; see docs/serving.md §paged for the layout and
lifecycle.
"""
from __future__ import annotations

import collections
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.spec import MeshCfg
from repro.models import model as M
from repro.plan import PrecisionPlan, SamplingParams
from repro.serve.api import Request
from repro.serve.sampling import sample_tokens
from repro.serve.spec import (
    DraftBundle,
    DraftRunner,
    check_spec_arch,
    rollback_caches,
)
from repro.serve.step import (
    global_cache_shapes,
    make_decode_step,
    make_place_step,
    make_prefill_step,
    make_verify_step,
)
from repro.transport.hostdev import (
    pack_tokens,
    pack_tokens_host,
    stage,
    unpack_tokens,
    unpack_tokens_host,
)

__all__ = [
    "AllocatorError",
    "CapacityError",
    "CapacityWarning",
    "GenResult",
    "InvariantError",
    "Request",
    "SamplingParams",
    "ServeEngine",
    "generate_static",
]


# ---------------------------------------------------------------------------
# request / result types (Request itself lives in repro.serve.api — the
# unified submit surface — and is re-exported here for compatibility)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GenResult:
    """Completed generation: emitted ids in order (eos included if hit)."""

    rid: int
    prompt_len: int
    tokens: list[int]
    admitted_step: int
    finished_step: int


@dataclasses.dataclass
class _ReqState:
    req: Request
    slot: int
    admitted_step: int
    tokens: list[int] = dataclasses.field(default_factory=list)

    def emit(self, tok: int) -> bool:
        """Record one sampled id; True when the request just finished."""
        self.tokens.append(tok)
        if self.req.eos_id is not None and tok == self.req.eos_id:
            return True
        return len(self.tokens) >= self.req.max_new


# ---------------------------------------------------------------------------
# typed failures
# ---------------------------------------------------------------------------


class CapacityWarning(UserWarning):
    """A configuration exceeds a soft capacity floor (currently: MoE
    dispatch capacity at engine construction) — decode may couple slots
    and break the per-request determinism contract. Typed so callers
    and tests filter/assert it instead of string-matching."""


class CapacityError(RuntimeError):
    """A resource pool is exhausted: no free slot, not enough free
    pages, or the drain loop hit its step budget with requests still
    unfinished. Retryable in principle — the request, not the engine,
    is at fault."""


class AllocatorError(RuntimeError):
    """Allocator API misuse: double allocation, release of an unowned
    slot, retain/release of a dead page. The caller's bookkeeping is
    wrong; the pool itself is still consistent."""


class InvariantError(AssertionError):
    """An internal conservation audit failed (slot/page leak, counter
    imbalance): engine state is corrupt and the instance should be
    discarded. Subclasses :class:`AssertionError` because these are
    self-checks on the engine's own bookkeeping, not caller errors."""


# ---------------------------------------------------------------------------
# slot manager
# ---------------------------------------------------------------------------


class SlotManager:
    """KV-slot allocator with leak-audit counters.

    Slots are the unit of cache residency: ``alloc`` hands the lowest
    free slot to a request at admission, ``release`` returns it at
    retirement. :meth:`audit` asserts the conservation invariant (every
    slot is exactly free xor owned, allocs == releases + active) — the
    scheduler-invariant tests drive it after every admit/evict cycle.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self._free = list(range(n_slots - 1, -1, -1))  # pop() -> lowest first
        self._owner: dict[int, int] = {}  # slot -> rid
        self.alloc_count = 0
        self.release_count = 0

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def active(self) -> dict[int, int]:
        return dict(self._owner)

    def alloc(self, rid: int) -> int:
        if not self._free:
            raise CapacityError("no free slot")
        slot = self._free.pop()
        if slot in self._owner:
            raise AllocatorError(f"slot {slot} double-allocated")
        self._owner[slot] = rid
        self.alloc_count += 1
        return slot

    def release(self, slot: int) -> None:
        if slot not in self._owner:
            raise AllocatorError(f"release of unowned slot {slot}")
        del self._owner[slot]
        self._free.append(slot)
        self.release_count += 1

    def audit(self) -> dict:
        free, owned = set(self._free), set(self._owner)
        if free & owned:
            raise InvariantError(f"slots both free and owned: {free & owned}")
        if len(self._free) != len(free):
            raise InvariantError("duplicate entries in the free list")
        if free | owned != set(range(self.n_slots)):
            raise InvariantError("slot leak: free ∪ owned != all slots")
        if self.alloc_count != self.release_count + len(owned):
            raise InvariantError("alloc/release counters out of balance")
        return {
            "free": len(free),
            "active": len(owned),
            "allocs": self.alloc_count,
            "releases": self.release_count,
        }


class PageAllocator:
    """Free-page allocator with refcounts — the page-granular twin of
    :class:`SlotManager`, same leak-audit contract.

    Pages are the unit of KV residency in the paged layout: ``alloc``
    hands out physical pool rows at admission, ``retain`` adds a
    reference when a shared-prefix page is reused (CoW sharing: shared
    pages are immutable by construction — decode only ever writes a
    slot's private tail pages), ``release`` drops one reference and
    returns the page to the free list when the count hits zero.
    :meth:`audit` asserts conservation (free xor live, allocs ==
    releases + live)."""

    def __init__(self, num_pages: int):
        if num_pages < 1:
            raise ValueError("need at least one page")
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, -1, -1))  # pop() -> lowest
        self._refs: dict[int, int] = {}  # page -> refcount
        self.alloc_count = 0
        self.release_count = 0
        self.peak = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return len(self._refs)

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise CapacityError(f"need {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            if p in self._refs:
                raise AllocatorError(f"page {p} double-allocated")
            self._refs[p] = 1
        self.alloc_count += n
        self.peak = max(self.peak, len(self._refs))
        return pages

    def retain(self, page: int) -> None:
        if page not in self._refs:
            raise AllocatorError(f"retain of dead page {page}")
        self._refs[page] += 1

    def release(self, page: int) -> bool:
        """Drop one reference; True when the page was actually freed."""
        if page not in self._refs:
            raise AllocatorError(f"release of dead page {page}")
        self._refs[page] -= 1
        if self._refs[page] > 0:
            return False
        del self._refs[page]
        self._free.append(page)
        self.release_count += 1
        return True

    def audit(self) -> dict:
        free, live = set(self._free), set(self._refs)
        if free & live:
            raise InvariantError(f"pages both free and live: {free & live}")
        if len(self._free) != len(free):
            raise InvariantError("duplicate entries in the free page list")
        if free | live != set(range(self.num_pages)):
            raise InvariantError("page leak: free ∪ live != all pages")
        if any(c < 1 for c in self._refs.values()):
            raise InvariantError("live page with refcount < 1")
        if self.alloc_count != self.release_count + len(live):
            raise InvariantError("page alloc/release counters out of balance")
        return {
            "free": len(free),
            "live": len(live),
            "allocs": self.alloc_count,
            "releases": self.release_count,
            "peak": self.peak,
        }


def _page_pool_bytes(caches) -> int:
    """Global bytes ONE page occupies summed over every paged pool node
    (all groups x reps x K/V, plus int8 scale planes). Works on the
    ``global_cache_shapes`` tree (ShapeDtypeStructs) or live arrays."""
    per_page = 0
    for group in caches:
        for node in group.values():
            if isinstance(node, (M.PagedKVCache, M.PagedQuantKVCache)):
                leaves = [node.k, node.v]
                if isinstance(node, M.PagedQuantKVCache):
                    leaves += [node.k_scale, node.v_scale]
                for leaf in leaves:
                    P = leaf.shape[1]  # stacked (R, P, page, ...)
                    size = int(np.prod(leaf.shape))
                    per_page += size * jnp.dtype(leaf.dtype).itemsize // P
    return per_page


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Continuous-batching driver over ``make_prefill_step`` /
    ``make_decode_step`` (see module docstring).

    Parameters mirror the step factories; ``storage`` is the sharded
    weight tree (``tree_to_storage``), ``plan`` the
    :class:`~repro.plan.PrecisionPlan` driving every precision choice
    including the ``host_device`` staging entry. ``cache_capacity`` caps
    ``prompt_len + max_new_tokens`` per request (validated at submit).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        mesh_cfg: MeshCfg,
        mesh,
        spec_tree,
        storage,
        *,
        plan: PrecisionPlan,
        max_slots: int,
        cache_capacity: int,
        window: int | None = None,
        weight_stationary: bool = False,
        paged: bool = False,
        page_size: int = 64,
        num_pages: int | None = None,
        share_prefix: bool = True,
        draft: DraftBundle | None = None,
        spec_k: int | None = None,
    ):
        if not cfg.causal:
            raise ValueError(f"{cfg.name} is encoder-only: nothing to serve")
        if cfg.num_image_tokens or cfg.embed_is_input_stub:
            raise ValueError(
                f"{cfg.name}: the serve engine stages token payloads only "
                "(no image/feature requests)"
            )
        if cfg.num_experts and max_slots * cfg.top_k > 8:
            warnings.warn(
                f"{cfg.name}: max_slots={max_slots} x top_k={cfg.top_k} "
                "exceeds the MoE dispatch capacity floor (8) — congested "
                "experts may drop ranked decode tokens, coupling slots "
                "(see the determinism contract in repro.serve.engine)",
                CapacityWarning,
                stacklevel=2,
            )
        self.cfg = cfg
        self.mesh_cfg = mesh_cfg
        self.mesh = mesh
        self.spec_tree = spec_tree
        self.storage = storage
        self.plan = plan.broadcast(cfg.num_groups + 1)
        self.max_slots = int(max_slots)
        self.cache_capacity = int(cache_capacity)
        self.window = window
        self.paged = bool(paged)
        self.page_size = int(page_size)
        if self.paged:
            if self.page_size < 1:
                raise ValueError("page_size must be >= 1")
            if window is not None or cfg.sliding_window:
                raise ValueError(
                    f"{cfg.name}: paged serving keeps the full context "
                    "resident — sliding-window (ring) serving stays on the "
                    "contiguous layout"
                )
        self.spec_k = int(spec_k) if spec_k is not None else self.plan.spec_k
        if draft is not None:
            check_spec_arch(cfg, window=window)
            if self.spec_k < 1:
                raise ValueError("spec_k must be >= 1")
            if draft.cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft vocab {draft.cfg.vocab_size} != target vocab "
                    f"{cfg.vocab_size} — draft ids must be target ids"
                )
        # page-table width: capacity rounded up to whole pages; under
        # speculative decoding the verify block can write up to spec_k
        # positions past a finished stream, so widen the table enough
        # that clamped block writes land in trash entries, never a
        # live page
        spec_pad = self.spec_k if draft is not None else 0
        self._table_width = -(
            -(self.cache_capacity + spec_pad) // self.page_size
        )
        self.num_pages = (
            int(num_pages) if num_pages is not None
            else self.max_slots * self._table_width
        )
        # padded (page-bucketed) prompts are causal-safe only for pure-
        # attention patterns: MoE capacity dispatch ranks tokens across the
        # sequence and recurrent state absorbs pad positions
        self._bucket = (
            self.paged
            and not cfg.num_experts
            and all(k == "attn" for k in cfg.pattern)
        )
        # prefix pages are bit-shareable only when position i depends on
        # tokens <= i alone; the MoE dispatch breaks that per-position
        # causality (capacity ranking sees the whole sequence)
        self.share_prefix = (
            bool(share_prefix) and self.paged and not cfg.num_experts
        )
        self.host_policy = self.plan.host_device_policies()[0]
        self.token_width = self.host_policy.token_wire_width(cfg.vocab_size)
        self.slots = SlotManager(self.max_slots)
        self.pages = PageAllocator(self.num_pages) if self.paged else None
        self._intern: dict[tuple, int] = {}  # prompt-prefix key -> page
        self._page_key: dict[int, tuple] = {}  # page -> interned key
        self._slot_pages: dict[int, list[int]] = {}  # slot -> page row
        self.step_log: list[dict] = []

        B = self.max_slots
        self._shard_batch = (
            not self.paged  # the page pool has no batch dim to shard
            and mesh_cfg.dshards > 1 and B % mesh_cfg.dshards == 0
        )
        dshapes = {
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
        if self.paged:
            dshapes["page_table"] = jax.ShapeDtypeStruct(
                (B, self._table_width), jnp.int32
            )
        self._decode = make_decode_step(
            cfg, mesh_cfg, mesh, spec_tree, dshapes, plan=self.plan,
            shard_batch=self._shard_batch, window_override=window,
            weight_stationary=weight_stationary, slot_caches=True,
            paged=self.paged,
        )
        self._place = None
        if weight_stationary:
            self._place, _ = make_place_step(
                cfg, mesh_cfg, mesh, spec_tree, plan=self.plan
            )
        self._weights = (
            self._place(storage) if self._place is not None else storage
        )
        self._prefill_cache: dict[int, object] = {}
        self._cache_dtype = self.plan.compute_dtype
        self._unpack = jax.jit(unpack_tokens)
        vocab = cfg.vocab_size
        width = self.token_width

        def sample_pack(logits):
            tok = jnp.argmax(
                logits[:, -1, :vocab], axis=-1
            ).astype(jnp.int32)  # (B,)
            return tok, pack_tokens(tok, width)

        self._sample = jax.jit(sample_pack)

        def sample_rng_pack(logits, temp, top_p, top_k, seed, step):
            # per-row sampling (docs/serving.md §sampling); temp<=0 rows
            # reduce to the same argmax as sample_pack, so mixed batches
            # keep greedy requests token-identical to the fast path
            tok = sample_tokens(
                logits[:, -1], vocab, temp, top_p, top_k, seed, step
            )
            return tok, pack_tokens(tok, width)

        self._sample_rng = jax.jit(sample_rng_pack)

        def verify_sample_pack(logits, temp, top_p, top_k, seed, step0):
            # (B, T) target samples over the verify block: position j of
            # the block is the candidate emitted index step0 + j, keyed
            # accordingly — identical keys to T successive decode ticks
            T = logits.shape[1]

            def bt(a):
                return jnp.broadcast_to(a[:, None], (a.shape[0], T))

            steps = step0[:, None] + jnp.arange(T, dtype=jnp.int32)
            tok = sample_tokens(
                logits, vocab, bt(temp), bt(top_p), bt(top_k), bt(seed),
                steps,
            )
            return tok, pack_tokens(tok, width)

        self._verify_sample = jax.jit(verify_sample_pack)

        self.draft = None
        self._verify = None
        self._rollback = None
        if draft is not None:
            self._verify = make_verify_step(
                cfg, mesh_cfg, mesh, spec_tree, plan=self.plan,
                n_slots=B, block=self.spec_k + 1,
                shard_batch=self._shard_batch,
                weight_stationary=weight_stationary, paged=self.paged,
                table_width=self._table_width,
            )
            # the draft keeps contiguous per-slot caches with spec_k
            # spare positions (its last micro step absorbs the final
            # proposal before rollback)
            self.draft = DraftRunner(
                draft, mesh_cfg, mesh, plan=self.plan,
                max_slots=B, capacity=self.cache_capacity + self.spec_k,
                spec_k=self.spec_k, token_width=width,
            )
            self._rollback = jax.jit(rollback_caches, donate_argnums=(0,))

        def insert(big, small, slot):
            # prefill caches (batch of 1) -> slot `slot` of the engine
            # caches; the pos leaves are the one rank mismatch: (R,)
            # scalar-per-rep from prefill vs the engine's (R, B) vector
            def one(b, s):
                if b.ndim == s.ndim:
                    return b.at[:, slot].set(s[:, 0])
                return b.at[:, slot].set(s)

            return jax.tree_util.tree_map(one, big, small)

        self._insert = jax.jit(insert, donate_argnums=(0,))

        page = self.page_size

        def insert_paged(big, small, slot, phys, start, pos_val):
            # scatter the prompt's freshly computed KV pages into the pool
            # (shared-prefix hits are already resident and immutable —
            # skipped, so the first writer's bits stay authoritative) and
            # stamp the slot's position; non-paged nodes (recurrent state)
            # keep the contiguous slot insert
            n_new = phys.shape[0]

            def pool_write(b, s):
                # b (R, P, page, ...) pool; s (R, 1, cap_pre, ...) prefill
                seg = jax.lax.dynamic_slice_in_dim(
                    s[:, 0], start, n_new * page, axis=1
                )
                seg = seg.reshape(s.shape[0], n_new, page, *s.shape[3:])
                return b.at[:, phys].set(seg.astype(b.dtype))

            def one_node(bn, sn):
                if isinstance(bn, M.PagedQuantKVCache):
                    return M.PagedQuantKVCache(
                        pool_write(bn.k, sn.k), pool_write(bn.v, sn.v),
                        pool_write(bn.k_scale, sn.k_scale),
                        pool_write(bn.v_scale, sn.v_scale),
                        bn.pos.at[:, slot].set(pos_val),
                    )
                if isinstance(bn, M.PagedKVCache):
                    return M.PagedKVCache(
                        pool_write(bn.k, sn.k), pool_write(bn.v, sn.v),
                        bn.pos.at[:, slot].set(pos_val),
                    )

                def one(b, s):
                    if b.ndim == s.ndim:
                        return b.at[:, slot].set(s[:, 0])
                    return b.at[:, slot].set(s)

                return jax.tree_util.tree_map(one, bn, sn)

            return [
                {key: one_node(bn, sg[key]) for key, bn in bg.items()}
                for bg, sg in zip(big, small)
            ]

        self._insert_paged = jax.jit(insert_paged, donate_argnums=(0,))

        def install_pages(big, pages, slot, phys, pos_val):
            # migrated pool pages (already pool dtype, exported by a
            # prefill worker with the same slicing math as pool_write
            # above) scattered into place; position stamped exactly like
            # the local prefill insert
            def one_node(bn, pn):
                if isinstance(bn, M.PagedQuantKVCache):
                    return M.PagedQuantKVCache(
                        bn.k.at[:, phys].set(pn["k"]),
                        bn.v.at[:, phys].set(pn["v"]),
                        bn.k_scale.at[:, phys].set(pn["k_scale"]),
                        bn.v_scale.at[:, phys].set(pn["v_scale"]),
                        bn.pos.at[:, slot].set(pos_val),
                    )
                if isinstance(bn, M.PagedKVCache):
                    return M.PagedKVCache(
                        bn.k.at[:, phys].set(pn["k"]),
                        bn.v.at[:, phys].set(pn["v"]),
                        bn.pos.at[:, slot].set(pos_val),
                    )
                raise TypeError(
                    "migrated admission covers paged pools only "
                    f"(got {type(bn).__name__})"
                )

            return [
                {key: one_node(bn, pg[key]) for key, bn in bg.items()}
                for bg, pg in zip(big, pages)
            ]

        self._install_pages = (
            jax.jit(install_pages, donate_argnums=(0,)) if self.paged
            else None
        )
        self._page_bytes = (
            _page_pool_bytes(self._cache_shapes()) if self.paged else 0
        )
        # streaming state (populated by begin_stream; run() wraps it)
        self._caches = None
        self._next_tok = np.zeros((B,), np.int32)
        self._pos_host = np.zeros((B,), np.int32)
        self._active: dict[int, _ReqState] = {}
        self._results: dict[int, GenResult] = {}
        self._step = 0
        self._rec: dict | None = None
        self._reset_sampling_state()

    def _reset_sampling_state(self) -> None:
        """Per-slot SamplingParams mirrors fed to the jitted samplers.
        Ballast rows (free/retired slots) are greedy with seed 0 —
        their draws are discarded, and per-row sampling keeps them from
        touching live rows."""
        B = self.max_slots
        self._temp = np.zeros((B,), np.float32)
        self._top_p = np.ones((B,), np.float32)
        self._top_k = np.zeros((B,), np.int32)
        self._seed = np.zeros((B,), np.uint32)
        self._nemit = np.zeros((B,), np.int32)  # emitted-token counts

    def _set_sampling_slot(self, slot: int, s: SamplingParams) -> None:
        self._temp[slot] = s.temperature
        self._top_p[slot] = s.top_p
        self._top_k[slot] = s.top_k
        self._seed[slot] = s.seed
        self._nemit[slot] = 0

    def _clear_sampling_slot(self, slot: int) -> None:
        self._set_sampling_slot(slot, SamplingParams())

    # -- compiled-program plumbing ---------------------------------------
    def _prefill(self, prompt_len: int):
        """One compiled prefill per distinct prompt length (per distinct
        page-*bucket* length when prompt bucketing is on — the paged
        engine pads prompts to page multiples so arrivals share
        programs; padding happens device-side, staging stays at the true
        length)."""
        if prompt_len not in self._prefill_cache:
            plan = self.plan
            if self.paged:
                # batch["last"] (the true last-token gather for padded
                # prompts) needs the replicated layout
                plan = dataclasses.replace(plan, seq_parallel=False)
            elif plan.seq_parallel and prompt_len % max(self.mesh_cfg.tp, 1):
                # seq-parallel needs S % tp == 0; odd lengths fall back to
                # the psum layout (pinned bit-exact by scenario_seq_parallel)
                plan = dataclasses.replace(plan, seq_parallel=False)
            bshapes = {
                "tokens": jax.ShapeDtypeStruct((1, prompt_len), jnp.int32)
            }
            cap = self.cache_capacity
            if self.paged:
                # page-rounded so any padded bucket length fits; the extra
                # tail positions never reach the pool (insert slices whole
                # prompt pages only) and a bigger prefill cache does not
                # change the logits
                cap = self._table_width * self.page_size
                bshapes["last"] = jax.ShapeDtypeStruct((), jnp.int32)
            self._prefill_cache[prompt_len] = make_prefill_step(
                self.cfg, self.mesh_cfg, self.mesh, self.spec_tree, bshapes,
                plan=plan, cache_capacity=cap, shard_batch=False,
                window_override=self.window,
            )
        return self._prefill_cache[prompt_len]

    def _cache_shapes(self):
        return global_cache_shapes(
            self.cfg, self.mesh_cfg, self.max_slots, self.cache_capacity,
            self._cache_dtype, shard_batch=self._shard_batch, per_slot=True,
            int8_kv=self.plan.int8_kv,
            paged_pages=self.num_pages if self.paged else None,
            page_size=self.page_size,
        )

    def _init_caches(self):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), self._cache_shapes(),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def _validate(self, req: Request):
        if max(req.prompt_ids) >= self.cfg.vocab_size or min(req.prompt_ids) < 0:
            raise ValueError(f"request {req.rid}: prompt id out of vocab")
        need = len(req.prompt_ids) + req.max_new
        # the geometry rules (linear cache must hold the request; rings
        # only when capacity <= window; narrow rings evict live tokens)
        # live with the cache constructors — same guard, same wording
        M.check_cache_geometry(
            self.cache_capacity, self.window, need,
            label=f"request {req.rid}: prompt+gen ",
        )
        if self.paged:
            need_pages = -(-need // self.page_size)
            if need_pages > self.num_pages:
                raise ValueError(
                    f"request {req.rid}: needs {need_pages} pages of "
                    f"{self.page_size}, the pool has {self.num_pages}"
                )

    def validate_request(self, req: Request) -> None:
        """Public admission-geometry validation (the fleet router's
        submit path — same checks :meth:`run` applies up front)."""
        self._validate(req)

    # -- the streaming surface (the fleet router drives these) ------------
    def begin_stream(self) -> None:
        """Reset allocators, caches and accounting for a fresh stream.

        An aborted previous stream (exception mid-decode) leaves its
        slots owned; every stream starts from a fresh allocator — the
        engine cache is rebuilt here, so stale residency means nothing.
        :meth:`run` calls this internally; the fleet router calls it
        once, then drives :meth:`admit` / :meth:`admit_pages` /
        :meth:`decode_tick` step by step.
        """
        self.slots = SlotManager(self.max_slots)
        B = self.max_slots
        if self.paged:
            self.pages = PageAllocator(self.num_pages)
            self._intern, self._page_key, self._slot_pages = {}, {}, {}
            # host-side page table; index num_pages = the pool's trash row
            # (unused entries and retired slots' ballast writes land there)
            self._table = np.full(
                (B, self._table_width), self.num_pages, np.int32
            )
        self._caches = self._init_caches()
        self._next_tok = np.zeros((B,), np.int32)  # per-slot feed tokens
        self._pos_host = np.zeros((B,), np.int32)  # absorbed-token counts
        self._reset_sampling_state()
        if self.draft is not None:
            self.draft.reset()
        self._active = {}
        self._results = {}
        self._step = 0
        self._rec = None
        self.step_log = []

    def _ensure_rec(self) -> dict:
        """The current step's record — admissions accumulate into it,
        :meth:`decode_tick` finalizes and appends it."""
        if self._rec is None:
            self._rec = {"step": self._step, "admitted": 0, "active": 0,
                         "decoded": 0, "host_device": 0}
            if self.paged:
                self._rec.update(page_table=0, prefill_hits=0,
                                 prefill_misses=0, kv_migration=0)
            if self.draft is not None:
                self._rec.update(spec_rounds=0, spec_proposed=0,
                                 spec_accepted=0, spec_emitted=0)
        return self._rec

    @property
    def has_work(self) -> bool:
        return bool(self._active)

    @property
    def active_slots(self) -> int:
        return len(self._active)

    @property
    def pending_record(self) -> bool:
        """True when admissions accumulated into a step record that no
        :meth:`decode_tick` has finalized yet."""
        return self._rec is not None

    def _prompt_hits(self, req: Request) -> list[int]:
        """Resident shared-prefix pages for this prompt (longest run of
        interned whole-prompt pages)."""
        hits: list[int] = []
        if self.paged and self.share_prefix:
            page = self.page_size
            for i in range(len(req.prompt_ids) // page):
                pid = self._intern.get(req.prompt_ids[:(i + 1) * page])
                if pid is None:
                    break
                hits.append(pid)
        return hits

    def can_admit(self, req: Request) -> tuple[bool, list[int]]:
        """Admission probe: a free slot and (paged) enough free pages
        once shared-prefix hits are discounted. Returns ``(ok, hits)``
        — the hit page ids let a fleet prefill worker skip resident
        prefix pages when building a migration parcel."""
        hits = self._prompt_hits(req)
        if not self.slots.free_slots:
            return False, hits
        if self.paged:
            need = -(-(len(req.prompt_ids) + req.max_new)
                     // self.page_size)
            if need - len(hits) > self.pages.free_pages:
                return False, hits
        return True, hits

    def _alloc_residency(self, req: Request, hits: list[int]):
        """Allocate the request's slot + page row, intern its new
        whole-prompt pages and stamp the page table. Shared logic
        between local and migrated admission."""
        S = len(req.prompt_ids)
        slot = self.slots.alloc(req.rid)
        row: list[int] = []
        if self.paged:
            page = self.page_size
            need = -(-(S + req.max_new) // page)
            full_pages = S // page  # whole-prompt pages, internable
            for pid in hits:
                self.pages.retain(pid)
            row = hits + self.pages.alloc(need - len(hits))
            for i in range(len(hits), full_pages):
                key = req.prompt_ids[:(i + 1) * page]
                self._intern[key] = row[i]
                self._page_key[row[i]] = key
            self._slot_pages[slot] = list(row)
            self._table[slot, :] = self.num_pages  # trash
            self._table[slot, :len(row)] = row
        return slot, row

    def _finish_admission(self, req: Request, slot: int, first: int,
                          rec: dict) -> None:
        st = _ReqState(req, slot, self._step)
        self._next_tok[slot] = first
        self._pos_host[slot] = len(req.prompt_ids)
        self._set_sampling_slot(slot, req.sampling)
        self._nemit[slot] = 1  # prefill's id is emitted index 0
        rec["admitted"] += 1
        if st.emit(first):
            self._results[req.rid] = self._retire(st, self._step)
        else:
            self._active[slot] = st

    def admit(self, req: Request) -> None:
        """Local-prefill admission of one request (between decode
        steps). Raises :class:`CapacityError` when :meth:`can_admit`
        says no — callers probe first."""
        ok, hits = self.can_admit(req)
        if not ok:
            raise CapacityError(
                f"request {req.rid}: no free slot/pages for admission"
            )
        self._validate(req)
        rec = self._ensure_rec()
        S, w, page = len(req.prompt_ids), self.token_width, self.page_size
        slot, row = self._alloc_residency(req, hits)
        planes = pack_tokens_host(
            np.asarray(req.prompt_ids, np.int32)[None, :], w
        )  # (w, 1, S) — h2d prompt staging (true length, no pads)
        rec["host_device"] += planes.nbytes
        tokens_dev = self._unpack(stage(planes))
        if self.draft is not None:
            # draft mirrors the target's residency from the same staged
            # prompt — one priced h2d crossing covers both prefills
            self.draft.prefill_insert(tokens_dev, slot)
        if self.paged:
            Spad = -(-S // page) * page if self._bucket else S
            rec["prefill_hits" if Spad in self._prefill_cache
                else "prefill_misses"] += 1
            if Spad > S:
                tokens_dev = jnp.pad(tokens_dev, ((0, 0), (0, Spad - S)))
            pbatch = {"tokens": tokens_dev,
                      "last": jnp.asarray(S - 1, jnp.int32)}
            logits, pcaches = self._prefill(Spad)(self.storage, pbatch)
            n_hits = len(hits)
            prompt_pages = -(-S // page)
            phys = jnp.asarray(row[n_hits:prompt_pages], jnp.int32)
            self._caches = self._insert_paged(
                self._caches, pcaches, np.int32(slot), phys,
                np.int32(n_hits * page), np.int32(S),
            )
        else:
            logits, pcaches = self._prefill(S)(
                self.storage, {"tokens": tokens_dev}
            )
            self._caches = self._insert(self._caches, pcaches, np.int32(slot))
        s = req.sampling
        if s.greedy:
            _, tok_planes = self._sample(logits)  # byte-identical fast path
        else:
            _, tok_planes = self._sample_rng(
                logits,
                np.asarray([s.temperature], np.float32),
                np.asarray([s.top_p], np.float32),
                np.asarray([s.top_k], np.int32),
                np.asarray([s.seed], np.uint32),
                np.zeros((1,), np.int32),  # first token = emitted index 0
            )
        tok_planes = np.asarray(tok_planes)  # (w, 1) — d2h first id
        rec["host_device"] += tok_planes.nbytes
        first = int(unpack_tokens_host(tok_planes)[0])
        self._finish_admission(req, slot, first, rec)

    def admit_pages(self, req: Request, pages, *, n_hits: int,
                    first_tok: int, wire_bytes: int = 0) -> None:
        """Migration admission: install prefill-worker KV pages shipped
        through the fleet fabric instead of running a local prefill.

        ``pages`` is the unpacked parcel pytree — per group, per cache
        node, ``{"k", "v"(, "k_scale", "v_scale")}`` arrays shaped
        ``(R, n_new, page, ...)`` in pool dtype covering prompt pages
        ``[n_hits:prompt_pages)`` — and ``first_tok`` the worker's
        greedy first id (the worker runs the same compiled prefill, so
        both are bit-identical to what :meth:`admit` would produce).
        The parcel's wire size lands in the step record's
        ``kv_migration`` field, NOT ``host_device``: the serve staging
        pin covers token/table traffic only, and the fabric hop log is
        the measured side of the fleet migration pin.
        """
        if not self.paged:
            raise ValueError("admit_pages needs the paged engine "
                             "(paged=True)")
        ok, hits = self.can_admit(req)
        if not ok:
            raise CapacityError(
                f"request {req.rid}: no free slot/pages for migration "
                "admission"
            )
        if len(hits) != int(n_hits):
            raise AllocatorError(
                f"request {req.rid}: parcel skipped {n_hits} prefix "
                f"pages but {len(hits)} are resident — probe and admit "
                "must see the same intern table"
            )
        self._validate(req)
        rec = self._ensure_rec()
        S, page = len(req.prompt_ids), self.page_size
        slot, row = self._alloc_residency(req, hits)
        prompt_pages = -(-S // page)
        phys = jnp.asarray(row[len(hits):prompt_pages], jnp.int32)
        staged = jax.tree_util.tree_map(stage, pages)
        rec["kv_migration"] += int(wire_bytes)
        self._caches = self._install_pages(
            self._caches, staged, np.int32(slot), phys, np.int32(S)
        )
        if self.draft is not None:
            # migration ships target KV, not tokens: the draft must
            # prefill locally, so the prompt crosses h2d here (priced)
            dplanes = pack_tokens_host(
                np.asarray(req.prompt_ids, np.int32)[None, :],
                self.token_width,
            )
            rec["host_device"] += dplanes.nbytes
            self.draft.prefill_insert(self._unpack(stage(dplanes)), slot)
        self._finish_admission(req, slot, int(first_tok), rec)

    def decode_tick(self) -> None:
        """One engine step: run one batched decode when any slot is
        active, then finalize the step record (idle steps append a
        zero-decode record, exactly like the drain loop)."""
        rec = self._ensure_rec()
        rec["active"] = len(self._active)
        if self._active and self.draft is not None:
            self._spec_tick(rec)
        elif self._active:
            w = self.token_width
            feed_planes = pack_tokens_host(
                self._next_tok[:, None], w
            )  # (w, B, 1)
            rec["host_device"] += feed_planes.nbytes  # h2d token staging
            tokens_dev = self._unpack(stage(feed_planes))
            batch = {"tokens": tokens_dev, "pos": stage(self._pos_host)}
            if self.paged:
                # the page table is scheduler state staged fresh each step
                # (retires/admissions edit the host copy between steps)
                rec["host_device"] += self._table.nbytes
                rec["page_table"] += self._table.nbytes
                batch["page_table"] = stage(self._table)
            logits, self._caches = self._decode(
                self._weights, self._caches, batch
            )
            if any(not st.req.sampling.greedy
                   for st in self._active.values()):
                _, out_planes = self._sample_rng(
                    logits, self._temp, self._top_p, self._top_k,
                    self._seed, self._nemit,
                )
            else:
                _, out_planes = self._sample(logits)  # byte-identical path
            out_planes = np.asarray(out_planes)  # (w, B) — d2h sampled ids
            rec["host_device"] += out_planes.nbytes
            sampled = unpack_tokens_host(out_planes)
            self._pos_host += 1  # mirrors cache.pos + 1 (ballast too)
            rec["decoded"] = len(self._active)
            for slot, st in list(self._active.items()):
                tok = int(sampled[slot])
                self._next_tok[slot] = tok
                self._nemit[slot] += 1
                if st.emit(tok):
                    self._results[st.req.rid] = self._retire(st, self._step)
                    del self._active[slot]
        self.step_log.append(rec)
        self._step += 1
        self._rec = None

    def _spec_tick(self, rec: dict) -> None:
        """One speculative round: draft proposes ``spec_k`` ids per slot,
        the target verifies all ``k+1`` block positions in ONE batched
        decode, and the standard accept rule keeps the longest prefix the
        draft reproduced (plus the target's own sample at the first
        divergence). Every emitted id is the target's sample under its
        per-request key fold, so streams are token-identical to the
        non-speculative engine at the same seeds — speculation changes
        wall-clock shape and wire traffic, never content.

        Cache discipline: both target and draft advance ``pos`` by
        ``k+1`` inside the jitted steps; rejected suffix entries are
        rolled back by re-stamping ``pos`` downward (entries beyond pos
        are mask-invisible and get overwritten bit-identically next
        round). Ballast slots skip rollback entirely — their writes land
        in trash (clamped pages / dropped scatters) or are masked.
        """
        w, k = self.token_width, self.spec_k
        T = k + 1
        drafts = self.draft.propose(
            self._next_tok, self._pos_host, self._nemit,
            self._temp, self._top_p, self._top_k, self._seed, rec,
        )  # (B, k) host int32
        feed = np.concatenate([self._next_tok[:, None], drafts], axis=1)
        feed_planes = pack_tokens_host(feed, w)  # (w, B, T)
        rec["host_device"] += feed_planes.nbytes  # h2d verify block
        tokens_dev = self._unpack(stage(feed_planes))
        batch = {"tokens": tokens_dev, "pos": stage(self._pos_host)}
        if self.paged:
            rec["host_device"] += self._table.nbytes
            rec["page_table"] += self._table.nbytes
            batch["page_table"] = stage(self._table)
        logits, self._caches = self._verify(
            self._weights, self._caches, batch
        )
        _, t_planes = self._verify_sample(
            logits, self._temp, self._top_p, self._top_k,
            self._seed, self._nemit,
        )
        t_planes = np.asarray(t_planes)  # (w, B, T) — d2h verified ids
        rec["host_device"] += t_planes.nbytes
        targets = unpack_tokens_host(t_planes)  # (B, T)
        self._pos_host += T  # mirrors the jitted pos += T (ballast too)
        rec["decoded"] = len(self._active)
        rec["spec_rounds"] += 1
        delta = np.zeros_like(self._pos_host)
        for slot, st in list(self._active.items()):
            accepted = considered = 0
            for j in range(T):
                tok = int(targets[slot, j])
                accepted += 1
                self._next_tok[slot] = tok
                self._nemit[slot] += 1
                rec["spec_emitted"] += 1
                if st.emit(tok):
                    self._results[st.req.rid] = self._retire(st, self._step)
                    del self._active[slot]
                    break
                if j < k:
                    # proposals past a finish are moot, not rejected —
                    # only *examined* ones count toward the acceptance
                    # rate (a perfect draft pins it at exactly 1.0)
                    considered += 1
                    if int(drafts[slot, j]) != tok:
                        break  # divergence: target's sample replaces it
            rec["spec_proposed"] += considered
            rec["spec_accepted"] += accepted - 1
            delta[slot] = T - accepted
        self._pos_host -= delta
        self._caches = self._rollback(self._caches, delta)
        self.draft.rollback(delta)

    def take_completed(self) -> dict[int, GenResult]:
        """Drain finished results (the router's stream-reassembly feed)."""
        out, self._results = self._results, {}
        return out

    def swap_weights(self, storage) -> None:
        """Hot-swap the weight tree between steps (the fleet's
        ``weight_publish`` install). The swap is unconditional at the
        engine level — in-flight slots continue decoding under the new
        weights. Fleet-level versioned-at-admission semantics (a
        replica swaps only while idle, so no in-flight request ever
        changes weights mid-stream) live in the router."""
        self.storage = storage
        self._weights = (
            self._place(storage) if self._place is not None else storage
        )

    def finish(self) -> dict[int, GenResult]:
        """End-of-stream conservation audits; returns completed results."""
        self.slots.audit()
        if self.paged:
            audit = self.pages.audit()
            if audit["live"] or self._intern or self._slot_pages:
                raise InvariantError("page leak after drain")
        return self._results

    # -- the serving loop -------------------------------------------------
    def run(self, requests, *, max_steps: int = 1_000_000) -> dict[int, GenResult]:
        """Drain ``requests`` (admission in list order) to completion.

        Returns ``{rid: GenResult}``. Appends one record per engine step
        to :attr:`step_log`:
        ``{"step", "admitted", "active", "decoded", "host_device"}`` —
        ``host_device`` is the *measured* staged byte count (sum of
        ``planes.nbytes`` over every boundary crossing that step).
        """
        requests = list(requests)
        if len({r.rid for r in requests}) != len(requests):
            raise ValueError("duplicate request ids")
        for r in requests:
            self._validate(r)
        self.begin_stream()
        queue = collections.deque(requests)
        while (queue or self._active) and self._step < max_steps:
            # admission: fill free slots between decode steps (FIFO —
            # the head of line waits for slots/pages to free)
            while queue:
                ok, _ = self.can_admit(queue[0])
                if not ok:
                    break
                self.admit(queue.popleft())
            self.decode_tick()
        if queue or self._active:
            raise CapacityError(f"engine stopped at max_steps={max_steps} "
                               f"with {len(queue) + len(self._active)} "
                               "unfinished")
        return self.finish()

    def _retire(self, st: _ReqState, step: int) -> GenResult:
        self.slots.release(st.slot)
        self._clear_sampling_slot(st.slot)
        if self.paged:
            for pid in self._slot_pages.pop(st.slot):
                if self.pages.release(pid):
                    # last holder gone: an interned prefix page dies with it
                    key = self._page_key.pop(pid, None)
                    if key is not None:
                        del self._intern[key]
            self._table[st.slot, :] = self.num_pages  # ballast -> trash
        return GenResult(
            rid=st.req.rid,
            prompt_len=len(st.req.prompt_ids),
            tokens=list(st.tokens),
            admitted_step=st.admitted_step,
            finished_step=step,
        )

    # -- accounting --------------------------------------------------------
    def wire_summary(self) -> dict:
        """Aggregate of :attr:`step_log` in the shape the analytic
        serve-wire model (:func:`repro.roofline.analysis.
        serve_host_device_bytes`) reproduces."""
        out = {
            "host_device": sum(r["host_device"] for r in self.step_log),
            "decode_steps": sum(1 for r in self.step_log if r["decoded"]),
            "admissions": sum(r["admitted"] for r in self.step_log),
            "steps": len(self.step_log),
            "token_width": self.token_width,
        }
        if self.paged:
            out["page_table"] = sum(
                r.get("page_table", 0) for r in self.step_log
            )
            out["page_table_entries"] = self.max_slots * self._table_width
            out["prefill_hits"] = sum(
                r.get("prefill_hits", 0) for r in self.step_log
            )
            out["prefill_misses"] = sum(
                r.get("prefill_misses", 0) for r in self.step_log
            )
        if self.draft is not None:
            rounds = sum(r.get("spec_rounds", 0) for r in self.step_log)
            proposed = sum(r.get("spec_proposed", 0) for r in self.step_log)
            accepted = sum(r.get("spec_accepted", 0) for r in self.step_log)
            emitted = sum(r.get("spec_emitted", 0) for r in self.step_log)
            out["spec_rounds"] = rounds
            out["spec_proposed"] = proposed
            out["spec_accepted"] = accepted
            out["spec_emitted"] = emitted
            out["acceptance_rate"] = accepted / max(proposed, 1)
            out["tokens_per_target_step"] = emitted / max(rounds, 1)
            out["spec_k"] = self.spec_k
        return out

    def kv_residency(self) -> dict:
        """Measured page-granular KV residency — the counterpart of the
        analytic :func:`repro.roofline.analysis.serve_paged_kv_bytes`.
        ``bytes_per_page`` sums every paged pool's per-page footprint
        across layers (int8 KV includes the scale planes)."""
        if not self.paged:
            raise ValueError("kv_residency is defined for the paged "
                               "engine (paged=True)")
        live, peak = self.pages.live_pages, self.pages.peak
        return {
            "pages_live": live,
            "pages_peak": peak,
            "page_size": self.page_size,
            "bytes_per_page": self._page_bytes,
            "kv_bytes_resident": live * self._page_bytes,
            "kv_bytes_peak": peak * self._page_bytes,
        }


# ---------------------------------------------------------------------------
# static one-shot reference path
# ---------------------------------------------------------------------------


def generate_static(
    cfg: ModelConfig,
    mesh_cfg: MeshCfg,
    mesh,
    spec_tree,
    storage,
    requests,
    *,
    plan: PrecisionPlan,
    window: int | None = None,
    image_features=None,
) -> dict[int, list[int]]:
    """The pre-engine reference path: classic static batching. Requests
    are grouped by prompt length, each group runs one batched prefill and
    a scalar-``pos`` decode loop to the group's longest request; per-
    request stop conditions truncate the streams afterwards. The engine
    is pinned bit-exact against this for identical request sets.

    Sampling follows each request's :class:`SamplingParams`: all-greedy
    groups keep the historical argmax loop (byte-identical to pre-
    sampling releases), and any sampled request switches its group to
    the shared per-row sampler (:func:`repro.serve.sampling.
    sample_tokens`) under the key-fold contract, so sampled streams are
    bit-exact against the engine at the same per-request seeds.

    Vision features ride on ``Request.image_features``; the legacy
    ``image_features={rid: array}`` kwarg still works one release behind
    a :class:`DeprecationWarning`."""
    plan = plan.broadcast(cfg.num_groups + 1)
    if image_features is not None:
        warnings.warn(
            "generate_static(image_features=...) is deprecated — set "
            "Request.image_features per request instead",
            DeprecationWarning, stacklevel=2,
        )

    def _feats(r):
        if r.image_features is not None:
            return r.image_features
        return None if image_features is None else image_features.get(r.rid)

    if cfg.num_image_tokens and any(_feats(r) is None for r in requests):
        raise ValueError(
            f"{cfg.name} needs image_features per request "
            f"(Request.image_features, "
            f"({cfg.num_image_tokens}, {cfg.vision_dim}) array)"
        )
    groups: dict[int, list[Request]] = {}
    for r in requests:
        groups.setdefault(len(r.prompt_ids), []).append(r)
    out: dict[int, list[int]] = {}
    for S, reqs in groups.items():
        B = len(reqs)
        gen = max(r.max_new for r in reqs)
        cap = S + gen
        toks = jnp.asarray([r.prompt_ids for r in reqs], jnp.int32)
        bshapes = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        batch = {"tokens": toks}
        if cfg.num_image_tokens:
            batch["image_features"] = jnp.asarray(
                np.stack([_feats(r) for r in reqs]),
                jnp.float32,
            )
            bshapes["image_features"] = jax.ShapeDtypeStruct(
                batch["image_features"].shape, jnp.float32
            )
        gplan = plan
        if gplan.seq_parallel and S % max(mesh_cfg.tp, 1):
            gplan = dataclasses.replace(gplan, seq_parallel=False)
        shard_batch = mesh_cfg.dshards > 1 and B % mesh_cfg.dshards == 0
        prefill = make_prefill_step(
            cfg, mesh_cfg, mesh, spec_tree, bshapes, plan=gplan,
            cache_capacity=cap, shard_batch=shard_batch,
        )
        dshapes = {
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
        decode = make_decode_step(
            cfg, mesh_cfg, mesh, spec_tree, dshapes, plan=gplan,
            shard_batch=shard_batch, window_override=window,
        )
        all_greedy = all(r.sampling.greedy for r in reqs)
        if not all_greedy:
            temp = np.asarray(
                [r.sampling.temperature for r in reqs], np.float32)
            topp = np.asarray([r.sampling.top_p for r in reqs], np.float32)
            topk = np.asarray([r.sampling.top_k for r in reqs], np.int32)
            seed = np.asarray([r.sampling.seed for r in reqs], np.uint32)

            @jax.jit
            def samp(lg, step, temp=temp, topp=topp, topk=topk, seed=seed):
                return sample_tokens(
                    lg[:, -1], cfg.vocab_size, temp, topp, topk, seed, step
                )[:, None]

        logits, caches = prefill(storage, batch)
        if all_greedy:
            tok = jnp.argmax(
                logits[:, -1, : cfg.vocab_size], -1
            )[:, None].astype(jnp.int32)
        else:
            tok = samp(logits, np.zeros((B,), np.int32))
        streams = [np.asarray(tok)[:, 0]]
        for i in range(gen - 1):
            logits, caches = decode(
                storage, caches,
                {"tokens": tok, "pos": jnp.asarray(S + i, jnp.int32)},
            )
            if all_greedy:
                tok = jnp.argmax(
                    logits[:, 0, : cfg.vocab_size], -1
                )[:, None].astype(jnp.int32)
            else:
                tok = samp(logits, np.full((B,), i + 1, np.int32))
            streams.append(np.asarray(tok)[:, 0])
        mat = np.stack(streams, axis=1)  # (B, gen)
        for b, r in enumerate(reqs):
            ids = mat[b].tolist()[: r.max_new]
            if r.eos_id is not None and r.eos_id in ids:
                ids = ids[: ids.index(r.eos_id) + 1]
            out[r.rid] = ids
    return out
