"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1  [arXiv:2402.19427].

Griffin block pattern: two RG-LRU recurrent blocks then one local
(sliding-window 2048) MQA attention block. 38 layers: we use 36 pattern
layers + 2 trailing recurrent layers folded in by repeating the pattern is
not possible (38 % 3 != 0), so the config rounds the pattern to 38 with a
('rglru','rglru','local') cycle x12 + ('rglru','rglru') tail modelled as
pattern length 19: ('rglru','rglru','local') x 6 + ('rglru',) — instead we
keep it simple and exact: pattern of length 19 repeated twice.
"""
from repro.configs.base import ModelConfig

_PATTERN = ("rglru", "rglru", "local") * 6 + ("rglru",)  # 19 layers, x2 = 38

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=_PATTERN,
    sliding_window=2048,
    lru_dim=4096,
    conv1d_width=4,
    rope_theta=1e4,
    num_precision_groups=2,  # pattern is 19 layers long -> 2 groups of 19
)
