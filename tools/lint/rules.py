"""The registered rules. Each encodes one repo invariant the static
auditor's guarantees rest on (see docs/audit.md for the catalog and the
rationale per rule)."""
from __future__ import annotations

import ast
import pathlib
from typing import Iterable

from tools.lint import Finding, Rule, SourceFile


def _attr_chain(node: ast.AST) -> str:
    """Dotted name of an attribute/name chain, '' when not a chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _calls(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node, _attr_chain(node.func)


class RawCollective(Rule):
    """Collectives move wire bytes; only the transport (which prices and
    packs them) and explicitly suppressed pinned sites may issue raw
    ``lax`` collectives — anywhere else they bypass the plan's byte
    accounting and the auditor's attribution."""

    name = "RAW-COLLECTIVE"
    description = "raw lax collective outside repro.transport"
    COLLECTIVES = frozenset({
        "psum", "all_gather", "ppermute", "all_to_all", "psum_scatter",
        "pmean", "pmax", "pmin",
    })
    ALLOWED_PREFIXES = ("src/repro/transport/",)

    def check(self, f: SourceFile) -> Iterable[Finding]:
        if f.rel.startswith(self.ALLOWED_PREFIXES):
            return
        for node, chain in _calls(f.tree):
            head, _, attr = chain.rpartition(".")
            if attr in self.COLLECTIVES and head in ("lax", "jax.lax"):
                yield Finding(
                    self.name, f.rel, node.lineno,
                    f"raw {chain} outside repro.transport — route through "
                    "the transport (priced) or suppress the pinned site",
                )


class UnpricedTransfer(Rule):
    """Host<->device staging is a paper traffic class: every
    ``device_put`` must run inside the modules that meter it
    (transport.hostdev staging, the fleet fabric's parcel channel in
    transport.fabric, the data pipeline's prefetch)."""

    name = "UNPRICED-TRANSFER"
    description = "device_put outside transport (hostdev/fabric) or data"
    ALLOWED_PREFIXES = ("src/repro/transport/", "src/repro/data/")

    def check(self, f: SourceFile) -> Iterable[Finding]:
        if f.rel.startswith(self.ALLOWED_PREFIXES):
            return
        for node, chain in _calls(f.tree):
            if chain in ("jax.device_put", "device_put"):
                yield Finding(
                    self.name, f.rel, node.lineno,
                    "unpriced host->device transfer — stage through "
                    "repro.transport.hostdev (metered) instead",
                )


class UnseededRng(Rule):
    """Global numpy RNG state breaks run reproducibility (and the data
    pipeline's shard-deterministic seeding contract): randomness comes
    from ``np.random.Generator``s seeded by ``SeedSequence`` words."""

    name = "UNSEEDED-RNG"
    description = "np.random global-state call"
    ALLOWED_ATTRS = frozenset({
        "default_rng", "Generator", "SeedSequence", "PCG64", "Philox",
        "BitGenerator",
    })

    def check(self, f: SourceFile) -> Iterable[Finding]:
        for node, chain in _calls(f.tree):
            head, _, attr = chain.rpartition(".")
            if head in ("np.random", "numpy.random") and (
                attr not in self.ALLOWED_ATTRS
            ):
                yield Finding(
                    self.name, f.rel, node.lineno,
                    f"{chain} mutates/reads global RNG state — use a "
                    "Generator seeded from SeedSequence words",
                )


class BareAssert(Rule):
    """``assert`` vanishes under ``python -O`` and raises an untyped
    ``AssertionError`` callers cannot catch specifically: library error
    paths raise typed exceptions instead. (Tests are exempt — the rule
    only walks library/tooling dirs.)"""

    name = "BARE-ASSERT"
    description = "bare assert in library code"

    def check(self, f: SourceFile) -> Iterable[Finding]:
        if not f.rel.startswith("src/"):
            return
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Assert):
                yield Finding(
                    self.name, f.rel, node.lineno,
                    "bare assert in library code — raise a typed "
                    "exception (stripped under -O, uncatchable by type)",
                )


class HardcodedInterpret(Rule):
    """Pallas kernel dispatch mode is decided once, by
    ``repro.kernels.bitpack.resolve_interpret`` (compiled on TPU,
    interpret elsewhere); a literal ``interpret=True/False`` pins one
    backend and silently breaks the other."""

    name = "HARDCODED-INTERPRET"
    description = "literal interpret= instead of resolve_interpret"

    def check(self, f: SourceFile) -> Iterable[Finding]:
        if not f.rel.startswith("src/"):
            return
        for node, _chain in _calls(f.tree):
            for kw in node.keywords:
                if kw.arg == "interpret" and isinstance(
                    kw.value, ast.Constant
                ) and isinstance(kw.value.value, bool):
                    yield Finding(
                        self.name, f.rel, node.lineno,
                        "hardcoded interpret= literal — dispatch through "
                        "repro.kernels.bitpack.resolve_interpret",
                    )


class DeprecatedShim(Rule):
    """The deprecation shims exist for *external* callers mid-release;
    in-repo code calling its own shims means the migration never
    finishes (and the DeprecationWarning noise hides real ones)."""

    name = "DEPRECATED-SHIM"
    description = "in-repo call of an own deprecation shim"
    #: shim entry points and the module that defines each (the definer
    #: may reference itself)
    SHIMS = {
        "compressed_all_gather": "src/repro/core/compressed.py",
        "compressed_psum_scatter": "src/repro/core/compressed.py",
        "quantize_ste": "src/repro/core/compressed.py",
        "legacy_request": "src/repro/serve/api.py",
    }

    def check(self, f: SourceFile) -> Iterable[Finding]:
        if not f.rel.startswith("src/"):
            return
        for node, chain in _calls(f.tree):
            attr = chain.rpartition(".")[2]
            definer = self.SHIMS.get(attr)
            if definer is not None and f.rel != definer:
                yield Finding(
                    self.name, f.rel, node.lineno,
                    f"{attr} is a deprecation shim (defined in {definer})"
                    " — call the replacement API",
                )


class DocsFreshness(Rule):
    """docs/*.md backtick references must resolve against the live
    source tree — the pre-existing checker registered as a rule so one
    driver runs everything."""

    name = "DOCS-FRESHNESS"
    description = "docs reference dead symbols/files"

    def check_repo(self, root: pathlib.Path) -> Iterable[Finding]:
        from tools import check_docs_freshness as cdf

        for msg in cdf.check():
            doc, _, rest = msg.partition(":")
            yield Finding(self.name, f"docs/{doc}", 0, rest.strip())


ALL_RULES = (
    RawCollective(),
    UnpricedTransfer(),
    UnseededRng(),
    BareAssert(),
    HardcodedInterpret(),
    DeprecatedShim(),
    DocsFreshness(),
)
