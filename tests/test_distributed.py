"""Runs the multi-device scenarios in subprocesses (the host device count
must be set before jax initialises, so these cannot share this process)."""
import os
import subprocess
import sys

import pytest

SCENARIOS = [
    "scenario_audit.py",
    "scenario_compressed_collectives.py",
    "scenario_dist_train.py",
    "scenario_fleet.py",
    "scenario_paged_serve.py",
    "scenario_perf_levers.py",
    "scenario_plan.py",
    "scenario_seq_parallel.py",
    "scenario_transport.py",
]


@pytest.mark.parametrize("script", SCENARIOS)
def test_scenario(script):
    path = os.path.join(os.path.dirname(__file__), "scenarios", script)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    ) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, path], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert proc.returncode == 0, (
        f"{script} failed:\nstdout:\n{proc.stdout[-3000:]}\n"
        f"stderr:\n{proc.stderr[-3000:]}"
    )
