"""Production serving launcher: continuous batching over the slotted
KV cache (`repro.serve.engine`), with the pre-engine static one-shot
path kept as the bit-exact reference (``--static`` / ``--check-static``).

One :class:`~repro.plan.PrecisionPlan` drives the weight wire format,
activation compression, sequence-parallel prefill, chunked gathers, the
int8 KV cache AND the host<->device token staging (the plan's
``host_device`` entry): pass ``--plan plan.json``. ``--round-to`` /
``--act-round-to`` are plain plan-builder sugar (routed through
:meth:`PrecisionPlan.build`, ignored when a plan is loaded); the layout
flags (``--int8-kv``, ``--seq-parallel``, ``--chunks``,
``--weight-stationary``) stay first-class and override the loaded plan.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --prompt-lens 64,48,64,32 --gen 32 --max-slots 2 [--int8-kv] \
      [--plan plan.json] [--check-static] [--ckpt ckpt.npz]

``--paged`` switches the engine to the block-paged KV layout (page pool
+ per-slot page table, ``--page-size`` tokens per page); ``--shared-prefix
N`` prepends N common tokens to every prompt so the refcounted prefix-
page sharing is visible in the printed page stats. Streams stay
bit-exact vs ``--contiguous`` and the static reference either way.

``--temperature/--top-p/--top-k/--seed`` switch every request to seeded
per-request sampling (request i gets ``seed + i``) under the key-fold
contract of :mod:`repro.serve.sampling` — ``--check-static`` still
holds bit-exactly. ``--spec-decode --draft tiny --spec-k 4`` adds
speculative decoding (:mod:`repro.serve.spec`): token streams are
IDENTICAL to the non-speculative run at the same seeds; only the
acceptance rate and wire/step shape change.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
import warnings

import jax
import numpy as np

from repro.checkpoint.ckpt import load_plan, load_storage
from repro.configs.registry import ARCHS, get_config, reduced
from repro.dist.spec import build_spec_tree, tree_to_storage
from repro.launch.mesh import make_mesh_from_cfg
from repro.launch.train import _null, parse_mesh
from repro.models.init import init_params
from repro.plan import PrecisionPlan, SamplingParams
from repro.roofline.analysis import serve_spec_decode_bytes
from repro.serve.engine import Request, ServeEngine, generate_static
from repro.serve.spec import build_draft

def plan_from_args(args, nrt: int) -> PrecisionPlan:
    """Serve-launcher plan resolution: ``--plan`` (or the checkpointed
    plan) wins; the precision flags are plan-builder sugar routed
    through the same :meth:`PrecisionPlan.build` the train launcher
    uses; layout flags override either source."""
    plan = None
    if args.plan:
        plan = PrecisionPlan.from_file(args.plan).broadcast(nrt)
    elif args.ckpt:
        plan = load_plan(args.ckpt)
        if plan is not None:
            plan = plan.broadcast(nrt)
        else:
            warnings.warn(
                f"checkpoint {args.ckpt} carries no PrecisionPlan "
                "(pre-plan training run?): serving falls back to the "
                "flag-built plan — pass --plan to pin the formats the "
                "run actually used",
                stacklevel=2,
            )
    if plan is None:
        plan = PrecisionPlan.build(
            nrt,
            round_to=args.round_to if args.round_to is not None else 2,
            act_round_to=(
                args.act_round_to if args.act_round_to is not None else 4
            ),
        )
    # layout flags stay first-class and override the loaded plan
    overrides = {}
    if args.seq_parallel:
        overrides["seq_parallel"] = True
    if args.int8_kv:
        overrides["int8_kv"] = True
    if args.chunks is not None:
        overrides["chunks"] = args.chunks
    if overrides:
        plan = dataclasses.replace(plan, **overrides)
    return plan


def sampling_from_args(args, rid: int) -> SamplingParams:
    """Per-request SamplingParams from the launcher flags: one shared
    temperature/top-p/top-k knob, a DISTINCT seed per request
    (``--seed + rid``) so streams are independent yet reproducible."""
    if args.temperature <= 0:
        return SamplingParams()
    return SamplingParams(
        temperature=args.temperature, top_p=args.top_p,
        top_k=args.top_k, seed=args.seed + rid,
    )


def build_requests(args, cfg) -> list[Request]:
    if args.prompt_lens:
        lens = [int(s) for s in args.prompt_lens.split(",")]
    else:
        lens = [args.prompt_len] * args.requests
    rng = np.random.default_rng(0)
    shared = tuple(
        int(t) for t in rng.integers(0, cfg.vocab_size, args.shared_prefix)
    )
    return [
        Request(
            rid=i,
            prompt_ids=shared + tuple(
                int(t) for t in rng.integers(0, cfg.vocab_size, S)
            ),
            max_new=args.gen,
            sampling=sampling_from_args(args, i),
        )
        for i, S in enumerate(lens)
    ]


def run_static(cfg, mesh_cfg, mesh, spec_tree, storage, requests, plan,
               window, image_features=None):
    t0 = time.time()
    if cfg.num_experts:
        # MoE capacity dispatch ranks a whole batch's tokens per expert,
        # so a *grouped* static prefill is not a valid comparison target
        # for the engine's batch-of-1 prefills (see repro.serve.engine):
        # reference MoE archs per request. Each call builds fresh step
        # closures (one compile per request, not per distinct length) —
        # acceptable for a reference path.
        streams = {}
        for r in requests:
            streams.update(generate_static(
                cfg, mesh_cfg, mesh, spec_tree, storage, [r], plan=plan,
                window=window, image_features=image_features,
            ))
        kind = "per-request static"
    else:
        streams = generate_static(
            cfg, mesh_cfg, mesh, spec_tree, storage, requests, plan=plan,
            window=window, image_features=image_features,
        )
        kind = "static one-shot"
    print(f"{kind} reference: {len(requests)} requests in "
          f"{time.time()-t0:.2f}s (incl. compile)")
    return streams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--prompt-lens", default="",
                    help="comma-separated per-request prompt lengths "
                         "(mixed-length continuous batching); overrides "
                         "--requests/--prompt-len")
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-slots", type=int, default=0,
                    help="KV slots resident in the engine (default: "
                         "min(4, requests))")
    ap.add_argument("--plan", default="",
                    help="PrecisionPlan JSON — the declarative source of "
                         "truth incl. the host_device staging entry")
    ap.add_argument("--ckpt", default="",
                    help="restore served weights (+ plan, unless --plan "
                         "overrides) from a training checkpoint")
    # precision sugar: builds the same plan --plan would declare
    ap.add_argument("--round-to", type=int, default=None,
                    help="ADT weight wire format (plan-builder sugar; "
                         "ignored when a plan is loaded)")
    ap.add_argument("--act-round-to", type=int, default=None,
                    help="activation wire format on the TP axis "
                         "(plan-builder sugar)")
    # layout flags: first-class, override a loaded plan
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel prefill activations (decode is "
                         "single-token and keeps the psum layout)")
    ap.add_argument("--chunks", type=int, default=None,
                    help="weight-gather chunk count (double buffering)")
    ap.add_argument("--weight-stationary", action="store_true")
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window decode override (long-context)")
    # per-request sampling (0 temperature = the greedy fast path)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default; "
                         ">0 switches every request to seeded sampling)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus cutoff (with --temperature > 0)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k cutoff, 0 = all (with --temperature > 0)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed; request i uses seed + i")
    # speculative decoding
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decoding: a draft model proposes "
                         "--spec-k tokens/slot, the target verifies them "
                         "in one batched step (streams stay identical)")
    ap.add_argument("--draft", default="tiny",
                    help="draft model: 'tiny' (auto-shrunk target, same "
                         "vocab) or a registry arch name (--spec-decode)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft proposals per round (--spec-decode)")
    layout = ap.add_mutually_exclusive_group()
    layout.add_argument("--paged", action="store_true",
                        help="block-paged KV layout: page pool + per-slot "
                             "page table, shared-prefix pages refcounted")
    layout.add_argument("--contiguous", action="store_true",
                        help="slotted contiguous KV layout (default)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per KV page (--paged)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page-pool size (default: slots x table width)")
    ap.add_argument("--no-share-prefix", action="store_true",
                    help="disable shared-prefix page interning (--paged)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many common tokens to every "
                         "prompt (demonstrates prefix-page sharing)")
    ap.add_argument("--static", action="store_true",
                    help="run ONLY the static one-shot reference path")
    ap.add_argument("--check-static", action="store_true",
                    help="run both paths and assert bit-exact token "
                         "streams (CI smoke)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    mesh_cfg = parse_mesh(args.mesh)
    mesh = make_mesh_from_cfg(mesh_cfg)

    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    nrt = cfg.num_groups + 1
    plan = plan_from_args(args, nrt)
    if args.ckpt:
        storage, ckpt_step = load_storage(args.ckpt, storage)
        print(f"restored weights from {args.ckpt} (train step {ckpt_step}, "
              f"plan rts {plan.round_tos})")

    requests = build_requests(args, cfg)
    lens = [len(r.prompt_ids) for r in requests]
    window = args.window or None
    # windowed decode rings only when capacity <= window (the engine
    # validates this): cap at the window so long prompts wrap instead of
    # silently dropping writes past a too-small linear cache
    cap = max(lens) + args.gen if window is None else min(
        max(lens) + args.gen, window
    )
    slots = args.max_slots or min(4, len(requests))

    image_features = None
    if cfg.num_image_tokens:
        # vision cross-attn archs serve via the static path only: image
        # payloads are not token-stageable through the engine's boundary
        if not args.static:
            raise SystemExit(
                f"{args.arch} has image inputs: serve it with --static "
                "(the continuous-batching engine stages token payloads "
                "only)"
            )
        frng = np.random.default_rng(0)
        image_features = {
            r.rid: frng.normal(
                0, 1, (cfg.num_image_tokens, cfg.vision_dim)
            ).astype(np.float32)
            for r in requests
        }

    ctx = mesh if mesh is not None else _null()
    with ctx:
        static_streams = None
        if args.static or args.check_static:
            static_streams = run_static(
                cfg, mesh_cfg, mesh, spec_tree, storage, requests, plan,
                window, image_features,
            )
            if args.static:
                for r in requests[:4]:
                    print(f"  req{r.rid}: "
                          f"{static_streams[r.rid][:16]}")
                return

        draft = None
        if args.spec_decode:
            draft = build_draft(cfg, mesh_cfg, args.draft)
            print(f"speculative decoding: draft {draft.cfg.name}, "
                  f"k={args.spec_k}")
        engine = ServeEngine(
            cfg, mesh_cfg, mesh, spec_tree, storage, plan=plan,
            max_slots=slots, cache_capacity=cap, window=window,
            weight_stationary=args.weight_stationary,
            paged=args.paged, page_size=args.page_size,
            num_pages=args.num_pages or None,
            share_prefix=not args.no_share_prefix,
            draft=draft, spec_k=args.spec_k if draft is not None else None,
        )
        t0 = time.time()
        results = engine.run(requests)
        wall = time.time() - t0

    total_new = sum(len(r.tokens) for r in results.values())
    summary = engine.wire_summary()
    print(f"{cfg.name}: {len(requests)} requests, prompts {min(lens)}"
          f"..{max(lens)}, +{args.gen} tokens, {slots} slots")
    print(f"engine: {summary['steps']} steps "
          f"({summary['decode_steps']} decode) in {wall:.2f}s "
          f"({total_new/max(wall, 1e-9):.1f} tok/s incl. compile)")
    print(f"host_device wire: {summary['host_device']} B staged at "
          f"{summary['token_width']} B/token "
          f"({4/summary['token_width']:.1f}x vs raw int32)")
    if args.spec_decode:
        print(f"spec decode: {summary['spec_rounds']} rounds, "
              f"acceptance {summary['acceptance_rate']:.2f}, "
              f"{summary['tokens_per_target_step']:.2f} emitted "
              f"tokens/target step (k={summary['spec_k']})")
        analytic = serve_spec_decode_bytes(
            plan, cfg.vocab_size, n_slots=slots,
            prompt_lens=[len(r.prompt_ids) for r in requests],
            spec_rounds=summary["spec_rounds"], spec_k=args.spec_k,
            page_table_entries=(
                summary["page_table_entries"] if args.paged else 0
            ),
        )
        if summary["host_device"] != analytic["total"]:
            raise SystemExit(
                f"spec-decode wire DIVERGED from the analytic model: "
                f"measured {summary['host_device']} != analytic "
                f"{analytic['total']} ({analytic})"
            )
        print(f"wire == serve_spec_decode_bytes: {analytic['total']} B "
              f"at {analytic['token_width']} B/id — measured equals "
              "analytic")
    if args.paged:
        res = engine.kv_residency()
        audit = engine.pages.audit()
        print(f"paged KV: page_size={res['page_size']}, "
              f"{audit['allocs']} page allocs / {audit['releases']} "
              f"releases, peak {res['pages_peak']} pages resident "
              f"({res['kv_bytes_peak']} B at {res['bytes_per_page']} "
              "B/page)")
        print(f"paged prefill: {summary['prefill_misses']} compiles, "
              f"{summary['prefill_hits']} bucket cache hits; page-table "
              f"staging {summary['page_table']} B")
    for r in requests[:4]:
        print(f"  req{r.rid}: {results[r.rid].tokens[:16]}")

    if args.check_static:
        bad = [
            r.rid for r in requests
            if results[r.rid].tokens != static_streams[r.rid]
        ]
        if bad:
            raise SystemExit(
                f"continuous vs static token streams DIVERGED for "
                f"requests {bad}"
            )
        print(f"check-static: {len(requests)} streams bit-exact vs the "
              "static one-shot reference")


if __name__ == "__main__":
    main()
