"""Production serving launcher: ADT-compressed weight placement + batched
prefill/decode with optional weight-stationary residency and int8 KV.

One :class:`~repro.plan.PrecisionPlan` drives the weight wire format,
activation compression, sequence-parallel prefill, chunked gathers and
the int8 KV cache: pass ``--plan plan.json`` or use the individual flags
as plan-builder sugar.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 8 --prompt-len 64 --gen 32 [--weight-stationary] [--int8-kv]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config, reduced
from repro.dist.spec import build_spec_tree, tree_to_storage
from repro.launch.mesh import make_mesh_from_cfg
from repro.launch.train import _null, parse_mesh
from repro.models.init import init_params
from repro.plan import PrecisionPlan
from repro.serve.step import (
    make_decode_step, make_place_step, make_prefill_step,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--plan", default="",
                    help="PrecisionPlan JSON (other precision flags are "
                         "ignored when set)")
    ap.add_argument("--round-to", type=int, default=2)
    ap.add_argument("--act-round-to", type=int, default=4,
                    help="activation wire format on the TP axis (<4 routes "
                         "TP psums through packed planes)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel prefill activations (decode is "
                         "single-token and keeps the psum layout)")
    ap.add_argument("--chunks", type=int, default=1,
                    help="weight-gather chunk count (double buffering)")
    ap.add_argument("--weight-stationary", action="store_true")
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window decode override (long-context)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    mesh_cfg = parse_mesh(args.mesh)
    mesh = make_mesh_from_cfg(mesh_cfg)

    B, S = args.requests, args.prompt_len
    cap = S + args.gen
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    nrt = cfg.num_groups + 1
    if args.plan:
        plan = PrecisionPlan.from_file(args.plan).broadcast(nrt)
    else:
        plan = PrecisionPlan.build(
            nrt,
            round_to=args.round_to,
            act_round_to=args.act_round_to,
            seq_parallel=args.seq_parallel,
            chunks=args.chunks,
            int8_kv=args.int8_kv,
        )

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.num_image_tokens:
        batch["image_features"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_image_tokens, cfg.vision_dim)),
            jnp.float32,
        )
    bshapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    dshapes = {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    shard_batch = B >= mesh_cfg.dshards
    window = args.window or None

    ctx = mesh if mesh is not None else _null()
    with ctx:
        prefill = make_prefill_step(
            cfg, mesh_cfg, mesh, spec_tree, bshapes, plan=plan,
            cache_capacity=cap, shard_batch=shard_batch,
        )
        decode = make_decode_step(
            cfg, mesh_cfg, mesh, spec_tree, dshapes, plan=plan,
            shard_batch=shard_batch, window_override=window,
            weight_stationary=args.weight_stationary,
        )
        weights = storage
        if args.weight_stationary:
            place, _ = make_place_step(
                cfg, mesh_cfg, mesh, spec_tree, plan=plan
            )
            t0 = time.time()
            weights = place(storage)
            jax.block_until_ready(jax.tree_util.tree_leaves(weights)[0])
            print(f"weight placement (ADT rts={plan.round_tos}): "
                  f"{time.time()-t0:.2f}s one-time")

        t0 = time.time()
        logits, caches = prefill(storage, batch)
        tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], -1)[:, None]
        t_pre = time.time() - t0

        outs = [tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            lg, caches = decode(
                weights, caches,
                {"tokens": tok.astype(jnp.int32),
                 "pos": jnp.asarray(S + i, jnp.int32)},
            )
            tok = jnp.argmax(lg[:, 0, : cfg.vocab_size], -1)[:, None]
            outs.append(tok)
        jax.block_until_ready(tok)
        t_dec = time.time() - t0

    total = (args.gen) * B
    print(f"{cfg.name}: {B} requests, prompt {S}, +{args.gen} tokens")
    print(f"prefill {t_pre:.2f}s | decode {t_dec:.2f}s "
          f"({total/max(t_dec,1e-9):.1f} tok/s incl. compile)")
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    for b in range(min(B, 3)):
        print(f"  req{b}: {gen[b][:16].tolist()}")


if __name__ == "__main__":
    main()
