"""``python -m tools.lint`` — run every repo invariant rule.

Exit 1 when any finding survives suppression. Run from the repo root
(or anywhere: paths resolve against the repo that contains this file).

  python -m tools.lint                 # whole repo
  python -m tools.lint src/repro/models/moe.py   # specific files
  python -m tools.lint --rules RAW-COLLECTIVE,BARE-ASSERT
  python -m tools.lint --list
"""
from __future__ import annotations

import argparse
import sys

from tools.lint import ROOT, run_lint
from tools.lint.rules import ALL_RULES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m tools.lint")
    ap.add_argument("paths", nargs="*", help="files to lint (default: repo)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule names (default: all)")
    ap.add_argument("--list", action="store_true",
                    help="list registered rules and exit")
    args = ap.parse_args(argv)

    rules = ALL_RULES
    if args.rules:
        wanted = set(args.rules.split(","))
        known = {r.name for r in ALL_RULES}
        unknown = wanted - known
        if unknown:
            print(f"unknown rules: {sorted(unknown)} (have {sorted(known)})")
            return 2
        rules = tuple(r for r in ALL_RULES if r.name in wanted)

    if args.list:
        for r in ALL_RULES:
            print(f"{r.name:20s} {r.description}")
        return 0

    findings = run_lint(rules, root=ROOT, paths=args.paths or None)
    for f in findings:
        print(f)
    n_rules = len(rules)
    if findings:
        print(f"\n{len(findings)} finding(s) across {n_rules} rule(s)")
        return 1
    print(f"lint OK ({n_rules} rules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
