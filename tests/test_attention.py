"""Attention correctness: tiled vs dense oracle, windows, caches, decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    KVCache,
    attend_decode,
    attend_tiled,
    init_cache,
)


def _dense_oracle(q, k, v, causal, window, q_offset=0):
    """Straightforward masked softmax attention (fp32)."""
    B, Sq, Kv, G, hd = q.shape
    Sk = k.shape[1]
    qp = q_offset + np.arange(Sq)
    kp = np.arange(Sk)
    mask = np.ones((Sq, Sk), bool)
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window is not None:
        mask &= (qp[:, None] - kp[None, :]) < window
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * (hd**-0.5)
    s = jnp.where(jnp.asarray(mask)[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bkgqh", p, v)
    return out.transpose(0, 3, 1, 2, 4)


def _rand(shape, seed):
    return jnp.asarray(
        np.random.default_rng(seed).normal(0, 1, shape), jnp.float32
    )


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 8, 24])
@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_tiled_matches_dense(causal, window, chunk):
    B, S, Kv, G, hd = 2, 64, 2, 2, 16
    q = _rand((B, S, Kv, G, hd), 0)
    k = _rand((B, S, Kv, hd), 1)
    v = _rand((B, S, Kv, hd), 2)
    if window is not None and not causal:
        pytest.skip("window only defined for causal here")
    got = attend_tiled(q, k, v, causal=causal, window=window, chunk=chunk)
    want = _dense_oracle(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal_skip", [True, False])
def test_causal_skip_equivalence(causal_skip):
    """The triangular-exact path must equal the masked-rectangle baseline."""
    B, S, Kv, G, hd = 1, 32, 1, 2, 8
    q = _rand((B, S, Kv, G, hd), 3)
    k = _rand((B, S, Kv, hd), 4)
    v = _rand((B, S, Kv, hd), 5)
    got = attend_tiled(
        q, k, v, causal=True, window=None, chunk=8, causal_skip=causal_skip
    )
    want = _dense_oracle(q, k, v, True, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_matches_prefill_continuation():
    """Decoding token t over a linear cache == full attention at position t."""
    B, S, Kv, G, hd = 2, 17, 2, 2, 8
    k = _rand((B, S, Kv, hd), 6)
    v = _rand((B, S, Kv, hd), 7)
    q_all = _rand((B, S, Kv, G, hd), 8)
    want = _dense_oracle(q_all, k, v, causal=True, window=None)

    cache = init_cache(B, S, Kv, hd, jnp.float32)
    cache = KVCache(k, v, jnp.asarray(S, jnp.int32))
    # check the last position via attend_decode
    got = attend_decode(
        q_all[:, -1:], cache, ring=False, window=None
    )
    np.testing.assert_allclose(
        np.asarray(got[:, 0]), np.asarray(want[:, -1]), rtol=2e-5, atol=2e-5
    )


def test_ring_cache_window_decode():
    """Ring-buffer decode == windowed attention over the full history."""
    B, Kv, G, hd, W = 1, 1, 2, 8, 8
    total = 29
    k_hist = _rand((B, total, Kv, hd), 9)
    v_hist = _rand((B, total, Kv, hd), 10)
    q = _rand((B, 1, Kv, G, hd), 11)

    # build ring cache as decode would have: slot j holds latest pos == j mod W
    pos = total - 1
    kc = jnp.zeros((B, W, Kv, hd), jnp.float32)
    vc = jnp.zeros((B, W, Kv, hd), jnp.float32)
    for t in range(total):
        kc = kc.at[:, t % W].set(k_hist[:, t])
        vc = vc.at[:, t % W].set(v_hist[:, t])
    cache = KVCache(kc, vc, jnp.asarray(total, jnp.int32))
    got = attend_decode(q, cache, ring=True, window=W)

    want = _dense_oracle(
        q, k_hist, v_hist, causal=True, window=W, q_offset=pos
    )
    np.testing.assert_allclose(
        np.asarray(got[:, 0]), np.asarray(want[:, 0]), rtol=2e-5, atol=2e-5
    )


def test_prefill_offset_chunks():
    """q_offset (prefill continuation) produces the same result as slicing
    full attention."""
    B, S, Kv, G, hd = 1, 48, 1, 1, 8
    q = _rand((B, S, Kv, G, hd), 12)
    k = _rand((B, S, Kv, hd), 13)
    v = _rand((B, S, Kv, hd), 14)
    full = attend_tiled(q, k, v, causal=True, window=None, chunk=16)
    tail = attend_tiled(
        q[:, 32:], k, v, causal=True, window=None, chunk=16, q_offset=32
    )
    np.testing.assert_allclose(
        np.asarray(full[:, 32:]), np.asarray(tail), rtol=2e-5, atol=2e-5
    )


def test_int8_prefill_decode_close_to_fp():
    """QuantKVCache prefill+decode ≈ fp cache path (per-slot scales)."""
    from repro.models.attention import (
        QuantKVCache, _quantize_kv, init_cache, mha,
    )
    from repro.configs.registry import get_config, reduced
    from repro.models.env import Env
    from repro.models.init import init_params

    cfg = reduced(get_config("qwen3-1.7b"))
    env = Env(attn_chunk=8)
    env8 = Env(attn_chunk=8, int8_kv=True)
    params, _ = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    w = jax.tree_util.tree_map(lambda a: a[0], params["groups"][0]["p0"])["attn"]
    B, S = 2, 16
    x = _rand((B, S, cfg.d_model), 20) * 0.3

    cache_fp = init_cache(B, S + 2, cfg.num_kv_heads, cfg.head_dim, jnp.float32)
    cache_q = init_cache(B, S + 2, cfg.num_kv_heads, cfg.head_dim, jnp.int8)
    y_fp, cache_fp = mha(x, w, cfg, env, mode="prefill", cache=cache_fp)
    y_q, cache_q = mha(x, w, cfg, env8, mode="prefill", cache=cache_q)
    assert isinstance(cache_q, QuantKVCache)
    np.testing.assert_allclose(np.asarray(y_fp), np.asarray(y_q), rtol=0.05, atol=0.02)

    xt = _rand((B, 1, cfg.d_model), 21) * 0.3
    d_fp, _ = mha(xt, w, cfg, env, mode="decode", cache=cache_fp, pos_offset=S)
    d_q, _ = mha(xt, w, cfg, env8, mode="decode", cache=cache_q, pos_offset=S)
    np.testing.assert_allclose(np.asarray(d_fp), np.asarray(d_q), rtol=0.08, atol=0.02)

    # quantizer itself: roundtrip error bounded by scale/2
    k = _rand((2, 4, 2, 16), 22)
    kq, sc = _quantize_kv(k)
    deq = np.asarray(kq, np.float32) * np.asarray(sc)[..., None]
    assert np.max(np.abs(deq - np.asarray(k))) <= np.max(np.asarray(sc)) * 0.51
