"""Subprocess scenario: §Perf levers preserve correctness on an 8-dev mesh.

  * accum_steps=2 matches accum_steps=1 gradients/updates (fp tolerance),
  * grad_round_to=2 (compressed gradient reduce-scatter) still descends,
  * weight-stationary decode == per-step-gather decode logits (rt=4 exact),
  * int8 KV decode ≈ fp decode logits.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.launch.mesh import make_mesh_from_cfg
from repro.models.init import init_params
from repro.optim.sgd import SGDConfig, init_momentum
from repro.plan import PrecisionPlan
from repro.serve.step import (
    make_decode_step, make_place_step, make_prefill_step,
)
from repro.train.step import make_train_step


def main():
    mesh_cfg = MeshCfg(tp=2, dp=4)
    mesh = make_mesh_from_cfg(mesh_cfg)
    cfg = reduced(get_config("qwen3-1.7b"))
    B, S = 8, 32
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    bshapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    nrt = cfg.num_groups + 1
    opt = SGDConfig(lr=0.05, momentum=0.9, weight_decay=0.0)

    with mesh:
        params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=2)
        spec = build_spec_tree(params, metas, mesh_cfg)

        # ---- accumulation equivalence --------------------------------
        losses = {}
        for accum in (1, 2):
            st = tree_to_storage(
                init_params(cfg, jax.random.PRNGKey(0), tp=2)[0], spec, mesh_cfg
            )
            step = make_train_step(
                cfg, mesh_cfg, mesh, spec, opt, bshapes,
                plan=PrecisionPlan.build(nrt, accum_steps=accum),
            )
            st, mom, m = step(st, init_momentum(st), batch, 0.05)
            _, _, m2 = step(st, mom, batch, 0.05)
            losses[accum] = (float(m["loss"]), float(m2["loss"]))
        assert abs(losses[1][0] - losses[2][0]) < 2e-4, losses
        assert abs(losses[1][1] - losses[2][1]) < 2e-3, losses
        print(f"  accum equivalence: {losses[1]} vs {losses[2]} OK")

        # ---- compressed gradients still train -------------------------
        st = tree_to_storage(
            init_params(cfg, jax.random.PRNGKey(0), tp=2)[0], spec, mesh_cfg
        )
        step_cg = make_train_step(
            cfg, mesh_cfg, mesh, spec, opt, bshapes,
            plan=PrecisionPlan.build(nrt, round_to=2, grad_round_to=2),
        )
        mom = init_momentum(st)
        ls = []
        for i in range(4):
            st, mom, m = step_cg(st, mom, batch, 0.05)
            ls.append(float(m["loss"]))
        assert ls[-1] < ls[0], ls
        assert all(np.isfinite(ls)), ls
        print(f"  compressed-grad training descends: {ls} OK")

        # ---- weight-stationary + int8-kv decode ----------------------
        params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=2)
        st = tree_to_storage(params, spec, mesh_cfg)
        pre = make_prefill_step(
            cfg, mesh_cfg, mesh, spec,
            {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)},
            plan=PrecisionPlan.build(nrt), cache_capacity=S + 2,
        )
        logits0, caches = pre(st, {"tokens": batch["tokens"]})
        dshapes = {
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
        tok = {"tokens": jnp.ones((B, 1), jnp.int32),
               "pos": jnp.asarray(S, jnp.int32)}

        dstep = make_decode_step(cfg, mesh_cfg, mesh, spec, dshapes,
                                 plan=PrecisionPlan.build(nrt))
        want, _ = dstep(st, caches, tok)

        place, _ = make_place_step(cfg, mesh_cfg, mesh, spec,
                                   plan=PrecisionPlan.build(nrt))
        placed = place(st)
        dstep_ws = make_decode_step(
            cfg, mesh_cfg, mesh, spec, dshapes,
            plan=PrecisionPlan.build(nrt), weight_stationary=True,
        )
        logits0b, caches_b = pre(st, {"tokens": batch["tokens"]})
        got, _ = dstep_ws(placed, caches_b, tok)
        np.testing.assert_allclose(
            np.asarray(want[..., : cfg.vocab_size]),
            np.asarray(got[..., : cfg.vocab_size]),
            rtol=1e-5, atol=1e-5,
        )
        print("  weight-stationary decode matches OK")

        # ---- int8 KV decode ≈ fp decode -------------------------------
        from repro.serve.step import global_cache_shapes

        def empty_caches(dtype):
            shapes = global_cache_shapes(cfg, mesh_cfg, B, 16, dtype)
            return jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), shapes
            )

        dstep_q = make_decode_step(
            cfg, mesh_cfg, mesh, spec, dshapes,
            plan=PrecisionPlan.build(nrt, int8_kv=True),
        )

        def roll(step_fn, caches, n=6):
            outs = []
            t = jnp.ones((B, 1), jnp.int32)
            for i in range(n):
                lg, caches = step_fn(
                    st, caches, {"tokens": t, "pos": jnp.asarray(i, jnp.int32)}
                )
                outs.append(np.asarray(lg[..., : cfg.vocab_size], np.float32))
                t = jnp.argmax(lg[:, 0, : cfg.vocab_size], -1)[:, None].astype(
                    jnp.int32
                )
            return np.stack(outs)

        out_fp = roll(dstep, empty_caches(jnp.float32))
        out_q = roll(dstep_q, empty_caches(jnp.int8))
        err = np.max(np.abs(out_fp - out_q)) / (np.max(np.abs(out_fp)) + 1e-9)
        assert err < 0.05, f"int8 kv relative error too large: {err}"
        print(f"  int8 KV decode rel err {err:.4f} OK")
        print("scenario_perf_levers OK")


if __name__ == "__main__":
    main()
