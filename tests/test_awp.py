"""AWP controller (Algorithm 1) unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.awp import AWPConfig, AWPController, oracle_round_to
from repro.core.formats import TransferFormat, bits_to_bytes


def test_bits_to_bytes_paper_example():
    # paper §III-A: "if AWP provides the value 14, RoundTo will be 2 bytes"
    assert bits_to_bytes(14) == 2
    assert bits_to_bytes(8) == 1
    assert bits_to_bytes(9) == 2
    assert bits_to_bytes(24) == 3
    assert bits_to_bytes(25) == 4
    assert bits_to_bytes(64) == 4


def test_formats():
    assert TransferFormat(2).name == "bf16"
    assert TransferFormat(1).compression_ratio == 4.0
    assert TransferFormat(4).is_identity
    with pytest.raises(ValueError):
        TransferFormat(5)


def test_algorithm1_fires_after_interval():
    c = AWPController(2, AWPConfig(threshold=-0.01, interval=3, initial_bits=8))
    norms = np.array([100.0, 50.0])
    c.update(norms**2)
    for _ in range(2):
        norms = norms * 0.97  # delta = -3% < T
        c.update(norms**2)
    assert c.round_to == (1, 1)  # 2 hits only: not fired yet
    norms = norms * 0.97
    c.update(norms**2)
    assert c.round_to == (2, 2)  # third hit -> fire, 8->16 bits
    # counters reset: immediately after firing nothing more happens
    assert np.all(c.state.counters == 0)


def test_algorithm1_consecutive_not_cumulative():
    """Regression: a miss must reset the counter. With a cumulative count
    an alternating hit/miss norm trajectory (noisy training) would fire
    after 2*INTERVAL steps even though no INTERVAL *consecutive* hits ever
    happen (Algorithm 1, paper §II)."""
    c = AWPController(1, AWPConfig(threshold=-0.01, interval=3))
    n = 100.0
    for i in range(40):
        n *= 0.97 if i % 2 == 0 else 1.03  # hit, miss, hit, miss, ...
        c.update([n**2])
    assert c.round_to == (1,)
    assert c.state.counters[0] <= 1
    # and a genuine consecutive run right after the noise still fires
    for _ in range(3):
        n *= 0.97
        c.update([n**2])
    assert c.round_to == (2,)


def test_algorithm1_no_fire_when_growing():
    c = AWPController(1, AWPConfig(threshold=-0.01, interval=2))
    n = 10.0
    for _ in range(20):
        n *= 1.05
        c.update([n**2])
    assert c.round_to == (1,)


def test_per_group_independence():
    c = AWPController(2, AWPConfig(threshold=-0.01, interval=2))
    a, b = 100.0, 100.0
    for _ in range(4):
        a *= 0.9   # shrinking -> fires
        b *= 1.1   # growing -> stays
        c.update([a**2, b**2])
    assert c.round_to[0] > 1
    assert c.round_to[1] == 1


def test_oracle_policy():
    assert oracle_round_to(3, 2) == (2, 2, 2)


@given(
    st.lists(
        st.floats(min_value=0.5, max_value=2.0), min_size=30, max_size=80
    ),
    st.integers(1, 5),
)
@settings(max_examples=40, deadline=None)
def test_property_monotone_and_bounded(factors, interval):
    """Bits per group only ever increase, never exceed 32, and the format
    stays valid whatever the norm trajectory does."""
    c = AWPController(1, AWPConfig(threshold=-0.005, interval=interval))
    n = 100.0
    seen = [c.round_to[0]]
    for f in factors:
        n = max(n * f, 1e-6)
        c.update([n**2])
        rt = c.round_to[0]
        assert 1 <= rt <= 4
        assert rt >= seen[-1]
        seen.append(rt)
    assert c.state.bits[0] <= 32


@given(st.integers(1, 20))
@settings(max_examples=20, deadline=None)
def test_property_history_matches_transitions(k):
    c = AWPController(1, AWPConfig(threshold=-0.001, interval=k))
    n = 100.0
    for _ in range(5 * k):
        n *= 0.99
        c.update([n**2])
    # each history entry strictly increases the bit vector
    for (s0, b0), (s1, b1) in zip(c.history, c.history[1:]):
        assert s1 > s0
        assert b1 > b0


def test_bytes_saved_fraction():
    c = AWPController(2, AWPConfig())
    assert c.bytes_saved_fraction() == pytest.approx(0.75)  # both at 8-bit
