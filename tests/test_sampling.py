"""Per-request sampling primitives (`repro.serve.sampling`).

The contract (docs/serving.md §sampling): the id sampled for the n-th
emitted token of a request is a pure function of ``(logits_row, seed,
n)`` under the key ``jax.random.fold_in(jax.random.PRNGKey(seed), n)``.
Every op in :func:`sample_tokens` is row-independent, so a row samples
the same id whatever batch shape it rides in — the property the serve
engine, the static reference, and the speculative verify step all rely
on for bit-exact streams.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.plan import SamplingParams
from repro.serve.sampling import fold_key, sample_tokens, uniform_for

VOCAB = 97


def _logits(rows, key=0, pad=0):
    lg = jax.random.normal(jax.random.PRNGKey(key), (rows, VOCAB + pad))
    if pad:
        lg = lg.at[:, VOCAB:].set(1e9)  # pad lanes must never win
    return lg * 3.0


def _params(rows, temp=0.8, top_p=1.0, top_k=0, seed0=11):
    return (
        np.full((rows,), temp, np.float32),
        np.full((rows,), top_p, np.float32),
        np.full((rows,), top_k, np.int32),
        np.arange(seed0, seed0 + rows, dtype=np.uint32),
        np.zeros((rows,), np.int32),
    )


def test_zero_temperature_is_argmax():
    lg = _logits(5, pad=3)
    temp, top_p, top_k, seed, step = _params(5, temp=0.0)
    tok = sample_tokens(lg, VOCAB, temp, top_p, top_k, seed, step)
    np.testing.assert_array_equal(
        np.asarray(tok), np.asarray(jnp.argmax(lg[:, :VOCAB], axis=-1))
    )


def test_top_k_one_is_argmax_for_any_seed():
    lg = _logits(4)
    for seed0 in (0, 3, 1234):
        temp, top_p, top_k, seed, step = _params(4, top_k=1, seed0=seed0)
        tok = sample_tokens(lg, VOCAB, temp, top_p, top_k, seed, step)
        np.testing.assert_array_equal(
            np.asarray(tok), np.asarray(jnp.argmax(lg, axis=-1))
        )


def test_tiny_top_p_keeps_only_the_best_id():
    # preceding-mass < top_p: the rank-0 id always survives (mass 0),
    # and with top_p ~ 0 nothing else does
    lg = _logits(6)
    temp, top_p, top_k, seed, step = _params(6, top_p=1e-6)
    tok = sample_tokens(lg, VOCAB, temp, top_p, top_k, seed, step)
    np.testing.assert_array_equal(
        np.asarray(tok), np.asarray(jnp.argmax(lg, axis=-1))
    )


def test_top_k_restricts_support():
    lg = _logits(1)
    best8 = set(np.asarray(jnp.argsort(-lg[0])[:8]).tolist())
    for s in range(40):
        temp, top_p, top_k, seed, step = _params(1, top_k=8, seed0=s)
        tok = int(sample_tokens(lg, VOCAB, temp, top_p, top_k, seed, step)[0])
        assert tok in best8


def test_uniform_for_matches_scalar_fold():
    seeds = np.asarray([1, 1, 7, 42], np.uint32)
    steps = np.asarray([0, 5, 5, 2], np.int32)
    got = np.asarray(uniform_for(seeds, steps))
    want = np.asarray(
        [jax.random.uniform(fold_key(int(s), int(n)), (), jnp.float32)
         for s, n in zip(seeds, steps)]
    )
    np.testing.assert_array_equal(got, want)
    # distinct steps under one seed give distinct draws (key folding)
    assert got[0] != got[1]


def test_batch_shape_invariance():
    """The same (logits_row, seed, step) samples the same id at B=1,
    embedded in a B=6 batch, and inside a (B, T) block — the property
    spec-decode's verify step depends on."""
    lg = _logits(6, key=9)
    temp, top_p, top_k, seed, step = _params(6, top_p=0.9, top_k=12)
    step = np.arange(6, dtype=np.int32)
    full = np.asarray(sample_tokens(lg, VOCAB, temp, top_p, top_k, seed, step))
    for r in range(6):
        one = sample_tokens(
            lg[r : r + 1], VOCAB, temp[r : r + 1], top_p[r : r + 1],
            top_k[r : r + 1], seed[r : r + 1], step[r : r + 1],
        )
        assert int(one[0]) == full[r]
    block = sample_tokens(
        lg.reshape(2, 3, -1), VOCAB, temp.reshape(2, 3),
        top_p.reshape(2, 3), top_k.reshape(2, 3), seed.reshape(2, 3),
        step.reshape(2, 3),
    )
    np.testing.assert_array_equal(np.asarray(block).reshape(-1), full)


def test_mixed_greedy_and_sampled_rows():
    lg = _logits(4, key=3)
    temp = np.asarray([0.0, 0.9, 0.0, 0.9], np.float32)
    top_p = np.full((4,), 0.95, np.float32)
    top_k = np.zeros((4,), np.int32)
    seed = np.asarray([0, 5, 0, 6], np.uint32)
    step = np.asarray([0, 3, 1, 3], np.int32)
    tok = np.asarray(sample_tokens(lg, VOCAB, temp, top_p, top_k, seed, step))
    arg = np.asarray(jnp.argmax(lg, axis=-1))
    assert tok[0] == arg[0] and tok[2] == arg[2]
    solo = sample_tokens(
        lg[1:2], VOCAB, temp[1:2], top_p[1:2], top_k[1:2], seed[1:2],
        step[1:2],
    )
    assert int(solo[0]) == tok[1]


def test_sampling_params_validation():
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.7).greedy
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(seed=-2)
