"""Parameter metadata: how each weight shards over TP and whether ADT
compresses it (biases/norm scales are never compressed — paper §III)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    """Sharding + compression descriptor for one parameter.

    tp_dim:   dimension sliced over the model axis (None = replicated).
    tp_units: number of logical units along tp_dim (e.g. kv heads). When
              units < tp, each unit is replicated tp/units times (GQA kv
              replication, DESIGN.md §3); when units % tp == 0 it's an even
              slice. 0 means "dim size itself is the unit count".
    compress: ADT byte-plane compression applies to the FSDP gather.
    """

    tp_dim: int | None = None
    tp_units: int = 0
    compress: bool = True
    # gradient synchronisation over the *model* axis: params that are
    # replicated over TP but consumed inside a TP region (after the
    # enter() boundary) produce rank-partial grads that must be psum'd.
    # Params used on replicated activations already get full grads via the
    # f/g custom_vjp pairs and must NOT be re-summed (DESIGN.md §3).
    grad_sync_model: bool = False
    # like grad_sync_model, but only when the step runs sequence-parallel
    # (Env.seq_parallel): params consumed on *sequence shards* — the
    # pre-boundary RMSNorm scales and the final norm — see each rank's
    # tokens only, so their grads are token-partial and must be psum'd.
    # In the replicated layout the same grads are full and identical per
    # rank (no sync); params consumed on replicated activations (sLSTM)
    # stay identical under both layouts and must never be re-summed.
    grad_sync_seq: bool = False

    def local_shape(self, shape: tuple[int, ...], tp: int) -> tuple[int, ...]:
        if self.tp_dim is None or tp == 1:
            return shape
        dim = self.tp_dim
        units = self.tp_units or shape[dim]
        if units % tp == 0:
            per = shape[dim] // tp
        elif tp % units == 0:
            per = shape[dim] // units  # one unit, replicated
        else:
            raise ValueError(
                f"cannot shard {units} units over tp={tp} (shape {shape})"
            )
        out = list(shape)
        out[dim] = per
        return tuple(out)

    def tp_slice_index(self, rank: int, shape: tuple[int, ...], tp: int) -> int:
        """Start offset (in elements along tp_dim) of `rank`'s slice."""
        dim = self.tp_dim
        units = self.tp_units or shape[dim]
        unit_w = shape[dim] // units
        if units % tp == 0:
            return rank * (units // tp) * unit_w
        return (rank * units // tp) * unit_w


REPLICATED_SMALL = ParamMeta(tp_dim=None, compress=False)
REPLICATED_BIG = ParamMeta(tp_dim=None, compress=True)
# RMSNorm scales applied *before* a TP-region enter: under the
# sequence-parallel layout they run on this rank's sequence shard, so
# their grads are token-partial (see grad_sync_seq above)
SEQ_NORM = ParamMeta(tp_dim=None, compress=False, grad_sync_seq=True)

# compression threshold: leaves smaller than this stay uncompressed and
# replicated-gathered in fp32 (the paper's "biases" carve-out)
COMPRESS_MIN_SIZE = 65536
