"""Checkpoint compatibility shims over :mod:`repro.checkpoint.sharded`.

The original implementation gathered the whole ``(storage, opt_state)``
tree into one blocking fp32 ``.npz``. The format is now the width-aware
sharded directory (``<path>.ckpt/``) written by
:func:`~repro.checkpoint.sharded.save_sharded`; these entry points keep
the historical call signatures so launchers and tests do not churn:

* :func:`save_checkpoint` — forwards to ``save_sharded`` (pass
  ``spec_tree=``/``round_tos=`` to store compressible fp32 leaves as
  width-sized wire tiers + residual tiers, ``extra=`` for e.g. the data
  pipeline's iterator state, ``async_ckpt=`` an
  :class:`~repro.checkpoint.sharded.AsyncCheckpointer` to overlap the
  write with the next step);
* :func:`load_checkpoint` / :func:`load_storage` / :func:`load_plan` —
  read the sharded directory, falling back to a legacy ``.npz`` if one
  is what's on disk (old runs stay restorable).

Structure mismatches raise
:class:`~repro.checkpoint.sharded.CheckpointError` naming the first
mismatching key path — typed, so it survives ``python -O`` (the old
bare ``assert``\\ s did not) and callers can catch it distinctly.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.checkpoint.sharded import (
    CheckpointError,
    AsyncCheckpointer,
    leaf_entries,
    awp_from_meta,
    load_sharded,
    read_meta,
    save_sharded,
)
from repro.core.awp import AWPController
from repro.plan import PrecisionPlan

__all__ = [
    "CheckpointError",
    "AsyncCheckpointer",
    "ckpt_dir",
    "save_checkpoint",
    "load_checkpoint",
    "load_storage",
    "load_plan",
]


def ckpt_dir(path: str) -> str:
    """Canonical sharded-checkpoint directory for a user-supplied path:
    a legacy ``foo.npz`` (or bare ``foo``) maps to ``foo.ckpt`` so save
    and load always agree on the on-disk name."""
    if path.endswith(".npz"):
        path = path[: -len(".npz")]
    if not path.endswith(".ckpt"):
        path = path + ".ckpt"
    return path


def _npz_path(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def save_checkpoint(
    path: str,
    storage,
    opt_state,
    awp: AWPController | None,
    step: int,
    plan: PrecisionPlan | None = None,
    *,
    spec_tree=None,
    round_tos=None,
    extra: dict | None = None,
    residuals: bool = True,
    async_ckpt: AsyncCheckpointer | None = None,
):
    """Write the sharded checkpoint at ``ckpt_dir(path)``.

    With ``async_ckpt`` the serialization runs on its worker thread and
    this returns immediately (call ``async_ckpt.wait()`` before reading
    the checkpoint back). Width-aware tiers need both ``spec_tree`` and
    ``round_tos`` — pass the AWP controller's *current* formats so a
    rt=2 weight occupies 2 bytes on disk."""
    target = ckpt_dir(path)
    parent = os.path.dirname(target)
    if parent:
        os.makedirs(parent, exist_ok=True)
    kw = dict(
        plan=plan, spec_tree=spec_tree, round_tos=round_tos,
        extra=extra, residuals=residuals,
    )
    if async_ckpt is not None:
        async_ckpt.save(target, storage, opt_state, awp, step, **kw)
        return None
    return save_sharded(target, storage, opt_state, awp, step, **kw)


# ---------------------------------------------------------------------------
# legacy .npz fallback
# ---------------------------------------------------------------------------


def _legacy_load(path: str):
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    return data, meta


def _legacy_checkpoint(path, storage_like, opt_like, awp):
    data, meta = _legacy_load(path)
    flat_like, treedef = jax.tree_util.tree_flatten((storage_like, opt_like))
    if meta["num_arrays"] != len(flat_like):
        paths = [p for p, _ in leaf_entries((storage_like, opt_like))]
        at = (
            paths[meta["num_arrays"]]
            if meta["num_arrays"] < len(paths)
            else f"<checkpoint leaf {len(flat_like)}>"
        )
        raise CheckpointError(
            f"checkpoint holds {meta['num_arrays']} leaves, restore "
            f"target has {len(flat_like)} (first unmatched: {at})"
        )
    flat = [data[f"a{i}"] for i in range(len(flat_like))]
    storage, opt_state = jax.tree_util.tree_unflatten(treedef, flat)
    awp_from_meta(awp, meta.get("awp"))
    return storage, opt_state, meta["step"]


def _legacy_storage(path, storage_like):
    data, meta = _legacy_load(path)
    flat_like, treedef = jax.tree_util.tree_flatten(storage_like)
    if meta["num_arrays"] < len(flat_like):
        paths = [p for p, _ in leaf_entries(storage_like)]
        raise CheckpointError(
            f"checkpoint holds {meta['num_arrays']} leaves, storage "
            f"target has {len(flat_like)} (first unmatched: "
            f"{paths[meta['num_arrays']]})"
        )
    flat = [data[f"a{i}"] for i in range(len(flat_like))]
    for (kpath, like), got in zip(leaf_entries(storage_like), flat):
        if tuple(like.shape) != tuple(got.shape):
            raise CheckpointError(
                f"checkpoint shape mismatch at {kpath}: checkpoint "
                f"{tuple(got.shape)} vs target {tuple(like.shape)}"
            )
    return jax.tree_util.tree_unflatten(treedef, flat), meta["step"]


def _resolve(path: str) -> tuple[str, bool]:
    """On-disk artifact for ``path``: ``(location, is_sharded)``.

    Prefers the sharded directory; falls back to a legacy ``.npz``."""
    d = ckpt_dir(path)
    if os.path.isdir(d):
        return d, True
    npz = _npz_path(path)
    if os.path.isfile(npz):
        return npz, False
    raise CheckpointError(f"no checkpoint found at {d!r} or {npz!r}")


def load_checkpoint(path: str, storage_like, opt_like,
                    awp: AWPController | None = None,
                    *, quality: str = "exact"):
    """Restore ``(storage, opt_state, step)`` (+ AWP controller state in
    place). ``quality`` follows :func:`load_sharded`; legacy ``.npz``
    checkpoints are always full precision."""
    loc, sharded = _resolve(path)
    if not sharded:
        return _legacy_checkpoint(loc, storage_like, opt_like, awp)
    storage, opt_state, step, _ = load_sharded(
        loc, storage_like, opt_like, awp, quality=quality
    )
    return storage, opt_state, step


def load_storage(path: str, storage_like, *, quality: str = "exact"):
    """Weights-only restore for serving: never materializes (and
    immediately discards) a momentum tree the size of the model.
    Returns ``(storage, step)``. ``quality="wire"`` reads only the
    width-priced tiers — the transport-truncated view a serving replica
    would receive over the wire."""
    loc, sharded = _resolve(path)
    if not sharded:
        return _legacy_storage(loc, storage_like)
    storage, _, step, _ = load_sharded(
        loc, storage_like, None, None, quality=quality
    )
    return storage, step


def load_plan(path: str) -> PrecisionPlan | None:
    """The PrecisionPlan persisted with the checkpoint (None for
    checkpoints written without one)."""
    loc, sharded = _resolve(path)
    if sharded:
        meta = read_meta(loc)
        plan = meta.get("plan")
    else:
        _, meta = _legacy_load(loc)
        plan = meta.get("plan")
    return PrecisionPlan.from_json_dict(plan) if plan is not None else None


def load_extra(path: str) -> dict:
    """Free-form ``extra`` state stored with a sharded checkpoint (e.g.
    the data pipeline's resumable iterator position). Legacy ``.npz``
    checkpoints have none — returns ``{}``."""
    loc, sharded = _resolve(path)
    if not sharded:
        return {}
    return read_meta(loc).get("extra") or {}
