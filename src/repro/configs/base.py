"""Model / run configuration system.

``ModelConfig`` is the single composable description every subsystem reads:
model definition, TP/FSDP sharding hints, precision-group layout for AWP,
and serving geometry.  One file per assigned architecture lives next to
this module; ``repro.configs.registry`` maps ``--arch`` ids to configs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal, Sequence

ArchType = Literal["dense", "moe", "vlm", "ssm", "hybrid", "audio", "cnn"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: ArchType
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention flavour -------------------------------------------------
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rotary_pct: float = 1.0          # chatglm-style partial ("2d") rotary: 0.5
    sliding_window: int | None = None  # SWA window (mixtral 4096, rg local 2048)
    causal: bool = True              # False -> encoder-only (hubert)
    cross_attn_every: int = 0        # VLM: every k-th layer cross-attends
    num_image_tokens: int = 0
    vision_dim: int = 0              # stub frontend embedding width

    # --- channel mixer -----------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dense_ff: int = 0            # arctic: parallel dense residual MLP
    moe_impl: Literal["tp", "ep"] = "tp"

    # --- recurrent families --------------------------------------------------
    # block_pattern: cycle of per-layer mixer kinds; "attn" | "local" |
    # "cross" | "mlstm" | "slstm" | "rglru".  Empty -> all "attn".
    block_pattern: tuple[str, ...] = ()
    lru_dim: int = 0                 # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4            # temporal conv in RG-LRU block
    mlstm_proj_factor: float = 2.0   # xLSTM up-projection factor

    # --- embeddings / output -------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    embed_is_input_stub: bool = False  # audio/vlm-frontend: inputs are embeddings

    # --- AWP / distribution hints -------------------------------------------
    num_precision_groups: int = 4    # AWP group granularity (paper: block level)
    scan_layers: bool = True         # lax.scan over homogeneous layer groups
    remat: bool = True               # activation checkpointing per layer

    # ------------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError("num_heads must be divisible by num_kv_heads")
        if self.block_pattern:
            if self.num_layers % len(self.block_pattern):
                raise ValueError(
                    f"{self.name}: num_layers ({self.num_layers}) must be a "
                    f"multiple of the block pattern ({len(self.block_pattern)})"
                )

    @property
    def pattern(self) -> tuple[str, ...]:
        if self.block_pattern:
            return self.block_pattern
        if self.cross_attn_every:
            pat = ["attn"] * self.cross_attn_every
            pat[-1] = "cross"
            return tuple(pat)
        return ("attn",)

    @property
    def layers_per_group(self) -> int:
        """Layers per scanned precision group (AWP granularity)."""
        pat = len(self.pattern)
        groups = min(self.num_precision_groups, self.num_layers // pat)
        per = self.num_layers // (groups * pat) * pat
        return per

    @property
    def num_groups(self) -> int:
        return self.num_layers // self.layers_per_group

    @property
    def is_decoder(self) -> bool:
        return self.causal

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: recurrent state or a (native/variant)
        sliding window. All our attention archs get a window *variant* for
        long_500k (DESIGN.md §5); encoder-only archs don't decode at all."""
        return self.is_decoder

    def active_params(self) -> int:
        """Approximate active parameter count (MoE: top_k experts)."""
        return self._param_count(active_only=True)

    def total_params(self) -> int:
        return self._param_count(active_only=False)

    def _param_count(self, active_only: bool) -> int:
        d, hd = self.d_model, self.head_dim
        h, kv = self.num_heads, self.num_kv_heads
        per_layer = 0
        counts = {}
        for kind in self.pattern:
            counts[kind] = counts.get(kind, 0) + 1
        reps = self.num_layers // len(self.pattern)
        for kind, n in counts.items():
            n *= reps
            if kind in ("attn", "local", "cross"):
                attn = d * hd * h + 2 * d * hd * kv + hd * h * d  # q,k,v,o
                per_layer += n * attn
            elif kind == "mlstm":
                dv = int(self.mlstm_proj_factor * d)
                per_layer += n * (d * dv * 3 + dv * d + 3 * d * dv // hd)
            elif kind == "slstm":
                per_layer += n * (8 * d * d // max(1, self.num_heads))
            elif kind == "rglru":
                dr = self.lru_dim or d
                per_layer += n * (2 * d * dr + dr * d + 2 * dr)
            if kind in ("attn", "local", "cross"):
                if self.num_experts:
                    e = self.top_k if active_only else self.num_experts
                    per_layer += n * (3 * d * self.d_ff * e)
                    if self.moe_dense_ff:
                        per_layer += n * 3 * d * self.moe_dense_ff
                elif self.d_ff:
                    per_layer += n * 3 * d * self.d_ff
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return per_layer + embed


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (workload) input geometry."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]
    window: int | None = None  # decode window override for long-context


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode", window=4_096),
}
