"""Repo invariant linter — a small AST rule engine.

The auditor (:mod:`repro.audit`) proves the *traced programs* move the
bytes the plan promised; this linter pins the *source-level* invariants
that keep that proof meaningful: collectives and host<->device staging
only happen inside the priced modules, kernels dispatch through the one
interpret-mode resolver, library error paths raise typed exceptions,
and nothing in-repo calls its own deprecation shims.

Rules are small classes with a ``check(file)`` hook (see
:mod:`tools.lint.rules`); repo-level rules (docs freshness) implement
``check_repo(root)`` instead. Findings are suppressed per line with

    # lint: allow(RULE-NAME): reason why the raw form is the contract

The reason is mandatory: a bare ``allow`` is itself reported. The
suppression binds to its own line or, on a comment-only line, to the
line below.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Iterable

ROOT = pathlib.Path(__file__).resolve().parent.parent.parent
#: directories whose .py files the AST rules walk (library + tooling;
#: tests are exempt: raw collectives / asserts are their idiom)
LINT_DIRS = ("src", "tools")

SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*allow\(([A-Z0-9-]+)\)\s*(?::\s*(\S.*))?"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str  # repo-relative
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"


@dataclasses.dataclass
class SourceFile:
    """Parsed view of one file handed to every AST rule."""

    path: pathlib.Path
    rel: str
    text: str
    tree: ast.AST
    lines: list[str]


class Rule:
    """Base rule. AST rules override ``check``; repo-level rules
    override ``check_repo`` (called once, not per file)."""

    name: str = ""
    description: str = ""

    def check(self, f: SourceFile) -> Iterable[Finding]:
        return ()

    def check_repo(self, root: pathlib.Path) -> Iterable[Finding]:
        return ()


def _iter_files(root: pathlib.Path, paths=None):
    if paths:
        cand = [pathlib.Path(p) for p in paths]
    else:
        cand = []
        for d in LINT_DIRS:
            base = root / d
            if base.is_dir():
                cand.extend(sorted(base.rglob("*.py")))
    for p in cand:
        if p.is_file() and p.suffix == ".py":
            yield p


def parse_suppressions(lines: list[str], rel: str):
    """(line -> {rule: reason}) plus findings for reason-less allows.

    A suppression on a comment-only line covers the next line; on a
    code line it covers that line.
    """
    by_line: dict[int, dict[str, str]] = {}
    bad: list[Finding] = []
    for i, raw in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(raw)
        if not m:
            continue
        rule, reason = m.group(1), (m.group(2) or "").strip()
        if not reason:
            bad.append(Finding(
                "LINT-SUPPRESS", rel, i,
                f"allow({rule}) has no reason — suppressions must say "
                "why the flagged form is the contract",
            ))
            continue
        target = i + 1 if raw.split("#", 1)[0].strip() == "" else i
        by_line.setdefault(target, {})[rule] = reason
    return by_line, bad


def run_lint(rules, *, root: pathlib.Path = ROOT, paths=None):
    """Run every rule; returns surviving findings (suppressed removed,
    malformed suppressions added)."""
    findings: list[Finding] = []
    files: list[SourceFile] = []
    for p in _iter_files(root, paths):
        rel = str(p.relative_to(root)) if p.is_relative_to(root) else str(p)
        text = p.read_text()
        try:
            tree = ast.parse(text, filename=rel)
        except SyntaxError as e:
            findings.append(Finding(
                "PARSE", rel, e.lineno or 0, f"syntax error: {e.msg}"
            ))
            continue
        files.append(SourceFile(p, rel, text, tree, text.splitlines()))

    per_file: dict[str, list[Finding]] = {f.rel: [] for f in files}
    for rule in rules:
        for f in files:
            per_file[f.rel].extend(rule.check(f))
        findings.extend(rule.check_repo(root))

    for f in files:
        allows, bad = parse_suppressions(f.lines, f.rel)
        findings.extend(bad)
        for fd in per_file[f.rel]:
            if fd.rule in allows.get(fd.line, {}):
                continue
            findings.append(fd)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))
