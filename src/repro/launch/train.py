"""Production training launcher.

Selects an assigned architecture (``--arch``), builds the FSDP×TP mesh,
and runs the A²DTWP loop (AWP controller + ADT-compressed gathers) on the
synthetic pipeline. On this CPU container use ``--reduced`` plus a small
``--mesh``; on a real pod run the full config on 16x16 or 2x16x16.

Every precision knob rides one :class:`~repro.plan.PrecisionPlan`:
``--plan plan.json`` loads a declarative plan (the single source of
truth — checkpointed next to the AWP state), and the individual flags
(``--grad-round-to``, ``--act-round-to``, ``--seq-parallel``, ``--bf16``,
``--chunks``, ``--grad-mode``, AWP options) are sugar that builds the
same plan. ``--chunks auto`` picks the double-buffered gather chunk
count from the roofline sweep (``repro.plan.pick_chunks``).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --mesh 2x4 --steps 100 --policy awp
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --mesh 2x4 --steps 20 --plan plan.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import (
    AsyncCheckpointer, load_checkpoint, load_extra, save_checkpoint,
)
from repro.configs.registry import ARCHS, get_config, reduced
from repro.data.pipeline import synthetic_feature_batch, synthetic_lm_batch
from repro.data.prefetch import Prefetcher
from repro.data.shards import ShardReader, batches
from repro.dist.spec import (
    DIST, LeafSpec, MeshCfg, build_spec_tree, dist_elems_per_group,
    tree_to_storage,
)
from repro.roofline.analysis import train_ingest_bytes
from repro.launch.mesh import make_mesh_from_cfg
from repro.models.init import init_params
from repro.optim.sgd import SGDConfig, init_momentum
from repro.plan import PrecisionPlan, pick_chunks
from repro.train.loop import Trainer
from repro.train.step import make_train_step


def parse_mesh(spec: str) -> MeshCfg:
    """"1x1" | "<dp>x<tp>" | "<pods>x<dp>x<tp>"."""
    parts = [int(p) for p in spec.split("x")]
    if len(parts) == 2:
        return MeshCfg(tp=parts[1], dp=parts[0])
    if len(parts) == 3:
        return MeshCfg(tp=parts[2], dp=parts[1], pods=parts[0])
    raise SystemExit(f"bad --mesh {spec!r}")


def plan_from_args(args, nrt: int, spec_tree, mesh_cfg) -> PrecisionPlan:
    """CLI flags -> PrecisionPlan (``--plan`` wins outright)."""
    if args.plan:
        return PrecisionPlan.from_file(args.plan).broadcast(nrt)
    schedule = "awp"
    round_to = 4
    if args.policy == "baseline":
        schedule = "static"
    elif args.policy.startswith("oracle:"):
        schedule = "static"
        round_to = int(args.policy.split(":")[1])
    elif args.policy != "awp":
        raise SystemExit(f"bad --policy {args.policy!r}")
    if args.chunks == "auto":
        # representative shard: the largest per-group flat shard length
        s_loc = max(
            (s.s_loc for s in jax.tree_util.tree_leaves(
                spec_tree, is_leaf=lambda x: isinstance(x, LeafSpec)
            ) if isinstance(s, LeafSpec) and s.kind == DIST),
            default=0,
        )
        chunks = pick_chunks(
            s_loc, max(mesh_cfg.dshards, 1),
            round_to if schedule == "static" else 1,
        )
        print(f"--chunks auto -> {chunks} (roofline sweep, s_loc={s_loc})")
    else:
        chunks = int(args.chunks)
    return PrecisionPlan.build(
        nrt,
        round_to=round_to,
        grad_round_to=args.grad_round_to,
        grad_mode=args.grad_mode,
        act_round_to=args.act_round_to,
        seq_parallel=args.seq_parallel,
        chunks=chunks,
        dtype="bf16" if args.bf16 else "f32",
        accum_steps=args.accum,
        schedule=schedule,
        awp_threshold=args.awp_threshold,
        awp_interval=args.awp_interval,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--plan", default="",
                    help="PrecisionPlan JSON: the declarative source of "
                         "truth for every precision knob (other precision "
                         "flags are ignored when set)")
    ap.add_argument("--policy", default="awp",
                    help="awp | baseline | oracle:<rt> (plan-builder sugar)")
    ap.add_argument("--awp-threshold", type=float, default=1e-3)
    ap.add_argument("--awp-interval", type=int, default=25)
    ap.add_argument("--bf16", action="store_true")
    ap.add_argument("--grad-round-to", type=int, default=4)
    ap.add_argument("--grad-mode", default="nearest",
                    choices=["truncate", "nearest", "stochastic"],
                    help="rounding of the compressed gradient "
                         "reduce-scatter (stochastic plumbs a per-step "
                         "PRNG key through the step)")
    ap.add_argument("--act-round-to", type=int, default=4,
                    help="activation wire format on the TP axis (<4 routes "
                         "TP psums and seq collectives through packed planes)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel activations: norms/residuals on "
                         "1/tp sequence shards, block boundaries become "
                         "seq_gather/seq_scatter (requires seq %% tp == 0)")
    ap.add_argument("--chunks", default="1",
                    help="weight-gather chunk count (int, or 'auto' to pick "
                         "from the roofline sweep)")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="also checkpoint every N steps (0 = only final); "
                         "each save stores the data-pipeline iterator "
                         "state so --resume replays the exact batch stream")
    ap.add_argument("--async-ckpt", action="store_true",
                    help="serialize checkpoints on a worker thread, "
                         "overlapped with the next train step")
    ap.add_argument("--resume", action="store_true",
                    help="restore storage/momentum/AWP/data state from "
                         "--ckpt and continue to --steps")
    ap.add_argument("--data-dir", default="",
                    help="ingest from a tiered shard dir (repro.data.write) "
                         "through the double-buffered prefetcher instead of "
                         "generating batches inline")
    ap.add_argument("--data-quality", type=int, default=4,
                    help="progressive-record tier: float payloads read only "
                         "their N most significant byte planes (ids are "
                         "always lossless)")
    ap.add_argument("--prefetch-depth", type=int, default=2)
    ap.add_argument("--losses-out", default="",
                    help="write the per-step loss stream as JSON (the "
                         "artifact --check compares against)")
    ap.add_argument("--check", default="",
                    help="reference losses JSON: verify this run's losses "
                         "are bit-exact on overlapping steps (resume "
                         "determinism) and exit nonzero otherwise")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh_cfg = parse_mesh(args.mesh)
    if mesh_cfg.tp * mesh_cfg.dshards > len(jax.devices()):
        raise SystemExit(
            f"mesh {args.mesh} needs {mesh_cfg.tp * mesh_cfg.dshards} devices, "
            f"have {len(jax.devices())} (set XLA_FLAGS=--xla_force_host_"
            f"platform_device_count=N)"
        )
    mesh = make_mesh_from_cfg(mesh_cfg)

    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    nrt = cfg.num_groups + 1
    plan = plan_from_args(args, nrt, spec_tree, mesh_cfg)
    print(f"{cfg.name}: {n/1e6:.1f}M params, mesh {mesh_cfg.shape}, "
          f"schedule {plan.schedule.source}, rts {plan.round_tos}")

    B, S = args.batch, args.seq
    audio = cfg.embed_is_input_stub
    if audio:
        batch_shapes = {
            "features": jax.ShapeDtypeStruct((B, S, cfg.vision_dim), jnp.float32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    else:
        batch_shapes = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if cfg.num_image_tokens:
        batch_shapes["image_features"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.vision_dim), jnp.float32
        )

    opt = SGDConfig(lr=args.lr, momentum=0.9, weight_decay=1e-4)

    def builder(round_tos):
        return make_train_step(
            cfg, mesh_cfg, mesh, spec_tree, opt, batch_shapes,
            plan=plan.with_round_tos(round_tos),
        )

    trainer = Trainer(
        builder, nrt, plan=plan,
        dist_elems_per_group=dist_elems_per_group(spec_tree, mesh_cfg, nrt),
        gather_axis_size=max(mesh_cfg.dshards, 1),
    )
    mom = init_momentum(storage)

    # -- resume: storage/momentum/AWP state + data iterator position ----
    start_step = 0
    data_state = None
    if args.resume:
        if not args.ckpt:
            raise SystemExit("--resume needs --ckpt")
        storage, mom, start_step = load_checkpoint(
            args.ckpt, storage, mom, trainer.controller
        )
        data_state = load_extra(args.ckpt).get("data_state")
        print(f"resumed {args.ckpt} at step {start_step}")
    if start_step >= args.steps:
        raise SystemExit(f"checkpoint step {start_step} >= --steps {args.steps}")

    # -- data source: tiered shards through the prefetcher, or inline ---
    reader = prefetcher = None
    ingest_plan = None
    if args.data_dir:
        reader = ShardReader(
            args.data_dir, quality=args.data_quality, seed=0
        )
        want_kind = "feature" if audio else "lm"
        if reader.kind != want_kind:
            raise SystemExit(
                f"--data-dir holds {reader.kind!r} shards, arch needs "
                f"{want_kind!r}"
            )
        for key, want in (("vocab", cfg.vocab_size), ("seq", S)):
            got = reader.meta.get(key)
            if got is not None and got != want:
                raise SystemExit(
                    f"--data-dir {key}={got} does not match run {key}={want}"
                )
        if data_state is not None:
            reader.load_state(data_state)
        # analytic ingest model from the reader's CURRENT position —
        # must be priced before the prefetcher starts reading ahead
        ingest_plan = train_ingest_bytes(
            plan, cfg.vocab_size, kind=reader.kind, batch=B, seq=S,
            steps=args.steps - start_step, dim=cfg.vision_dim,
            reader=reader,
        )
        prefetcher = Prefetcher(
            batches(reader, B), kind=reader.kind, vocab=cfg.vocab_size,
            plan=plan, depth=args.prefetch_depth,
        )

    async_ckpt = AsyncCheckpointer() if args.async_ckpt else None

    def checkpoint(step):
        save_checkpoint(
            args.ckpt, storage, mom, trainer.controller, step, plan=plan,
            spec_tree=spec_tree, round_tos=trainer.current_round_tos(),
            extra={"data_state": data_state} if data_state else None,
            async_ckpt=async_ckpt,
        )

    rngi = np.random.default_rng(0)
    ctx = mesh if mesh is not None else _null()
    t0 = time.time()
    done = 0
    with ctx:
        for step in range(start_step, args.steps):
            io_log = None
            if prefetcher is not None:
                batch, io_log = prefetcher.next()
                data_state = io_log["data_state"]
            elif audio:
                f, l = synthetic_feature_batch(
                    cfg.vision_dim, cfg.vocab_size, B, S, step
                )
                batch = {"features": f, "labels": l}
            else:
                t, l = synthetic_lm_batch(cfg.vocab_size, B, S, step)
                batch = {"tokens": t, "labels": l}
            if cfg.num_image_tokens and "image_features" not in batch:
                batch["image_features"] = jnp.asarray(
                    rngi.normal(0, 1, (B, cfg.num_image_tokens, cfg.vision_dim)),
                    jnp.float32,
                )
            extra = (
                (jax.random.PRNGKey(step),) if plan.needs_rng else ()
            )
            storage, mom, _ = trainer.run_step(
                storage, mom, batch, args.lr, *extra, io_log=io_log
            )
            done += 1
            if args.ckpt and args.ckpt_every and (
                (step + 1) % args.ckpt_every == 0 and step + 1 < args.steps
            ):
                checkpoint(step + 1)
            if done % 20 == 0:
                r = trainer.records[-1]
                print(f"step {step+1:4d}  loss {r.loss:.4f}  rts {r.round_tos}"
                      f"  wire {r.wire_bytes/1e6:.1f}MB"
                      f"  {(time.time()-t0)/done:.2f}s/step", flush=True)
    if prefetcher is not None:
        prefetcher.close()
        reader.close()
    s = trainer.summary()
    print(f"done: loss {s['final_loss']:.4f}  wire-reduction "
          f"{s['wire_reduction']*100:.1f}%  recompiles {s['recompiles']}")
    if "wire_by_entry" in s:
        entries = ", ".join(
            f"{k} {v/1e6:.1f}MB" for k, v in s["wire_by_entry"].items() if v
        )
        print(f"wire by plan entry: {entries}")
    if ingest_plan is not None and "io_by_entry" in s:
        io = s["io_by_entry"]
        measured = {
            "shard_read": io.get("shard_read", 0),
            "ingest_h2d": io.get("host_device", 0),
        }
        analytic = {k: ingest_plan[k] for k in measured}
        status = "OK" if measured == analytic else "MISMATCH"
        print(f"ingest bytes measured {measured} analytic {analytic} "
              f"[{status}]")
        if measured != analytic:
            raise SystemExit("measured ingest bytes != analytic model")
    print(f"AWP: {s['bits_history']}")
    if args.ckpt:
        checkpoint(args.steps)
        if async_ckpt is not None:
            async_ckpt.wait()
        print(f"checkpoint -> {args.ckpt} (plan + data state persisted)")

    losses = [r.loss for r in trainer.records]
    if args.losses_out:
        with open(args.losses_out, "w") as f:
            json.dump({"start_step": start_step, "losses": losses}, f)
        print(f"losses -> {args.losses_out}")
    if args.check:
        with open(args.check) as f:
            ref = json.load(f)
        mism = [
            (g, ref["losses"][g - ref["start_step"]], losses[g - start_step])
            for g in range(
                max(start_step, ref["start_step"]),
                min(start_step + len(losses),
                    ref["start_step"] + len(ref["losses"])),
            )
            if ref["losses"][g - ref["start_step"]] != losses[g - start_step]
        ]
        if mism:
            for g, a, b in mism[:5]:
                print(f"step {g}: ref {a!r} != run {b!r}")
            raise SystemExit(
                f"--check: {len(mism)} loss mismatches vs {args.check}"
            )
        print(f"--check OK: losses bit-exact vs {args.check}")


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
