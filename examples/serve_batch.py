"""Serve a small model with batched requests: prefill + decode loop.

Demonstrates the serving path end-to-end on CPU: compressed weight
placement (ADT), batched prefill building the KV caches, then a decode
loop producing tokens for the whole batch, with greedy sampling over the
(vocab-parallel in distributed mode) logits.

Run:  PYTHONPATH=src python examples/serve_batch.py --arch qwen3-1.7b \
          --requests 8 --prompt-len 48 --gen 24
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.models.init import init_params
from repro.plan import PrecisionPlan
from repro.serve.step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--round-to", type=int, default=2,
                    help="ADT wire format for weight placement")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=4096)
    B, S = args.requests, args.prompt_len
    cap = S + args.gen

    params, _metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, _metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    plan = PrecisionPlan.build(cfg.num_groups + 1, round_to=args.round_to)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
    )
    batch = {"tokens": prompts}
    if cfg.num_image_tokens:
        batch["image_features"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_image_tokens, cfg.vision_dim)),
            jnp.float32,
        )
    bshapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}

    prefill = make_prefill_step(
        cfg, mesh_cfg, None, spec_tree, bshapes, plan=plan,
        cache_capacity=cap,
    )
    dshapes = {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    decode = make_decode_step(cfg, mesh_cfg, None, spec_tree, dshapes,
                              plan=plan)

    t0 = time.time()
    logits, caches = prefill(storage, batch)
    tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        step_batch = {
            "tokens": tok.astype(jnp.int32),
            "pos": jnp.asarray(S + i, jnp.int32),
        }
        logits, caches = decode(storage, caches, step_batch)
        tok = jnp.argmax(logits[:, 0, : cfg.vocab_size], axis=-1)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    total_new = gen.size
    print(f"arch={cfg.name}  requests={B}  prompt={S}  generated={args.gen}")
    print(f"prefill: {t_prefill:.2f}s   decode: {t_decode:.2f}s "
          f"({total_new / max(t_decode, 1e-9):.1f} tok/s on CPU, "
          f"first decode step includes compile)")
    print(f"weight placement format: {args.round_to} bytes/weight "
          f"({4 / args.round_to:.1f}x motion reduction vs fp32)")
    print("sample generations (token ids):")
    for b in range(min(B, 4)):
        print(f"  req{b}: {gen[b][:16].tolist()}")


if __name__ == "__main__":
    main()
