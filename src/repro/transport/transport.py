"""The compression transport: pack -> collective -> unpack pipelines.

This module owns every compressed byte that crosses a mesh link:

  * :func:`all_gather` — weight path. fp32 shard -> byte planes (Pallas
    bitpack on TPU, oracle on CPU) -> plane all-gather over the FSDP axes
    -> bitunpack. Its custom VJP is a (optionally compressed)
    reduce-scatter, so training steps just call it and get the paper's
    weight/gradient motion for free.
  * :func:`reduce_scatter` — gradient path (beyond-paper): pack the chunk
    destined for each peer, ``all_to_all`` the planes, unpack and reduce
    locally in fp32. Handles arbitrary-rank leaves and any scatter axis
    (placed / stacked storage included); the reshape to per-peer plane
    blocks happens here, never at call sites.
  * :func:`seq_gather` / :func:`seq_scatter` — activation path (TP axis).
    The sequence-parallel conjugate pair: compressed all-gather along the
    sequence dim with a compressed reduce-scatter VJP, and vice versa.
    Dtype-preserving (bf16 activations round-trip through an exact fp32
    cast before packing).
  * :func:`all_reduce` — compressed all-reduce, decomposed into
    reduce-scatter + all-gather of packed planes along a divisible split
    axis. NOT differentiable by design: it is the forward/cotangent mover
    inside the TP-region custom VJPs (``core.collectives``), whose
    transposes must stay pinned to identity to avoid double-counting.
  * :func:`quantize` — single-device format truncation (pack∘unpack) with
    a straight-through VJP: what the compute side sees when there is no
    collective to ride on.

Kernel dispatch is backend-aware: ``CompressionPolicy.impl="auto"`` lowers
the Pallas kernels compiled on TPU and falls back to the pure-jnp oracle on
CPU (where the distributed steps want pure-HLO collectives); ``"pallas"``
forces the kernels, running them in interpret mode off-TPU. Both impls are
bit-exact by construction (same byte-plane semantics), which
``tests/test_transport.py`` locks in.

The chunked path (``policy.chunks > 1``) splits the gather into
independent pack -> all-gather -> unpack block pipelines so XLA's async
collectives can overlap block k's wire time with block k±1's pack/unpack
(double buffering), then re-interleaves the blocks to the exact layout of
the unchunked gather.

Wire formats per entry point (see docs/collectives.md for the plane
layout and a worked byte example): weight-path forwards move
``policy.round_to`` bytes/element, gradient/cotangent paths
``policy.grad_round_to``; ``seq_gather``/``seq_scatter`` forwards use the
policy's forward fields and their VJPs the grad fields, so one activation
policy describes both directions of the TP axis.
"""
from __future__ import annotations

import functools
from typing import Hashable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels import ref
from repro.kernels.bitpack import BLOCK_ROWS, LANES, bitpack_2d
from repro.kernels.bitunpack import bitunpack_2d
from repro.transport.policy import FP32_BYTES, CompressionPolicy, policy_for
from repro.utils.trees import round_up

AxisNames = Hashable | Sequence[Hashable]


# ---------------------------------------------------------------------------
# mesh-axis helpers
# ---------------------------------------------------------------------------


def _one_axis_size(name) -> int:
    if hasattr(lax, "axis_size"):  # jax >= 0.5
        return lax.axis_size(name)
    import jax.core as jcore  # 0.4.x: axis_frame resolves to the bound size

    frame = jcore.axis_frame(name)
    return int(getattr(frame, "size", frame))


def axis_size(axis_names: AxisNames) -> int:
    """Static total size of one axis name or a tuple of axis names."""
    if isinstance(axis_names, (tuple, list)):
        total = 1
        for a in axis_names:
            total *= _one_axis_size(a)
        return total
    return _one_axis_size(axis_names)


def resolve_impl(impl: str, mode: str = "truncate") -> str:
    """auto -> pallas on TPU, ref on CPU. Rounding modes other than
    truncation need PRNG/word-level arithmetic and live in the ref path."""
    if mode != "truncate":
        return "ref"
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


# ---------------------------------------------------------------------------
# pack / unpack dispatch (exact-shape planes)
# ---------------------------------------------------------------------------


def pack_planes(
    w: jnp.ndarray,
    round_to: int,
    *,
    mode: str = "truncate",
    impl: str = "auto",
    key=None,
) -> jnp.ndarray:
    """fp32 array (any shape) -> uint8 byte planes ``(round_to, *w.shape)``.

    Plane 0 is the most significant byte. The Pallas path pads to the
    kernel's tile internally and slices back, so the planes returned are
    always exact-shape — safe to feed straight into a collective.
    """
    if resolve_impl(impl, mode) == "ref":
        return ref.bitpack_ref(w, round_to, mode=mode, key=key)
    flat = w.reshape(-1)
    n = flat.shape[0]
    tile = BLOCK_ROWS * LANES
    padded = round_up(max(n, 1), tile)
    flat = jnp.pad(flat, (0, padded - n))
    # interpret mode resolves inside the kernel wrapper (backend-aware)
    planes = bitpack_2d(flat.reshape(-1, LANES), round_to)
    return planes.reshape(round_to, padded)[:, :n].reshape(
        (round_to,) + w.shape
    )


def unpack_planes(planes: jnp.ndarray, *, impl: str = "auto") -> jnp.ndarray:
    """uint8 byte planes ``(round_to, *shape)`` -> fp32 ``shape``."""
    if resolve_impl(impl) == "ref":
        return ref.bitunpack_ref(planes)
    round_to = planes.shape[0]
    shape = planes.shape[1:]
    flat = planes.reshape(round_to, -1)
    n = flat.shape[1]
    tile = BLOCK_ROWS * LANES
    padded = round_up(max(n, 1), tile)
    flat = jnp.pad(flat, ((0, 0), (0, padded - n)))
    out = bitunpack_2d(flat.reshape(round_to, -1, LANES))
    return out.reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# forward implementations
# ---------------------------------------------------------------------------


def _packed_all_gather(x, axis_names, round_to, mode, impl, axis: int,
                       key=None):
    """Compressed all-gather of an arbitrary-rank array along ``axis``.

    Dtype-preserving: non-fp32 inputs (bf16 activations) are cast to fp32
    — exactly — before packing and the unpacked result is cast back.
    ``key`` feeds stochastic rounding (required iff mode="stochastic").
    """
    axis = axis % x.ndim  # planes prepend a dim: negatives must resolve first
    out_dtype = x.dtype
    xf = x.astype(jnp.float32)
    planes = pack_planes(xf, round_to, mode=mode, impl=impl, key=key)
    # planes prepend the plane dim, so the data axis shifts by one
    planes_g = lax.all_gather(planes, axis_names, axis=axis + 1, tiled=True)
    return unpack_planes(planes_g, impl=impl).astype(out_dtype)


def _packed_reduce_scatter(g, axis_names, round_to, mode, impl, axis: int,
                           key=None):
    """Compressed reduce-scatter of an arbitrary-rank array along ``axis``.

    The scatter dim is split into per-peer plane blocks *here* — call
    sites never reshape. Each peer's block is packed, the planes ride one
    ``all_to_all`` (single- or multi-axis), and the unpacked
    contributions are accumulated locally in fp32 before casting back to
    the input dtype. Trailing dims are unconstrained; only the scatter
    dim must divide by the axis size (inherent to reduce-scatter).
    """
    axis = axis % g.ndim  # moveaxis target 0 below: resolve negatives first
    size = axis_size(axis_names)
    length = g.shape[axis]
    if length % size:
        raise ValueError(
            f"scatter dim {axis} of shape {g.shape} not divisible by "
            f"axis size {size}"
        )
    out_dtype = g.dtype
    gm = jnp.moveaxis(g.astype(jnp.float32), axis, 0)
    gm = gm.reshape((size, length // size) + gm.shape[1:])
    planes = pack_planes(gm, round_to, mode=mode, impl=impl, key=key)
    # (round_to, size, loc, ...): exchange the `size` dim; after the
    # all_to_all the exchanged dim stays `size` (= one block per peer).
    planes_x = lax.all_to_all(
        planes, axis_names, split_axis=1, concat_axis=1, tiled=False
    )
    contribs = unpack_planes(planes_x, impl=impl)
    out = jnp.sum(contribs, axis=0)  # fp32 accumulation
    return jnp.moveaxis(out, 0, axis).astype(out_dtype)


def _all_gather_impl(w, axis_names, policy: CompressionPolicy, axis: int,
                     key=None):
    if not policy.compresses:
        return lax.all_gather(w, axis_names, axis=axis, tiled=True)
    if (
        policy.chunks > 1
        and axis == 0
        and w.ndim == 1
        and w.shape[0] % policy.chunks == 0
    ):
        return _chunked_all_gather(w, axis_names, policy, key)
    return _packed_all_gather(
        w, axis_names, policy.round_to, policy.mode, policy.impl, axis,
        key=key,
    )


def _chunked_all_gather(w, axis_names, policy: CompressionPolicy, key=None):
    """Double-buffered gather: independent per-block plane pipelines,
    re-interleaved to match the unchunked layout exactly."""
    n_chunks = policy.chunks
    loc = w.shape[0] // n_chunks
    gathered = []
    for c in range(n_chunks):
        piece = lax.slice_in_dim(w, c * loc, (c + 1) * loc)
        planes = pack_planes(
            piece, policy.round_to, mode=policy.mode, impl=policy.impl,
            key=None if key is None else jax.random.fold_in(key, c),
        )
        planes_g = lax.all_gather(planes, axis_names, axis=1, tiled=True)
        gathered.append(unpack_planes(planes_g, impl=policy.impl))
    # gathered[c] = concat_d shard_d[block c]; the full gather is
    # concat_d concat_c shard_d[block c] — transpose (chunk, device) out.
    n_dev = axis_size(axis_names)
    stacked = jnp.stack(gathered, 0).reshape(n_chunks, n_dev, loc)
    return jnp.transpose(stacked, (1, 0, 2)).reshape(-1)


def _reduce_scatter_impl(g, axis_names, policy: CompressionPolicy, axis: int,
                         key=None):
    if not policy.compresses_grads:
        return lax.psum_scatter(
            g, axis_names, scatter_dimension=axis, tiled=True
        )
    return _packed_reduce_scatter(
        g, axis_names, policy.grad_round_to, policy.grad_mode, policy.impl,
        axis, key=key,
    )


def _seq_gather_impl(x, axis_names, policy: CompressionPolicy, axis: int):
    if not policy.compresses:
        return lax.all_gather(x, axis_names, axis=axis, tiled=True)
    return _packed_all_gather(
        x, axis_names, policy.round_to, policy.mode, policy.impl, axis
    )


def _seq_scatter_impl(x, axis_names, policy: CompressionPolicy, axis: int):
    # forward activation path: the policy's *forward* format fields
    if not policy.compresses:
        return lax.psum_scatter(
            x, axis_names, scatter_dimension=axis, tiled=True
        )
    return _packed_reduce_scatter(
        x, axis_names, policy.round_to, policy.mode, policy.impl, axis
    )


def pick_split_axis(shape, size: int) -> int | None:
    """Rightmost dim divisible by ``size`` — the axis the compressed
    all-reduce decomposition splits along (rightmost so the per-peer
    blocks stay contiguous in the activation layout (B, S, d): feature
    dim first, then sequence, then batch). None = no divisible dim; the
    caller falls back to an uncompressed ``lax.psum``."""
    for a in reversed(range(len(shape))):
        if shape[a] >= size and shape[a] % size == 0:
            return a
    return None


def _all_reduce_impl(
    x, axis_names, policy: CompressionPolicy, use_grad_format: bool
):
    rt = policy.grad_round_to if use_grad_format else policy.round_to
    mode = policy.grad_mode if use_grad_format else policy.mode
    if rt >= FP32_BYTES:
        # same barrier as the uncompressed TP-region paths: keeps the
        # psum in the compute dtype (stops the CPU backend's
        # excess-precision pass from cancelling a bf16 down-cast)
        return lax.psum(lax.optimization_barrier(x), axis_names)
    size = axis_size(axis_names)
    axis = pick_split_axis(x.shape, size)
    if axis is None:
        return lax.psum(lax.optimization_barrier(x), axis_names)
    part = _packed_reduce_scatter(x, axis_names, rt, mode, policy.impl, axis)
    return _packed_all_gather(part, axis_names, rt, mode, policy.impl, axis)


def _quantize_impl(w, policy: CompressionPolicy, key=None):
    if not policy.compresses:
        # rt=4 keeps every byte: rounding is a no-op regardless of mode
        return w
    planes = pack_planes(
        w, policy.round_to, mode=policy.mode, impl=policy.impl, key=key
    )
    return unpack_planes(planes, impl=policy.impl)


def _key_cotangent(key):
    """Cotangent for an (integer) PRNG-key primal in a custom VJP: the
    zero of jax's float0 — integer inputs carry no tangent."""
    if key is None:
        return None
    return np.zeros(np.shape(key), jax.dtypes.float0)


# fold id of the backward (cotangent) pack. Deliberately outside the
# forward chunked gather's per-chunk fold range (0..chunks-1) so forward
# and backward stochastic-rounding noise never share a stream.
_BWD_FOLD = 0x62776421


# ---------------------------------------------------------------------------
# differentiable entry points
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def all_gather(
    w_local: jnp.ndarray,
    axis_names: AxisNames,
    policy: CompressionPolicy,
    axis: int = 0,
    key=None,
) -> jnp.ndarray:
    """Compressed all-gather with a reduce-scatter VJP.

    Forward moves ``policy.round_to`` of every fp32 byte over
    ``axis_names``; backward reduce-scatters the cotangent at
    ``policy.grad_round_to`` (4 = uncompressed, paper-faithful). The
    format itself is not differentiated — straight-through, like the
    paper's fp32 master-weight update.

    ``key`` is the stochastic-rounding PRNG key (a primal input so it
    can reach the backward pack: the forward uses it as-is — folded per
    chunk when chunked — and the cotangent reduce-scatter packs with a
    dedicated fold outside the chunk range). Required exactly when a
    used direction has ``mode="stochastic"``.
    """
    return _all_gather_impl(w_local, axis_names, policy, axis, key)


def _ag_fwd(w_local, axis_names, policy, axis, key):
    return _all_gather_impl(w_local, axis_names, policy, axis, key), key


def _ag_bwd(axis_names, policy, axis, key, g):
    gkey = None if key is None else jax.random.fold_in(key, _BWD_FOLD)
    return (
        _reduce_scatter_impl(g, axis_names, policy, axis, key=gkey),
        _key_cotangent(key),
    )


all_gather.defvjp(_ag_fwd, _ag_bwd)


def reduce_scatter(
    g: jnp.ndarray,
    axis_names: AxisNames,
    policy: CompressionPolicy,
    axis: int = 0,
    key=None,
) -> jnp.ndarray:
    """Compressed reduce-scatter along ``axis`` (default 0: the flat
    gradient path, ``(S,)`` -> ``(S_loc,)``).

    Any rank is accepted — stacked leaves scatter their flat dim at
    ``axis=1``, placed activations their sequence dim — with the reshape
    to per-peer plane blocks handled inside the transport. Wire format is
    ``policy.grad_round_to`` bytes; rounding defaults to *nearest* (not
    the paper's truncation) because gradient sums are bias-sensitive.
    ``grad_mode="stochastic"`` needs ``key``.
    """
    return _reduce_scatter_impl(g, axis_names, policy, axis, key=key)


# -- activation path (TP axis) ----------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def seq_gather(
    x: jnp.ndarray,
    axis_names: AxisNames,
    policy: CompressionPolicy,
    axis: int = 1,
) -> jnp.ndarray:
    """Sequence-parallel enter: compressed all-gather of activation
    shards along ``axis`` (1 = sequence), with a compressed
    reduce-scatter VJP.

    Forward moves ``policy.round_to`` of every fp32 byte; the cotangent
    rides the same packed-plane pipeline at ``policy.grad_round_to``.
    Dtype-preserving (bf16 activations cast exactly through fp32).
    """
    return _seq_gather_impl(x, axis_names, policy, axis)


def _sg_fwd(x, axis_names, policy, axis):
    return _seq_gather_impl(x, axis_names, policy, axis), None


def _sg_bwd(axis_names, policy, axis, _, g):
    return (_reduce_scatter_impl(g, axis_names, policy, axis),)


seq_gather.defvjp(_sg_fwd, _sg_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def seq_scatter(
    x: jnp.ndarray,
    axis_names: AxisNames,
    policy: CompressionPolicy,
    axis: int = 1,
) -> jnp.ndarray:
    """Sequence-parallel exit: compressed reduce-scatter of partial
    activations along ``axis``, with a compressed all-gather VJP.

    Forward packs each peer's block at ``policy.round_to`` bytes
    (contributions are summed in fp32 *after* unpacking — planes are
    never added); the cotangent all-gathers at ``policy.grad_round_to``.
    """
    return _seq_scatter_impl(x, axis_names, policy, axis)


def _ss_fwd(x, axis_names, policy, axis):
    return _seq_scatter_impl(x, axis_names, policy, axis), None


def _ss_bwd(axis_names, policy, axis, _, g):
    if not policy.compresses_grads:
        return (lax.all_gather(g, axis_names, axis=axis, tiled=True),)
    return (
        _packed_all_gather(
            g, axis_names, policy.grad_round_to, policy.grad_mode,
            policy.impl, axis,
        ),
    )


seq_scatter.defvjp(_ss_fwd, _ss_bwd)


def all_reduce(
    x: jnp.ndarray,
    axis_names: AxisNames,
    policy: CompressionPolicy,
    *,
    use_grad_format: bool = False,
) -> jnp.ndarray:
    """Compressed all-reduce: reduce-scatter + all-gather of packed
    planes along the rightmost divisible dim (``pick_split_axis``);
    uncompressed policies and shapes with no divisible dim fall back to
    ``lax.psum``.

    NOT differentiable on purpose: this is the data mover *inside* the
    TP-region custom VJPs (``core.collectives.tp_region_enter/exit``),
    whose transposes are pinned to identity — differentiating through
    the decomposition would re-introduce the replicated-operand
    double-count those VJPs exist to prevent. ``use_grad_format=True``
    selects the policy's grad fields (cotangent psums).
    """
    return _all_reduce_impl(x, axis_names, policy, use_grad_format)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantize(w: jnp.ndarray, policy: CompressionPolicy, key=None) -> jnp.ndarray:
    """Format truncation (pack∘unpack) with a straight-through VJP.
    ``key`` feeds stochastic rounding (trivial-mesh materialization)."""
    return _quantize_impl(w, policy, key)


def _q_fwd(w, policy, key):
    return _quantize_impl(w, policy, key), key


def _q_bwd(policy, key, g):
    return (g, _key_cotangent(key))


quantize.defvjp(_q_fwd, _q_bwd)


# ---------------------------------------------------------------------------
# object API
# ---------------------------------------------------------------------------


class Transport:
    """Pack -> collective -> unpack pipeline bound to a set of mesh axes.

    The functional forms above are what the custom-VJP machinery uses;
    this object is the ergonomic entry point for code that talks to one
    axis group repeatedly (steps, tests, benchmarks)::

        t = Transport(mesh_cfg.fsdp_axes)
        w_full = t.all_gather(w_shard, policy)        # differentiable
        g_shard = t.reduce_scatter(g_full, policy)    # any rank, axis=...

        tp = Transport(mesh_cfg.model_axis)           # activation path
        x_full = tp.seq_gather(x_shard, act_policy)   # compressed fwd+bwd
        y_shard = tp.seq_scatter(y_partial, act_policy)
        y = tp.all_reduce(y_partial, act_policy)      # inside TP VJPs only
    """

    def __init__(self, axis_names: AxisNames):
        if isinstance(axis_names, list):
            axis_names = tuple(axis_names)
        self.axis_names = axis_names

    def all_gather(self, w, policy, *, axis: int = 0, key=None):
        return all_gather(w, self.axis_names, policy_for(policy), axis, key)

    def reduce_scatter(self, g, policy, *, axis: int = 0, key=None):
        return reduce_scatter(
            g, self.axis_names, policy_for(policy), axis, key
        )

    def seq_gather(self, x, policy, *, axis: int = 1):
        return seq_gather(x, self.axis_names, policy_for(policy), axis)

    def seq_scatter(self, x, policy, *, axis: int = 1):
        return seq_scatter(x, self.axis_names, policy_for(policy), axis)

    def all_reduce(self, x, policy, *, use_grad_format: bool = False):
        return all_reduce(
            x, self.axis_names, policy_for(policy),
            use_grad_format=use_grad_format,
        )

    def quantize(self, w, policy, *, key=None):
        return quantize(w, policy_for(policy), key)

    def axis_size(self) -> int:
        return axis_size(self.axis_names)
