"""Attribute traced collectives to PrecisionPlan traffic classes and pin
the jaxpr-derived wire bytes against the analytic byte model.

The attribution works from the transport's packing structure: a
compressed pipeline always moves ``uint8`` planes whose *leading dim is
the wire width* (bytes/element), so the jaxpr alone reveals the format
every collective used. The verifier then checks three things:

(a) **format** — every collective inside a compressing plan region moves
    uint8 planes at one of the plan's declared widths, never raw fp32;
(b) **inventory** — the collective multiset matches what the plan +
    parameter spec tree say must move (per-leaf weight gathers, gradient
    reduce-scatters, grad-sync psums, metric psums), with zero
    unattributed communication eqns left over;
(c) **bytes** — per traffic class, the jaxpr-derived ring wire bytes
    equal ``PrecisionPlan.wire_table``'s analytic bytes (the same
    numbers ``roofline.analysis`` charges), closing the
    measured/analytic/traced triangle.

Classes ``weights`` / ``gradients`` / ``grad_sync`` / ``metrics`` are
pinned against *independent* expectations derived from the spec tree —
a wrong wire dtype (e.g. fp32 where rt=2 planes were promised) diverges
by ``4/rt`` and fails. ``activations`` / ``seq_boundary`` eqn payloads
are only discoverable from the trace, so their pin is the width
contract: detected plane widths must be plan widths, raw psums are legal
only where the transport's own fallback rule (no tp-divisible dim, or an
uncompressed policy) permits them. ``relayout`` (lossless re-layout:
``seq_split`` / ``seq_merge``, EP-MoE token exchange) and
``host_device`` (no jaxpr carrier — the staging happens outside jit)
are accounting-only, and so are the fleet-fabric classes
``kv_migration`` / ``weight_publish``: their parcels cross *between*
processes (prefill worker -> decode replica, trainer -> replica), so no
jaxpr ever carries them — the measured side is the
``FabricChannel`` hop log, pinned EQUAL to
``roofline.analysis.fleet_migration_bytes`` by the fleet scenario.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from collections import Counter

import jax

from repro.audit.jaxpr import CommEqn, collect_comm_eqns
from repro.dist.spec import DIST, LeafSpec, MeshCfg, REPL, TP_SMALL
from repro.plan import PrecisionPlan
from repro.transport.policy import FP32_BYTES, ring_wire_bytes
from repro.transport.transport import pick_split_axis

_RING_KIND = {
    "all_gather": "all-gather",
    "all_to_all": "all-to-all",
    "reduce_scatter": "reduce-scatter",
    "psum": "all-reduce",
    "pmax": "all-reduce",
    "pmin": "all-reduce",
    "ppermute": "collective-permute",
}

# Verifiable step kinds; "place" runs the gathers once over whole leaves
TRAIN_KINDS = ("train", "cnn_train")
KINDS = ("train", "cnn_train", "prefill", "decode", "place")


class AuditError(Exception):
    """The traced program's data motion violates its plan. Carries the
    failing :class:`AuditReport` for inspection."""

    def __init__(self, report: "AuditReport"):
        self.report = report
        lines = "\n  ".join(report.violations)
        super().__init__(
            f"audit failed ({len(report.violations)} violation(s)):\n  {lines}"
        )


@dataclasses.dataclass
class ClassTotal:
    """Per-traffic-class byte tallies. ``structural=True`` marks classes
    whose analytic side is derived from the traced structure (payload
    geometry is unknowable without the trace); their verification
    content is the format/legality contract, not byte independence."""

    eqns: int = 0
    jaxpr_bytes: float = 0.0
    analytic_bytes: float = 0.0
    structural: bool = False

    def to_json_dict(self) -> dict:
        return {
            "eqns": self.eqns,
            "jaxpr_bytes": round(self.jaxpr_bytes),
            "analytic_bytes": round(self.analytic_bytes),
            "structural": self.structural,
        }


@dataclasses.dataclass
class AuditReport:
    kind: str
    mesh: str
    classes: dict
    violations: list
    n_comm_eqns: int
    notes: list

    @property
    def ok(self) -> bool:
        return not self.violations

    def raise_if_failed(self) -> "AuditReport":
        if not self.ok:
            raise AuditError(self)
        return self

    @property
    def total_jaxpr_bytes(self) -> int:
        return round(sum(c.jaxpr_bytes for c in self.classes.values()))

    @property
    def total_analytic_bytes(self) -> int:
        return round(sum(c.analytic_bytes for c in self.classes.values()))

    def to_json_dict(self) -> dict:
        return {
            "kind": self.kind,
            "mesh": self.mesh,
            "ok": self.ok,
            "n_comm_eqns": self.n_comm_eqns,
            "classes": {
                k: v.to_json_dict() for k, v in sorted(self.classes.items())
            },
            "violations": list(self.violations),
            "notes": list(self.notes),
        }


def _eqn_ring_bytes(e: CommEqn) -> float:
    kind = _RING_KIND[e.prim]
    payload = e.out_bytes if kind in ("all-gather", "all-to-all") else e.in_bytes
    return ring_wire_bytes(kind, payload, e.group_size) * e.mult


def _itemwidth(dtype_name: str) -> int:
    import numpy as np

    return int(np.dtype(dtype_name).itemsize)


# ---------------------------------------------------------------------------
# expected inventories (the spec-tree / plan side — independent of the trace)
# ---------------------------------------------------------------------------


def _iter_leaf_groups(spec_tree, num_entries, groups_info=None):
    """Yield ``(group_index, LeafSpec)`` for both parameter layouts:
    the LLM ``{"groups": [...], <top>}`` tree (top leaves ride the last
    entry) and the CNN ``{"layers": {name: ...}}`` tree (``groups_info``
    maps layer name -> group)."""
    is_leaf = lambda x: isinstance(x, LeafSpec)  # noqa: E731

    def leaves(sub):
        return [
            s for s in jax.tree_util.tree_leaves(sub, is_leaf=is_leaf)
            if isinstance(s, LeafSpec)
        ]

    if "groups" in spec_tree:
        for g, sub in enumerate(spec_tree["groups"]):
            for s in leaves(sub):
                yield g, s
        top = {k: v for k, v in spec_tree.items() if k != "groups"}
        for s in leaves(top):
            yield num_entries - 1, s
    elif "layers" in spec_tree and groups_info is not None:
        name_to_group = groups_info[0]
        for name, sub in spec_tree["layers"].items():
            for s in leaves(sub):
                yield name_to_group[name], s
    else:
        raise ValueError(
            "unrecognized spec tree layout (need 'groups', or 'layers' "
            "with groups_info)"
        )


def _local_psum_shape(s: LeafSpec, mesh_cfg: MeshCfg) -> tuple[int, ...]:
    """Per-device shape of a storage leaf inside the shard_map body —
    the operand shape of its grad-sync psum."""
    lead = (s.reps,) if s.stacked else ()
    if mesh_cfg.trivial or s.kind == REPL:
        return lead + tuple(s.logical)
    if s.kind == TP_SMALL:
        return lead + (1,) + tuple(s.local_logical)
    if s.meta.tp_dim is not None:
        return lead + (1, s.s_loc)
    return lead + (s.s_loc,)


@dataclasses.dataclass
class _Expected:
    """Multiset expectations keyed by observable jaxpr features."""

    # (payload_elems, wire_width) -> count
    weight_gathers: Counter
    grad_scatters: Counter
    # (shape, dtype) -> Counter of class tags ("grad_sync" | "metrics")
    dp_psums: dict
    model_psums: dict
    dist_elems: list


def _expected_inventory(
    plan: PrecisionPlan, mesh_cfg: MeshCfg, spec_tree, kind: str,
    groups_info=None,
) -> _Expected:
    policies = plan.weight_policies()
    num_entries = len(policies)
    n = mesh_cfg.dshards
    tp = mesh_cfg.tp
    train = kind in TRAIN_KINDS
    accum = plan.accum_steps if kind == "train" else 1

    weights: Counter = Counter()
    grads: Counter = Counter()
    dp_psums: dict = {}
    model_psums: dict = {}
    dist_elems = [0] * num_entries

    def add_psum(table, shape, dtype, tag, count=1):
        table.setdefault((tuple(shape), dtype), Counter())[tag] += count

    for g, s in _iter_leaf_groups(spec_tree, num_entries, groups_info):
        pol = policies[g]
        # model-axis grad sync is orthogonal to the storage kind:
        # _sync_grads applies it to every flagged leaf, DIST included
        # (compute-replicated leaves whose storage shards over the
        # model axis, e.g. mlstm wq/wk)
        if (
            kind == "train"
            and tp > 1
            and (
                s.meta.grad_sync_model
                or (plan.seq_parallel and s.meta.grad_sync_seq)
            )
        ):
            add_psum(
                model_psums, _local_psum_shape(s, mesh_cfg), "float32",
                "grad_sync",
            )
        if s.kind == DIST:
            s_pad = s.s_loc * max(n, 1)
            dist_elems[g] += s_pad
            if n <= 1:
                continue  # no gather axis: weights stage host->device
            width = pol.round_to if pol.compresses else FP32_BYTES
            chunked = (
                pol.compresses
                and pol.chunks > 1
                and s.s_loc % pol.chunks == 0
            )
            if kind == "place":
                if s.stacked:
                    weights[(s.reps * s_pad, width)] += 1
                elif chunked:
                    weights[(s_pad // pol.chunks, width)] += pol.chunks
                else:
                    weights[(s_pad, width)] += 1
                continue
            if chunked:
                weights[(s_pad // pol.chunks, width)] += (
                    s.reps * pol.chunks * accum
                )
            else:
                weights[(s_pad, width)] += s.reps * accum
            if train:
                gw = (
                    pol.grad_round_to
                    if pol.compresses_grads else FP32_BYTES
                )
                grads[(s_pad, gw)] += s.reps * accum
        else:
            if train and n > 1:
                add_psum(
                    dp_psums, _local_psum_shape(s, mesh_cfg), "float32",
                    "grad_sync",
                )

    if kind == "train":
        if n > 1:
            add_psum(dp_psums, (), "float32", "metrics", 2)  # loss + count
            add_psum(dp_psums, (num_entries,), "float32", "metrics")
        if tp > 1:
            add_psum(model_psums, (num_entries,), "float32", "metrics")
    elif kind == "cnn_train" and n > 1:
        add_psum(dp_psums, (), "float32", "metrics")  # loss
        add_psum(dp_psums, (num_entries,), "float32", "metrics")

    return _Expected(weights, grads, dp_psums, model_psums, dist_elems)


# ---------------------------------------------------------------------------
# attribution + verification
# ---------------------------------------------------------------------------


def _take_psum(table, e: CommEqn) -> str | None:
    """Consume one expected psum matching this eqn; returns its class."""
    tags = table.get((e.in_shape, e.in_dtype))
    if not tags:
        return None
    for tag in ("grad_sync", "metrics"):
        if tags.get(tag, 0) > 0:
            tags[tag] -= 1
            return tag
    return None


def _act_widths(plan: PrecisionPlan) -> set[int]:
    """Plane widths the activation / seq-boundary policies may put on
    the wire (forward and cotangent directions)."""
    widths = set()
    for pol in (plan.activations, plan.seq_policy()):
        if pol is None:
            continue
        if pol.round_to < FP32_BYTES:
            widths.add(pol.round_to)
        if pol.grad_round_to < FP32_BYTES:
            widths.add(pol.grad_round_to)
    return widths


def audit_step(
    step_fn,
    abstract_args,
    plan: PrecisionPlan,
    *,
    mesh_cfg: MeshCfg,
    spec_tree,
    kind: str = "train",
    groups_info=None,
    mesh=None,
) -> AuditReport:
    """Trace ``step_fn`` under abstract inputs and verify its data
    motion against ``plan``. Returns an :class:`AuditReport`; call
    ``report.raise_if_failed()`` (or check ``report.ok``) to enforce.

    ``step_fn`` is any step-factory product (train / cnn_train /
    prefill / decode / place — pass the matching ``kind``);
    ``abstract_args`` the ShapeDtypeStruct argument tuple it lowers
    against; ``spec_tree`` the parameter spec tree the step was built
    from (``groups_info`` additionally for the CNN layout). ``mesh``
    is entered during tracing when given (shard_map steps carry their
    mesh, so this is only needed for sharding-annotated callables).
    """
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    n = mesh_cfg.dshards
    tp = mesh_cfg.tp
    num_entries = (
        len(spec_tree["groups"]) + 1
        if "groups" in spec_tree
        else groups_info[1]
    )
    plan = plan.broadcast(num_entries)
    policies = plan.weight_policies()

    ctx = mesh if mesh is not None else contextlib.nullcontext()
    with ctx:
        closed = jax.make_jaxpr(step_fn)(*abstract_args)
    eqns = collect_comm_eqns(closed)

    exp = _expected_inventory(plan, mesh_cfg, spec_tree, kind, groups_info)
    fsdp = frozenset(mesh_cfg.fsdp_axes)
    model = frozenset((mesh_cfg.model_axis,))
    act_widths = _act_widths(plan)
    act_pol = plan.seq_policy() if plan.seq_parallel else plan.activations
    boundary_class = "seq_boundary" if plan.seq_parallel else "activations"

    classes: dict[str, ClassTotal] = {}
    violations: list[str] = []
    notes: list[str] = []

    def tally(name, e, analytic, structural=False):
        c = classes.setdefault(name, ClassTotal())
        c.eqns += e.mult
        c.jaxpr_bytes += _eqn_ring_bytes(e)
        c.analytic_bytes += analytic
        c.structural = c.structural or structural

    got_weights: Counter = Counter()
    got_grads: Counter = Counter()

    for e in eqns:
        if e.in_ctrl:
            violations.append(
                "collective under data-dependent control flow "
                f"(unpriceable trip count): {e.describe()}"
            )
            continue
        if e.prim == "device_put":
            violations.append(
                f"device transfer inside the traced step: {e.describe()} "
                "(host/device staging must live outside jit, priced by "
                "the plan's host_device entry)"
            )
            continue
        if e.axis_index_groups:
            violations.append(
                f"axis_index_groups collective (unattributable to one "
                f"mesh axis): {e.describe()}"
            )
            continue
        axes = frozenset(e.axes)

        if axes == fsdp:
            width = e.plane_width or _itemwidth(e.in_dtype)
            if e.prim == "all_gather":
                key = (e.payload_elems, width)
                got_weights[key] += e.mult
                pol_w = e.payload_elems * width
                tally(
                    "weights", e,
                    ring_wire_bytes("all-gather", pol_w, n) * e.mult,
                )
            elif e.prim in ("all_to_all", "reduce_scatter"):
                key = (e.payload_elems, width)
                got_grads[key] += e.mult
                tally(
                    "gradients", e,
                    ring_wire_bytes(
                        "reduce-scatter", e.payload_elems * width, n
                    ) * e.mult,
                )
            elif e.prim == "psum":
                tag = _take_psum(exp.dp_psums, e)
                if tag is None:
                    violations.append(
                        f"unattributed data-axis psum: {e.describe()}"
                    )
                else:
                    tally(tag, e, _eqn_ring_bytes(e))
            else:
                violations.append(
                    f"unattributed data-axis collective: {e.describe()}"
                )
        elif axes == model:
            if e.prim in ("pmax", "pmin"):
                # min/max all-reduces (vocab-parallel softmax max) are
                # exempt from the plane-compression contract: the uint8
                # pipeline relies on sums being ring-splittable, which
                # max/min are not — raw dtype IS their wire format
                tally(
                    boundary_class, e, _eqn_ring_bytes(e), structural=True
                )
                continue
            if e.prim == "psum":
                tag = _take_psum(exp.model_psums, e)
                if tag is not None:
                    tally(tag, e, _eqn_ring_bytes(e))
                    continue
                if len(e.in_shape) == 0:
                    # per-layer scalar reductions (MoE aux loss, shard
                    # diagnostics): metrics by construction
                    tally("metrics", e, _eqn_ring_bytes(e), structural=True)
                    continue
                # raw all-reduce on the activation path: legal only where
                # the transport's own fallback rule would emit one
                compressing = act_pol is not None and (
                    act_pol.round_to < FP32_BYTES
                    or act_pol.grad_round_to < FP32_BYTES
                )
                if compressing and pick_split_axis(e.in_shape, tp) is not None:
                    violations.append(
                        "raw psum inside a compressing activation region "
                        f"(expected uint8 planes): {e.describe()}"
                    )
                    continue
                pol = act_pol
                elems = math.prod(e.in_shape)
                if pol is None:
                    analytic = _eqn_ring_bytes(e)
                else:
                    analytic = pol.all_reduce_wire_bytes(
                        elems, tp,
                        uncompressed_bytes=_itemwidth(e.in_dtype),
                    ) * e.mult
                tally(boundary_class, e, analytic, structural=True)
            elif e.is_packed:
                width = e.plane_width
                if width not in act_widths:
                    violations.append(
                        f"plane width {width} not declared by the plan's "
                        f"activation/seq policies {sorted(act_widths)}: "
                        f"{e.describe()}"
                    )
                    continue
                pol = act_pol
                grad = (
                    pol is not None
                    and width == pol.grad_round_to
                    and width != pol.round_to
                )
                elems = e.payload_elems
                if e.prim == "all_gather":
                    analytic = pol.seq_gather_wire_bytes(elems, tp, grad=grad)
                else:
                    analytic = pol.seq_scatter_wire_bytes(elems, tp, grad=grad)
                tally(boundary_class, e, analytic * e.mult, structural=True)
            elif e.prim in ("all_gather", "all_to_all", "reduce_scatter"):
                # raw-dtype re-layout: seq_split/seq_merge, EP-MoE token
                # exchange, uncompressed boundary legs — lossless, priced
                # at the aval's own width
                tally("relayout", e, _eqn_ring_bytes(e), structural=True)
            else:
                violations.append(
                    f"unattributed model-axis collective: {e.describe()}"
                )
        else:
            violations.append(
                f"collective over unrecognized axis set {sorted(axes)} "
                f"(fsdp={sorted(fsdp)}, model={sorted(model)}): "
                f"{e.describe()}"
            )

    # -- inventory diffs ---------------------------------------------------
    def diff(name, got: Counter, want: Counter):
        for key in sorted(set(got) | set(want)):
            elems, width = key
            d = got[key] - want[key]
            if d > 0:
                violations.append(
                    f"{name}: {d} unexpected collective(s) of {elems} "
                    f"elems at {width} B/elem (plan promised widths "
                    f"{sorted({w for _, w in want})})"
                )
            elif d < 0:
                violations.append(
                    f"{name}: {-d} missing collective(s) of {elems} "
                    f"elems at {width} B/elem"
                )

    diff("weights", got_weights, exp.weight_gathers)
    diff("gradients", got_grads, exp.grad_scatters)
    for table, where in ((exp.dp_psums, "data"), (exp.model_psums, "model")):
        for (shape, dtype), tags in table.items():
            for tag, cnt in tags.items():
                if cnt > 0:
                    violations.append(
                        f"{tag}: missing {cnt} {where}-axis psum(s) of "
                        f"{dtype}{list(shape)}"
                    )

    # -- analytic totals for the independent classes -----------------------
    accum = plan.accum_steps if kind == "train" else 1
    table = plan.wire_table(
        exp.dist_elems, n, training=kind in TRAIN_KINDS, tp=tp
    )
    for name, scale in (("weights", accum), ("gradients", accum)):
        want = table[name] * scale
        c = classes.get(name)
        have = round(c.analytic_bytes) if c else 0
        if round(have) != round(want):
            violations.append(
                f"{name}: analytic bytes {have} != wire_table {want} "
                "(per-eqn policy pricing drifted from the plan table)"
            )
        elif c is not None:
            c.analytic_bytes = float(want)
    if n <= 1 and table["host_device"]:
        classes["host_device"] = ClassTotal(
            eqns=0, jaxpr_bytes=0.0,
            analytic_bytes=float(table["host_device"]), structural=True,
        )
        notes.append(
            "host_device is accounting-only: staging happens outside jit "
            "(no jaxpr carrier); bytes from the plan's host_device entry"
        )
    for name in ("kv_migration", "weight_publish"):
        if table[name]:
            classes[name] = ClassTotal(
                eqns=0, jaxpr_bytes=0.0,
                analytic_bytes=float(table[name]), structural=True,
            )
        if getattr(plan, name, None) is not None:
            notes.append(
                f"{name} is accounting-only: fleet fabric parcels cross "
                "between processes (no jaxpr carrier); measured bytes "
                "live in the FabricChannel hop log, pinned against "
                "roofline.fleet_migration_bytes"
            )

    # -- the byte pin ------------------------------------------------------
    _OFF_DEVICE = ("host_device", "kv_migration", "weight_publish")
    for name, c in sorted(classes.items()):
        if name in _OFF_DEVICE:
            continue
        if round(c.jaxpr_bytes) != round(c.analytic_bytes):
            violations.append(
                f"{name}: jaxpr wire bytes {round(c.jaxpr_bytes)} != "
                f"analytic {round(c.analytic_bytes)}"
            )

    mesh_str = f"{mesh_cfg.pods}x{mesh_cfg.dp}x{mesh_cfg.tp}" \
        if mesh_cfg.pods > 1 else f"{mesh_cfg.dp}x{mesh_cfg.tp}"
    return AuditReport(
        kind=kind,
        mesh=mesh_str,
        classes=classes,
        violations=violations,
        n_comm_eqns=sum(e.mult for e in eqns),
        notes=notes,
    )
