"""Width-aware async sharded checkpointing.

The old format gathered the whole tree into one blocking fp32 ``.npz`` —
the second-largest unpriced byte stream in the system after ingest. This
module replaces it with a per-leaf shard directory whose byte layout is
owned by the same plane decomposition as the wire:

  * **per-leaf shards** — every storage / optimizer leaf is its own
    file, written via :mod:`repro.utils.planes` (MSB-first byte planes,
    bit-compatible with ``kernels/ref.py``);
  * **width-aware tiers** — a compressible (``DIST``) fp32 leaf in a
    precision group currently at ``rt`` bytes is split at the AWP
    controller's width: the *wire tier* (``leaf.w.bin``) holds planes
    ``[0, rt)`` — exactly ``ceil(elems · rt)`` bytes on disk, so a rt=2
    weight costs 2 bytes, not 4 — and the *residual tier*
    (``leaf.r.bin``) holds planes ``[rt, 4)``. Reading both tiers is
    bitwise fp32 (resume stays exact under any AWP trajectory); reading
    the wire tier alone reproduces the transport's truncation — the
    serving restore and ``residuals=False`` exports move/keep only the
    width-priced bytes. This is the checkpoint twin of the data
    pipeline's progressive record tiers;
  * **async overlap** — :class:`AsyncCheckpointer` snapshots the
    host-mutable AWP state synchronously (jax arrays are immutable, so
    leaf references alone pin the device state) and runs the
    device→host copies + plane splits + file writes on a worker thread
    while the next train step executes. ``wait()`` joins and re-raises.

``meta.json`` records the step, the :class:`~repro.plan.PrecisionPlan`,
the AWP controller state, free-form ``extra`` state (the data pipeline's
resumable iterator position rides here) and a per-leaf manifest (key
path, dtype, shape, width, tier byte sizes) — the numbers
:func:`repro.roofline.analysis.train_checkpoint_bytes` must reproduce
analytically (measured == analytic is pinned by the train-I/O tests).

Structure mismatches raise :class:`CheckpointError` naming the offending
key path — never a bare ``assert`` (stripped under ``python -O``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

from repro.utils.planes import plane_join, plane_split

META = "meta.json"
FP32 = np.dtype(np.float32)
VALID_QUALITIES = ("exact", "wire")


class CheckpointError(Exception):
    """Checkpoint structure / format mismatch (typed — survives -O)."""


# ---------------------------------------------------------------------------
# tree walking
# ---------------------------------------------------------------------------


def _key_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:  # pragma: no cover - future key types
            parts.append(str(k))
    return "/".join(parts)


def leaf_entries(tree) -> list[tuple[str, object]]:
    """Flatten a pytree to ``[(key_path, leaf), ...]`` in canonical
    order — the manifest's leaf order and the structure-check unit."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_key_str(kp), leaf) for kp, leaf in flat]


def assign_widths(storage_like, spec_tree, round_tos) -> dict[str, int]:
    """Per-leaf on-disk width (bytes/element) at the controller's
    current formats: compressible (``DIST``) fp32 leaves take their
    precision group's ``round_to`` (group ``g`` for ``groups[g]``
    subtrees, the last entry for top-level leaves — the same layout as
    ``dist_elems_per_group``); everything else stays at full width.

    Shared by the writer and the analytic byte model so the two cannot
    drift."""
    from repro.dist.spec import DIST, LeafSpec

    rts = tuple(int(r) for r in round_tos)
    widths: dict[str, int] = {}

    def visit(prefix, sub_storage, sub_spec, rt):
        for (path, leaf), (_, spec) in zip(
            leaf_entries(sub_storage), leaf_entries(sub_spec)
        ):
            dt = np.dtype(leaf.dtype)
            full = dt.itemsize
            w = full
            if (
                isinstance(spec, LeafSpec)
                and spec.kind == DIST
                and dt == FP32
            ):
                w = min(rt, full)
            # a bare-array subtree flattens to one leaf with an empty
            # key path — the manifest key is then the prefix itself
            key = "/".join(p for p in (prefix, path) if p)
            widths[key] = w

    for g, gs in enumerate(storage_like["groups"]):
        visit(f"groups/{g}", gs, spec_tree["groups"][g], rts[g])
    for k in storage_like:
        if k != "groups":
            visit(k, storage_like[k], spec_tree[k], rts[-1])
    return widths


# ---------------------------------------------------------------------------
# AWP state <-> manifest meta
# ---------------------------------------------------------------------------


def awp_to_meta(awp) -> dict | None:
    """Snapshot an AWPController's host-mutable state into plain JSON.

    Called synchronously by the async path BEFORE the worker thread
    starts: the controller mutates every step, so deferring the snapshot
    would race with the next ``update``. Accepts a pre-snapshotted dict
    (pass-through) or ``None``."""
    if awp is None or isinstance(awp, dict):
        return awp
    return {
        "bits": awp.state.bits.tolist(),
        "counters": awp.state.counters.tolist(),
        "prev_norms": (
            awp.state.prev_norms.tolist()
            if awp.state.prev_norms is not None
            else None
        ),
        "step": awp.state.step,
        "history": [[s, list(b)] for s, b in awp.history],
    }


def awp_from_meta(awp, meta: dict | None) -> None:
    if awp is None or not meta:
        return
    awp.state.bits = np.asarray(meta["bits"], np.int64)
    awp.state.counters = np.asarray(meta["counters"], np.int64)
    awp.state.prev_norms = (
        np.asarray(meta["prev_norms"])
        if meta["prev_norms"] is not None
        else None
    )
    awp.state.step = meta["step"]
    awp.history = [(s, tuple(b)) for s, b in meta["history"]]


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------


def encode_leaf(arr: np.ndarray, width: int, residuals: bool):
    """One leaf -> ``(wire, res, info)`` tier byte strings + manifest
    entry fields. The wire tier of a tiered fp32 leaf is planes
    ``[0, width)`` plane-major — exactly ``elems * width`` bytes;
    ``res`` is ``None`` for untiered leaves or ``residuals=False``.

    This is the one tier codec: the on-disk writer (:func:`save_sharded`)
    and the fleet fabric's weight parcels
    (:func:`repro.transport.fabric.pack_weight_parcel`) both call it, so
    a published checkpoint is byte-identical to a saved one."""
    dt = arr.dtype
    tiered = dt == FP32 and width < FP32.itemsize
    if tiered:
        planes = plane_split(arr)
        wire = planes[:width].tobytes()
        res = planes[width:].tobytes() if residuals else None
    else:
        width = dt.itemsize
        wire = arr.tobytes()
        res = None
    info = {
        "dtype": dt.str,
        "shape": list(arr.shape),
        "width": int(width),
        "bytes": len(wire),
        "residual_bytes": len(res) if res is not None else 0,
        "tiered": bool(tiered),
    }
    return wire, res, info


def decode_leaf(
    wire: bytes, e: dict, quality: str, res: bytes | None = None,
    *, where: str = "checkpoint",
) -> np.ndarray:
    """Inverse of :func:`encode_leaf`: tier bytes + manifest entry ->
    leaf array. ``quality="exact"`` needs the residual tier for tiered
    leaves; ``"wire"`` zero-fills the dropped planes (the transport's
    truncation)."""
    dtype = np.dtype(e["dtype"])
    shape = tuple(e["shape"])
    wire_u8 = np.frombuffer(wire, np.uint8)
    if not e["tiered"]:
        return wire_u8.view(dtype).reshape(shape).copy()
    n = int(np.prod(shape)) if shape else 1
    planes = wire_u8.reshape(e["width"], n)
    if quality == "exact":
        if res is None:
            raise CheckpointError(
                f"exact restore of {e['path']} needs the residual tier, "
                f"but this {where} was written residuals=False "
                f"(width {e['width']}); use quality='wire'"
            )
        planes = np.concatenate([
            planes,
            np.frombuffer(res, np.uint8).reshape(
                FP32.itemsize - e["width"], n
            ),
        ])
    return plane_join(planes, dtype, shape)


def _write_leaf(arr: np.ndarray, width: int, base: str, residuals: bool):
    """One leaf -> wire tier (+ optional residual tier) on disk; returns
    the manifest entry fields."""
    wire, res, info = encode_leaf(arr, width, residuals)
    with open(base + ".w.bin", "wb") as f:
        f.write(wire)
    if res is not None:
        with open(base + ".r.bin", "wb") as f:
            f.write(res)
    return info


def save_sharded(
    path: str,
    storage,
    opt_state,
    awp,
    step: int,
    *,
    plan=None,
    spec_tree=None,
    round_tos=None,
    extra: dict | None = None,
    residuals: bool = True,
) -> dict:
    """Write the sharded checkpoint directory at ``path`` (atomically:
    a tmp sibling is renamed over the target). ``round_tos`` +
    ``spec_tree`` enable width-aware tiers (pass the controller's
    *current* formats); without them every leaf is full width.
    ``residuals=False`` drops the residual tiers — a width-priced
    export (serving hand-off) that restores only at ``quality="wire"``.

    ``awp`` may be an ``AWPController`` or a pre-snapshotted meta dict
    (the async path). Returns the manifest."""
    awp_meta = awp_to_meta(awp)
    widths: dict[str, int] = {}
    if round_tos is not None:
        if spec_tree is None:
            raise CheckpointError(
                "width-aware save needs spec_tree alongside round_tos"
            )
        widths = assign_widths(storage, spec_tree, round_tos)

    tmp = path + f".tmp.{os.getpid()}"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    trees = {}
    for tree_name, tree in (("storage", storage), ("opt", opt_state)):
        entries = []
        if tree is not None:
            for i, (kpath, leaf) in enumerate(leaf_entries(tree)):
                arr = np.asarray(leaf)  # device->host copy happens HERE
                width = (
                    widths.get(kpath, arr.dtype.itemsize)
                    if tree_name == "storage"
                    else arr.dtype.itemsize
                )
                base = os.path.join(tmp, f"{tree_name}_{i:05d}")
                info = _write_leaf(arr, width, base, residuals)
                info["path"] = kpath
                info["file"] = f"{tree_name}_{i:05d}"
                entries.append(info)
        trees[tree_name] = entries
    meta = {
        "version": 1,
        "format": "sharded-v1",
        "step": int(step),
        "plan": plan.to_json_dict() if plan is not None else None,
        "awp": awp_meta,
        "extra": extra or {},
        "residuals": bool(residuals),
        "trees": trees,
    }
    with open(os.path.join(tmp, META), "w") as f:
        json.dump(meta, f)
    shutil.rmtree(path, ignore_errors=True)
    os.replace(tmp, path)
    return meta


# ---------------------------------------------------------------------------
# load
# ---------------------------------------------------------------------------


def read_meta(path: str) -> dict:
    mp = os.path.join(path, META)
    if not os.path.isfile(mp):
        raise CheckpointError(f"no sharded checkpoint at {path!r}")
    with open(mp) as f:
        return json.load(f)


def _check_structure(entries: list[dict], like, tree_name: str):
    """Manifest vs restore-target structure; CheckpointError names the
    first mismatching key path."""
    want = leaf_entries(like)
    if len(entries) != len(want):
        extra_path = (
            want[len(entries)][0]
            if len(want) > len(entries)
            else entries[len(want)]["path"]
        )
        raise CheckpointError(
            f"checkpoint {tree_name} tree holds {len(entries)} leaves, "
            f"restore target has {len(want)} (first unmatched: "
            f"{tree_name}/{extra_path})"
        )
    for e, (kpath, leaf) in zip(entries, want):
        if e["path"] != kpath:
            raise CheckpointError(
                f"checkpoint structure mismatch at {tree_name}/{kpath}: "
                f"checkpoint has {tree_name}/{e['path']}"
            )
        if tuple(e["shape"]) != tuple(leaf.shape):
            raise CheckpointError(
                f"checkpoint shape mismatch at {tree_name}/{kpath}: "
                f"checkpoint {tuple(e['shape'])} vs target "
                f"{tuple(leaf.shape)}"
            )
        if np.dtype(e["dtype"]) != np.dtype(leaf.dtype):
            raise CheckpointError(
                f"checkpoint dtype mismatch at {tree_name}/{kpath}: "
                f"checkpoint {np.dtype(e['dtype'])} vs target "
                f"{np.dtype(leaf.dtype)}"
            )


def _read_leaf(path: str, e: dict, quality: str) -> np.ndarray:
    base = os.path.join(path, e["file"])
    with open(base + ".w.bin", "rb") as f:
        wire = f.read()
    res = None
    if e["tiered"] and quality == "exact":
        rpath = base + ".r.bin"
        if os.path.isfile(rpath):
            with open(rpath, "rb") as f:
                res = f.read()
    return decode_leaf(wire, e, quality, res)


def _load_tree(path: str, entries: list[dict], like, quality: str):
    arrs = [_read_leaf(path, e, quality) for e in entries]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, arrs)


def load_sharded(
    path: str,
    storage_like,
    opt_like=None,
    awp=None,
    *,
    quality: str = "exact",
):
    """Restore ``(storage, opt_state, step, meta)`` from a sharded dir.

    ``quality="exact"`` reads wire + residual tiers (bitwise fp32 —
    resume-grade); ``"wire"`` reads only the width-priced wire tiers
    (the transport's truncation — serving-grade, and the only mode a
    ``residuals=False`` export supports). ``opt_like=None`` skips the
    optimizer tree entirely (weights-only restore: the serve path never
    materializes a momentum tree)."""
    if quality not in VALID_QUALITIES:
        raise CheckpointError(f"quality must be in {VALID_QUALITIES}")
    meta = read_meta(path)
    _check_structure(meta["trees"]["storage"], storage_like, "storage")
    storage = _load_tree(path, meta["trees"]["storage"], storage_like, quality)
    opt_state = None
    if opt_like is not None:
        _check_structure(meta["trees"]["opt"], opt_like, "opt")
        opt_state = _load_tree(path, meta["trees"]["opt"], opt_like, quality)
    awp_from_meta(awp, meta.get("awp"))
    return storage, opt_state, meta["step"], meta


def manifest_bytes(meta: dict) -> dict:
    """Measured on-disk byte totals of a sharded checkpoint, from its
    manifest: ``wire`` (width-priced tiers), ``residual``, ``total``.
    The analytic model ``train_checkpoint_bytes`` must equal this, and
    the tests additionally pin these numbers to ``os.path.getsize``."""
    wire = residual = 0
    for entries in meta["trees"].values():
        for e in entries:
            wire += e["bytes"]
            residual += e["residual_bytes"]
    return {"wire": wire, "residual": residual, "total": wire + residual}


# ---------------------------------------------------------------------------
# async
# ---------------------------------------------------------------------------


class AsyncCheckpointer:
    """Serialize checkpoints on a worker thread, overlapped with the
    next train step.

    One save in flight at a time: a new :meth:`save` first joins the
    previous one (bounding host memory at ~one checkpoint). The
    device→host copy happens *synchronously* in :meth:`save` — the train
    steps donate their storage/opt buffers, so the old device arrays may
    be deleted the moment the next step runs; holding references is not
    a snapshot under donation. What overlaps the next step is everything
    downstream of the copy: plane splits, tier writes, the manifest.
    The host-mutable AWP controller state and the caller's ``extra``
    dict are likewise snapshotted up front. Failures surface on the next
    :meth:`save`/:meth:`wait` as :class:`CheckpointError`."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self._exc: BaseException | None = None
        self.saves = 0

    @property
    def in_flight(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def save(self, path, storage, opt_state, awp, step, **kw):
        self.wait()
        awp_meta = awp_to_meta(awp)
        extra = dict(kw.pop("extra", None) or {})
        # synchronous d2h snapshot (donation-safe, see class docstring)
        host_storage = jax.tree_util.tree_map(np.asarray, storage)
        host_opt = (
            jax.tree_util.tree_map(np.asarray, opt_state)
            if opt_state is not None
            else None
        )

        def work():
            try:
                save_sharded(
                    path, host_storage, host_opt, awp_meta, step,
                    extra=extra, **kw,
                )
            except BaseException as e:  # re-raised by wait()
                self._exc = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        self.saves += 1

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise CheckpointError(f"async checkpoint failed: {exc}") from exc
