"""Shape-agnostic jit'd wrappers around the ADT Pallas kernels.

These accept arbitrary-shaped fp32 arrays, handle the pad-to-tile plumbing,
and dispatch to either the Pallas kernel or the pure-jnp oracle in
:mod:`repro.kernels.ref`. The kernel path is backend-aware (compiled on
real TPU, interpret elsewhere — see ``bitpack.resolve_interpret``); there
is no hard-coded interpret mode.

The ``impl`` switch exists because the distributed step functions lower on
the CPU dry-run path where we want pure-HLO collectives with no callbacks;
that dispatch now lives in :mod:`repro.transport` (``impl="auto"``), and
kernel correctness is proven separately by the test suite.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bitpack import BLOCK_ROWS, LANES, bitpack_2d
from repro.kernels.bitunpack import bitunpack_2d
from repro.kernels.l2norm import NORM_BLOCK_ROWS, l2norm_sq_2d
from repro.utils.trees import round_up


def _to_tiles(w: jnp.ndarray, block_rows: int) -> tuple[jnp.ndarray, int]:
    """Flatten + zero-pad to a (rows, 128) tile grid."""
    flat = w.reshape(-1)
    n = flat.shape[0]
    tile = block_rows * LANES
    padded = round_up(max(n, 1), tile)
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(-1, LANES), n


@functools.partial(jax.jit, static_argnames=("round_to", "impl", "mode"))
def bitpack(
    w: jnp.ndarray,
    round_to: int,
    *,
    impl: str = "pallas",
    mode: str = "truncate",
    key=None,
) -> jnp.ndarray:
    """Pack arbitrary-shaped fp32 -> ``(round_to, padded_rows, 128)`` u8 planes."""
    if impl == "ref" or mode != "truncate":
        # rounding modes live in the ref path (they need PRNG plumbing)
        tiles, _ = _to_tiles(w, BLOCK_ROWS)
        return ref.bitpack_ref(tiles, round_to, mode=mode, key=key)
    tiles, _ = _to_tiles(w, BLOCK_ROWS)
    return bitpack_2d(tiles, round_to)


@functools.partial(jax.jit, static_argnames=("impl",))
def bitunpack(planes: jnp.ndarray, *, impl: str = "pallas") -> jnp.ndarray:
    """Unpack planes -> flat fp32 of the padded size (caller unpads)."""
    if impl == "ref":
        return ref.bitunpack_ref(planes).reshape(-1)
    return bitunpack_2d(planes).reshape(-1)


@functools.partial(jax.jit, static_argnames=("round_to", "impl", "mode"))
def quantize(
    w: jnp.ndarray,
    round_to: int,
    *,
    impl: str = "pallas",
    mode: str = "truncate",
    key=None,
) -> jnp.ndarray:
    """pack∘unpack at the original shape — what the compute side sees."""
    if round_to == 4 and mode == "truncate":
        return w
    planes = bitpack(w, round_to, impl=impl, mode=mode, key=key)
    flat = bitunpack(planes, impl=impl)
    return flat[: math.prod(w.shape)].reshape(w.shape)


@functools.partial(jax.jit, static_argnames=("impl",))
def l2norm_sq(w: jnp.ndarray, *, impl: str = "pallas") -> jnp.ndarray:
    """Σw² over an arbitrary-shaped array -> f32 scalar."""
    if impl == "ref":
        return ref.l2norm_sq_ref(w)
    tiles, _ = _to_tiles(w.astype(jnp.float32), NORM_BLOCK_ROWS)
    return l2norm_sq_2d(tiles)
