"""Fleet tier unit tests: fabric parcels, typed errors, analytic pins.

The contracts pinned here (see docs/fleet.md):

  * KV page parcels are bitwise lossless for fp32, bf16 and int8 pool
    leaves, priced at ``kv_wire_width`` bytes per element;
  * weight parcels byte-match the sharded checkpointer three ways
    (``parcel.nbytes == manifest_bytes == train_checkpoint_bytes``) and
    restore bitwise when the publish policy is uncompressed;
  * the engine's ``swap_weights`` hot-swap makes post-swap streams
    equal a fresh run from the swapped storage;
  * every misuse path raises a typed error (``FabricError`` /
    ``RouterError`` / ``ReplicaError``), never a bare assert;
  * a 2-replica fleet's streams are bit-exact vs a single engine, and
    the fabric hop log equals ``fleet_migration_bytes`` (the full
    topology matrix lives in ``tests/scenarios/scenario_fleet.py``).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.fleet import (
    DecodeReplica,
    FleetRouter,
    PrefillWorker,
    ReplicaError,
    RouterError,
    WeightPublisher,
    check_fleet_arch,
)
from repro.models.init import init_params
from repro.plan import PrecisionPlan
from repro.roofline.analysis import fleet_migration_bytes, train_checkpoint_bytes
from repro.serve.engine import Request, ServeEngine
from repro.transport import (
    CompressionPolicy,
    FabricChannel,
    FabricError,
    pack_kv_pages,
    pack_weight_parcel,
    unpack_kv_pages,
    unpack_weight_parcel,
)

CAPACITY = 24
SLOTS = 2
PAGE = 8


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=4096)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2),) * (cfg.num_groups + 1),
        host_device=CompressionPolicy(round_to=2),
    )
    return cfg, mesh_cfg, spec_tree, storage, plan


def _requests(cfg, spec=((16, 6), (12, 8), (16, 4), (8, 8))):
    rng = np.random.default_rng(7)
    return [
        Request(
            rid=i,
            prompt_ids=tuple(int(t) for t in rng.integers(0, cfg.vocab_size, S)),
            max_new=gen,
        )
        for i, (S, gen) in enumerate(spec)
    ]


def _engine(setup, storage=None, **kw):
    cfg, mesh_cfg, spec_tree, storage0, plan = setup
    kw.setdefault("max_slots", SLOTS)
    kw.setdefault("cache_capacity", CAPACITY)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", PAGE)
    return ServeEngine(
        cfg, mesh_cfg, None, spec_tree,
        storage if storage is not None else storage0, plan=plan, **kw,
    )


# ---------------------------------------------------------------------------
# fabric: KV page parcels
# ---------------------------------------------------------------------------


def test_kv_wire_width_pricing():
    # compressing policies ship a pool leaf at max(itemsize, round_to),
    # capped at raw fp32 words; uncompressed pads everything to 4
    assert CompressionPolicy(round_to=4).kv_wire_width(1) == 4
    assert CompressionPolicy(round_to=4).kv_wire_width(2) == 4
    assert CompressionPolicy(round_to=1).kv_wire_width(1) == 1
    assert CompressionPolicy(round_to=1).kv_wire_width(2) == 2
    assert CompressionPolicy(round_to=2).kv_wire_width(1) == 2
    assert CompressionPolicy(round_to=2).kv_wire_width(4) == 4
    assert CompressionPolicy(round_to=3).kv_wire_width(4) == 4


@pytest.mark.parametrize("dtype,rt", [
    ("float32", 4), ("float32", 2), ("bfloat16", 2), ("bfloat16", 4),
    ("int8", 1), ("int8", 4),
])
def test_kv_parcel_lossless_roundtrip(dtype, rt):
    rng = np.random.default_rng(11)
    dt = np.dtype(dtype)
    if dt.kind == "f":
        leaves = {
            "k": rng.standard_normal((2, 3, PAGE, 4)).astype(dt),
            "v": rng.standard_normal((2, 3, PAGE, 4)).astype(dt),
        }
    else:
        leaves = {
            "k": rng.integers(-128, 128, (2, 3, PAGE, 4)).astype(dt),
            "scale": rng.standard_normal((2, 3, PAGE)).astype(np.float32),
        }
    pol = CompressionPolicy(round_to=rt)
    parcel = pack_kv_pages(leaves, pol, meta={"rid": 5})
    out = unpack_kv_pages(parcel)
    for key in leaves:
        assert out[key].dtype == leaves[key].dtype
        np.testing.assert_array_equal(
            np.asarray(out[key]), leaves[key],
        )
    # priced exactly at kv_wire_width bytes per element, per leaf
    want = sum(
        arr.size * pol.kv_wire_width(arr.dtype.itemsize)
        for arr in leaves.values()
    )
    assert parcel.nbytes == want
    assert parcel.meta == {"rid": 5}


def test_kv_parcel_truncated_wire_raises():
    leaves = {"k": np.ones((2, PAGE), np.float32)}
    parcel = pack_kv_pages(leaves, CompressionPolicy(round_to=2))
    wire, info = parcel.entries[0]
    bad = dataclasses.replace(parcel, entries=((wire[:-1], info),))
    with pytest.raises(FabricError):
        unpack_kv_pages(bad)


def test_fabric_channel_typed_errors_and_summary():
    ch = FabricChannel()
    parcel = pack_kv_pages(
        {"k": np.zeros((1, PAGE), np.float32)}, CompressionPolicy(round_to=4)
    )
    with pytest.raises(FabricError):
        ch.send(parcel, cls="gradients", src="a", dst="b")
    with pytest.raises(FabricError):
        ch.send(object(), cls="kv_migration", src="a", dst="b")
    ch.send(parcel, cls="kv_migration", src="w0", dst="r0")
    ch.send(parcel, cls="kv_migration", src="w0", dst="r1")
    ws = ch.wire_summary()
    assert ws["kv_migration"] == 2 * parcel.nbytes
    assert ws["weight_publish"] == 0
    assert ws["hops"] == {"kv_migration": 2, "weight_publish": 0}
    assert ws["total"] == 2 * parcel.nbytes


# ---------------------------------------------------------------------------
# fabric: weight parcels
# ---------------------------------------------------------------------------


def test_weight_parcel_three_way_byte_pin(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    nrt = cfg.num_groups + 1
    for rt in (4, 2):
        pol = CompressionPolicy(round_to=rt)
        parcel = pack_weight_parcel(
            storage, spec_tree=spec_tree, round_tos=(rt,) * nrt,
            policy=pol, version=0,
        )
        # parcel bytes == manifest pricing == analytic checkpoint model
        from repro.checkpoint.sharded import manifest_bytes

        measured = manifest_bytes(parcel.manifest_meta())
        analytic = train_checkpoint_bytes(
            storage, spec_tree=spec_tree, round_tos=(rt,) * nrt,
            residuals=parcel.residuals,
        )
        assert parcel.nbytes == measured["total"] == analytic["total"], rt
        restored = unpack_weight_parcel(parcel, storage)
        if rt == 4:
            # uncompressed publish ships residuals: bitwise restore
            assert parcel.residuals
            for a, b in zip(
                jax.tree_util.tree_leaves(restored),
                jax.tree_util.tree_leaves(storage),
            ):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            assert not parcel.residuals


def test_weight_parcel_structure_mismatch_raises(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    nrt = cfg.num_groups + 1
    parcel = pack_weight_parcel(
        storage, spec_tree=spec_tree, round_tos=(2,) * nrt,
        policy=CompressionPolicy(round_to=2), version=0,
    )
    bad = dataclasses.replace(parcel, entries=parcel.entries[:-1])
    with pytest.raises(FabricError):
        unpack_weight_parcel(bad, storage)


# ---------------------------------------------------------------------------
# analytic model arithmetic
# ---------------------------------------------------------------------------


def test_fleet_migration_bytes_arithmetic(setup):
    cfg, _, _, _, plan = setup
    out = fleet_migration_bytes(
        plan, cfg, page_size=PAGE, migrated_pages=9,
        publish_wire_bytes=1000, publish_installs=2,
    )
    # fp32 pool at a compressing policy still ships raw words: K + V
    # per attention layer at 4 B/elem
    layers = cfg.num_groups * cfg.layers_per_group
    per_page = 2 * PAGE * cfg.num_kv_heads * cfg.head_dim * 4 * layers
    assert out["kv_width"] == 4
    assert out["page_wire_bytes"] == per_page
    assert out["kv_migration"] == 9 * per_page
    assert out["weight_publish"] == 2000
    assert out["total"] == out["kv_migration"] + 2000
    # int8 pools: payload at 1 B/elem under a 1-byte policy, fp32
    # scale rows always at raw width
    pol = CompressionPolicy(round_to=1)
    out8 = fleet_migration_bytes(
        pol, cfg, page_size=PAGE, migrated_pages=1, int8_kv=True,
    )
    per_page8 = (
        2 * PAGE * cfg.num_kv_heads * cfg.head_dim * 1
        + 2 * PAGE * cfg.num_kv_heads * 4
    ) * layers
    assert out8["kv_width"] == 1
    assert out8["kv_migration"] == per_page8


# ---------------------------------------------------------------------------
# typed errors: arch gate, replica, router
# ---------------------------------------------------------------------------


def test_check_fleet_arch_rejects_non_fleet_families():
    for name in ("hubert-xlarge", "mixtral-8x7b", "llama-3.2-vision-90b",
                 "xlstm-1.3b"):
        with pytest.raises(ReplicaError):
            check_fleet_arch(reduced(get_config(name)))
    check_fleet_arch(reduced(get_config("qwen3-1.7b")))


def test_replica_requires_paged_engine(setup):
    contiguous = _engine(setup, paged=False)
    with pytest.raises(ReplicaError):
        DecodeReplica("r0", contiguous)


def test_router_constructor_validation(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    replica = DecodeReplica("r0", _engine(setup))

    def worker(name, page_size=PAGE):
        return PrefillWorker(
            name, cfg, mesh_cfg, None, spec_tree, plan=plan,
            cache_capacity=CAPACITY, page_size=page_size,
        )

    with pytest.raises(RouterError):
        FleetRouter([], [worker("w0")])
    with pytest.raises(RouterError):
        FleetRouter([replica], [])
    with pytest.raises(RouterError):
        FleetRouter([replica], [worker("r0")])  # name collision
    with pytest.raises(RouterError):  # geometry mismatch
        FleetRouter([replica], [worker("w1", page_size=PAGE // 2)])


def test_router_lifecycle_errors(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    replica = DecodeReplica("r0", _engine(setup))
    worker = PrefillWorker(
        "w0", cfg, mesh_cfg, None, spec_tree, plan=plan,
        cache_capacity=CAPACITY, page_size=PAGE,
    )
    router = FleetRouter([replica], [worker])
    req = _requests(cfg)[0]
    with pytest.raises(RouterError):  # submit before any publish
        router.submit(req)
    publisher = WeightPublisher(cfg, spec_tree, plan=plan)
    p0 = publisher.publish(storage)
    router.publish(p0)
    with pytest.raises(RouterError):  # versions must be monotonic
        router.publish(p0)
    router.submit(req)
    with pytest.raises(RouterError):  # duplicate rid
        router.submit(req)
    with pytest.raises(RouterError):  # cannot drain the last replica
        router.remove_replica("r0")
    with pytest.raises(RouterError):  # unknown replica
        router.remove_replica("nope")
    with pytest.raises(RouterError):  # join needs a distinct name
        router.add_replica(DecodeReplica("r0", _engine(setup)))


def test_worker_n_hits_range(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    worker = PrefillWorker(
        "w0", cfg, mesh_cfg, None, spec_tree, plan=plan,
        cache_capacity=CAPACITY, page_size=PAGE,
    )
    req = Request(rid=0, prompt_ids=(1,) * 12, max_new=4)
    with pytest.raises(ReplicaError):
        worker.prefill(storage, req, n_hits=2)  # only 1 whole page
    with pytest.raises(ReplicaError):  # capacity overflow
        worker.prefill(
            storage, Request(rid=1, prompt_ids=(1,) * 20, max_new=8)
        )


# ---------------------------------------------------------------------------
# determinism: fleet vs single engine, swap_weights
# ---------------------------------------------------------------------------


def test_fleet_streams_match_single_engine(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    reqs = _requests(cfg)
    single = _engine(setup).run(reqs)

    replicas = [DecodeReplica(f"r{i}", _engine(setup)) for i in range(2)]
    worker = PrefillWorker(
        "w0", cfg, mesh_cfg, None, spec_tree, plan=plan,
        cache_capacity=CAPACITY, page_size=PAGE,
    )
    router = FleetRouter(replicas, [worker])
    publisher = WeightPublisher(cfg, spec_tree, plan=plan)
    parcel = publisher.publish(storage)
    router.publish(parcel)
    results = router.run(reqs)
    for r in reqs:
        assert results[r.rid].tokens == single[r.rid].tokens, r.rid
    # both replicas saw traffic and the fabric pin holds
    assert len({m["replica"] for m in router.placements.values()}) == 2
    ws = router.wire_summary()
    analytic = fleet_migration_bytes(
        plan, cfg, page_size=PAGE, migrated_pages=ws["migrated_pages"],
        publish_wire_bytes=parcel.nbytes,
        publish_installs=ws["publish_installs"],
    )
    assert ws["kv_migration"] == analytic["kv_migration"]
    assert ws["weight_publish"] == analytic["weight_publish"]


def test_swap_weights_equals_fresh_engine(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    params1, _ = init_params(cfg, jax.random.PRNGKey(1), tp=1)
    storage1 = tree_to_storage(params1, spec_tree, mesh_cfg)
    reqs = _requests(cfg, spec=((16, 5), (12, 6)))
    eng = _engine(setup)
    base = eng.run(reqs)
    eng.swap_weights(storage1)
    swapped = eng.run(reqs)
    fresh = _engine(setup, storage=storage1).run(reqs)
    for r in reqs:
        assert swapped[r.rid].tokens == fresh[r.rid].tokens, r.rid
    # different weights genuinely produce different streams
    assert any(
        swapped[r.rid].tokens != base[r.rid].tokens for r in reqs
    )


def test_install_refuses_busy_replica(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    replica = DecodeReplica("r0", _engine(setup))
    worker = PrefillWorker(
        "w0", cfg, mesh_cfg, None, spec_tree, plan=plan,
        cache_capacity=CAPACITY, page_size=PAGE,
    )
    req = _requests(cfg)[0]
    pages, first = worker.prefill(storage, req)
    parcel = pack_kv_pages(
        pages, plan.kv_migration_policy(),
        meta={"rid": req.rid, "n_hits": 0, "first": first},
    )
    replica.admit_parcel(req, parcel)
    with pytest.raises(ReplicaError):
        replica.install(storage, 1)
    # drain so the module fixture's engine state stays clean
    while replica.engine.has_work or replica.engine.pending_record:
        replica.tick()
    replica.engine.take_completed()
    replica.engine.finish()
