"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Mapping to the paper:

  table2_3_profile       — per-kernel cost profile (Bitpack / Bitunpack /
                           l2-norm measured on CPU; transfer terms modeled
                           bytes/bandwidth, as Tables II/III)
  fig2_bitpack_kernel    — SIMD-Bitpack throughput (Pallas interpret vs
                           jnp oracle) over VGG-sized weight arrays
  fig3_convergence       — time-to-validation-error, baseline vs oracle vs
                           A²DTWP on the reduced AlexNet (§V-B, Fig. 3)
  fig4_normalized_time   — normalized execution time of oracle/A²DTWP vs
                           the fp32 baseline across batch sizes (Fig. 4)
  compression_ratio      — weight-motion bytes per format (the ~2.94x
                           CPU→GPU reduction of Table II)
  roofline_table         — §Roofline terms per (arch x shape) read from
                           results/dryrun_*.json (produced by the dry-run)

Keep each entry fast: the full harness must finish in a few minutes on one
CPU core.
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def row(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return 1e6 * statistics.median(ts)


# ---------------------------------------------------------------------------


def table2_3_profile():
    """Tables II/III: per-batch component profile for VGG-sized weights."""
    from repro.kernels import ops
    from repro.transport import pack_planes, unpack_planes

    n = 20_000_000  # ~VGG-A conv+fc weight count (paper: ~133M at full fc)
    w = jnp.asarray(np.random.default_rng(0).normal(0, 1, n), jnp.float32)
    pack = jax.jit(lambda x: pack_planes(x, 2, impl="ref"))
    unpack = jax.jit(lambda p: unpack_planes(p, impl="ref"))
    us_pack = _time(pack, w, iters=5)
    us_unpack = _time(unpack, pack(w), iters=5)
    us_norm = _time(lambda x: ops.l2norm_sq(x, impl="ref"), w, iters=5)
    row("table2.bitpack_20M_weights", us_pack, "paper_x86=19.71ms_on_133M")
    row("table2.bitunpack_20M_weights", us_unpack, "paper_x86=4.51ms")
    row("table2.awp_l2norm_20M_weights", us_norm, "paper_x86=3.88ms")
    # modeled transfer at PCIe3 x8 (paper x86 system)
    bw = 7.9e9
    fp32_us = n * 4 / bw * 1e6
    rt2_us = n * 2 / bw * 1e6
    row("table2.transfer_fp32_modeled", fp32_us, "paper=153.93ms_on_133M")
    row(
        "table2.transfer_rt2_modeled", rt2_us,
        f"reduction={fp32_us/rt2_us:.2f}x_paper=2.94x",
    )


def fig2_bitpack_kernel():
    """Pallas bitpack/bitunpack vs jnp oracle through the transport
    dispatch (kernels compiled on TPU, interpret on CPU)."""
    from repro.kernels.bitpack import resolve_interpret
    from repro.transport import pack_planes

    mode = "pallas_interp" if resolve_interpret(None) else "pallas"
    w = jnp.asarray(
        np.random.default_rng(1).normal(0, 1, (4096, 128)), jnp.float32
    ).reshape(-1)
    for rt in (1, 2, 3):
        fp = jax.jit(lambda x, rt=rt: pack_planes(x, rt, impl="pallas"))
        fr = jax.jit(lambda x, rt=rt: pack_planes(x, rt, impl="ref"))
        us_p = _time(fp, w, iters=5)
        us_r = _time(fr, w, iters=5)
        row(f"fig2.bitpack_rt{rt}_{mode}", us_p, f"ref_us={us_r:.1f}")


def fig3_convergence(steps=140):
    """Fig 3: top-5 val-error vs modeled elapsed time (reduced AlexNet)."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "examples"))
    from awp_cnn_repro import NETS, run_policy, LINK_BW
    from repro.data.pipeline import SyntheticImageNet
    from repro.dist.spec import MeshCfg
    from repro.models.cnn import reduced_cnn

    cfg = reduced_cnn(NETS["alexnet"], num_classes=20, in_hw=32)
    data = SyntheticImageNet(num_classes=20, hw=32)
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=256)
    for policy in ("baseline", "oracle:2", "awp"):
        t0 = time.perf_counter()
        r = run_policy(policy, cfg, data, mesh_cfg, None, steps, 64, 0.05)
        err = r["curve"][-1]["top5_err"]
        xfer = r["curve"][-1]["modeled_xfer_s"]
        row(
            f"fig3.alexnet_{policy.replace(':', '')}",
            1e6 * (time.perf_counter() - t0) / steps,
            f"top5err={err:.3f}_modeled_xfer_s={xfer:.3f}",
        )


def fig4_normalized_time():
    """Fig 4: normalized execution time vs baseline across batch sizes.

    Modeled per the paper's own account: batch time = compute (equal across
    policies) + weight transfer (bytes/bw). Compute time measured once."""
    from repro.models.cnn import ALEXNET, VGG_A, RESNET34, reduced_cnn, init_cnn, cnn_loss

    bw = 7.9e9
    for name, full in (("alexnet", ALEXNET), ("vgg", VGG_A), ("resnet", RESNET34)):
        cfg = reduced_cnn(full, num_classes=20, in_hw=32)
        params, metas, _ = init_cnn(cfg, jax.random.PRNGKey(0))
        wbytes = sum(
            int(np.prod(v["w"].shape)) * 4 for v in params["layers"].values()
        )
        for batch in (16, 32, 64):
            imgs = jnp.zeros((batch, 32, 32, 3), jnp.float32)
            labels = jnp.zeros((batch,), jnp.int32)
            lossf = jax.jit(
                lambda lp, i, l: cnn_loss(lp, i, l, cfg, train=False)
            )
            us_compute = _time(lossf, params["layers"], imgs, labels, iters=5)
            t_fp32 = us_compute + wbytes / bw * 1e6
            t_rt2 = us_compute + wbytes / 2 / bw * 1e6
            row(
                f"fig4.{name}_b{batch}_oracle2_norm_time",
                t_rt2,
                f"normalized={t_rt2/t_fp32:.3f}_fp32_us={t_fp32:.0f}",
            )


def compression_ratio():
    from repro.core.formats import TransferFormat
    from repro.transport import CompressionPolicy

    for rt in (1, 2, 3, 4):
        f = TransferFormat(rt)
        pol = CompressionPolicy(round_to=rt)
        # the format table and the transport accounting must agree
        assert f.compression_ratio == 1.0 / pol.wire_fraction
        row(
            f"compression.{f.name}", 0.0,
            f"ratio={f.compression_ratio:.2f}x_bits={f.bits}"
            f"_wire_frac={pol.wire_fraction:.2f}",
        )


def serve_engine_bench(out_path="BENCH_serve.json"):
    """Serve-engine benchmark: contiguous vs block-paged KV on the same
    request mix (mixed prompt lengths + a 2-page shared prefix). Emits
    ``BENCH_serve.json`` with tokens/sec, decode-step wall-clock, KV
    bytes resident per token, and host<->device wire bytes per token —
    the committed snapshot CI regenerates and uploads as an artifact."""
    from repro.configs.registry import get_config, reduced
    from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
    from repro.models.init import init_params
    from repro.plan import PrecisionPlan
    from repro.serve.engine import Request, ServeEngine
    from repro.transport import CompressionPolicy

    page = 8
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=4096)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2),) * (cfg.num_groups + 1),
        host_device=CompressionPolicy(round_to=2),
    )
    rng = np.random.default_rng(0)
    shared = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 2 * page))
    reqs = [
        Request(rid=i, prompt_ids=shared + tuple(
            int(t) for t in rng.integers(0, cfg.vocab_size, tail)),
            max_new=8)
        for i, tail in enumerate((8, 4, 12, 6, 10, 5))
    ]
    report = {"arch": cfg.name, "page_size": page, "requests": len(reqs),
              "max_slots": 2, "layouts": {}}
    for layout in ("contiguous", "paged"):
        eng = ServeEngine(
            cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
            max_slots=2, cache_capacity=40,
            paged=layout == "paged", page_size=page,
        )
        eng.run(reqs)  # warm the compile caches
        t0 = time.perf_counter()
        results = eng.run(reqs)
        wall = time.perf_counter() - t0
        new_tokens = sum(len(r.tokens) for r in results.values())
        wire = eng.wire_summary()
        decode_steps = wire["decode_steps"]
        entry = {
            "wall_s": round(wall, 4),
            "new_tokens": new_tokens,
            "tokens_per_s": round(new_tokens / wall, 2),
            "decode_step_us": round(1e6 * wall / max(decode_steps, 1), 1),
            "wire_bytes_per_token": round(
                wire["host_device"] / new_tokens, 2
            ),
        }
        if layout == "paged":
            res = eng.kv_residency()
            cap_tokens = eng.pages.peak * page
            entry["kv_bytes_resident_per_token"] = round(
                res["kv_bytes_peak"] / cap_tokens
            )
            entry["pages_peak"] = res["pages_peak"]
            entry["prefill_compiles"] = wire["prefill_misses"]
            entry["prefill_bucket_hits"] = wire["prefill_hits"]
        else:
            # contiguous: every slot holds full capacity whether used
            # or not — the resident-bytes-per-token baseline paging beats
            kv_bytes = _page_pool_equiv_bytes(cfg, 40, 2)
            entry["kv_bytes_resident_per_token"] = round(kv_bytes / (40 * 2))
        report["layouts"][layout] = entry
        row(
            f"serve.{layout}_tokens_per_s", entry["decode_step_us"],
            f"tok_per_s={entry['tokens_per_s']}"
            f"_wireB_per_tok={entry['wire_bytes_per_token']}",
        )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    row("serve.bench_json", 0.0, f"wrote={out_path}")


def spec_decode_bench(out_path="BENCH_serve.json"):
    """Speculative-decoding benchmark: sampled requests drained three
    ways on the same engine geometry — non-speculative, spec with the
    target as its own draft (acceptance pinned 1.0), and spec with the
    auto-shrunk tiny draft. Asserts all three produce IDENTICAL token
    streams (speculation moves wall-clock/wire shape, never content)
    and the measured wire equals ``serve_spec_decode_bytes``. Merges a
    ``spec_decode`` section into the committed ``BENCH_serve.json``."""
    from repro.configs.registry import get_config, reduced
    from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
    from repro.models.init import init_params
    from repro.plan import PrecisionPlan, SamplingParams
    from repro.roofline.analysis import serve_spec_decode_bytes
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.spec import DraftBundle, build_draft
    from repro.transport import CompressionPolicy

    spec_k = 3
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=4096)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2),) * (cfg.num_groups + 1),
        host_device=CompressionPolicy(round_to=2),
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt_ids=tuple(
            int(t) for t in rng.integers(0, cfg.vocab_size, s)),
            max_new=12,
            sampling=SamplingParams(temperature=0.8, top_p=0.95,
                                    top_k=40, seed=100 + i))
        for i, s in enumerate((16, 12, 16, 8))
    ]
    drafts = {
        "none": None,
        "self": DraftBundle(cfg, spec_tree, storage),
        "tiny": build_draft(cfg, mesh_cfg, "tiny"),
    }
    section = {"spec_k": spec_k, "sampling": "temp=0.8,p=0.95,k=40",
               "drafts": {}}
    streams = {}
    for name, draft in drafts.items():
        eng = ServeEngine(
            cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
            max_slots=2, cache_capacity=32,
            draft=draft, spec_k=spec_k if draft is not None else None,
        )
        eng.run(reqs)  # warm the compile caches
        t0 = time.perf_counter()
        results = eng.run(reqs)
        wall = time.perf_counter() - t0
        streams[name] = {r.rid: results[r.rid].tokens for r in reqs}
        assert streams[name] == streams["none"], name  # identical streams
        new_tokens = sum(len(r.tokens) for r in results.values())
        wire = eng.wire_summary()
        entry = {
            "wall_s": round(wall, 4),
            "new_tokens": new_tokens,
            "tokens_per_s": round(new_tokens / wall, 2),
            "wire_bytes_per_token": round(
                wire["host_device"] / new_tokens, 2),
        }
        if draft is not None:
            analytic = serve_spec_decode_bytes(
                plan, cfg.vocab_size, n_slots=2,
                prompt_lens=[len(r.prompt_ids) for r in reqs],
                spec_rounds=wire["spec_rounds"], spec_k=spec_k,
            )
            assert wire["host_device"] == analytic["total"], (wire, analytic)
            entry["acceptance_rate"] = round(wire["acceptance_rate"], 4)
            entry["tokens_per_target_step"] = round(
                wire["tokens_per_target_step"], 4)
            entry["spec_rounds"] = wire["spec_rounds"]
            entry["analytic_match"] = True
        section["drafts"][name] = entry
        row(
            f"spec.{name}_tokens_per_s", 1e6 * wall,
            f"tok_per_s={entry['tokens_per_s']}"
            + (f"_accept={entry['acceptance_rate']}"
               f"_tps={entry['tokens_per_target_step']}"
               if draft is not None else ""),
        )
    report = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            report = json.load(f)
    report["spec_decode"] = section
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    row("spec.bench_json", 0.0, f"wrote={out_path}")


def fleet_bench(out_path="BENCH_fleet.json"):
    """Fleet-tier benchmark: a 2-replica disaggregated fleet (1 prefill
    worker, paged engines) on a mixed request set, fp32 and int8 KV
    pools. Emits ``BENCH_fleet.json`` with tokens/sec and fabric
    migration bytes per token per plan point, asserting the measured
    hop log equals ``fleet_migration_bytes`` — the committed snapshot
    CI regenerates and uploads as an artifact."""
    import dataclasses

    from repro.configs.registry import get_config, reduced
    from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
    from repro.fleet import (
        DecodeReplica,
        FleetRouter,
        PrefillWorker,
        WeightPublisher,
    )
    from repro.models.init import init_params
    from repro.plan import PrecisionPlan
    from repro.roofline.analysis import fleet_migration_bytes
    from repro.serve.engine import Request, ServeEngine
    from repro.transport import CompressionPolicy

    page = 8
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=4096)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    base_plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2),) * (cfg.num_groups + 1),
        host_device=CompressionPolicy(round_to=2),
    )
    rng = np.random.default_rng(0)
    shared = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, page))
    reqs = [
        Request(rid=i, prompt_ids=shared + tuple(
            int(t) for t in rng.integers(0, cfg.vocab_size, tail)),
            max_new=8)
        for i, tail in enumerate((8, 4, 12, 6, 10, 5))
    ]
    report = {"arch": cfg.name, "page_size": page, "replicas": 2,
              "workers": 1, "requests": len(reqs), "plans": {}}
    for point in ("fp32_kv", "int8_kv"):
        plan = (dataclasses.replace(base_plan, int8_kv=True)
                if point == "int8_kv" else base_plan)
        engines = [
            ServeEngine(
                cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
                max_slots=2, cache_capacity=28, paged=True, page_size=page,
            )
            for _ in range(2)
        ]
        worker = PrefillWorker(
            "w0", cfg, mesh_cfg, None, spec_tree, plan=plan,
            cache_capacity=28, page_size=page,
        )
        publisher = WeightPublisher(cfg, spec_tree, plan=plan)
        parcel = publisher.publish(storage)

        def fleet_run():
            router = FleetRouter(
                [DecodeReplica(f"r{i}", e) for i, e in enumerate(engines)],
                [worker],
            )
            router.publish(publisher.publish(storage))
            return router, router.run(reqs)

        fleet_run()  # warm the compile caches
        t0 = time.perf_counter()
        router, results = fleet_run()
        wall = time.perf_counter() - t0
        new_tokens = sum(len(r.tokens) for r in results.values())
        ws = router.wire_summary()
        analytic = fleet_migration_bytes(
            plan, cfg, page_size=page,
            migrated_pages=ws["migrated_pages"],
            int8_kv=plan.int8_kv, publish_wire_bytes=parcel.nbytes,
            publish_installs=ws["publish_installs"],
        )
        for cls in ("kv_migration", "weight_publish"):
            assert ws[cls] == analytic[cls], (point, cls, ws, analytic)
        entry = {
            "wall_s": round(wall, 4),
            "new_tokens": new_tokens,
            "tokens_per_s": round(new_tokens / wall, 2),
            "ticks": ws["ticks"],
            "migrated_pages": ws["migrated_pages"],
            "page_wire_bytes": analytic["page_wire_bytes"],
            "kv_wire_width": analytic["kv_width"],
            "kv_migration_bytes": ws["kv_migration"],
            "kv_migration_bytes_per_token": round(
                ws["kv_migration"] / new_tokens, 2
            ),
            "weight_publish_bytes": ws["weight_publish"],
            "publish_installs": ws["publish_installs"],
            "analytic_match": True,
        }
        report["plans"][point] = entry
        row(
            f"fleet.{point}_tokens_per_s", 1e6 * wall / max(ws["ticks"], 1),
            f"tok_per_s={entry['tokens_per_s']}"
            f"_migB_per_tok={entry['kv_migration_bytes_per_token']}",
        )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    row("fleet.bench_json", 0.0, f"wrote={out_path}")


def train_io_bench(out_path="BENCH_train.json"):
    """Training-I/O benchmark: tiered shard ingest through the
    prefetcher + width-aware sync/async checkpointing on the reduced
    qwen3-1.7b. Emits ``BENCH_train.json`` with steps/sec, ingest bytes
    per step at two quality tiers (measured == analytic asserted), and
    checkpoint wall/bytes for sync vs async saves — the committed
    snapshot CI regenerates and uploads as an artifact."""
    import shutil
    import tempfile

    from repro.checkpoint.ckpt import (
        AsyncCheckpointer, ckpt_dir, save_checkpoint,
    )
    from repro.checkpoint.sharded import manifest_bytes, read_meta
    from repro.configs.registry import get_config, reduced
    from repro.data.prefetch import Prefetcher
    from repro.data.shards import ShardReader, batches, write_lm_shards
    from repro.dist.spec import (
        MeshCfg, build_spec_tree, dist_elems_per_group, tree_to_storage,
    )
    from repro.models.init import init_params
    from repro.optim.sgd import SGDConfig, init_momentum
    from repro.plan import PrecisionPlan
    from repro.roofline.analysis import (
        train_checkpoint_bytes, train_ingest_bytes,
    )
    from repro.train.loop import Trainer
    from repro.train.step import make_train_step

    b, seq, steps = 2, 32, 6
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh_cfg = MeshCfg()
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    nrt = cfg.num_groups + 1
    plan = PrecisionPlan.build(nrt, round_to=2, schedule="static")
    shapes = {
        "tokens": jax.ShapeDtypeStruct((b, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, seq), jnp.int32),
    }
    trainer = Trainer(
        lambda rts: make_train_step(
            cfg, mesh_cfg, None, spec_tree, SGDConfig(lr=0.05),
            shapes, plan=plan.with_round_tos(rts),
        ),
        nrt, plan=plan,
        dist_elems_per_group=dist_elems_per_group(spec_tree, mesh_cfg, nrt),
        gather_axis_size=1,
    )
    mom = init_momentum(storage)
    tmp = tempfile.mkdtemp(prefix="train_io_bench_")
    report = {"arch": cfg.name, "batch": b, "seq": seq, "steps": steps,
              "ingest": {}, "checkpoint": {}}
    try:
        # LM shards are all-integer (lossless floor), so quality is moot
        # here: one ingest entry, first (compile) step excluded from the
        # timing but included in the measured-vs-analytic byte pin
        shard_dir = os.path.join(tmp, "shards")
        write_lm_shards(shard_dir, vocab=cfg.vocab_size, seq=seq,
                        num_records=b * (steps + 1))
        rd = ShardReader(shard_dir, seed=0)
        analytic = train_ingest_bytes(
            plan, cfg.vocab_size, kind="lm", batch=b, seq=seq,
            steps=steps + 1, reader=rd,
        )
        pf = Prefetcher(batches(rd, b), kind="lm",
                        vocab=cfg.vocab_size, plan=plan)
        io = {"shard_read": 0, "host_device": 0}
        t0 = None
        for _ in range(steps + 1):
            batch, log = pf.next()
            storage, mom, m = trainer.run_step(
                storage, mom, batch, 0.05, io_log=log,
            )
            io = {k: io[k] + log[k] for k in io}
            if t0 is None:  # warmup step done: compile paid, start clock
                jax.block_until_ready(m["loss"])
                t0 = time.perf_counter()
        jax.block_until_ready(m["loss"])
        wall = time.perf_counter() - t0
        pf.close()
        rd.close()
        assert io["shard_read"] == analytic["shard_read"], (io, analytic)
        assert io["host_device"] == analytic["ingest_h2d"], (io, analytic)
        report["ingest"] = {
            "steps_per_s": round(steps / wall, 2),
            "shard_read_bytes_per_step": io["shard_read"] // (steps + 1),
            "h2d_bytes_per_step": io["host_device"] // (steps + 1),
            "token_width": analytic["token_width"],
        }
        row(
            "train_io.ingest", 1e6 * wall / steps,
            f"shardB_per_step={io['shard_read'] // (steps + 1)}"
            f"_h2dB_per_step={io['host_device'] // (steps + 1)}",
        )
        rts = trainer.current_round_tos()
        for mode in ("sync", "async"):
            ck = os.path.join(tmp, f"ck_{mode}")
            ac = AsyncCheckpointer() if mode == "async" else None
            t0 = time.perf_counter()
            save_checkpoint(ck, storage, mom, trainer.controller, steps,
                            plan=plan, spec_tree=spec_tree, round_tos=rts,
                            async_ckpt=ac)
            t_submit = time.perf_counter() - t0
            if ac is not None:
                ac.wait()
            t_total = time.perf_counter() - t0
            mb = manifest_bytes(read_meta(ckpt_dir(ck)))
            entry = {
                "submit_us": round(1e6 * t_submit, 1),
                "total_us": round(1e6 * t_total, 1),
                "wire_bytes": mb["wire"],
                "residual_bytes": mb["residual"],
                "total_bytes": mb["total"],
            }
            report["checkpoint"][mode] = entry
            row(f"train_io.ckpt_{mode}", entry["total_us"],
                f"submit_us={entry['submit_us']}_totalB={mb['total']}")
        analytic_ck = train_checkpoint_bytes(
            storage, mom, spec_tree=spec_tree, round_tos=rts,
        )
        assert analytic_ck == {
            k: report["checkpoint"]["sync"][f"{k}_bytes"]
            for k in ("wire", "residual", "total")
        }
        full = train_checkpoint_bytes(storage, mom, spec_tree=spec_tree,
                                      round_tos=(4,) * nrt)
        report["checkpoint"]["wire_vs_fp32"] = round(
            analytic_ck["wire"] / full["wire"], 4
        )
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    row("train_io.bench_json", 0.0, f"wrote={out_path}")


def _page_pool_equiv_bytes(cfg, capacity, slots):
    """Contiguous-layout resident KV bytes (fp32): every attn layer holds
    slots x capacity x kv_heads x head_dim x 2 (K+V)."""
    layers = cfg.num_groups * cfg.layers_per_group
    attn = sum(1 for k in cfg.pattern if k == "attn") / len(cfg.pattern)
    return int(
        layers * attn * 2 * slots * capacity
        * cfg.num_kv_heads * cfg.head_dim * 4
    )


def roofline_table():
    """§Roofline terms from the dry-run JSONs (if present)."""
    for mesh_name, path in (
        ("16x16", "results/dryrun_single_pod.json"),
        ("2x16x16", "results/dryrun_multi_pod.json"),
    ):
        if not os.path.exists(path):
            continue
        with open(path) as f:
            results = json.load(f)
        for r in results:
            tag = f"roofline.{mesh_name}.{r['arch']}.{r['shape']}"
            if "skipped" in r:
                row(tag, 0.0, "skipped=" + r["skipped"].split(":")[0])
                continue
            if "error" in r:
                row(tag, 0.0, "ERROR")
                continue
            rf = r["roofline"]
            row(
                tag,
                1e6 * max(rf["compute_s"], rf["memory_s"], rf["collective_s"]),
                f"dom={rf['dominant']}_c={rf['compute_s']:.3f}"
                f"_m={rf['memory_s']:.3f}_x={rf['collective_s']:.3f}"
                f"_useful={rf['useful_ratio']:.2f}",
            )


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    entries = [
        ("table2_3_profile", table2_3_profile),
        ("fig2_bitpack_kernel", fig2_bitpack_kernel),
        ("compression_ratio", compression_ratio),
        ("fig4_normalized_time", fig4_normalized_time),
        ("fig3_convergence", lambda: fig3_convergence(
            steps=int(os.environ.get("BENCH_FIG3_STEPS", "140"))
        )),
        ("serve_engine_bench", serve_engine_bench),
        ("spec_decode_bench", spec_decode_bench),
        ("fleet_bench", fleet_bench),
        ("train_io_bench", train_io_bench),
        ("roofline_table", roofline_table),
    ]
    print("name,us_per_call,derived")
    for name, fn in entries:
        if only and only not in name:
            continue
        fn()
    print(f"# {len(ROWS)} rows", file=sys.stderr)


if __name__ == "__main__":
    main()
