"""Execution environment threaded through every model function.

Carries the mesh-axis names (None = single device: every collective helper
degrades to identity), the TP degree, compute dtype, and the performance
levers toggled during §Perf hillclimbing.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
from jax import lax

from repro.core.collectives import tp_region_enter, tp_region_exit


@dataclasses.dataclass(frozen=True)
class Env:
    model_axis: str | None = None           # TP axis name
    fsdp_axes: tuple[str, ...] | None = None  # weight-gather axes
    tp: int = 1
    dtype: Any = jnp.float32                # compute dtype (bf16 = beyond-paper)
    attn_chunk: int = 1024                  # flash-chunk size (q and kv)
    causal_skip: bool = True                # skip fully-masked kv chunks
    seq_parallel: bool = False              # sequence-parallel activations
    int8_kv: bool = False                   # int8 KV cache (decode, §Perf)
    mlstm_chunk: int = 0                    # chunkwise mLSTM (0 = sequential)

    # ------------------------------------------------------------------
    def enter(self, x):
        """Megatron 'f': identity fwd / model-axis psum bwd."""
        if self.model_axis is None:
            return x
        return tp_region_enter(x, self.model_axis)

    def exit(self, x):
        """Megatron 'g': model-axis psum fwd / identity bwd."""
        if self.model_axis is None:
            return x
        return tp_region_exit(x, self.model_axis)

    def model_rank(self):
        if self.model_axis is None:
            return 0
        return lax.axis_index(self.model_axis)

    def heads_local(self, heads: int) -> int:
        """Local head count when sharding `heads` over the model axis
        (replicated up when heads < tp, see DESIGN.md kv-replication note)."""
        return max(1, heads // self.tp)

    def ff_local(self, ff: int) -> int:
        return max(1, ff // self.tp)
