"""The paper's own evaluation networks in pure JAX: AlexNet (modified, extra
FC-4096 — §IV-B), VGG-A, and ResNet-34, plus reduced variants for the CPU
reproduction runs.

These are data-parallel only (the paper's setting: one model replica per
GPU, master weights on the host) — the FSDP axis of our TPU mapping plays
the host's role, and ADT compresses the per-batch weight gather exactly
like the paper's CPU→GPU send. AWP here runs at *per-layer* granularity
(the paper's main mode; ResNet uses block granularity, §IV-B).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.meta import ParamMeta

# layer spec atoms:
#   ("conv", out_ch, kernel, stride)        conv + ReLU
#   ("pool",)                               2x2 max pool
#   ("block", out_ch, stride, repeats)      resnet basic block group
#   ("gap",)                                global average pool
#   ("fc", width)                           fully-connected + ReLU (+dropout)


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    layers: tuple
    num_classes: int = 200
    in_hw: int = 224
    in_ch: int = 3
    dropout: float = 0.5
    # paper §IV-B: ResNet adapts precision per *building block*
    awp_granularity: str = "layer"  # "layer" | "block"
    # paper §IV-B initialises every weight N(0, 1e-2); that assumes the
    # full-scale topology/dataset — the reduced CPU runs use He init
    # (orthogonal to AWP/ADT, noted in DESIGN.md §8)
    paper_init: bool = True
    # ResNet uses batch normalization (He et al. 2016); norm params are
    # uncompressed, like the paper's biases
    batch_norm: bool = False


ALEXNET = CNNConfig(
    "alexnet",
    (
        ("conv", 64, 11, 4), ("pool",),
        ("conv", 192, 5, 1), ("pool",),
        ("conv", 384, 3, 1), ("conv", 384, 3, 1), ("conv", 256, 3, 1),
        ("pool",),
        ("fc", 4096), ("fc", 4096), ("fc", 4096),  # extra FC-4096 (paper)
    ),
)

VGG_A = CNNConfig(
    "vgg-a",
    (
        ("conv", 64, 3, 1), ("pool",),
        ("conv", 128, 3, 1), ("pool",),
        ("conv", 256, 3, 1), ("conv", 256, 3, 1), ("pool",),
        ("conv", 512, 3, 1), ("conv", 512, 3, 1), ("pool",),
        ("conv", 512, 3, 1), ("conv", 512, 3, 1), ("pool",),
        ("fc", 4096), ("fc", 4096),
    ),
)

RESNET34 = CNNConfig(
    "resnet-34",
    (
        ("conv", 64, 7, 2), ("pool",),
        ("block", 64, 1, 3), ("block", 128, 2, 4),
        ("block", 256, 2, 6), ("block", 512, 2, 3),
        ("gap",),
    ),
    awp_granularity="block",
    batch_norm=True,
)


def reduced_cnn(cfg: CNNConfig, num_classes: int = 10, in_hw: int = 32) -> CNNConfig:
    """CPU-scale variant of the same family (channels /8, fc /32)."""
    out = []
    for spec in cfg.layers:
        if spec[0] == "conv":
            _, ch, k, s = spec
            out.append(("conv", max(8, ch // 8), min(k, 5), min(s, 2)))
        elif spec[0] == "block":
            _, ch, s, n = spec
            out.append(("block", max(8, ch // 8), s, min(n, 2)))
        elif spec[0] == "fc":
            out.append(("fc", max(32, spec[1] // 32)))
        else:
            out.append(spec)
    # deep plain stacks (VGG/ResNet) need normalization to train at this
    # reduced scale with plain SGD; full-scale VGG-A trains without BN in
    # the paper — scale artifact, noted in DESIGN.md §8.
    add_bn = cfg.batch_norm or cfg.name.startswith("vgg")
    return dataclasses.replace(
        cfg, name=cfg.name + "-mini", layers=tuple(out),
        num_classes=num_classes, in_hw=in_hw, dropout=0.1,
        paper_init=False, batch_norm=add_bn,
    )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_cnn(cfg: CNNConfig, key):
    """(params, metas, group_of_layer). params = {"layers": {name: {...}}}.

    group_of_layer maps each compressed layer name -> AWP group index.
    Weight init: zero-mean normal, var 1e-2 (paper §IV-B); biases 0.1 for
    AlexNet, 0 otherwise (paper §IV-B)."""
    params, metas = {}, {}
    groups: dict[str, int] = {}
    bias0 = 0.1 if cfg.name.startswith("alexnet") else 0.0
    hw, ch = cfg.in_hw, cfg.in_ch
    gidx = 0
    n = 0

    def _std(fan_in):
        return 0.1 if cfg.paper_init else math.sqrt(2.0 / fan_in)

    def conv_entry(name, cin, cout, k, group):
        nonlocal key
        key, sub = jax.random.split(key)
        params[name] = {
            "w": _std(k * k * cin)
            * jax.random.normal(sub, (k, k, cin, cout), jnp.float32),
            "b": jnp.full((cout,), bias0, jnp.float32),
        }
        metas[name] = {
            "w": ParamMeta(tp_dim=None, compress=True),
            "b": ParamMeta(tp_dim=None, compress=False),
        }
        if cfg.batch_norm:
            params[name]["bn_scale"] = jnp.ones((cout,), jnp.float32)
            params[name]["bn_bias"] = jnp.zeros((cout,), jnp.float32)
            metas[name]["bn_scale"] = ParamMeta(tp_dim=None, compress=False)
            metas[name]["bn_bias"] = ParamMeta(tp_dim=None, compress=False)
        groups[name] = group

    for spec in cfg.layers:
        kind = spec[0]
        if kind == "conv":
            _, cout, k, s = spec
            conv_entry(f"conv{n}", ch, cout, k, gidx)
            ch = cout
            hw = max(1, math.ceil(hw / s))
            n += 1
            if cfg.awp_granularity == "layer":
                gidx += 1
        elif kind == "pool":
            hw = max(1, hw // 2)
        elif kind == "block":
            _, cout, s, reps = spec
            for r in range(reps):
                stride = s if r == 0 else 1
                conv_entry(f"block{n}a", ch, cout, 3, gidx)
                conv_entry(f"block{n}b", cout, cout, 3, gidx)
                if stride != 1 or ch != cout:
                    conv_entry(f"block{n}p", ch, cout, 1, gidx)
                ch = cout
                hw = max(1, math.ceil(hw / stride))
                n += 1
                gidx += 1  # per building block (paper: ResNet granularity)
        elif kind == "gap":
            hw = 1
        elif kind == "fc":
            width = spec[1]
            cin = ch * hw * hw if hw > 1 else ch
            key, sub = jax.random.split(key)
            params[f"fc{n}"] = {
                "w": _std(cin)
                * jax.random.normal(sub, (cin, width), jnp.float32),
                "b": jnp.full((width,), bias0, jnp.float32),
            }
            metas[f"fc{n}"] = {
                "w": ParamMeta(tp_dim=None, compress=True),
                "b": ParamMeta(tp_dim=None, compress=False),
            }
            groups[f"fc{n}"] = gidx
            ch, hw = width, 1
            n += 1
            if cfg.awp_granularity == "layer":
                gidx += 1
        else:
            raise ValueError(kind)
    if cfg.awp_granularity == "block" and cfg.layers[-1][0] != "fc":
        gidx += 0
    # classifier head
    key, sub = jax.random.split(key)
    cin = ch * hw * hw if hw > 1 else ch
    params["head"] = {
        "w": _std(cin)
        * jax.random.normal(sub, (cin, cfg.num_classes), jnp.float32),
        "b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }
    metas["head"] = {
        "w": ParamMeta(tp_dim=None, compress=True),
        "b": ParamMeta(tp_dim=None, compress=False),
    }
    groups["head"] = gidx
    num_groups = gidx + 1
    return {"layers": params}, {"layers": metas}, (groups, num_groups)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _conv(x, w, b, stride):
    y = lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b[None, None, None, :]


def _bn(x, layer):
    """Batch-statistics normalization (batch stats in train AND eval — the
    synthetic-data demo has i.i.d. batches, so this is equivalent up to
    noise; running stats omitted, noted in DESIGN.md §8)."""
    if "bn_scale" not in layer:
        return x
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + 1e-5)
    return xn * layer["bn_scale"] + layer["bn_bias"]


def _conv_bn(x, layer, stride):
    return _bn(_conv(x, layer["w"], layer["b"], stride), layer)


def cnn_forward(layers, images, cfg: CNNConfig, *, train: bool, key=None,
                act_quant=None):
    """images (B, H, W, C) -> logits (B, num_classes). ``layers`` is the
    materialized params dict {"convN": {w, b}, ...}. ``act_quant`` is an
    optional straight-through format truncation applied at stage
    boundaries (the activation-policy analogue of the paper's weight
    transfer: DP CNNs have no TP axis, so the activation group models
    the HBM/host motion of the stage outputs instead of a collective)."""
    aq = act_quant if act_quant is not None else (lambda v: v)
    x = images
    n = 0
    for spec in cfg.layers:
        kind = spec[0]
        if kind == "conv":
            _, cout, k, s = spec
            x = aq(jax.nn.relu(_conv_bn(x, layers[f"conv{n}"], s)))
            n += 1
        elif kind == "pool":
            x = lax.reduce_window(
                x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "SAME"
            )
        elif kind == "block":
            _, cout, s, reps = spec
            for r in range(reps):
                stride = s if r == 0 else 1
                ident = x
                y = jax.nn.relu(_conv_bn(x, layers[f"block{n}a"], stride))
                y = _conv_bn(y, layers[f"block{n}b"], 1)
                if f"block{n}p" in layers:
                    ident = _conv_bn(x, layers[f"block{n}p"], stride)
                x = aq(jax.nn.relu(y + ident))
                n += 1
        elif kind == "gap":
            x = jnp.mean(x, axis=(1, 2))
        elif kind == "fc":
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            x = aq(jax.nn.relu(x @ layers[f"fc{n}"]["w"] + layers[f"fc{n}"]["b"]))
            if train and cfg.dropout and key is not None:
                key = jax.random.fold_in(key, n)
                keep = jax.random.bernoulli(key, 1 - cfg.dropout, x.shape)
                x = jnp.where(keep, x / (1 - cfg.dropout), 0)
            n += 1
    if x.ndim > 2:
        x = x.reshape(x.shape[0], -1)
    return x @ layers["head"]["w"] + layers["head"]["b"]


def cnn_loss(layers, images, labels, cfg, *, train=True, key=None,
             act_quant=None):
    logits = cnn_forward(
        layers, images, cfg, train=train, key=key, act_quant=act_quant
    )
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def topk_error(layers, images, labels, cfg, k=5):
    logits = cnn_forward(layers, images, cfg, train=False)
    k = min(k, logits.shape[-1])
    _, top = lax.top_k(logits, k)
    hit = jnp.any(top == labels[:, None], axis=1)
    return 1.0 - jnp.mean(hit.astype(jnp.float32))
