"""Disaggregated multi-replica serving tier (`repro.fleet`).

The serve engine scaled out (see docs/fleet.md): a host-side
:class:`FleetRouter` load-balances an admission queue over several
paged :class:`~repro.serve.engine.ServeEngine` replicas
(:class:`DecodeReplica`), with prefill disaggregated onto dedicated
:class:`PrefillWorker` roles whose KV pages migrate to the decode
fleet as compressed byte-plane parcels through the priced
:class:`~repro.transport.FabricChannel` (``kv_migration`` traffic
class), and live weight refresh fed by a trainer-side
:class:`WeightPublisher` (``weight_publish`` class, versioned-at-
admission rolling installs).

Everything is deterministic and lossless by construction: router-level
token streams are bit-exact against a single engine and against
``generate_static``; the fabric hop log is pinned EQUAL to the
analytic :func:`repro.roofline.analysis.fleet_migration_bytes`.
"""
from repro.fleet.errors import ReplicaError, RouterError
from repro.fleet.publish import WeightPublisher
from repro.fleet.replica import DecodeReplica, PrefillWorker, check_fleet_arch
from repro.fleet.router import FleetRouter

__all__ = [
    "DecodeReplica",
    "FleetRouter",
    "PrefillWorker",
    "ReplicaError",
    "RouterError",
    "WeightPublisher",
    "check_fleet_arch",
]
