"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Follows arXiv:2405.04517 with the exponential-gating stabilizer ``m``:

mLSTM (per head, d_k = d_v = head width):
    m_t   = max(f̃_t + m_{t-1}, ĩ_t)
    i'_t  = exp(ĩ_t − m_t);  f'_t = exp(f̃_t + m_{t-1} − m_t)
    C_t   = f'_t C_{t-1} + i'_t k_t v_tᵀ
    n_t   = f'_t n_{t-1} + i'_t k_t
    h_t   = C_tᵀ q_t / max(|n_tᵀ q_t|, 1)

sLSTM (per unit, heads mix via block-diagonal recurrent matrices):
    c_t = f'_t c_{t-1} + i'_t z_t ;  n_t = f'_t n_{t-1} + i'_t
    h_t = o_t · c_t / n_t

Both expose a ``lax.scan`` training path and an O(1)-state single-step
decode path (this is why xlstm-1.3b runs ``long_500k`` natively).

TP mapping (DESIGN.md §5): mLSTM shards the value dimension (and the down
projection) over the model axis; q/k/gate projections are replicated
(4 heads < 16 shards — head sharding impossible). sLSTM compute is fully
replicated over the model axis: its per-layer weights are ~8·(d/H)·d,
negligible next to the mLSTM projections.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.env import Env
from repro.models.layers import rms_norm


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MLSTMState:
    C: jnp.ndarray  # (B, H, dk, dv_local)
    n: jnp.ndarray  # (B, H, dk)
    m: jnp.ndarray  # (B, H)

    def tree_flatten(self):
        return (self.C, self.n, self.m), None

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SLSTMState:
    c: jnp.ndarray  # (B, d)
    n: jnp.ndarray  # (B, d)
    h: jnp.ndarray  # (B, d)
    m: jnp.ndarray  # (B, d)

    def tree_flatten(self):
        return (self.c, self.n, self.h, self.m), None

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch)


def init_mlstm_state(batch, heads, dk, dv_local, dtype):
    return MLSTMState(
        jnp.zeros((batch, heads, dk, dv_local), dtype),
        jnp.zeros((batch, heads, dk), dtype),
        jnp.full((batch, heads), -1e30, dtype),
    )


def init_slstm_state(batch, d, dtype):
    z = jnp.zeros((batch, d), dtype)
    return SLSTMState(z, z, z, jnp.full((batch, d), -1e30, dtype))


def _mlstm_step(state: MLSTMState, qkvif):
    q, k, v, i_t, f_t = qkvif  # q,k: (B,H,dk); v: (B,H,dvl); i,f: (B,H)
    dk = q.shape[-1]
    m_new = jnp.maximum(f_t + state.m, i_t)
    ip = jnp.exp(i_t - m_new)
    fp = jnp.exp(f_t + state.m - m_new)
    C = fp[..., None, None] * state.C + ip[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = fp[..., None] * state.n + ip[..., None] * k
    qs = q * (dk**-0.5)
    num = jnp.einsum("bhkv,bhk->bhv", C, qs)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qs))
    h = num / jnp.maximum(den, 1.0)[..., None]
    return MLSTMState(C, n, m_new), h


def mlstm_chunkwise(q, k, v, i_t, f_t, state: MLSTMState, chunk: int):
    """Chunkwise-parallel mLSTM (xLSTM appendix / GLA-style) — §Perf lever.

    The sequential form reads+writes the (dk, dv) matrix state every
    timestep (the dominant memory term of xlstm train, see EXPERIMENTS.md
    §Roofline); the chunkwise form materializes state once per ``chunk``
    steps and computes intra-chunk interactions as masked matmuls
    (MXU-friendly). Exact up to fp reassociation (tested vs the scan).

    Shapes: q,k (B,S,H,dk); v (B,S,H,dv); i_t,f_t (B,S,H) — f_t already in
    log space (log_sigmoid). Returns (h (B,S,H,dv), final state).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    if S % chunk:
        raise ValueError(
            f"chunkwise mLSTM needs S divisible by chunk, got S={S} "
            f"chunk={chunk}"
        )
    n_chunks = S // chunk
    rs = lambda a: a.reshape(B, n_chunks, chunk, *a.shape[2:]).swapaxes(0, 1)
    qc, kc, vc = rs(q), rs(k), rs(v)
    ic, fc = rs(i_t), rs(f_t)
    scale = dk**-0.5

    def body(carry, inp):
        C_in, n_in, m_in = carry.C, carry.n, carry.m
        qq, kk, vv, ii, ff = inp  # (B, chunk, H, ...)
        b = jnp.cumsum(ff, axis=1)              # (B,chunk,H) log decay 1..t
        btot = b[:, -1:]                        # (B,1,H)
        # stabilizers
        m_inter = b + m_in[:, None]             # (B,chunk,H)
        w_intra_max = jnp.max(ii - b, axis=1, keepdims=True)  # rough bound
        # per-position max over s<=t of (b_t - b_s + i_s): use running max
        g = ii - b                              # (B,chunk,H): i_s - b_s
        g_run = jax.lax.cummax(g, axis=1)       # max_{s<=t}
        m_t = jnp.maximum(m_inter, b + g_run)   # (B,chunk,H)
        # intra-chunk: D_ts = exp(b_t - b_s + i_s - m_t), s <= t
        wmat = (
            b[:, :, None] - b[:, None, :] + ii[:, None, :]
            - m_t[:, :, None]
        )  # (B, t, s, H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        d = jnp.where(mask[None, :, :, None], jnp.exp(wmat), 0.0)
        s_qk = jnp.einsum(
            "bthd,bshd->btsh", qq, kk, preferred_element_type=jnp.float32
        ) * scale
        h_intra = jnp.einsum(
            "btsh,bshv->bthv", s_qk * d, vv,
            preferred_element_type=jnp.float32,
        )
        n_intra = jnp.einsum("btsh,bshd->bthd", d, kk,
                             preferred_element_type=jnp.float32)
        # inter-chunk
        dec = jnp.exp(m_inter - m_t)            # (B,chunk,H)
        h_inter = jnp.einsum(
            "bthd,bhdv->bthv", qq * scale, C_in,
            preferred_element_type=jnp.float32,
        ) * dec[..., None]
        n_tot = n_intra + n_in[:, None] * dec[..., None]
        num = h_intra + h_inter
        den = jnp.abs(
            jnp.einsum("bthd,bthd->bth", qq * scale, n_tot)
        )
        h = (num / jnp.maximum(den, 1.0)[..., None]).astype(q.dtype)

        # state to next chunk
        m_out = jnp.maximum(btot[:, 0] + m_in, jnp.max(g, axis=1) + btot[:, 0])
        wst = jnp.exp(btot - b + ii - m_out[:, None])  # (B,chunk,H)
        C_out = (
            jnp.exp(btot[:, 0] + m_in - m_out)[..., None, None] * C_in
            + jnp.einsum("bshd,bshv->bhdv", kk * wst[..., None], vv,
                         preferred_element_type=jnp.float32)
        )
        n_out = (
            jnp.exp(btot[:, 0] + m_in - m_out)[..., None] * n_in
            + jnp.einsum("bshd,bsh->bhd", kk, wst,
                         preferred_element_type=jnp.float32)
        )
        return MLSTMState(C_out.astype(C_in.dtype), n_out.astype(n_in.dtype),
                          m_out), h

    state, hs = lax.scan(body, state, (qc, kc, vc, ic, fc))
    return hs.swapaxes(0, 1).reshape(B, S, H, dv), state


def mlstm_block(x, w, cfg, env: Env, *, mode="train", state=None):
    """x: (B,S,d) -> (y, state'). w keys: ln, wq, wk, wv, wi, wf, wog, w_down.

    Under ``env.seq_parallel`` the incoming ``x`` is a sequence shard;
    ``env.enter`` gathers the full sequence (the recurrence is sequential
    in time) and ``env.exit`` reduce-scatters the partial outputs."""
    d = x.shape[-1]
    H = cfg.num_heads
    dv = int(cfg.mlstm_proj_factor * d)
    dv_l = env.ff_local(dv)
    dk = dv // H  # key width per head (= value width pre-sharding)
    dkh = dk

    xn = rms_norm(x, w["ln"], cfg.norm_eps)
    xin = env.enter(xn)
    B, S = xin.shape[:2]
    # value columns use a (dvh, H) layout — outer dim = within-head value
    # index, inner dim = head — so a contiguous TP slice of wv/wog/w_down
    # shards the *within-head* value dim and every rank keeps all heads
    # (4 heads never divide a 16-way model axis; DESIGN.md §5).
    dvh_l = dv_l // H
    q = (xin @ w["wq"]).reshape(B, S, H, dkh)
    k = (xin @ w["wk"]).reshape(B, S, H, dkh)
    v = (xin @ w["wv"]).reshape(B, S, dvh_l, H).transpose(0, 1, 3, 2)
    i_t = (xin @ w["wi"]).reshape(B, S, H)
    f_t = jax.nn.log_sigmoid((xin @ w["wf"]).reshape(B, S, H))
    og = jax.nn.sigmoid(xin @ w["wog"])  # (B,S,dv_l) in (dvh, H) layout

    if state is None:
        state = init_mlstm_state(B, H, dkh, dv_l // H, x.dtype)

    if mode == "decode":
        if S != 1:
            raise ValueError(f"decode expects a single token, got S={S}")
        state, h = _mlstm_step(
            state, (q[:, 0], k[:, 0], v[:, 0], i_t[:, 0], f_t[:, 0])
        )
        h = h[:, None]  # (B,1,H,dvl/H)
    elif env.mlstm_chunk and S % env.mlstm_chunk == 0 and S > env.mlstm_chunk:
        h, state = mlstm_chunkwise(
            q, k, v, i_t, f_t, state, env.mlstm_chunk
        )
    else:
        def body(st, inp):
            st, h = _mlstm_step(st, inp)
            return st, h

        seq = (
            q.transpose(1, 0, 2, 3),
            k.transpose(1, 0, 2, 3),
            v.transpose(1, 0, 2, 3),
            i_t.transpose(1, 0, 2),
            f_t.transpose(1, 0, 2),
        )
        state, hs = lax.scan(body, state, seq)
        h = hs.transpose(1, 0, 2, 3)  # (B,S,H,dvl/H)

    # back to the flat (dvh, H) column layout before gating/down-proj
    h = h.transpose(0, 1, 3, 2).reshape(B, h.shape[1], dv_l)
    h = h * og[:, : h.shape[1]]
    y = env.exit(h @ w["w_down"])
    return y, state


def _slstm_step(state: SLSTMState, wx, r, b, num_heads):
    """One sLSTM step. wx: (B, 4d) precomputed input contributions."""
    B, d4 = wx.shape
    d = d4 // 4
    h_prev = state.h
    # block-diagonal recurrent contribution: r is (H, dh, 4*dh)
    H = num_heads
    dh = d // H
    hh = h_prev.reshape(B, H, dh)
    rec = jnp.einsum("bhi,hio->bho", hh, r)  # (B, H, 4*dh)
    # regroup per-head gate quarters into the (z|i|f|o) layout of wx
    rec = rec.reshape(B, H, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * d)
    pre = wx + rec + b
    z_t, i_t, f_t, o_t = jnp.split(pre, 4, axis=-1)
    z_t = jnp.tanh(z_t)
    o_t = jax.nn.sigmoid(o_t)
    f_log = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(f_log + state.m, i_t)
    ip = jnp.exp(i_t - m_new)
    fp = jnp.exp(f_log + state.m - m_new)
    c = fp * state.c + ip * z_t
    n = fp * state.n + ip
    h = o_t * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c, n, h, m_new), h


def slstm_block(x, w, cfg, env: Env, *, mode="train", state=None):
    """x: (B,S,d) -> (y, state'). Replicated over the model axis.

    w keys: ln, w_in (d, 4d), r (H, dh, 4dh), b (4d,), w_out (d, d).

    sLSTM compute is fully replicated over the model axis, so under
    ``env.seq_parallel`` the shard is re-replicated (fwd all-gather / bwd
    slice) for the recurrence and the output sliced back onto shards."""
    x = env.seq_unshard(x)
    B, S, d = x.shape
    xn = rms_norm(x, w["ln"], cfg.norm_eps)
    wx = xn @ w["w_in"]  # (B,S,4d)
    if state is None:
        state = init_slstm_state(B, d, x.dtype)

    if mode == "decode":
        if S != 1:
            raise ValueError(f"decode expects a single token, got S={S}")
        state, h = _slstm_step(state, wx[:, 0], w["r"], w["b"], cfg.num_heads)
        hs = h[:, None]
    else:
        def body(st, wx_t):
            return _slstm_step(st, wx_t, w["r"], w["b"], cfg.num_heads)

        state, hs = lax.scan(body, state, wx.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)
    y = hs @ w["w_out"]
    return env.seq_shard(y), state
