"""Static data-motion auditor scenarios (multi-device).

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the test
driver sets it): the jaxpr walker and ``audit_step`` trace real
shard_map programs, so the mesh axes must exist even though nothing
executes.

Covers the two layers the auditor is made of:

  * walker unit checks — ``collect_comm_eqns`` on hand-built shard_map
    programs: axis resolution, group sizes, scan multipliers, pmax,
    packed-plane detection, control-flow poisoning.
  * end-to-end pins — registry combos must audit green with jaxpr
    bytes == analytic bytes per non-structural class, and a
    deliberately wrong plan must be *rejected*.
"""
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.audit import AuditError, audit_step, collect_comm_eqns
from repro.audit.cases import build_case, make_plan, parse_mesh
from repro.configs.registry import get_config, reduced
from repro.dist.shard import shard_map
from repro.dist.spec import MeshCfg
from repro.launch.mesh import make_mesh_from_cfg


# ---------------------------------------------------------------------------
# walker unit checks
# ---------------------------------------------------------------------------


def _traced_eqns(inner, *args, mesh_cfg=MeshCfg(dp=2, tp=2),
                 in_specs=P("data"), out_specs=P("data")):
    mesh = make_mesh_from_cfg(mesh_cfg)
    f = shard_map(inner, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return collect_comm_eqns(jax.make_jaxpr(f)(*args))


def test_walker_psum_axes_group_and_scan_mult():
    def inner(x):
        def body(c, _):
            return c + lax.psum(x, "model"), None
        out, _ = lax.scan(body, jnp.zeros_like(x), None, length=3)
        return out

    eqns = _traced_eqns(inner, jnp.zeros((8, 4), jnp.float32))
    psums = [e for e in eqns if e.prim == "psum"]
    assert len(psums) == 1, [e.describe() for e in eqns]
    e = psums[0]
    assert e.axes == ("model",)
    assert e.group_size == 2
    assert e.mult == 3  # scan length multiplies the wire bytes
    assert not e.in_ctrl
    assert e.in_dtype == "float32" and e.in_bytes == 4 * 4 * 4


def test_walker_records_pmax():
    def inner(x):
        return lax.pmax(x, "model")

    eqns = _traced_eqns(inner, jnp.zeros((8, 4), jnp.float32))
    assert [e.prim for e in eqns] == ["pmax"]
    assert eqns[0].axes == ("model",) and eqns[0].group_size == 2


def test_walker_packed_plane_detection():
    def inner(x):
        return lax.all_gather(x, "model", axis=1, tiled=True)

    # uint8 with the plane count as the leading dim = the transport's
    # packed wire format
    eqns = _traced_eqns(inner, jnp.zeros((2, 8, 4), jnp.uint8),
                        out_specs=P(None, "data"),
                        in_specs=P(None, "data"))
    (e,) = eqns
    assert e.prim == "all_gather"
    assert e.is_packed and e.plane_width == 2
    # logical (pre-packing) payload: gathered elements without planes
    assert e.payload_elems == e.out_bytes // 2


def test_walker_poisons_data_dependent_control_flow():
    def inner(x):
        return lax.while_loop(
            lambda c: jnp.sum(c) < 10.0,
            lambda c: lax.psum(c, "model"),
            x,
        )

    eqns = _traced_eqns(inner, jnp.zeros((8, 4), jnp.float32))
    psums = [e for e in eqns if e.prim == "psum"]
    assert psums and all(e.in_ctrl for e in psums)


# ---------------------------------------------------------------------------
# end-to-end audit pins
# ---------------------------------------------------------------------------


def _audit(arch, kind, mesh_spec, plan_name, *, seq_parallel=False,
           plan_override=None):
    mesh_cfg = parse_mesh(mesh_spec)
    n = reduced(get_config(arch)).num_groups + 1
    plan = make_plan(plan_name, n, seq_parallel=seq_parallel)
    case = build_case(arch, kind, mesh_cfg, plan)
    assert case is not None, (arch, kind, "not applicable")
    return audit_step(
        case.step, case.args,
        plan_override if plan_override is not None else case.plan,
        mesh_cfg=mesh_cfg, spec_tree=case.spec_tree, kind=kind,
        mesh=case.mesh,
    )


GREEN_COMBOS = [
    # (arch, kind, mesh, plan, seq_parallel)
    ("qwen3-1.7b", "train", "2x1", "rt4", False),
    ("qwen3-1.7b", "train", "1x2", "rt2", False),
    ("qwen3-1.7b", "train", "1x2", "awp_widened", False),
    ("qwen3-1.7b", "train", "1x2", "rt2", True),
    ("qwen3-1.7b", "prefill", "1x2", "rt2", False),
    ("qwen3-1.7b", "decode", "1x2", "rt2", False),
    ("qwen3-1.7b", "place", "2x1", "rt4", False),
    # DIST leaves with grad_sync_model (mlstm wq/wk) must have their
    # model-axis grad-sync psums in the expected inventory
    ("xlstm-1.3b", "train", "1x2", "rt4", False),
    # cross-attention must stay symbolically connected to the loss
    # (the attend_tiled short-kv truncation regression)
    ("llama-3.2-vision-90b", "train", "1x2", "rt4", False),
]


def test_registry_combos_audit_green():
    for arch, kind, mesh_spec, plan_name, sp in GREEN_COMBOS:
        report = _audit(arch, kind, mesh_spec, plan_name, seq_parallel=sp)
        assert report.ok, (arch, kind, mesh_spec, plan_name,
                           report.violations)
        assert report.n_comm_eqns > 0, (arch, kind, mesh_spec, plan_name)
        # the tentpole pin: traced wire bytes EQUAL the analytic model,
        # class by class (structural classes derive their analytic side
        # from the trace, so equality there is vacuous — skip them)
        for name, c in report.classes.items():
            if c.structural:
                continue
            assert round(c.jaxpr_bytes) == round(c.analytic_bytes), (
                arch, kind, mesh_spec, plan_name, name,
                c.jaxpr_bytes, c.analytic_bytes,
            )


def test_wrong_plan_is_rejected():
    # trace under rt4 (4-byte planes) but audit against rt2: the traced
    # weight traffic no longer matches the plan's inventory
    mesh_cfg = parse_mesh("2x1")
    n = reduced(get_config("qwen3-1.7b")).num_groups + 1
    report = _audit(
        "qwen3-1.7b", "train", "2x1", "rt4",
        plan_override=make_plan("rt2", n),
    )
    assert not report.ok
    try:
        report.raise_if_failed()
    except AuditError as e:
        assert e.report is report
    else:
        raise SystemExit("raise_if_failed did not raise")


def _main():
    tests = [(k, v) for k, v in sorted(globals().items())
             if k.startswith("test_")]
    for name, fn in tests:
        fn()
        print(f"ok {name}")
    print(f"{len(tests)} audit scenarios passed")


if __name__ == "__main__":
    _main()
