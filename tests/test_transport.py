"""Transport layer unit tests: policy accounting + kernel dispatch.

The pallas-vs-ref equivalence here runs through the *Transport dispatch*
(``impl="pallas"`` forces the kernels — interpret mode off-TPU — and
``impl="ref"`` the jnp oracle); the multi-device collective paths are
covered by ``tests/scenarios/scenario_transport.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressed import (
    all_gather_wire_bytes,
    psum_scatter_wire_bytes,
)
from repro.kernels import ref
from repro.transport import (
    CompressionPolicy,
    pack_planes,
    policy_for,
    quantize,
    resolve_impl,
    ring_wire_bytes,
    unpack_planes,
)

ROUND_TOS = (1, 2, 3, 4)
SHAPES = [(7,), (130,), (64, 33), (3, 5, 7), (1,), (40000,), (256, 128)]


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------


def test_policy_validation():
    with pytest.raises(ValueError):
        CompressionPolicy(round_to=5)
    with pytest.raises(ValueError):
        CompressionPolicy(grad_round_to=0)
    with pytest.raises(ValueError):
        CompressionPolicy(mode="floor")
    with pytest.raises(ValueError):
        CompressionPolicy(impl="cuda")
    with pytest.raises(ValueError):
        CompressionPolicy(chunks=0)


def test_policy_for_coercion():
    p = policy_for(2)
    assert p.round_to == 2 and p.grad_round_to == 4
    p2 = policy_for(p, grad_round_to=2)
    assert p2.round_to == 2 and p2.grad_round_to == 2
    assert policy_for(p) is p


def test_policy_wire_accounting_matches_legacy_helpers():
    """core.compressed wire helpers must be pure views of the policy."""
    for rt in ROUND_TOS:
        pol = CompressionPolicy(round_to=rt, grad_round_to=rt)
        for s_loc, n in [(1024, 4), (333, 7), (65536, 256)]:
            assert (
                all_gather_wire_bytes(s_loc, n, rt)
                == pol.all_gather_wire_bytes(s_loc, n)
                == (n - 1) * s_loc * rt
            )
            assert (
                psum_scatter_wire_bytes(s_loc, n, rt)
                == pol.reduce_scatter_wire_bytes(s_loc, n)
                == (n - 1) * s_loc * rt
            )
        assert pol.host_device_bytes(1000) == 1000 * rt
        assert pol.wire_fraction == rt / 4.0


def test_ring_formula_is_shared_source_of_truth():
    # the HLO analyzers charge collectives with the same ring model the
    # policy derives its byte counts from
    assert ring_wire_bytes("all-gather", 16384, 4) == 12288
    assert ring_wire_bytes("all-reduce", 100, 4) == 150
    assert ring_wire_bytes("reduce-scatter", 100, 4) == 75
    assert ring_wire_bytes("collective-permute", 42, 9) == 42
    with pytest.raises(ValueError):
        ring_wire_bytes("broadcast", 1, 2)


def test_activation_wire_accounting():
    """seq_gather / seq_scatter / all-reduce byte formulas derive from the
    shared ring model; compressed all-reduce = rs + ag at round_to."""
    pol = CompressionPolicy(round_to=2, grad_round_to=2)
    n, elems = 4, 4096
    assert pol.seq_gather_wire_bytes(elems, n) == (n - 1) * elems * 2 // n
    assert pol.seq_scatter_wire_bytes(elems, n) == (n - 1) * elems * 2 // n
    # compressed all-reduce: both halves at round_to bytes — exactly
    # round_to/4 of the fp32 ring all-reduce
    fp32 = CompressionPolicy(round_to=4)
    assert (
        pol.all_reduce_wire_bytes(elems, n)
        == fp32.all_reduce_wire_bytes(elems, n) // 2
    )
    assert fp32.all_reduce_wire_bytes(elems, n) == round(
        ring_wire_bytes("all-reduce", elems * 4, n)
    )
    # uncompressed bf16 psums are charged at the compute width
    assert fp32.all_reduce_wire_bytes(elems, n, uncompressed_bytes=2) == round(
        ring_wire_bytes("all-reduce", elems * 2, n)
    )
    # asymmetric policy: cotangent direction follows the GRAD fields
    # (mirrors all_reduce(use_grad_format=True) / the seq VJPs)
    asym = CompressionPolicy(round_to=4, grad_round_to=2)
    assert asym.all_reduce_wire_bytes(elems, n) == round(
        ring_wire_bytes("all-reduce", elems * 4, n)
    )
    assert (
        asym.all_reduce_wire_bytes(elems, n, grad=True)
        == pol.all_reduce_wire_bytes(elems, n)
    )
    assert (
        asym.seq_gather_wire_bytes(elems, n, grad=True)
        == pol.seq_gather_wire_bytes(elems, n)
    )


def test_act_policy_for_cli_helper():
    from repro.transport import act_policy_for

    assert act_policy_for(4) is None
    p = act_policy_for(2)
    assert p.round_to == 2 and p.grad_round_to == 2 and p.mode == "nearest"


def test_pick_split_axis():
    from repro.transport import pick_split_axis

    assert pick_split_axis((8, 32, 48), 2) == 2   # rightmost divisible
    assert pick_split_axis((8, 32, 33), 2) == 1   # odd feature dim: seq
    assert pick_split_axis((8, 1, 48), 2) == 2    # decode (S=1) still ok
    assert pick_split_axis((7, 3), 2) is None     # fallback to lax.psum
    assert pick_split_axis((2,), 4) is None       # dim smaller than group


def test_resolve_impl_backend_aware():
    # no hard-coded interpret: "auto" picks by backend, rounding modes
    # that need PRNG plumbing always take the ref path
    expected = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert resolve_impl("auto") == expected
    assert resolve_impl("pallas") == "pallas"
    assert resolve_impl("ref") == "ref"
    assert resolve_impl("pallas", mode="stochastic") == "ref"


# ---------------------------------------------------------------------------
# pallas-vs-ref equivalence through the dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("round_to", ROUND_TOS)
@pytest.mark.parametrize("shape", SHAPES)
def test_pack_unpack_pallas_matches_ref(shape, round_to):
    w = _rand(shape, seed=round_to, scale=2.0)
    planes_p = pack_planes(w, round_to, impl="pallas")
    planes_r = pack_planes(w, round_to, impl="ref")
    assert planes_p.shape == (round_to,) + shape
    np.testing.assert_array_equal(np.asarray(planes_p), np.asarray(planes_r))
    out_p = unpack_planes(planes_p, impl="pallas")
    out_r = unpack_planes(planes_r, impl="ref")
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_r))
    np.testing.assert_array_equal(
        np.asarray(out_r), np.asarray(ref.quantize_ref(w, round_to))
    )


@pytest.mark.parametrize("impl", ["pallas", "ref", "auto"])
@pytest.mark.parametrize("round_to", ROUND_TOS)
def test_quantize_dispatch_matches_oracle(round_to, impl):
    w = _rand((4097,), seed=11 * round_to, scale=3.0)
    got = quantize(w, CompressionPolicy(round_to=round_to, impl=impl))
    want = ref.quantize_ref(w, round_to)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_straight_through_grad():
    w = _rand((512,), seed=3)
    pol = CompressionPolicy(round_to=2)
    g = jax.grad(lambda x: jnp.sum(quantize(x, pol) * 3.0))(w)
    np.testing.assert_allclose(np.asarray(g), 3.0)
