"""Generate the data tables of EXPERIMENTS.md from results/*.json.

Usage: PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import json
import os


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def roofline_table(path, mesh_name):
    if not os.path.exists(path):
        return f"(missing {path})\n"
    with open(path) as f:
        rs = json.load(f)
    lines = [
        f"### Mesh {mesh_name}\n",
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS/HLO | wire GiB/dev | temp GiB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rs:
        if "skipped" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | "
                f"*skipped: {r['skipped'].split('(')[0].strip()}* | — | — | — | — |"
            )
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR |||||||||")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"**{rf['dominant']}** | {rf['useful_ratio']:.2f} | "
            f"{rf['wire_bytes']/2**30:.2f} | "
            f"{fmt_bytes(r['memory']['temp_bytes'])} | {r['compile_s']} |"
        )
    return "\n".join(lines) + "\n"


def hillclimb_tables(path):
    if not os.path.exists(path):
        return f"(missing {path})\n"
    with open(path) as f:
        out = json.load(f)
    parts = []
    for lname, steps in out.items():
        parts.append(f"### {lname}\n")
        parts.append(
            "| step | compute s | memory s | collective s | dominant | "
            "useful | wire GiB | temp GiB |"
        )
        parts.append("|---|---|---|---|---|---|---|---|")
        for tag, r in steps.items():
            if "roofline" not in r:
                parts.append(f"| {tag} | ERROR |||||||")
                continue
            rf = r["roofline"]
            parts.append(
                f"| {tag} | {rf['compute_s']:.4f} | {rf['memory_s']:.4f} | "
                f"{rf['collective_s']:.4f} | {rf['dominant']} | "
                f"{rf['useful_ratio']:.2f} | {rf['wire_bytes']/2**30:.2f} | "
                f"{fmt_bytes(r['memory']['temp_bytes'])} |"
            )
        parts.append("")
    return "\n".join(parts) + "\n"


def cnn_tables():
    parts = []
    for net in ("alexnet", "vgg", "resnet"):
        path = f"results/cnn_repro_{net}.json"
        if not os.path.exists(path):
            continue
        with open(path) as f:
            res = json.load(f)
        parts.append(f"### {net} (reduced, synthetic ImageNet-200-like)\n")
        parts.append(
            "| policy | final loss | top-5 err | wire reduction | recompiles |"
        )
        parts.append("|---|---|---|---|---|")
        for pol, r in res.items():
            parts.append(
                f"| {pol} | {r['final_loss']:.3f} | "
                f"{r['curve'][-1]['top5_err']:.3f} | "
                f"{r['wire_reduction']*100:.1f}% | {r['recompiles']} |"
            )
        if "awp" in res:
            parts.append(
                f"\nAWP trajectory: `{res['awp']['bits_history']}`\n"
            )
    return "\n".join(parts) + "\n"


def time_to_error():
    """Paper §V accounting: batch time = compute + transfer(bytes/bw), with
    the paper's own x86 VGG compute:transfer ratio (285 ms : 153.93 ms)."""
    parts = []
    T_X, T_C = 153.93e-3, 285e-3
    for net in ("alexnet", "vgg", "resnet"):
        path = f"results/cnn_repro_{net}.json"
        if not os.path.exists(path):
            continue
        res = json.load(open(path))
        base = res["baseline"]
        wire_fp32 = base["wire_bytes_fp32"] / base["steps"]
        bw = wire_fp32 / T_X

        def elapsed(pol, target):
            r = res[pol]
            for c in r["curve"]:
                if c["top5_err"] <= target:
                    frac = c["step"] / r["steps"]
                    return c["step"] * T_C + r["wire_bytes"] * frac / bw, c["step"]
            return None, None

        finals = [res[p]["curve"][-1]["top5_err"] for p in res]
        target = max(min(finals) + 0.02, 0.05)
        parts.append(f"### {net}: modeled time to top-5 err ≤ {target:.2f}\n")
        parts.append("| policy | modeled s | steps | vs baseline |")
        parts.append("|---|---|---|---|")
        tb, _ = elapsed("baseline", target)
        for pol in res:
            t, s = elapsed(pol, target)
            if t is None:
                parts.append(f"| {pol} | not reached | — | — |")
            else:
                rel = f"{(t/tb-1)*100:+.1f}%" if tb else "—"
                parts.append(f"| {pol} | {t:.1f} | {s} | {rel} |")
        parts.append("")
    return "\n".join(parts) + "\n"


def main():
    print("## §Roofline — baseline tables (round_to=2, all combos)\n")
    print(roofline_table("results/dryrun_single_pod.json", "16×16 (single pod, 256 chips)"))
    print()
    print(roofline_table("results/dryrun_multi_pod.json", "2×16×16 (two pods, 512 chips)"))
    print()
    print("## §Perf — hillclimb ladders\n")
    print(hillclimb_tables("results/hillclimb.json"))
    print()
    print("## CNN reproduction (paper §V methodology)\n")
    print(cnn_tables())
    print()
    print("## Time-to-error (paper Fig. 3/4 accounting)\n")
    print(time_to_error())


if __name__ == "__main__":
    main()
