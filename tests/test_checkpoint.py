"""Checkpoint round-trips: suffix normalization (save("ckpt") used to
write ckpt.npz and then fail to load "ckpt"), sharded storage layouts,
optimizer state, and the full AWP controller state (bits / counters /
prev_norms / step / history)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.configs.registry import get_config, reduced
from repro.core.awp import AWPConfig, AWPController
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.models.init import init_params
from repro.optim.sgd import init_momentum


def _sharded_state():
    """Real sharded storage: a reduced arch laid out for a 2x2 mesh
    (tree_to_storage is a host-side layout transform — no devices
    needed), plus momentum."""
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh_cfg = MeshCfg(tp=2, dp=2)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=2)
    spec = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec, mesh_cfg)
    return storage, init_momentum(storage)


def _exercised_awp(num_groups: int) -> AWPController:
    """Controller with non-trivial counters AND a widening in history."""
    awp = AWPController(num_groups, AWPConfig(threshold=-1e-3, interval=2))
    norms = np.linspace(1.0, 2.0, num_groups)
    awp.update(norms**2)
    awp.update((norms * 0.9) ** 2)   # big drop: counters tick
    awp.update((norms * 0.8) ** 2)   # second consecutive hit: widen fires
    assert len(awp.history) > 1, "expected a bits transition in history"
    assert awp.state.counters.any() or awp.history[-1][0] > 0
    return awp


@pytest.mark.parametrize("suffix", ["", ".npz"])
def test_roundtrip_suffix_normalized(tmp_path, suffix):
    storage, mom = _sharded_state()
    n_groups = len(storage["groups"]) + 1
    awp = _exercised_awp(n_groups)
    path = str(tmp_path / "ckpt") + suffix
    save_checkpoint(path, storage, mom, awp, step=13)

    # the on-disk artifact is always the .npz name
    assert (tmp_path / "ckpt.npz").exists()

    # load back through the same (possibly suffix-less) path
    awp2 = AWPController(n_groups, AWPConfig(threshold=-1e-3, interval=2))
    s2, m2, step = load_checkpoint(path, storage, mom, awp2)
    assert step == 13

    for got, want in zip(
        jax.tree_util.tree_leaves(s2), jax.tree_util.tree_leaves(storage)
    ):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(
        jax.tree_util.tree_leaves(m2), jax.tree_util.tree_leaves(mom)
    ):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    np.testing.assert_array_equal(awp2.state.bits, awp.state.bits)
    np.testing.assert_array_equal(awp2.state.counters, awp.state.counters)
    np.testing.assert_array_equal(awp2.state.prev_norms, awp.state.prev_norms)
    assert awp2.state.step == awp.state.step
    assert awp2.history == awp.history
    assert awp2.state.round_to() == awp.state.round_to()


def test_cross_suffix_load(tmp_path):
    """Saving under one spelling and loading under the other both work."""
    storage = {"a": jnp.arange(6, dtype=jnp.float32)}
    opt = {"m": jnp.zeros((6,))}
    save_checkpoint(str(tmp_path / "x"), storage, opt, None, step=1)
    _, _, step = load_checkpoint(str(tmp_path / "x.npz"), storage, opt)
    assert step == 1
    save_checkpoint(str(tmp_path / "y.npz"), storage, opt, None, step=2)
    _, _, step = load_checkpoint(str(tmp_path / "y"), storage, opt)
    assert step == 2


def test_structure_mismatch_raises(tmp_path):
    storage = {"a": jnp.arange(6, dtype=jnp.float32)}
    opt = {"m": jnp.zeros((6,))}
    save_checkpoint(str(tmp_path / "z"), storage, opt, None, step=0)
    with pytest.raises(AssertionError):
        load_checkpoint(
            str(tmp_path / "z"), {"a": storage["a"], "b": storage["a"]}, opt
        )
