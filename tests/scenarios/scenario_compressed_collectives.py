"""Subprocess scenario: compressed collectives on an 8-device host mesh.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the test
runner sets it); asserts raise on failure.
"""
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.shard import shard_map

from repro.core.compressed import (
    compressed_all_gather,
    compressed_psum_scatter,
)
from repro.core.collectives import (
    seq_gather,
    seq_scatter,
    tp_region_enter,
    tp_region_exit,
)
from repro.kernels import ref


def main():
    devs = np.array(jax.devices()).reshape(4, 2)
    mesh = Mesh(devs, ("data", "model"))
    D = 4

    S = 4 * 1024
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 1, (S,)).astype(np.float32))

    # ---- compressed_all_gather forward -------------------------------
    for rt in (1, 2, 3, 4):
        f = shard_map(
            functools.partial(
                compressed_all_gather, axis_names="data", round_to=rt
            ),
            mesh=mesh,
            in_specs=P("data"),
            out_specs=P(None),
        )
        got = np.asarray(jax.jit(f)(w))
        want = np.asarray(ref.quantize_ref(w, rt))
        np.testing.assert_array_equal(got, want), rt

    # ---- VJP: cotangent reduce-scatters correctly ---------------------
    def loss_fn(w_local, coef_local):
        w_full = compressed_all_gather(w_local, "data", 2)
        # every shard computes a different function of the full weights
        return jnp.sum(w_full * coef_local) / D

    coef = jnp.asarray(rng.normal(0, 1, (D, S)).astype(np.float32))

    def per_shard(w_local, coef_shard):
        l = loss_fn(w_local, coef_shard[0])
        g = jax.grad(loss_fn)(w_local, coef_shard[0])
        return jax.lax.psum(l, "data"), g

    f = shard_map(
        per_shard, mesh=mesh, in_specs=(P("data"), P("data", None)),
        out_specs=(P(), P("data")),
    )
    _, g = jax.jit(f)(w, coef)
    # d/dw_full of sum over shards = sum_d coef_d / D; shard s of that is the
    # expected gradient of w_local (format is not differentiated: straight-
    # through, like the paper's master-weights update).
    want_full = np.sum(np.asarray(coef), axis=0) / D
    np.testing.assert_allclose(np.asarray(g).reshape(-1), want_full, rtol=1e-6)

    # ---- compressed_psum_scatter --------------------------------------
    gmat = jnp.asarray(rng.normal(0, 1, (D, S)).astype(np.float32))

    def rs(g_all):  # g_all: (S,) distinct per device via index trick
        i = jax.lax.axis_index("data")
        mine = g_all[i]
        return compressed_psum_scatter(mine, "data", 2)

    f = shard_map(
        rs, mesh=mesh, in_specs=P(None, None), out_specs=P("data")
    )
    got = np.asarray(jax.jit(f)(gmat))
    want = np.sum(np.asarray(gmat), axis=0)
    # rt=2 keeps 7 mantissa bits, nearest rounding: tolerance ~2^-8 relative
    tol = np.abs(want) * 2**-7 + 4 * 2**-7
    assert np.all(np.abs(got - want) <= tol), np.max(np.abs(got - want) - tol)

    # exact when uncompressed
    def rs4(g_all):
        i = jax.lax.axis_index("data")
        return compressed_psum_scatter(g_all[i], "data", 4)

    f4 = shard_map(rs4, mesh=mesh, in_specs=P(None, None), out_specs=P("data"))
    got4 = np.asarray(jax.jit(f4)(gmat))
    np.testing.assert_allclose(got4, want, rtol=1e-6)

    # ---- multi-axis gather (pod-like) ----------------------------------
    mesh3 = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("pod", "data", "model"))
    f = shard_map(
        functools.partial(
            compressed_all_gather, axis_names=("pod", "data"), round_to=2
        ),
        mesh=mesh3,
        in_specs=P(("pod", "data")),
        out_specs=P(None),
    )
    got = np.asarray(jax.jit(f)(w))
    np.testing.assert_array_equal(got, np.asarray(ref.quantize_ref(w, 2)))

    # ---- TP f/g pair: column->row parallel MLP matches single device ---
    d_in, d_hid, B = 32, 64, 16
    x = jnp.asarray(rng.normal(0, 1, (B, d_in)).astype(np.float32))
    w1 = jnp.asarray(rng.normal(0, 0.1, (d_in, d_hid)).astype(np.float32))
    w2 = jnp.asarray(rng.normal(0, 0.1, (d_hid, d_in)).astype(np.float32))

    def tp_mlp(x, w1_local, w2_local):
        x = tp_region_enter(x, "model")
        h = jax.nn.relu(x @ w1_local)
        y = tp_region_exit(h @ w2_local, "model")
        return y

    def tp_loss(x, w1_local, w2_local):
        return jnp.sum(tp_mlp(x, w1_local, w2_local) ** 2)

    def shard_fn(x, w1, w2):
        l = tp_loss(x, w1, w2)
        gw1, gw2 = jax.grad(tp_loss, argnums=(1, 2))(x, w1, w2)
        return l, gw1, gw2

    f = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(None, None), P(None, "model"), P("model", None)),
        out_specs=(P(), P(None, "model"), P("model", None)),
    )
    l, gw1, gw2 = jax.jit(f)(x, w1, w2)

    def ref_loss(x, w1, w2):
        return jnp.sum((jax.nn.relu(x @ w1) @ w2) ** 2)

    lr = ref_loss(x, w1, w2)
    gw1r, gw2r = jax.grad(ref_loss, argnums=(1, 2))(x, w1, w2)
    np.testing.assert_allclose(float(l), float(lr), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw1r), rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw2), np.asarray(gw2r), rtol=2e-4, atol=1e-5)

    # ---- sequence-parallel pair round-trips and transposes -------------
    seq = 16
    xs = jnp.asarray(rng.normal(0, 1, (B, seq, d_in)).astype(np.float32))

    def sp(x_shard):
        full = seq_gather(x_shard, "model")
        return seq_scatter(full, "model")

    f = shard_map(
        sp, mesh=mesh, in_specs=P(None, "model", None),
        out_specs=P(None, "model", None),
    )
    got = np.asarray(jax.jit(f)(xs))
    # gather then reduce-scatter of a replicated-free value = 2x (2 model shards sum)
    np.testing.assert_allclose(got, 2 * np.asarray(xs), rtol=1e-6)

    # ---- same pair with an activation policy (compressed shim path) ----
    from repro.transport import CompressionPolicy

    act_pol = CompressionPolicy(round_to=2, grad_round_to=2, mode="nearest")

    def sp_c(x_shard):
        full = seq_gather(x_shard, "model", act_pol)
        return seq_scatter(full, "model", act_pol)

    fc = shard_map(
        sp_c, mesh=mesh, in_specs=P(None, "model", None),
        out_specs=P(None, "model", None),
    )
    gotc = np.asarray(jax.jit(fc)(xs))
    want = 2 * np.asarray(xs)
    tol = np.abs(want) * 2**-7 + 2**-6
    assert np.all(np.abs(gotc - want) <= tol), np.max(np.abs(gotc - want) - tol)

    # ---- compressed TP f/g pair still matches the reference MLP --------
    def tp_mlp_c(x, w1_local, w2_local):
        xin = tp_region_enter(x, "model", act_pol)
        h = jax.nn.relu(xin @ w1_local)
        return tp_region_exit(h @ w2_local, "model", act_pol)

    def tp_loss_c(x, w1_local, w2_local):
        return jnp.sum(tp_mlp_c(x, w1_local, w2_local) ** 2)

    fc = shard_map(
        lambda x, w1, w2: (
            tp_loss_c(x, w1, w2),
            *jax.grad(tp_loss_c, argnums=(1, 2))(x, w1, w2),
        ),
        mesh=mesh,
        in_specs=(P(None, None), P(None, "model"), P("model", None)),
        out_specs=(P(), P(None, "model"), P("model", None)),
    )
    lc, gw1c, gw2c = jax.jit(fc)(x, w1, w2)
    # rt=2 nearest keeps ~8 mantissa bits on every wire crossing
    np.testing.assert_allclose(float(lc), float(lr), rtol=2e-2)
    np.testing.assert_allclose(np.asarray(gw1c), np.asarray(gw1r), rtol=0.1,
                               atol=5e-2)
    np.testing.assert_allclose(np.asarray(gw2c), np.asarray(gw2r), rtol=0.1,
                               atol=5e-2)
    print("  compressed TP f/g pair matches reference OK")

    # ---- TP-region cotangent psum accumulates in the COMPUTE dtype -----
    # (the claim in core/collectives.py's comments; asserted here so the
    # comment and the code cannot drift). The uncompressed bwd psum must
    # run on cotangents already cast to the fwd input dtype — bf16 in,
    # bf16 on the wire.
    def collect_eqns(jaxpr, out):
        for eqn in jaxpr.eqns:
            out.append(eqn)
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):  # ClosedJaxpr
                    collect_eqns(v.jaxpr, out)
                elif hasattr(v, "eqns"):  # Jaxpr
                    collect_eqns(v, out)
        return out

    def enter_loss(xv):
        y = tp_region_enter(xv, "model")
        return jnp.sum((y * y).astype(jnp.float32))

    xb = xs.astype(jnp.bfloat16)
    fng = shard_map(
        jax.grad(enter_loss), mesh=mesh,
        in_specs=P(None, "model", None), out_specs=P(None, "model", None),
    )
    eqns = collect_eqns(jax.make_jaxpr(fng)(xb).jaxpr, [])
    psums = [e for e in eqns if e.primitive.name == "psum"]
    assert psums, "no psum found in tp_region_enter bwd"
    for e in psums:
        dt = e.invars[0].aval.dtype
        assert dt == jnp.bfloat16, (
            f"cotangent psum accumulates in {dt}, expected the compute "
            f"dtype bfloat16"
        )
    # and the returned cotangent stays in the compute dtype end to end
    gb = jax.jit(fng)(xb)
    assert gb.dtype == jnp.bfloat16, gb.dtype
    print("  tp_region bwd psum accumulation dtype == compute dtype OK")

    print("scenario_compressed_collectives OK")


if __name__ == "__main__":
    main()
