"""Pallas TPU kernel: ADT Bitpack — fp32 -> uint8 byte planes.

TPU adaptation of the paper's AVX2 ``_mm256_shuffle_epi8`` pipeline
(Fig. 2 / Algorithm 4).  Instead of packing kept bytes contiguously inside a
SIMD register (a lane-local byte shuffle, which has no TPU analogue), we emit
a struct-of-arrays byte-plane layout: plane ``k`` holds byte ``k`` (MSB first)
of every weight.  Each plane is a dense uint8 array that tiles cleanly into
VMEM and vectorizes on the VPU; transferring ``round_to`` planes moves exactly
``round_to/4`` of the fp32 bytes — the same wire saving as the paper's packed
stream.

The kernel operates on weights reshaped to ``(rows, 128)`` (lane-aligned) and
is gridded over row-blocks so the VMEM working set stays bounded:

  in  block: (BLOCK_ROWS, 128) f32   = 128 KiB  at BLOCK_ROWS=256
  out block: (round_to, BLOCK_ROWS, 128) u8 ≤ 128 KiB
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128

_SHIFTS = (24, 16, 8, 0)


def resolve_interpret(interpret: bool | None) -> bool:
    """Backend-aware dispatch: compiled on real TPU, interpret elsewhere.

    ``None`` (the default everywhere) resolves at trace time; passing an
    explicit bool pins the mode (tests force ``interpret=True``).
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _bitpack_kernel(w_ref, out_ref, *, round_to: int):
    u = jax.lax.bitcast_convert_type(w_ref[...], jnp.uint32)
    for k in range(round_to):
        out_ref[k, :, :] = (
            (u >> jnp.uint32(_SHIFTS[k])) & jnp.uint32(0xFF)
        ).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("round_to", "interpret", "block_rows"))
def bitpack_2d(
    w: jnp.ndarray,
    round_to: int,
    *,
    interpret: bool | None = None,
    block_rows: int = BLOCK_ROWS,
) -> jnp.ndarray:
    """Pack a ``(rows, 128)`` fp32 array into ``(round_to, rows, 128)`` u8 planes.

    ``rows`` must be a multiple of ``block_rows``; use :func:`ops.bitpack`
    for arbitrary shapes (it pads / reshapes).
    """
    rows, lanes = w.shape
    if lanes != LANES:
        raise ValueError(f"last dim must be {LANES}, got {lanes}")
    if rows % block_rows:
        raise ValueError(f"rows ({rows}) must be a multiple of {block_rows}")
    grid = (rows // block_rows,)
    interpret = resolve_interpret(interpret)
    return pl.pallas_call(
        functools.partial(_bitpack_kernel, round_to=round_to),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec(
            (round_to, block_rows, LANES), lambda i: (0, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((round_to, rows, LANES), jnp.uint8),
        interpret=interpret,
    )(w)
