"""Checkpoint round-trips on the width-aware sharded format: suffix
normalization (save("ckpt") / save("ckpt.npz") both land on ckpt.ckpt/),
real sharded storage layouts, optimizer state, the full AWP controller
state, width-aware wire/residual tiers (an rt=2 leaf occupies exactly
half the disk bytes of its fp32 twin), async overlap, typed
CheckpointError structure diagnostics, and the legacy .npz read path."""
import json
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.ckpt import (
    AsyncCheckpointer, CheckpointError, ckpt_dir, load_checkpoint,
    load_extra, load_storage, save_checkpoint,
)
from repro.checkpoint.sharded import (
    assign_widths, load_sharded, manifest_bytes, read_meta, save_sharded,
)
from repro.configs.registry import get_config, reduced
from repro.core.awp import AWPConfig, AWPController
from repro.dist.spec import (
    DIST, REPL, LeafSpec, MeshCfg, build_spec_tree, tree_to_storage,
)
from repro.models.init import init_params
from repro.optim.sgd import init_momentum
from repro.roofline.analysis import train_checkpoint_bytes


def _sharded_state():
    """Real sharded storage: a reduced arch laid out for a 2x2 mesh
    (tree_to_storage is a host-side layout transform — no devices
    needed), plus momentum."""
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh_cfg = MeshCfg(tp=2, dp=2)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=2)
    spec = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec, mesh_cfg)
    return storage, init_momentum(storage), spec


def _exercised_awp(num_groups: int) -> AWPController:
    """Controller with non-trivial counters AND a widening in history."""
    awp = AWPController(num_groups, AWPConfig(threshold=-1e-3, interval=2))
    norms = np.linspace(1.0, 2.0, num_groups)
    awp.update(norms**2)
    awp.update((norms * 0.9) ** 2)   # big drop: counters tick
    awp.update((norms * 0.8) ** 2)   # second consecutive hit: widen fires
    assert len(awp.history) > 1, "expected a bits transition in history"
    assert awp.state.counters.any() or awp.history[-1][0] > 0
    return awp


def _leaf_spec(kind):
    return LeafSpec(kind=kind, meta=None, logical=(), local_logical=())


@pytest.mark.parametrize("suffix", ["", ".npz"])
def test_roundtrip_suffix_normalized(tmp_path, suffix):
    storage, mom, _ = _sharded_state()
    n_groups = len(storage["groups"]) + 1
    awp = _exercised_awp(n_groups)
    path = str(tmp_path / "ckpt") + suffix
    save_checkpoint(path, storage, mom, awp, step=13)

    # the on-disk artifact is always the sharded .ckpt directory
    assert (tmp_path / "ckpt.ckpt").is_dir()

    # load back through the same (possibly suffix-less) path
    awp2 = AWPController(n_groups, AWPConfig(threshold=-1e-3, interval=2))
    s2, m2, step = load_checkpoint(path, storage, mom, awp2)
    assert step == 13

    for got, want in zip(
        jax.tree_util.tree_leaves(s2), jax.tree_util.tree_leaves(storage)
    ):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(
        jax.tree_util.tree_leaves(m2), jax.tree_util.tree_leaves(mom)
    ):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    np.testing.assert_array_equal(awp2.state.bits, awp.state.bits)
    np.testing.assert_array_equal(awp2.state.counters, awp.state.counters)
    np.testing.assert_array_equal(awp2.state.prev_norms, awp.state.prev_norms)
    assert awp2.state.step == awp.state.step
    assert awp2.history == awp.history
    assert awp2.state.round_to() == awp.state.round_to()


def test_cross_suffix_load(tmp_path):
    """Saving under one spelling and loading under the other both work."""
    storage = {"a": jnp.arange(6, dtype=jnp.float32)}
    opt = {"m": jnp.zeros((6,))}
    save_checkpoint(str(tmp_path / "x"), storage, opt, None, step=1)
    _, _, step = load_checkpoint(str(tmp_path / "x.npz"), storage, opt)
    assert step == 1
    save_checkpoint(str(tmp_path / "y.npz"), storage, opt, None, step=2)
    _, _, step = load_checkpoint(str(tmp_path / "y"), storage, opt)
    assert step == 2


# ---------------------------------------------------------------------------
# typed structure errors
# ---------------------------------------------------------------------------


def test_structure_mismatch_raises_typed_with_path(tmp_path):
    storage = {"a": jnp.arange(6, dtype=jnp.float32)}
    opt = {"m": jnp.zeros((6,))}
    save_checkpoint(str(tmp_path / "z"), storage, opt, None, step=0)
    with pytest.raises(CheckpointError, match="storage/b"):
        load_checkpoint(
            str(tmp_path / "z"), {"a": storage["a"], "b": storage["a"]}, opt
        )
    with pytest.raises(CheckpointError, match="storage/a"):
        load_checkpoint(
            str(tmp_path / "z"), {"a": jnp.zeros((7,), jnp.float32)}, opt
        )
    with pytest.raises(CheckpointError, match="dtype.*storage/a"):
        load_checkpoint(str(tmp_path / "z"), {"a": jnp.zeros(6, jnp.int32)}, opt)
    with pytest.raises(CheckpointError, match="opt/m"):
        load_checkpoint(str(tmp_path / "z"), storage, {"m": jnp.zeros((9,))})
    with pytest.raises(CheckpointError, match="no checkpoint"):
        load_checkpoint(str(tmp_path / "missing"), storage, opt)


def test_legacy_npz_mismatch_raises_typed(tmp_path):
    storage = {"a": jnp.arange(6, dtype=jnp.float32)}
    opt = {"m": jnp.zeros((6,))}
    flat, _ = jax.tree_util.tree_flatten((storage, opt))
    np.savez(
        tmp_path / "old.npz",
        __meta__=json.dumps({"step": 0, "num_arrays": len(flat)}),
        **{f"a{i}": np.asarray(x) for i, x in enumerate(flat)},
    )
    with pytest.raises(CheckpointError, match="leaves"):
        load_checkpoint(
            str(tmp_path / "old"), {"a": storage["a"], "b": storage["a"]}, opt
        )
    with pytest.raises(CheckpointError, match="shape mismatch at a"):
        load_storage(str(tmp_path / "old"), {"a": jnp.zeros((9,), jnp.float32)})


def test_legacy_npz_roundtrip_and_weights_only(tmp_path):
    """Old-format checkpoints written by previous releases stay loadable
    through every shim."""
    storage = {"a": jnp.arange(6, dtype=jnp.float32), "b": jnp.ones((2, 3))}
    opt = {"m": jnp.zeros((6,)), "n": jnp.zeros((2, 3))}
    flat, _ = jax.tree_util.tree_flatten((storage, opt))
    np.savez(
        tmp_path / "old.npz",
        __meta__=json.dumps({"step": 5, "num_arrays": len(flat)}),
        **{f"a{i}": np.asarray(x) for i, x in enumerate(flat)},
    )
    s, o, step = load_checkpoint(str(tmp_path / "old"), storage, opt)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(s["a"]), np.asarray(storage["a"]))
    s2, step = load_storage(str(tmp_path / "old"), storage)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(s2["b"]), np.asarray(storage["b"]))
    assert load_extra(str(tmp_path / "old")) == {}


# ---------------------------------------------------------------------------
# width-aware tiers
# ---------------------------------------------------------------------------


@settings(max_examples=20)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 4),
    st.integers(1, 200),
)
def test_wire_tier_is_exactly_elems_times_rt(seed, rt, n):
    """Property: a compressible fp32 leaf checkpointed in a group at
    round_to=rt puts EXACTLY n·rt bytes in its wire shard file (and
    n·(4-rt) in the residual), measured with os.path.getsize."""
    rng = np.random.default_rng(seed)
    storage = {
        "groups": [{"w": jnp.asarray(rng.normal(0, 1, n), jnp.float32)}],
        "top": jnp.asarray(rng.normal(0, 1, 3), jnp.float32),
    }
    spec = {
        "groups": [{"w": _leaf_spec(DIST)}],
        "top": _leaf_spec(REPL),
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "c.ckpt")
        meta = save_sharded(
            path, storage, None, None, 0, spec_tree=spec, round_tos=(rt, 4)
        )
        e = {x["path"]: x for x in meta["trees"]["storage"]}["groups/0/w"]
        assert e["width"] == rt
        wire_file = os.path.join(path, e["file"] + ".w.bin")
        assert os.path.getsize(wire_file) == n * rt
        if rt < 4:
            res_file = os.path.join(path, e["file"] + ".r.bin")
            assert os.path.getsize(res_file) == n * (4 - rt)
        else:
            assert not os.path.exists(
                os.path.join(path, e["file"] + ".r.bin")
            )
        # exact restore is bitwise regardless of the width split
        s2, _, _, _ = load_sharded(path, storage)
        np.testing.assert_array_equal(
            np.asarray(s2["groups"][0]["w"]).view(np.uint8),
            np.asarray(storage["groups"][0]["w"]).view(np.uint8),
        )
        # manifest totals == analytic model == summed file sizes
        mb = manifest_bytes(meta)
        analytic = train_checkpoint_bytes(
            storage, None, spec_tree=spec, round_tos=(rt, 4)
        )
        assert mb == analytic
        ondisk = sum(
            os.path.getsize(os.path.join(path, f))
            for f in os.listdir(path) if f.endswith(".bin")
        )
        assert mb["total"] == ondisk


def test_rt2_leaf_is_half_the_fp32_twin(tmp_path):
    """The acceptance criterion verbatim: the same leaf checkpointed at
    rt=2 occupies half the wire bytes of its fp32 (rt=4) twin."""
    n = 1024
    storage = {"groups": [{"w": jnp.asarray(
        np.random.default_rng(0).normal(0, 1, n), jnp.float32)}]}
    spec = {"groups": [{"w": _leaf_spec(DIST)}]}

    def wire_size(rt, residuals):
        d = tmp_path / f"rt{rt}_{residuals}"
        meta = save_sharded(
            str(d), storage, None, None, 0, spec_tree=spec,
            round_tos=(rt,), residuals=residuals,
        )
        e = meta["trees"]["storage"][0]
        return os.path.getsize(str(d / (e["file"] + ".w.bin")))

    assert wire_size(2, True) * 2 == wire_size(4, True)
    # and a residual-free export's TOTAL on-disk size is half as well
    wire_size(2, False), wire_size(4, False)
    half = sum(
        os.path.getsize(str(tmp_path / "rt2_False" / f))
        for f in os.listdir(tmp_path / "rt2_False") if f.endswith(".bin")
    )
    full = sum(
        os.path.getsize(str(tmp_path / "rt4_False" / f))
        for f in os.listdir(tmp_path / "rt4_False") if f.endswith(".bin")
    )
    assert half * 2 == full


def test_wire_quality_load_matches_transport_truncation(tmp_path):
    n = 64
    w = np.random.default_rng(1).normal(0, 1, n).astype(np.float32)
    storage = {"groups": [{"w": jnp.asarray(w)}]}
    spec = {"groups": [{"w": _leaf_spec(DIST)}]}
    save_checkpoint(str(tmp_path / "c"), storage, None, None, 0,
                    spec_tree=spec, round_tos=(2,))
    got, _ = load_storage(str(tmp_path / "c"), storage, quality="wire")
    want = (w.view(np.uint32) & np.uint32(0xFFFF0000)).view(np.float32)
    np.testing.assert_array_equal(np.asarray(got["groups"][0]["w"]), want)


def test_residual_free_export_refuses_exact_load(tmp_path):
    storage = {"groups": [{"w": jnp.ones((8,), jnp.float32)}]}
    spec = {"groups": [{"w": _leaf_spec(DIST)}]}
    save_checkpoint(str(tmp_path / "e"), storage, None, None, 0,
                    spec_tree=spec, round_tos=(2,), residuals=False)
    with pytest.raises(CheckpointError, match="residual"):
        load_storage(str(tmp_path / "e"), storage)
    load_storage(str(tmp_path / "e"), storage, quality="wire")


def test_assign_widths_group_and_toplevel_mapping():
    """Groups map to their round_tos entry, top-level leaves to the last
    one, non-DIST / non-f32 leaves stay full width — the same layout
    dist_elems_per_group uses."""
    storage = {
        "groups": [
            {"w": jnp.zeros((4,), jnp.float32), "b": jnp.zeros((2,), jnp.float32)},
            {"w": jnp.zeros((4,), jnp.float32)},
        ],
        "emb": jnp.zeros((4,), jnp.float32),
        "ids": jnp.zeros((4,), jnp.int32),
    }
    spec = {
        "groups": [
            {"w": _leaf_spec(DIST), "b": _leaf_spec(REPL)},
            {"w": _leaf_spec(DIST)},
        ],
        "emb": _leaf_spec(DIST),
        "ids": _leaf_spec(DIST),  # DIST but not f32: stays full width
    }
    widths = assign_widths(storage, spec, (1, 2, 3))
    assert widths == {
        "groups/0/w": 1, "groups/0/b": 4, "groups/1/w": 2,
        "emb": 3, "ids": 4,
    }


def test_opt_state_always_full_width(tmp_path):
    """Momentum mirrors the master weights' role: it accumulates
    full-precision updates, so width assignment never applies."""
    storage, mom, spec = _sharded_state()
    nrt = len(storage["groups"]) + 1
    save_checkpoint(str(tmp_path / "c"), storage, mom, None, 1,
                    spec_tree=spec, round_tos=(1,) * nrt)
    meta = read_meta(ckpt_dir(str(tmp_path / "c")))
    assert any(e["tiered"] for e in meta["trees"]["storage"])
    assert not any(e["tiered"] for e in meta["trees"]["opt"])
    # full fidelity round-trip even with every group at rt=1
    s2, m2, _ = load_checkpoint(str(tmp_path / "c"), storage, mom)
    for got, want in zip(
        jax.tree_util.tree_leaves((s2, m2)),
        jax.tree_util.tree_leaves((storage, mom)),
    ):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_checkpoint_bytes_measured_equals_analytic(tmp_path):
    """The real reduced-arch tree: manifest totals == analytic model ==
    summed shard file sizes, for a width-mixed save."""
    storage, mom, spec = _sharded_state()
    nrt = len(storage["groups"]) + 1
    rts = tuple(2 + (i % 2) for i in range(nrt))
    meta = save_checkpoint(str(tmp_path / "c"), storage, mom, None, 1,
                           spec_tree=spec, round_tos=rts)
    mb = manifest_bytes(meta)
    analytic = train_checkpoint_bytes(
        storage, mom, spec_tree=spec, round_tos=rts
    )
    assert mb == analytic
    d = ckpt_dir(str(tmp_path / "c"))
    ondisk = sum(
        os.path.getsize(os.path.join(d, f))
        for f in os.listdir(d) if f.endswith(".bin")
    )
    assert mb["total"] == ondisk


# ---------------------------------------------------------------------------
# async
# ---------------------------------------------------------------------------


def test_async_checkpoint_identical_to_sync(tmp_path):
    storage, mom, spec = _sharded_state()
    nrt = len(storage["groups"]) + 1
    awp = _exercised_awp(nrt)
    kw = dict(spec_tree=spec, round_tos=(2,) * nrt,
              extra={"data_state": {"pos": 3}})
    save_checkpoint(str(tmp_path / "sync"), storage, mom, awp, 4, **kw)
    ac = AsyncCheckpointer()
    save_checkpoint(str(tmp_path / "async"), storage, mom, awp, 4,
                    async_ckpt=ac, **kw)
    ac.wait()
    assert ac.saves == 1 and not ac.in_flight
    ma = read_meta(ckpt_dir(str(tmp_path / "sync")))
    mb = read_meta(ckpt_dir(str(tmp_path / "async")))
    assert ma == mb
    for e in ma["trees"]["storage"]:
        for ext in (".w.bin", ".r.bin"):
            fa = tmp_path / "sync.ckpt" / (e["file"] + ext)
            fb = tmp_path / "async.ckpt" / (e["file"] + ext)
            assert fa.exists() == fb.exists()
            if fa.exists():
                assert fa.read_bytes() == fb.read_bytes()


def test_async_checkpoint_snapshot_survives_mutation(tmp_path):
    """The d2h snapshot happens in save(): mutating the AWP controller
    and rebinding the arrays afterwards must not leak into the write
    (donated-buffer safety is exercised end-to-end by the launcher)."""
    awp = AWPController(2, AWPConfig(threshold=-1e-3, interval=1))
    awp.update(np.array([1.0, 1.0]))
    storage = {"w": jnp.arange(4, dtype=jnp.float32)}
    ac = AsyncCheckpointer()
    save_checkpoint(str(tmp_path / "a"), storage, None, awp, 1, async_ckpt=ac)
    awp.update(np.array([0.5, 0.5]))  # mutates bits/counters/history
    ac.wait()
    awp2 = AWPController(2, AWPConfig(threshold=-1e-3, interval=1))
    load_checkpoint(str(tmp_path / "a"), storage, None, awp2)
    assert awp2.state.step == 1 and awp2.history == [(0, (8, 8))]


def test_async_error_surfaces_on_wait():
    ac = AsyncCheckpointer()
    ac.save("/proc/definitely/not/writable/x.ckpt",
            {"w": jnp.zeros((2,))}, None, None, 0)
    with pytest.raises(CheckpointError, match="async checkpoint failed"):
        ac.wait()
