"""CompressionPolicy — the single source of truth for ADT wire formats.

Every component that either *moves* compressed bytes (the transport
collectives) or *accounts* for them (the training loop's wire-byte log,
the roofline model, the benchmark harness) derives its numbers from this
module, so the analytical model and the implementation cannot drift —
the failure mode that ``test_collective_wire_bytes`` exists to catch.

A policy describes one precision group's transfer behaviour:

  * ``round_to``      — bytes kept per fp32 weight on the gather path
                        (paper §III: 1=fp8e7, 2=bf16, 3=bf24, 4=fp32),
  * ``mode``          — rounding applied before truncation on that path,
  * ``grad_round_to`` / ``grad_mode`` — the same for the backward
                        reduce-scatter (4 = paper-faithful uncompressed),
  * ``impl``          — kernel dispatch: ``auto`` picks the Pallas kernels
                        on TPU (compiled) and the pure-jnp oracle on CPU;
                        ``pallas`` forces the kernels (interpret off-TPU),
                        ``ref`` forces the oracle,
  * ``chunks``        — >1 splits the weight gather into that many plane
                        blocks so pack / wire / unpack of successive
                        blocks overlap (double buffering).

One policy instance describes ONE precision group. The framework runs
four groups (docs/transport.md has the full table): *weights* (per-layer
AWP formats, the ``round_tos`` tuples every step factory takes),
*gradients* (the same policies' ``grad_*`` fields), *activations* (a
separate policy on ``Env.act_policy`` whose forward fields cover the TP
forward collectives and whose grad fields cover activation cotangents),
and *KV cache* (``Env.int8_kv`` — scale-quantized int8, not byte planes,
because KV is resident state rather than wire traffic).

Invariants the rest of the framework relies on (previously stated only
in test comments):

  * Axis names are fixed: the FSDP gather axes are ``("data",)`` or
    ``("pod", "data")`` (one logical axis — multi-axis collectives treat
    the tuple as a single group) and the TP axis is ``"model"``
    (``MeshCfg.model_axis``). Policies never carry axis names; binding a
    policy to axes is :class:`~repro.transport.Transport`'s job.
  * A policy is frozen + hashable so it can sit in ``custom_vjp``
    nondiff argnums and jit static closures; swapping any field means a
    recompile (the AWP controller's compiled-step cache keys on it).
  * Wire-byte math lives ONLY here, derived from :func:`ring_wire_bytes`
    — the trainer log, benchmark harness, and both HLO analyzers consume
    these methods so the analytical model cannot drift from the
    implementation (``test_collective_wire_bytes`` locks this in).
"""
from __future__ import annotations

import dataclasses

VALID_ROUND_TO = (1, 2, 3, 4)
VALID_MODES = ("truncate", "nearest", "stochastic")
VALID_IMPLS = ("auto", "pallas", "ref")
FP32_BYTES = 4


def ring_wire_bytes(kind: str, payload_bytes: float, group_size: int) -> float:
    """Per-device wire bytes of one ring-algorithm collective.

    ``payload_bytes`` is the *output* size for all-gather / all-to-all,
    the *input* size for all-reduce / reduce-scatter, and the transferred
    size for collective-permute. This is the one formula shared by the
    transport accounting and the HLO cost analyzer.
    """
    n = max(int(group_size), 1)
    kind = kind.replace("-start", "")
    if kind == "all-gather":
        return payload_bytes * (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * payload_bytes * (n - 1) / n
    if kind in ("reduce-scatter", "all-to-all"):
        return payload_bytes * (n - 1) / n
    if kind == "collective-permute":
        return float(payload_bytes)
    raise ValueError(f"unknown collective kind {kind!r}")


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    """Wire format + dispatch choices for one precision group."""

    round_to: int = 4
    grad_round_to: int = 4
    mode: str = "truncate"
    grad_mode: str = "nearest"
    impl: str = "auto"
    chunks: int = 1

    def __post_init__(self):
        if self.round_to not in VALID_ROUND_TO:
            raise ValueError(f"round_to must be in {VALID_ROUND_TO}")
        if self.grad_round_to not in VALID_ROUND_TO:
            raise ValueError(f"grad_round_to must be in {VALID_ROUND_TO}")
        if self.mode not in VALID_MODES:
            raise ValueError(f"mode must be in {VALID_MODES}")
        if self.grad_mode not in VALID_MODES:
            raise ValueError(f"grad_mode must be in {VALID_MODES}")
        if self.impl not in VALID_IMPLS:
            raise ValueError(f"impl must be in {VALID_IMPLS}")
        if self.chunks < 1:
            raise ValueError("chunks must be >= 1")

    # -- format properties ------------------------------------------------
    @property
    def compresses(self) -> bool:
        return self.round_to < FP32_BYTES

    @property
    def compresses_grads(self) -> bool:
        return self.grad_round_to < FP32_BYTES

    @property
    def bytes_per_element(self) -> int:
        """Wire bytes per fp32 element on the weight path."""
        return self.round_to

    @property
    def wire_fraction(self) -> float:
        """Fraction of fp32 bytes that actually hit the wire (weights)."""
        return self.round_to / FP32_BYTES

    # -- canonical byte accounting ---------------------------------------
    def all_gather_wire_bytes(self, s_local: int, axis_size: int) -> int:
        """Bytes received per device for one compressed all-gather of a
        shard of ``s_local`` fp32 elements over ``axis_size`` devices."""
        payload = axis_size * s_local * self.round_to
        return round(ring_wire_bytes("all-gather", payload, axis_size))

    def reduce_scatter_wire_bytes(self, s_local: int, axis_size: int) -> int:
        """Bytes received per device for one (compressed) reduce-scatter
        producing an ``s_local``-element shard."""
        payload = axis_size * s_local * self.grad_round_to
        return round(ring_wire_bytes("reduce-scatter", payload, axis_size))

    def host_device_bytes(self, elems: int) -> int:
        """Paper's host->device model: every weight moves once per batch."""
        return elems * self.round_to

    # -- host<->device token staging (serve engine) -----------------------
    def token_wire_width(self, vocab_size: int) -> int:
        """Staged bytes per token id on the host<->device boundary.

        Token ids are integers, so the adapted representation must stay
        *lossless*: an uncompressed policy (``round_to == 4``) stages raw
        int32 words (the fp32-baseline analogue), while a compressing
        policy keeps only the low byte planes a ``vocab_size`` id can
        actually populate — never narrower than that floor even if
        ``round_to`` asks for fewer bytes (ADT adapts the format *to the
        data*; a truncated id would be a different token)."""
        needed = max(1, (max(int(vocab_size) - 1, 1).bit_length() + 7) // 8)
        if self.round_to >= FP32_BYTES:
            return FP32_BYTES
        return min(FP32_BYTES, max(needed, self.round_to))

    def kv_wire_width(self, itemsize: int) -> int:
        """Parcel bytes per KV pool element on the fleet fabric.

        Migrated pages must land BIT-EXACT in the destination pool, so
        the adapted representation is floored at the pool leaf's own
        ``itemsize`` — an int8 pool ships 1 byte/element, a bf16 pool 2,
        fp32 leaves (including int8-KV scale rows) always 4. An
        uncompressed policy (``round_to == 4``) pads every element to
        raw fp32-width words, the fleet analogue of staging raw int32
        token ids; a compressing policy drops exactly the pad planes
        and nothing else (same lossless-floor contract as
        :meth:`token_wire_width`)."""
        it = int(itemsize)
        if self.round_to >= FP32_BYTES:
            return FP32_BYTES
        return min(FP32_BYTES, max(it, self.round_to))

    def token_host_bytes(self, n_tokens: int, vocab_size: int) -> int:
        """Bytes staged across the host<->device boundary for ``n_tokens``
        ids in one direction — the serve engine's ``host_device`` wire
        entry (prompts h2d, sampled tokens d2h, next-step tokens h2d)."""
        return n_tokens * self.token_wire_width(vocab_size)

    # -- activation-path accounting (TP axis; this policy = act group) ----
    # Forward collectives move (round_to, mode) planes, cotangent
    # collectives (grad_round_to, grad_mode) planes — exactly mirroring
    # the transport's seq_gather/seq_scatter VJPs and
    # all_reduce(use_grad_format=...). ``grad=True`` selects the
    # cotangent direction so the accounting cannot drift from the
    # implementation for policies with round_to != grad_round_to.
    def _act_width(self, grad: bool) -> int:
        return self.grad_round_to if grad else self.round_to

    def seq_gather_wire_bytes(
        self, elems_out: int, axis_size: int, *, grad: bool = False
    ) -> int:
        """Bytes received per device for one compressed ``seq_gather``
        producing ``elems_out`` gathered activation elements
        (``grad=True``: the ``seq_scatter`` VJP's cotangent gather)."""
        payload = elems_out * self._act_width(grad)
        return round(ring_wire_bytes("all-gather", payload, axis_size))

    def seq_scatter_wire_bytes(
        self, elems_in: int, axis_size: int, *, grad: bool = False
    ) -> int:
        """Bytes received per device for one compressed ``seq_scatter``
        of ``elems_in`` input elements (``grad=True``: the ``seq_gather``
        VJP's cotangent reduce-scatter). The packed pipeline is an
        ``all_to_all`` of planes, whose ring wire cost equals the
        reduce-scatter formula at the packed width."""
        payload = elems_in * self._act_width(grad)
        return round(ring_wire_bytes("reduce-scatter", payload, axis_size))

    def seq_pair_wire_bytes(
        self, elems: int, axis_size: int, *, grad: bool = False
    ) -> int:
        """Bytes received per device for one sequence-parallel TP-region
        boundary pair — ``seq_gather`` into the region + ``seq_scatter``
        out of it — in a single direction (``grad=True``: the pair's
        cotangent legs, an rs + ag at ``grad_round_to``). ``elems`` is
        the *full* (gathered) activation element count.

        This equals ``all_reduce_wire_bytes(elems, n)`` at the same
        width: sequence parallelism moves the all-reduce's rs+ag halves
        to the region boundaries rather than adding traffic (HyPar /
        Megatron-SP invariant — the win is sharded norm/residual compute
        and activation memory, plus the psum entries it *removes*: the
        embedding exit becomes a lone reduce-scatter at half the
        all-reduce's wire, and EP-MoE boundary collectives vanish).
        Versus the fp32 psum pair, a compressing policy still cuts the
        wire by ``round_to / 4`` — the quantity the roofline's
        plane-wire split tracks."""
        return self.seq_gather_wire_bytes(
            elems, axis_size, grad=grad
        ) + self.seq_scatter_wire_bytes(elems, axis_size, grad=grad)

    def all_reduce_wire_bytes(
        self,
        elems: int,
        axis_size: int,
        uncompressed_bytes: int = FP32_BYTES,
        *,
        grad: bool = False,
    ) -> int:
        """Bytes received per device for one TP-region all-reduce of
        ``elems`` activation elements. ``grad=False`` is the forward
        ``tp_region_exit`` psum, ``grad=True`` the ``tp_region_enter``
        cotangent psum (``transport.all_reduce(use_grad_format=True)``).

        Compressed: the reduce-scatter + all-gather decomposition, both
        halves at the selected width — exactly ``width/4`` of the fp32
        all-reduce. Uncompressed: the ring all-reduce at
        ``uncompressed_bytes`` per element (the compute dtype's width on
        TPU; the CPU emulation backend promotes to fp32, which the
        roofline corrects analytically)."""
        if self._act_width(grad) < FP32_BYTES:
            return self.seq_scatter_wire_bytes(
                elems, axis_size, grad=grad
            ) + self.seq_gather_wire_bytes(elems, axis_size, grad=grad)
        payload = elems * uncompressed_bytes
        return round(ring_wire_bytes("all-reduce", payload, axis_size))


def act_policy_for(round_to: int) -> CompressionPolicy | None:
    """CLI shortcut (``--act-round-to N``) -> activation-group policy.

    ``None`` at 4 = uncompressed, bit-identical to the historical paths.
    Nearest rounding in both directions: activation psums and cotangent
    sums are bias-sensitive, like gradients."""
    rt = int(round_to)
    if rt >= FP32_BYTES:
        return None
    return CompressionPolicy(round_to=rt, grad_round_to=rt, mode="nearest")


def policy_for(
    round_to, grad_round_to: int | None = None, **overrides
) -> CompressionPolicy:
    """Coerce an int ``round_to`` (legacy call sites) or an existing policy
    into a CompressionPolicy, optionally overriding fields."""
    if isinstance(round_to, CompressionPolicy):
        pol = round_to
        if grad_round_to is not None and grad_round_to != pol.grad_round_to:
            overrides = {"grad_round_to": grad_round_to, **overrides}
        return dataclasses.replace(pol, **overrides) if overrides else pol
    return CompressionPolicy(
        round_to=int(round_to),
        grad_round_to=4 if grad_round_to is None else int(grad_round_to),
        **overrides,
    )
