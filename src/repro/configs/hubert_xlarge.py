"""hubert-xlarge [audio] — encoder-only (w2v2 backbone)  [arXiv:2106.07447].

The mel/conv feature extractor is a stub per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, T, 1280).
Encoder-only => no decode step; decode_32k and long_500k are skipped
(DESIGN.md §5). Training objective: masked frame classification over the
504-unit codebook (HuBERT-style cluster targets).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    embed_is_input_stub=True,
    vision_dim=1280,  # frontend embedding width (frames)
    rope_theta=1e4,
    num_precision_groups=4,
)
