"""ADT data-representation formats (paper §III / §V-A).

The paper's transfer formats are byte-truncations of IEEE-754 fp32:

  ============  =======  ==============================
  format        bytes    layout
  ============  =======  ==============================
  ``fp8e7``     1        1 sign + 7 exponent
  ``bf16``      2        1 sign + 8 exponent + 7 mantissa (== bfloat16)
  ``bf24``      3        1 sign + 8 exponent + 15 mantissa
  ``fp32``      4        full single precision
  ============  =======  ==============================

AWP reasons in *bits* (it adds ``N = 8`` bits at a time); ADT transfers in
*bytes* ("rounded to the nearest number of bytes that retains all of its
information", §III-A).
"""
from __future__ import annotations

import dataclasses

FORMAT_NAMES = {1: "fp8e7", 2: "bf16", 3: "bf24", 4: "fp32"}

MIN_BITS = 8
MAX_BITS = 32


def bits_to_bytes(bits: int) -> int:
    """Paper §III-A: round bit count up to whole bytes, clamp to [1, 4]."""
    if bits <= 0:
        raise ValueError(f"bits must be positive, got {bits}")
    return min(4, max(1, (min(bits, MAX_BITS) + 7) // 8))


@dataclasses.dataclass(frozen=True)
class TransferFormat:
    """Static description of one precision group's wire format."""

    round_to: int  # bytes kept per fp32 weight (1..4)

    def __post_init__(self):
        if self.round_to not in (1, 2, 3, 4):
            raise ValueError(f"round_to must be 1..4, got {self.round_to}")

    @property
    def name(self) -> str:
        return FORMAT_NAMES[self.round_to]

    @property
    def bits(self) -> int:
        return 8 * self.round_to

    @property
    def compression_ratio(self) -> float:
        return 4.0 / self.round_to

    @property
    def is_identity(self) -> bool:
        return self.round_to == 4
