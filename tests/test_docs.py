"""Docs stay truthful: every symbol/file a docs/*.md page references in
backticks must still exist in the source tree (the same check CI runs as
a dedicated step — see tools/check_docs_freshness.py)."""
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_docs_reference_live_symbols():
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        import check_docs_freshness as cdf
    finally:
        sys.path.pop(0)
    stale = cdf.check()
    assert not stale, "\n".join(stale)


def test_docs_exist():
    names = {p.name for p in (ROOT / "docs").glob("*.md")}
    assert {"transport.md", "collectives.md", "architecture.md"} <= names
