"""chatglm3-6b [dense] — 2d (partial) RoPE + GQA kv=2  [arXiv:2406.12793].

ChatGLM applies rotary embedding to half of each head's dims ("2d RoPE");
modelled here as rotary_pct=0.5.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    arch_type="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    rotary_pct=0.5,
    rope_theta=1e4,
    num_precision_groups=4,
)
