"""Storage layout + sharding specs for the FSDP×TP mesh (DESIGN.md §3).

Every parameter leaf is classified into one of three storage *kinds*:

  * ``DIST`` — large / compressible: the fp32 master copy lives as flat
    shards, TP-sliced first (leading ``tp`` dim when ``meta.tp_dim`` is
    set), then flattened and zero-padded so the flat dim splits evenly
    over the FSDP axes. Materialization is a compressed all-gather
    through :mod:`repro.transport`; its VJP reduce-scatters the gradient
    back onto the shards.
  * ``TP_SMALL`` — small but TP-sheared (biases along a sliced dim):
    stored as stacked per-rank slices, replicated over the FSDP axes.
  * ``REPL`` — small replicated leaves (norm scales, gates): stored at
    the logical shape on every device.

Kind assignment depends only on the *logical* shape, the
:class:`~repro.models.meta.ParamMeta`, and ``compress_min_size`` — never
on the mesh geometry — so a single-device reference run and a
distributed run classify (and therefore AWP-monitor) exactly the same
set of weights.

On the trivial mesh (``tp == 1 and dshards == 1``) storage *is* the
logical array and materialization degenerates to the straight-through
format truncation — the paper's single-accelerator setting.

Invariants (previously stated only in test comments — property-tested by
``tests/test_dist_layout.py``):

  * Axis names are fixed by :class:`MeshCfg`: the TP axis is
    ``"model"``, the FSDP gather axes ``("data",)`` or
    ``("pod", "data")``; multi-axis tuples are one logical collective
    group everywhere (gathers, reduce-scatters, axis_size).
  * DIST storage order is **TP-slice first, then flatten, then
    zero-pad** to a ``dshards`` multiple: rank ``r``'s flat shard
    reconstructs exactly ``meta``'s TP-local logical slice, and the
    padding tail is always at the end (``materialize_leaf`` slices it
    off after the gather). Stacked leaves keep the layer-repetition dim
    OUTSIDE the TP/flat dims: ``(reps, tp, pad_rep)``.
  * When ``tp_units < tp`` (kv-head replication) consecutive rank
    groups share unit content — ``repl_factor`` records the
    multiplicity, and the AWP norm monitor divides it back out so
    single-device and distributed runs see identical Σw².
  * Storage shapes / kinds depend only on logical shape + meta +
    ``compress_min_size``, never on values or mesh *placement*, so a
    checkpoint written on one mesh reshapes onto another by pure
    layout transforms.
  * Materialization and placement route every wire byte through
    :mod:`repro.transport` (``all_gather``/``quantize``); their
    gradients reduce-scatter through the same transport, including the
    stacked ``axis=1`` case (generalized packed reduce-scatter).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.meta import COMPRESS_MIN_SIZE, ParamMeta
from repro.transport import CompressionPolicy, policy_for
from repro.transport import transport as _T
from repro.utils.trees import round_up

DIST = "dist"
REPL = "repl"
TP_SMALL = "tp_small"


# ---------------------------------------------------------------------------
# mesh geometry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshCfg:
    """(pods ×) data × model mesh geometry + compression threshold.

    ``dshards = dp * pods`` is the FSDP sharding degree: the weight
    gather runs over ``("pod", "data")`` when pods > 1 so the multi-pod
    hierarchy is one logical gather axis.
    """

    tp: int = 1
    dp: int = 1
    pods: int = 1
    # leaves with fewer logical elements stay uncompressed (the paper's
    # "biases" carve-out); element count, not bytes
    compress_min_size: int = COMPRESS_MIN_SIZE

    @property
    def dshards(self) -> int:
        return self.dp * self.pods

    @property
    def model_axis(self) -> str:
        return "model"

    @property
    def fsdp_axes(self) -> tuple[str, ...]:
        return ("pod", "data") if self.pods > 1 else ("data",)

    @property
    def shape(self) -> tuple[int, ...]:
        if self.pods > 1:
            return (self.pods, self.dp, self.tp)
        return (self.dp, self.tp)

    @property
    def axis_names(self) -> tuple[str, ...]:
        if self.pods > 1:
            return ("pod", "data", "model")
        return ("data", "model")

    @property
    def trivial(self) -> bool:
        return self.tp == 1 and self.dshards == 1


SINGLE = MeshCfg(tp=1, dp=1)


def _fsdp_spec_entry(mesh_cfg: MeshCfg):
    """PartitionSpec entry for the flat FSDP-sharded dim."""
    axes = mesh_cfg.fsdp_axes
    return axes if len(axes) > 1 else axes[0]


def seq_activation_pspec(
    mesh_cfg: MeshCfg, ndim: int = 3, *, seq_axis: int = 1,
    shard_batch: bool = True,
):
    """PartitionSpec of a sequence-parallel activation ``(B, S/tp, d, …)``.

    This is the one layout contract for sequence-sharded activations
    (``Env.seq_parallel``): batch over the FSDP axes, the sequence dim
    over the model axis, everything else replicated. The train/serve
    steps keep these internal to their shard_map bodies; tests and
    future pipelined steps that expose sharded activations at a jit
    boundary must use this spec so the layout cannot drift.
    """
    dims: list[Any] = [None] * ndim
    if mesh_cfg.dshards > 1 and shard_batch:
        dims[0] = _fsdp_spec_entry(mesh_cfg)
    if mesh_cfg.tp > 1:
        dims[seq_axis] = mesh_cfg.model_axis
    return P(*dims)


# ---------------------------------------------------------------------------
# leaf specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Storage descriptor for one parameter leaf.

    ``logical`` / ``local_logical`` are the *unstacked* global and
    TP-local logical shapes (``stacked`` leaves carry a leading
    layer-repetition dim ``reps`` in storage). ``s_loc`` is the flat
    element count per FSDP shard summed over reps — the quantity the
    wire-byte accounting multiplies by the policy's bytes/element.
    ``repl_factor`` is how many model-axis ranks hold each element
    (divided out by the AWP norm monitor).
    """

    kind: str
    meta: ParamMeta
    logical: tuple[int, ...]
    local_logical: tuple[int, ...]
    stacked: bool = False
    reps: int = 1
    pad_rep: int = 0          # per-rep padded flat length (DIST)
    s_loc: int = 0            # per-FSDP-shard flat elems, all reps (DIST)
    repl_factor: int = 1

    @property
    def n_local(self) -> int:
        return math.prod(self.local_logical) if self.local_logical else 1


def build_leaf_spec(
    shape, meta: ParamMeta, mesh_cfg: MeshCfg, *, stacked: bool = False
) -> LeafSpec:
    """Classify one leaf and precompute its storage geometry."""
    shape = tuple(int(s) for s in shape)
    base = shape[1:] if stacked else shape
    reps = shape[0] if stacked else 1
    n = math.prod(base) if base else 1
    local = tuple(meta.local_shape(base, mesh_cfg.tp))
    n_local = math.prod(local) if local else 1

    compressible = meta.compress and n >= mesh_cfg.compress_min_size
    if compressible:
        kind = DIST
    elif meta.tp_dim is not None and mesh_cfg.tp > 1:
        kind = TP_SMALL
    else:
        kind = REPL

    repl_factor = 1
    pad_rep = n_local
    s_loc = 0
    if kind == DIST:
        tp = max(mesh_cfg.tp, 1)
        if meta.tp_dim is None:
            repl_factor = tp  # same FSDP shard on every model rank
        else:
            units = meta.tp_units or base[meta.tp_dim]
            repl_factor = 1 if units % tp == 0 else tp // units
        pad_rep = round_up(max(n_local, 1), mesh_cfg.dshards)
        s_loc = reps * (pad_rep // mesh_cfg.dshards)

    return LeafSpec(
        kind=kind,
        meta=meta,
        logical=base,
        local_logical=local,
        stacked=stacked,
        reps=reps,
        pad_rep=pad_rep,
        s_loc=s_loc,
        repl_factor=repl_factor,
    )


def build_spec_tree(params, metas, mesh_cfg: MeshCfg):
    """Spec tree matching the ``{"groups": [...], <top leaves>}`` layout.

    Group subtrees are layer-stacked (leading repetition dim); top-level
    leaves are not. Works on concrete arrays and ShapeDtypeStructs.
    """

    def walk(p, m, stacked):
        return jax.tree_util.tree_map(
            lambda x, mm: build_leaf_spec(
                x.shape, mm, mesh_cfg, stacked=stacked
            ),
            p,
            m,
        )

    groups = [
        walk(gp, gm, True)
        for gp, gm in zip(params["groups"], metas["groups"])
    ]
    top = {
        k: walk(params[k], metas[k], False) for k in params if k != "groups"
    }
    return {"groups": groups, **top}


# ---------------------------------------------------------------------------
# logical -> storage
# ---------------------------------------------------------------------------


def storage_shape(spec: LeafSpec, mesh_cfg: MeshCfg) -> tuple[int, ...]:
    lead = (spec.reps,) if spec.stacked else ()
    if mesh_cfg.trivial or spec.kind == REPL:
        return lead + spec.logical
    if spec.kind == TP_SMALL:
        return lead + (mesh_cfg.tp,) + spec.local_logical
    if spec.meta.tp_dim is not None:
        return lead + (mesh_cfg.tp, spec.pad_rep)
    return lead + (spec.pad_rep,)


def _tp_slice(x, spec: LeafSpec, rank: int, tp: int):
    """Rank's TP-local logical slice of an unstacked logical array."""
    meta = spec.meta
    if meta.tp_dim is None or tp == 1:
        return x
    start = meta.tp_slice_index(rank, spec.logical, tp)
    width = spec.local_logical[meta.tp_dim]
    return lax.slice_in_dim(x, start, start + width, axis=meta.tp_dim)


def leaf_to_storage(x, spec: LeafSpec, mesh_cfg: MeshCfg):
    """Lay one logical leaf out in storage form (host-side, once)."""
    if isinstance(x, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct(storage_shape(spec, mesh_cfg), x.dtype)
    x = jnp.asarray(x)
    if mesh_cfg.trivial or spec.kind == REPL:
        return x
    tp = mesh_cfg.tp

    if spec.kind == TP_SMALL:
        def one(rep_x):
            return jnp.stack(
                [_tp_slice(rep_x, spec, r, tp) for r in range(tp)], axis=0
            )
    else:  # DIST
        def one(rep_x):
            def flat_pad(sl):
                flat = sl.reshape(-1)
                return jnp.pad(flat, (0, spec.pad_rep - flat.shape[0]))

            if spec.meta.tp_dim is not None:
                return jnp.stack(
                    [
                        flat_pad(_tp_slice(rep_x, spec, r, tp))
                        for r in range(tp)
                    ],
                    axis=0,
                )
            return flat_pad(rep_x)

    if spec.stacked:
        return jnp.stack([one(x[i]) for i in range(spec.reps)], axis=0)
    return one(x)


def tree_to_storage(params, spec_tree, mesh_cfg: MeshCfg):
    return jax.tree_util.tree_map(
        lambda x, s: leaf_to_storage(x, s, mesh_cfg),
        params,
        spec_tree,
    )


# ---------------------------------------------------------------------------
# partition specs
# ---------------------------------------------------------------------------


def leaf_partition_spec(spec: LeafSpec, mesh_cfg: MeshCfg):
    """PartitionSpec of the *storage* array under the production mesh."""
    lead = (None,) if spec.stacked else ()
    if mesh_cfg.trivial or spec.kind == REPL:
        return P(*(lead + (None,) * len(spec.logical)))
    if spec.kind == TP_SMALL:
        return P(
            *(lead + (mesh_cfg.model_axis,) + (None,) * len(spec.local_logical))
        )
    flat = _fsdp_spec_entry(mesh_cfg)
    if spec.meta.tp_dim is not None:
        return P(*(lead + (mesh_cfg.model_axis, flat)))
    return P(*(lead + (flat,)))


def tree_partition_specs(spec_tree, mesh_cfg: MeshCfg):
    return jax.tree_util.tree_map(
        lambda s: leaf_partition_spec(s, mesh_cfg),
        spec_tree,
        is_leaf=lambda v: isinstance(v, LeafSpec),
    )


# ---------------------------------------------------------------------------
# materialization (inside the compiled step)
# ---------------------------------------------------------------------------


def materialize_leaf(
    x,
    spec: LeafSpec,
    mesh_cfg: MeshCfg,
    round_to,
    grad_round_to: int | None = None,
    *,
    key=None,
):
    """Device-local storage shard -> TP-local logical weights.

    ``round_to`` is an int (legacy call sites) or a
    :class:`~repro.transport.CompressionPolicy`. Called per layer
    repetition (the scan body slices the stacked leading dim away), so
    ``x`` here never carries the reps dim. ``key`` is the
    stochastic-rounding PRNG key threaded from the step functions
    (required iff a used direction of the policy is stochastic).
    """
    policy = policy_for(round_to, grad_round_to)
    if mesh_cfg.trivial:
        if spec.kind == DIST:
            return _T.quantize(x, policy, key)
        return x
    if spec.kind == REPL:
        return x
    if spec.kind == TP_SMALL:
        return x[0]  # local block (1, *local_logical)
    # DIST: (1, s_loc) or (s_loc,) local shard
    flat = x.reshape(-1)
    if mesh_cfg.dshards > 1:
        full = _T.all_gather(flat, mesh_cfg.fsdp_axes, policy, 0, key)
    else:
        full = _T.quantize(flat, policy, key)
    n = spec.n_local
    if n != full.shape[0]:
        full = lax.slice_in_dim(full, 0, n)
    return full.reshape(spec.local_logical)


# ---------------------------------------------------------------------------
# weight-stationary placement (serving)
# ---------------------------------------------------------------------------


def placed_leaf(
    x, spec: LeafSpec, mesh_cfg: MeshCfg, round_to, resident_dtype=None
):
    """Run the compressed gather ONCE, emitting per-TP-rank resident
    logical weights (stacked leaves keep their reps dim). Decode steps
    built with ``weight_stationary=True`` then contain no weight
    collectives at all."""
    policy = policy_for(round_to)

    def cast(v):
        return v.astype(resident_dtype) if resident_dtype is not None else v

    if mesh_cfg.trivial:
        if spec.kind == DIST:
            return cast(_T.quantize(x, policy))
        return cast(x)
    if spec.kind == REPL:
        return cast(x)
    if spec.kind == TP_SMALL:
        return cast(x[:, 0] if spec.stacked else x[0])
    # DIST
    axis = 1 if spec.stacked else 0
    flat = x.reshape((spec.reps, -1) if spec.stacked else (-1,))
    if mesh_cfg.dshards > 1:
        full = _T.all_gather(flat, mesh_cfg.fsdp_axes, policy, axis)
    else:
        full = _T.quantize(flat, policy)
    n = spec.n_local
    if n != full.shape[axis]:
        full = lax.slice_in_dim(full, 0, n, axis=axis)
    lead = (spec.reps,) if spec.stacked else ()
    return cast(full.reshape(lead + spec.local_logical))


def placed_leaf_pspec(spec: LeafSpec, mesh_cfg: MeshCfg):
    """PartitionSpec of a placed (resident) leaf: TP-sliced dims map to
    the model axis, everything else replicated."""
    lead = (None,) if spec.stacked else ()
    dims: list[Any] = [None] * len(spec.local_logical)
    if spec.meta.tp_dim is not None and spec.kind in (DIST, TP_SMALL):
        dims[spec.meta.tp_dim] = mesh_cfg.model_axis
    return P(*(lead + tuple(dims)))


def materialize_placed_leaf(x, spec: LeafSpec, mesh_cfg: MeshCfg):
    """Placed weights are already TP-local logical: identity consume."""
    return x


# ---------------------------------------------------------------------------
# wire-accounting geometry
# ---------------------------------------------------------------------------


def dist_elems_per_group(spec_tree, mesh_cfg: MeshCfg, num_groups: int):
    """Global compressed (DIST) element count per precision group — the
    geometry :meth:`repro.plan.PrecisionPlan.wire_table` multiplies by a
    policy's bytes/element. The last group covers the top-level leaves
    (embedding / head / projectors), matching the ``round_tos`` layout."""
    elems = [0] * num_groups

    def visit(idx, subtree):
        for s in jax.tree_util.tree_leaves(
            subtree, is_leaf=lambda x: isinstance(x, LeafSpec)
        ):
            if isinstance(s, LeafSpec) and s.kind == DIST:
                elems[idx] += s.s_loc * mesh_cfg.dshards

    for g, gs in enumerate(spec_tree["groups"]):
        visit(g, gs)
    visit(num_groups - 1, {k: v for k, v in spec_tree.items() if k != "groups"})
    return elems
