"""The A²DTWP training loop: jitted steps per wire-format + host-side AWP.

``Trainer`` owns the compiled-step cache: AWP only ever widens formats
(8→16→24→32 bits), so at most ``3 × num_groups`` recompiles happen over a
whole run — each logged, amortized to ~0 exactly as in the paper where
AWP's reconfiguration also happens outside the accelerator graph.

A :class:`~repro.plan.PrecisionPlan` is the preferred way to drive the
loop: its schedule source selects between the static oracle and AWP
(with the controller hyper-parameters folded in), and its per-entry
:meth:`~repro.plan.PrecisionPlan.wire_table` becomes the wire log — the
plan is the unit of cost accounting. The legacy ``policy=`` strings
("awp" / "baseline" / "oracle:<rt>") keep working.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.awp import AWPConfig, AWPController
from repro.plan import PrecisionPlan
from repro.transport import CompressionPolicy


@dataclasses.dataclass
class StepRecord:
    step: int
    loss: float
    round_tos: tuple[int, ...]
    wire_bytes: int
    recompiled: bool
    wall_s: float
    # per-traffic-class split (plan-driven runs; None for legacy policies)
    wire_by_entry: dict | None = None
    # training-I/O bytes of this step's batch (shard_read = stored bytes
    # the reader moved off disk, host_device = bytes staged across the
    # boundary at the plan's host_device policy) — populated by
    # ingest-from-shards runs via ``run_step(..., io_log=...)``; None for
    # inline synthetic batches. Same role as wire_by_entry: the measured
    # numbers the analytic models (train_ingest_bytes) are pinned to.
    io_by_entry: dict | None = None


class Trainer:
    """Generic A²DTWP loop.

    step_builder(round_tos) -> step_fn(storage, opt, batch, lr, *extra)
        returning (storage, opt, metrics with 'loss' and 'group_norms_sq').
        Plan-driven callers typically close over the plan:
        ``lambda rts: make_train_step(..., plan=plan.with_round_tos(rts))``.
    plan: drive schedule + accounting from a PrecisionPlan (overrides
        ``policy`` / ``awp_config``): schedule "awp" runs Algorithm 1
        with the plan's threshold/interval/initial bits, "static" pins
        the plan's own formats (the paper's oracle; rt=4 = baseline).
    policy (legacy): "awp" (Algorithm 1), "oracle:<rt>" (fixed format),
        "baseline" (fp32 — the paper's 32-bit FP baseline).
    """

    def __init__(
        self,
        step_builder: Callable,
        num_groups: int,
        *,
        plan: PrecisionPlan | None = None,
        policy: str = "awp",
        awp_config: AWPConfig | None = None,
        dist_elems_per_group: list[int] | None = None,
        gather_axis_size: int = 1,
    ):
        self.step_builder = step_builder
        self.num_groups = num_groups
        self.plan = plan.broadcast(num_groups) if plan is not None else None
        if self.plan is not None:
            policy = (
                "awp" if self.plan.schedule.source == "awp" else "plan"
            )
            awp_config = self.plan.awp_config() or awp_config
        self.policy = policy
        self.controller = AWPController(num_groups, awp_config)
        self._cache: dict[tuple[int, ...], Callable] = {}
        self.records: list[StepRecord] = []
        self.dist_elems = dist_elems_per_group or [0] * num_groups
        self.gather_n = gather_axis_size

    # ------------------------------------------------------------------
    def current_round_tos(self) -> tuple[int, ...]:
        if self.policy == "baseline":
            return (4,) * self.num_groups
        if self.policy == "plan":
            return self.plan.round_tos
        if self.policy.startswith("oracle:"):
            return (int(self.policy.split(":")[1]),) * self.num_groups
        return self.controller.round_to

    def _step_fn(self, round_tos):
        if round_tos not in self._cache:
            self._cache[round_tos] = self.step_builder(round_tos)
        return self._cache[round_tos]

    def wire_entries(self, round_tos) -> dict | None:
        """Per-traffic-class wire bytes of one step at these formats
        (plan-driven runs only — the plan is the accounting unit)."""
        if self.plan is None:
            return None
        return self.plan.with_round_tos(round_tos).wire_table(
            self.dist_elems, self.gather_n
        )

    def wire_bytes(self, round_tos) -> int:
        table = self.wire_entries(round_tos)
        if table is not None:
            return table["total"]
        total = 0
        for g, rt in enumerate(round_tos):
            pol = CompressionPolicy(round_to=rt)
            n = self.gather_n
            if n <= 1:
                # paper's host→device model: every weight moves once/batch
                total += pol.host_device_bytes(self.dist_elems[g])
            else:
                total += pol.all_gather_wire_bytes(self.dist_elems[g] // n, n)
        return total

    # ------------------------------------------------------------------
    def run_step(self, storage, opt_state, batch, lr, *extra, io_log=None):
        rts = self.current_round_tos()
        recompiled = rts not in self._cache
        fn = self._step_fn(rts)
        t0 = time.time()
        storage, opt_state, metrics = fn(storage, opt_state, batch, lr, *extra)
        loss = float(metrics["loss"])
        if self.policy == "awp":
            norms = np.asarray(metrics["group_norms_sq"])
            self.controller.update(norms)
        entries = self.wire_entries(rts)
        self.records.append(
            StepRecord(
                step=len(self.records),
                loss=loss,
                round_tos=rts,
                wire_bytes=(
                    entries["total"] if entries is not None
                    else self.wire_bytes(rts)
                ),
                recompiled=recompiled,
                wall_s=time.time() - t0,
                wire_by_entry=entries,
                io_by_entry=(
                    {k: v for k, v in io_log.items() if isinstance(v, int)}
                    if io_log is not None
                    else None
                ),
            )
        )
        return storage, opt_state, metrics

    # ------------------------------------------------------------------
    @property
    def bits_history(self):
        return self.controller.history

    def summary(self) -> dict:
        total_wire = sum(r.wire_bytes for r in self.records)
        base_wire = sum(
            self.wire_bytes((4,) * self.num_groups) for _ in self.records
        )
        out = {
            "steps": len(self.records),
            "final_loss": self.records[-1].loss if self.records else None,
            "recompiles": sum(r.recompiled for r in self.records),
            "wire_bytes": total_wire,
            "wire_bytes_fp32": base_wire,
            "wire_reduction": 1 - total_wire / base_wire if base_wire else 0.0,
            "bits_history": self.bits_history,
        }
        if self.plan is not None:
            by_entry: dict[str, int] = {}
            for r in self.records:
                for k, v in (r.wire_by_entry or {}).items():
                    if k != "total":
                        by_entry[k] = by_entry.get(k, 0) + v
            out["wire_by_entry"] = by_entry
        if any(r.io_by_entry for r in self.records):
            io: dict[str, int] = {}
            for r in self.records:
                for k, v in (r.io_by_entry or {}).items():
                    io[k] = io.get(k, 0) + v
            out["io_by_entry"] = io
        return out
