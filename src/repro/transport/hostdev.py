"""Host<->device staging of integer token payloads (serve engine).

The paper's §III motion class this models is the host->device boundary:
on a real system prompts arrive on the host (tokenizer output) and
sampled ids return to it (detokenizer / stop conditions), so every serve
step moves token ids across the PCIe/DMA link. The transport adapts the
representation before the move exactly like the weight path adapts fp32
words: an int32 id is split into byte planes (most-significant first,
mirroring :func:`repro.transport.pack_planes`) and only the planes a
``vocab_size`` id can populate are staged —
:meth:`~repro.transport.CompressionPolicy.token_wire_width` is the
single width formula shared by this module, the engine's measured wire
log, and the roofline's analytic serve model, so the three cannot drift.

Unlike the fp32 weight planes this packing is *lossless* by
construction (ids are exact integers): ``unpack ∘ pack`` is the
identity for any id in ``[0, 2**(8*width))``.

Two symmetric implementations:

  * :func:`pack_tokens_host` / :func:`unpack_tokens_host` — pure numpy,
    run on the host side of the boundary (the engine's scheduler).
  * :func:`pack_tokens` / :func:`unpack_tokens` — jnp, traced into the
    device-side jitted programs (sampler pack, prompt unpack).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "pack_tokens",
    "unpack_tokens",
    "pack_tokens_host",
    "unpack_tokens_host",
    "stage",
]


def stage(x):
    """The one host->device staging entry (the priced h2d boundary).

    Every array the serve engine moves onto the device crosses here, so
    the engine's per-step wire log (``rec["host_device"] += x.nbytes``
    at each call site), the roofline's analytic serve model, and the
    lint rule UNPRICED-TRANSFER all agree on where h2d bytes originate.
    Functionally ``jax.device_put``; the indirection is the audit
    surface, not a behavior change.
    """
    return jax.device_put(x)


def _shifts(width: int):
    """Bit shifts per plane, most-significant plane first."""
    return [8 * (width - 1 - i) for i in range(width)]


def pack_tokens(tokens: jnp.ndarray, width: int) -> jnp.ndarray:
    """int token ids (any shape) -> uint8 planes ``(width, *shape)``.

    Device-side variant (jit-traceable): the serve engine packs sampled
    ids with this before they leave the device."""
    t = tokens.astype(jnp.uint32)
    return jnp.stack(
        [((t >> s) & 0xFF).astype(jnp.uint8) for s in _shifts(width)], axis=0
    )


def unpack_tokens(planes: jnp.ndarray) -> jnp.ndarray:
    """uint8 planes ``(width, *shape)`` -> int32 ids ``shape``."""
    width = planes.shape[0]
    t = jnp.zeros(planes.shape[1:], jnp.uint32)
    for i, s in enumerate(_shifts(width)):
        t = t | (planes[i].astype(jnp.uint32) << s)
    return t.astype(jnp.int32)


def pack_tokens_host(tokens, width: int) -> np.ndarray:
    """Host-side (numpy) twin of :func:`pack_tokens`: the engine stages
    prompts and next-step tokens with this; ``result.nbytes`` is the
    measured h2d wire contribution."""
    t = np.asarray(tokens, np.uint32)
    return np.stack(
        [((t >> s) & 0xFF).astype(np.uint8) for s in _shifts(width)], axis=0
    )


def unpack_tokens_host(planes) -> np.ndarray:
    """Host-side twin of :func:`unpack_tokens` (sampled ids arriving d2h)."""
    planes = np.asarray(planes, np.uint8)
    width = planes.shape[0]
    t = np.zeros(planes.shape[1:], np.uint32)
    for i, s in enumerate(_shifts(width)):
        t |= planes[i].astype(np.uint32) << np.uint32(s)
    return t.astype(np.int32)
