"""Production training launcher.

Selects an assigned architecture (``--arch``), builds the FSDP×TP mesh,
and runs the A²DTWP loop (AWP controller + ADT-compressed gathers) on the
synthetic pipeline. On this CPU container use ``--reduced`` plus a small
``--mesh``; on a real pod run the full config on 16x16 or 2x16x16.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --mesh 2x4 --steps 100 --policy awp
  XLA_FLAGS=--xla_force_host_platform_device_count=8 ... --mesh 2x4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import save_checkpoint
from repro.configs.registry import ARCHS, get_config, reduced
from repro.core.awp import AWPConfig
from repro.data.pipeline import synthetic_feature_batch, synthetic_lm_batch
from repro.dist.spec import (
    DIST, LeafSpec, MeshCfg, build_spec_tree, tree_to_storage,
)
from repro.launch.mesh import make_mesh_from_cfg
from repro.models.init import init_params
from repro.optim.sgd import SGDConfig, init_momentum
from repro.train.loop import Trainer
from repro.train.step import make_train_step
from repro.transport import act_policy_for


def parse_mesh(spec: str) -> MeshCfg:
    """"1x1" | "<dp>x<tp>" | "<pods>x<dp>x<tp>"."""
    parts = [int(p) for p in spec.split("x")]
    if len(parts) == 2:
        return MeshCfg(tp=parts[1], dp=parts[0])
    if len(parts) == 3:
        return MeshCfg(tp=parts[2], dp=parts[1], pods=parts[0])
    raise SystemExit(f"bad --mesh {spec!r}")


def count_dist_elems(spec_tree, mesh_cfg, n_groups):
    elems = [0] * n_groups

    def visit(idx, subtree):
        for s in jax.tree_util.tree_leaves(
            subtree, is_leaf=lambda x: isinstance(x, LeafSpec)
        ):
            if isinstance(s, LeafSpec) and s.kind == DIST:
                elems[idx] += s.s_loc * mesh_cfg.dshards

    for g, gs in enumerate(spec_tree["groups"]):
        visit(g, gs)
    visit(n_groups - 1, {k: v for k, v in spec_tree.items() if k != "groups"})
    return elems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--policy", default="awp")
    ap.add_argument("--awp-threshold", type=float, default=1e-3)
    ap.add_argument("--awp-interval", type=int, default=25)
    ap.add_argument("--bf16", action="store_true")
    ap.add_argument("--grad-round-to", type=int, default=4)
    ap.add_argument("--act-round-to", type=int, default=4,
                    help="activation wire format on the TP axis (<4 routes "
                         "TP psums and seq collectives through packed planes)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel activations: norms/residuals on "
                         "1/tp sequence shards, block boundaries become "
                         "seq_gather/seq_scatter (requires seq %% tp == 0)")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh_cfg = parse_mesh(args.mesh)
    if mesh_cfg.tp * mesh_cfg.dshards > len(jax.devices()):
        raise SystemExit(
            f"mesh {args.mesh} needs {mesh_cfg.tp * mesh_cfg.dshards} devices, "
            f"have {len(jax.devices())} (set XLA_FLAGS=--xla_force_host_"
            f"platform_device_count=N)"
        )
    mesh = make_mesh_from_cfg(mesh_cfg)

    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=mesh_cfg.tp)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, mesh {mesh_cfg.shape}, "
          f"policy {args.policy}")

    B, S = args.batch, args.seq
    audio = cfg.embed_is_input_stub
    if audio:
        batch_shapes = {
            "features": jax.ShapeDtypeStruct((B, S, cfg.vision_dim), jnp.float32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    else:
        batch_shapes = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if cfg.num_image_tokens:
        batch_shapes["image_features"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.vision_dim), jnp.float32
        )

    opt = SGDConfig(lr=args.lr, momentum=0.9, weight_decay=1e-4)
    nrt = cfg.num_groups + 1

    act_policy = act_policy_for(args.act_round_to)

    def builder(round_tos):
        return make_train_step(
            cfg, mesh_cfg, mesh, spec_tree, round_tos, opt, batch_shapes,
            dtype=jnp.bfloat16 if args.bf16 else jnp.float32,
            grad_round_to=args.grad_round_to, accum_steps=args.accum,
            act_policy=act_policy, seq_parallel=args.seq_parallel,
        )

    trainer = Trainer(
        builder, nrt, policy=args.policy,
        awp_config=AWPConfig(
            threshold=args.awp_threshold, interval=args.awp_interval
        ),
        dist_elems_per_group=count_dist_elems(spec_tree, mesh_cfg, nrt),
        gather_axis_size=max(mesh_cfg.dshards, 1),
    )
    mom = init_momentum(storage)

    rngi = np.random.default_rng(0)
    ctx = mesh if mesh is not None else _null()
    t0 = time.time()
    with ctx:
        for step in range(args.steps):
            if audio:
                f, l = synthetic_feature_batch(
                    cfg.vision_dim, cfg.vocab_size, B, S, step
                )
                batch = {"features": f, "labels": l}
            else:
                t, l = synthetic_lm_batch(cfg.vocab_size, B, S, step)
                batch = {"tokens": t, "labels": l}
            if cfg.num_image_tokens:
                batch["image_features"] = jnp.asarray(
                    rngi.normal(0, 1, (B, cfg.num_image_tokens, cfg.vision_dim)),
                    jnp.float32,
                )
            storage, mom, _ = trainer.run_step(storage, mom, batch, args.lr)
            if step % 20 == 19:
                r = trainer.records[-1]
                print(f"step {step+1:4d}  loss {r.loss:.4f}  rts {r.round_tos}"
                      f"  wire {r.wire_bytes/1e6:.1f}MB"
                      f"  {(time.time()-t0)/(step+1):.2f}s/step", flush=True)
    s = trainer.summary()
    print(f"done: loss {s['final_loss']:.4f}  wire-reduction "
          f"{s['wire_reduction']*100:.1f}%  recompiles {s['recompiles']}")
    print(f"AWP: {s['bits_history']}")
    if args.ckpt:
        save_checkpoint(args.ckpt, storage, mom, trainer.controller, args.steps)
        print(f"checkpoint -> {args.ckpt}")


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
