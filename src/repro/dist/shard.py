"""Version-compat ``shard_map``.

jax moved ``shard_map`` from ``jax.experimental.shard_map`` to the top
level and renamed ``check_rep`` to ``check_vma`` along the way. Every
call site in this repo goes through this wrapper so the rest of the code
is version-agnostic. Replication checking defaults to *off*: the
custom-VJP collective pairs in :mod:`repro.core.collectives` and the
transport layer intentionally produce device-varying intermediates that
older checkers reject.
"""
from __future__ import annotations

import inspect

import jax

try:
    _shard_map = jax.shard_map  # jax >= 0.6
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False, **kw):
    if "check_vma" in _PARAMS:
        kw["check_vma"] = check_vma
    elif "check_rep" in _PARAMS:
        kw["check_rep"] = check_vma
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
    )
