"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attn  [arXiv:2401.04088].

8 experts < 16 TP shards, so EP over the model axis does not divide; the
TP-MoE mapping (every expert's d_ff sharded over `model`, local dispatch)
is used instead (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    top_k=2,
    sliding_window=4096,
    moe_impl="tp",
    rope_theta=1e6,
    num_precision_groups=4,
)
