"""Continuous-batching serve engine: scheduler invariants, determinism,
bit-exactness vs static batching, and host<->device wire accounting.

The contracts pinned here (see docs/serving.md):

  * no KV-slot leaks across admit/evict cycles (``SlotManager.audit``);
  * per-request token streams are a pure function of the prompt —
    identical regardless of arrival order or batch companions;
  * continuous batching is BIT-EXACT vs the static one-shot reference
    (``generate_static``) for identical request sets, mixed prompt
    lengths included, fp32 and int8-KV alike;
  * the engine's measured ``host_device`` staged bytes equal the
    analytic roofline serve model
    (``repro.roofline.analysis.serve_host_device_bytes``) — the serving
    twin of ``test_collective_wire_bytes``'s no-drift rule.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import load_plan, load_storage, save_checkpoint
from repro.configs.registry import get_config, reduced
from repro.dist.spec import MeshCfg, build_spec_tree, tree_to_storage
from repro.models.init import init_params
from repro.plan import PrecisionPlan
from repro.roofline.analysis import serve_host_device_bytes
from repro.serve.engine import (
    AllocatorError,
    CapacityError,
    GenResult,
    InvariantError,
    Request,
    ServeEngine,
    SlotManager,
    generate_static,
)
from repro.transport import CompressionPolicy
from repro.transport.hostdev import (
    pack_tokens,
    pack_tokens_host,
    unpack_tokens,
    unpack_tokens_host,
)

CAPACITY = 24
SLOTS = 2


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-1.7b"))
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=4096)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2),) * (cfg.num_groups + 1),
        host_device=CompressionPolicy(round_to=2),
    )
    return cfg, mesh_cfg, spec_tree, storage, plan


def _requests(cfg, spec=((16, 6), (12, 8), (16, 4), (8, 8), (12, 5))):
    rng = np.random.default_rng(7)
    return [
        Request(
            rid=i,
            prompt_ids=tuple(int(t) for t in rng.integers(0, cfg.vocab_size, S)),
            max_new=gen,
        )
        for i, (S, gen) in enumerate(spec)
    ]


@pytest.fixture(scope="module")
def engine(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    return ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
        max_slots=SLOTS, cache_capacity=CAPACITY,
    )


@pytest.fixture(scope="module")
def static_streams(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    return generate_static(
        cfg, mesh_cfg, None, spec_tree, storage, _requests(cfg), plan=plan
    )


# ---------------------------------------------------------------------------
# slot manager invariants (pure python)
# ---------------------------------------------------------------------------


def test_slot_manager_alloc_release_audit():
    sm = SlotManager(3)
    a = sm.alloc(10)
    b = sm.alloc(11)
    assert (a, b) == (0, 1)  # lowest free slot first
    sm.audit()
    sm.release(a)
    c = sm.alloc(12)
    assert c == a  # freed slot is reused
    sm.release(b)
    sm.release(c)
    audit = sm.audit()
    assert audit == {"free": 3, "active": 0, "allocs": 3, "releases": 3}


def test_slot_manager_rejects_double_free_and_exhaustion():
    sm = SlotManager(1)
    s = sm.alloc(1)
    with pytest.raises(CapacityError):
        sm.alloc(2)
    sm.release(s)
    with pytest.raises(AllocatorError):
        sm.release(s)


def test_slot_manager_audit_catches_leak():
    sm = SlotManager(2)
    sm.alloc(1)
    sm._owner.pop(0)  # simulate a lost slot (neither free nor owned)
    with pytest.raises(InvariantError):
        sm.audit()


# ---------------------------------------------------------------------------
# token staging (host<->device byte planes)
# ---------------------------------------------------------------------------


def test_token_planes_lossless_and_host_device_parity():
    ids = np.array([0, 1, 255, 256, 65535, 99999, 151935], np.int32)
    for width in (1, 2, 3, 4):
        sub = ids[ids < 2 ** (8 * width)]
        host = pack_tokens_host(sub, width)
        dev = np.asarray(pack_tokens(jnp.asarray(sub), width))
        assert host.dtype == np.uint8 and host.shape == (width,) + sub.shape
        np.testing.assert_array_equal(host, dev)
        np.testing.assert_array_equal(unpack_tokens_host(host), sub)
        np.testing.assert_array_equal(
            np.asarray(unpack_tokens(jnp.asarray(host))), sub
        )


def test_token_wire_width_adapts_to_vocab():
    # compressing policies stage the lossless floor, never narrower
    assert CompressionPolicy(round_to=1).token_wire_width(256) == 1
    assert CompressionPolicy(round_to=1).token_wire_width(257) == 2
    assert CompressionPolicy(round_to=2).token_wire_width(151936) == 3
    assert CompressionPolicy(round_to=3).token_wire_width(512) == 3
    # uncompressed policy = raw int32 staging (the fp32-baseline analogue)
    assert CompressionPolicy(round_to=4).token_wire_width(512) == 4
    assert CompressionPolicy(round_to=2).token_host_bytes(10, 512) == 20


# ---------------------------------------------------------------------------
# scheduler end-to-end contracts
# ---------------------------------------------------------------------------


def test_continuous_matches_static_mixed_lengths(engine, setup, static_streams):
    cfg = setup[0]
    reqs = _requests(cfg)
    results = engine.run(reqs)
    assert set(results) == {r.rid for r in reqs}
    for r in reqs:
        assert isinstance(results[r.rid], GenResult)
        assert results[r.rid].tokens == static_streams[r.rid], r.rid
    # with 2 slots and 5 requests, admissions must have been staggered
    assert max(g.admitted_step for g in results.values()) > 0


def test_no_slot_leaks_across_admit_evict_cycles(engine, setup):
    cfg = setup[0]
    reqs = _requests(cfg)
    engine.run(reqs)
    audit = engine.slots.audit()
    assert audit["active"] == 0 and audit["free"] == SLOTS
    assert audit["allocs"] == audit["releases"]
    assert audit["allocs"] >= len(reqs)  # every request got a slot


def test_deterministic_streams_regardless_of_arrival_order(engine, setup):
    cfg = setup[0]
    reqs = _requests(cfg)
    a = engine.run(reqs)
    b = engine.run(list(reversed(reqs)))
    for r in reqs:
        assert a[r.rid].tokens == b[r.rid].tokens, r.rid


def test_wire_log_pins_analytic_serve_model(engine, setup):
    cfg, _, _, _, plan = setup
    reqs = _requests(cfg)
    engine.run(reqs)
    measured = engine.wire_summary()
    analytic = serve_host_device_bytes(
        plan, cfg.vocab_size, n_slots=SLOTS,
        prompt_lens=[len(r.prompt_ids) for r in reqs],
        decode_steps=measured["decode_steps"],
    )
    assert measured["host_device"] == analytic["total"]
    assert measured["token_width"] == analytic["token_width"]
    # per-step: admissions stage prompt+first token, decode the full batch
    w = measured["token_width"]
    by_rid = {r.rid: len(r.prompt_ids) for r in reqs}
    admit_order = [r.rid for r in reqs]  # engine admits in list order
    i = 0
    for rec in engine.step_log:
        expect = 0
        for _ in range(rec["admitted"]):
            expect += w * (by_rid[admit_order[i]] + 1)
            i += 1
        if rec["decoded"]:
            expect += 2 * w * SLOTS
        assert rec["host_device"] == expect, rec


def test_stop_on_eos_truncates_and_matches_static(engine, setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    base = _requests(cfg)[:2]
    free_run = engine.run(base)
    # pick an id the longer stream actually emits mid-way as the eos
    target = free_run[1].tokens[2]
    reqs = [
        base[0],
        Request(rid=1, prompt_ids=base[1].prompt_ids,
                max_new=base[1].max_new, eos_id=target),
    ]
    results = engine.run(reqs)
    want = free_run[1].tokens[: free_run[1].tokens.index(target) + 1]
    assert results[1].tokens == want
    assert results[1].tokens[-1] == target
    ref = generate_static(
        cfg, mesh_cfg, None, spec_tree, storage, reqs, plan=plan
    )
    for r in reqs:
        assert results[r.rid].tokens == ref[r.rid]


def test_int8_kv_continuous_matches_static(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    import dataclasses

    plan8 = dataclasses.replace(plan, int8_kv=True)
    reqs = _requests(cfg, spec=((12, 5), (8, 6), (12, 4)))
    engine = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=plan8,
        max_slots=2, cache_capacity=CAPACITY,
    )
    results = engine.run(reqs)
    ref = generate_static(
        cfg, mesh_cfg, None, spec_tree, storage, reqs, plan=plan8
    )
    for r in reqs:
        assert results[r.rid].tokens == ref[r.rid], r.rid


def test_engine_restores_from_checkpoint(tmp_path, setup, engine, static_streams):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    path = str(tmp_path / "served")
    momentum = jax.tree_util.tree_map(jnp.zeros_like, storage)
    save_checkpoint(path, storage, momentum, None, 3, plan=plan)
    restored_plan = load_plan(path)
    assert restored_plan == plan.broadcast(len(plan.weights))
    like = jax.tree_util.tree_map(jnp.zeros_like, storage)
    restored, step = load_storage(path, like)  # weights-only serve restore
    assert step == 3
    eng = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, restored, plan=restored_plan,
        max_slots=SLOTS, cache_capacity=CAPACITY,
    )
    results = eng.run(_requests(cfg)[:2])
    for rid in (0, 1):
        assert results[rid].tokens == static_streams[rid]


def test_windowed_ring_decode_matches_static(setup):
    # capacity == window -> the cache rings; prompt+gen exceed capacity
    # so the ring genuinely wraps, and the masked linear cache of the
    # static reference must still agree token for token
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    window = 12
    reqs = _requests(cfg, spec=((16, 8), (10, 8)))
    engine = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
        max_slots=2, cache_capacity=window, window=window,
    )
    results = engine.run(reqs)
    ref = generate_static(
        cfg, mesh_cfg, None, spec_tree, storage, reqs, plan=plan,
        window=window,
    )
    for r in reqs:
        assert results[r.rid].tokens == ref[r.rid], r.rid


def test_non_ring_window_capacity_is_rejected(setup):
    # window set but capacity > window: the cache stays linear (mha only
    # rings when C <= window), so an overflowing request must be refused
    # up front instead of silently dropping its KV writes
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    engine = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
        max_slots=1, cache_capacity=20, window=12,
    )
    with pytest.raises(ValueError, match="does not ring"):
        engine.run([Request(rid=0, prompt_ids=(1,) * 16, max_new=8)])
    # ring narrower than the window: wrapping would evict tokens the
    # attention mask still wants — refused rather than silently diverging
    narrow = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
        max_slots=1, cache_capacity=10, window=16,
    )
    with pytest.raises(ValueError, match="live tokens would be evicted"):
        narrow.run([Request(rid=0, prompt_ids=(1,) * 8, max_new=8)])
    # ...but a narrow ring the request never wraps is fine
    narrow.run([Request(rid=1, prompt_ids=(1, 2, 3), max_new=2)])


def test_moe_engine_matches_per_request_static():
    # MoE decode routes the slot batch through one capacity dispatch;
    # with max_slots * top_k <= 8 (the capacity floor) no token drops, so
    # streams stay companion-independent and match per-request (batch-of-
    # 1) static references — the comparison the launcher's --check-static
    # uses for MoE archs (grouped prefill would change capacity pressure)
    cfg = reduced(get_config("mixtral-8x7b"))
    assert cfg.num_experts and 2 * cfg.top_k <= 8
    mesh_cfg = MeshCfg(tp=1, dp=1, compress_min_size=4096)
    params, metas = init_params(cfg, jax.random.PRNGKey(0), tp=1)
    spec_tree = build_spec_tree(params, metas, mesh_cfg)
    storage = tree_to_storage(params, spec_tree, mesh_cfg)
    plan = PrecisionPlan(
        weights=(CompressionPolicy(round_to=2),) * (cfg.num_groups + 1),
        host_device=CompressionPolicy(round_to=2),
    )
    reqs = _requests(cfg, spec=((12, 4), (8, 5), (12, 3)))
    engine = ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage, plan=plan,
        max_slots=2, cache_capacity=CAPACITY,
    )
    results = engine.run(reqs)
    for r in reqs:
        ref = generate_static(
            cfg, mesh_cfg, None, spec_tree, storage, [r], plan=plan
        )
        assert results[r.rid].tokens == ref[r.rid], r.rid


def test_request_validation(engine):
    with pytest.raises(ValueError):
        Request(rid=0, prompt_ids=(), max_new=4)
    with pytest.raises(ValueError):
        Request(rid=0, prompt_ids=(1,), max_new=0)
    with pytest.raises(ValueError):  # prompt + gen beyond cache capacity
        engine.run([Request(rid=0, prompt_ids=(1,) * 20,
                            max_new=CAPACITY)])
    with pytest.raises(ValueError):  # duplicate rid
        engine.run([
            Request(rid=0, prompt_ids=(1, 2), max_new=1),
            Request(rid=0, prompt_ids=(3, 4), max_new=1),
        ])


# ---------------------------------------------------------------------------
# block-paged KV cache (page pool + page table + shared-prefix interning)
# ---------------------------------------------------------------------------

from repro.models.attention import init_cache
from repro.roofline.analysis import serve_paged_kv_bytes
from repro.serve.engine import PageAllocator

PAGE = 8


def _paged_engine(setup, plan=None, **kw):
    cfg, mesh_cfg, spec_tree, storage, default_plan = setup
    kw.setdefault("max_slots", SLOTS)
    kw.setdefault("cache_capacity", CAPACITY)
    kw.setdefault("page_size", PAGE)
    return ServeEngine(
        cfg, mesh_cfg, None, spec_tree, storage,
        plan=plan or default_plan, paged=True, **kw,
    )


def test_page_allocator_refcount_and_audit():
    pa = PageAllocator(4)
    a, b = pa.alloc(2)
    assert (a, b) == (0, 1)
    pa.retain(a)  # shared-prefix second holder
    assert pa.refcount(a) == 2
    assert not pa.release(a)  # still one holder -> not freed
    assert pa.release(a)  # last holder -> freed
    assert pa.release(b)
    audit = pa.audit()
    assert audit["live"] == 0 and audit["free"] == 4
    assert audit["allocs"] == audit["releases"] + audit["live"]
    with pytest.raises(AllocatorError):
        pa.release(a)  # double free
    with pytest.raises(CapacityError):
        pa.alloc(5)  # exhaustion
    pa._refs[9] = 1  # simulate a leaked page
    with pytest.raises(InvariantError):
        pa.audit()


def test_paged_matches_contiguous_and_static(engine, setup, static_streams):
    # mixed prompt lengths, slot reuse after release (5 requests, 2
    # slots), prompt bucketing active: streams must stay BIT-EXACT
    cfg = setup[0]
    reqs = _requests(cfg)
    paged = _paged_engine(setup)
    results = paged.run(reqs)
    cont = engine.run(reqs)
    for r in reqs:
        assert results[r.rid].tokens == static_streams[r.rid], r.rid
        assert results[r.rid].tokens == cont[r.rid].tokens, r.rid
    paged.slots.audit()
    audit = paged.pages.audit()
    assert audit["live"] == 0
    assert audit["allocs"] == audit["releases"] >= len(reqs)
    # bucketing: lens (16,12,16,8,12) at page 8 -> buckets {16, 8},
    # so 2 compiles serve all 5 admissions
    w = paged.wire_summary()
    assert w["prefill_misses"] == 2
    assert w["prefill_hits"] == 3


def test_paged_int8_kv_matches_static(setup):
    import dataclasses

    cfg, mesh_cfg, spec_tree, storage, plan = setup
    plan8 = dataclasses.replace(plan, int8_kv=True)
    reqs = _requests(cfg, spec=((12, 5), (8, 6), (12, 4)))
    results = _paged_engine(setup, plan=plan8).run(reqs)
    ref = generate_static(
        cfg, mesh_cfg, None, spec_tree, storage, reqs, plan=plan8
    )
    for r in reqs:
        assert results[r.rid].tokens == ref[r.rid], r.rid


def test_paged_shared_prefix_refcount_and_residency(setup):
    # 3 requests share a 2-page system prompt; all resident at once, so
    # the measured peak must equal the analytic page-granular model:
    # shared pages stored ONCE + per-request private tails
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    rng = np.random.default_rng(3)
    shared = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 2 * PAGE))
    tails, gen = (4, 9, 12), 6
    reqs = [
        Request(rid=i, prompt_ids=shared + tuple(
            int(t) for t in rng.integers(0, cfg.vocab_size, t)),
            max_new=gen)
        for i, t in enumerate(tails)
    ]
    eng = _paged_engine(setup, max_slots=len(reqs), cache_capacity=40)
    results = eng.run(reqs)
    ref = generate_static(
        cfg, mesh_cfg, None, spec_tree, storage, reqs, plan=plan
    )
    for r in reqs:
        assert results[r.rid].tokens == ref[r.rid], r.rid
    analytic = serve_paged_kv_bytes(
        cfg, page_size=PAGE,
        requests=[(len(r.prompt_ids), gen) for r in reqs],
        shared_prefix_len=len(shared),
    )
    assert analytic["shared_pages"] == 2
    res = eng.kv_residency()
    assert res["bytes_per_page"] == analytic["bytes_per_page"]
    assert res["pages_peak"] == analytic["pages"]
    assert res["kv_bytes_peak"] == analytic["kv_bytes_resident"]
    # every retirement dropped its refcounts back to zero
    assert res["pages_live"] == 0 and res["kv_bytes_resident"] == 0
    audit = eng.pages.audit()
    assert audit["live"] == 0 and audit["allocs"] == audit["releases"]
    # sharing actually deduped: without it every request would intern
    # its own copy of the 2 shared pages
    no_share = sum(-(-(len(r.prompt_ids) + gen) // PAGE) for r in reqs)
    assert analytic["pages"] == no_share - 2 * (len(reqs) - 1) < no_share


def test_paged_wire_log_pins_analytic_serve_model(setup):
    cfg, _, _, _, plan = setup
    reqs = _requests(cfg)
    eng = _paged_engine(setup)
    eng.run(reqs)
    measured = eng.wire_summary()
    analytic = serve_host_device_bytes(
        plan, cfg.vocab_size, n_slots=SLOTS,
        prompt_lens=[len(r.prompt_ids) for r in reqs],
        decode_steps=measured["decode_steps"],
        page_table_entries=measured["page_table_entries"],
    )
    assert measured["host_device"] == analytic["total"]
    assert measured["page_table"] == analytic["page_table_h2d"]


def test_paged_rejects_windows_and_oversized_requests(setup):
    cfg, mesh_cfg, spec_tree, storage, plan = setup
    with pytest.raises(ValueError, match="contiguous"):
        _paged_engine(setup, window=12)
    eng = _paged_engine(setup, num_pages=2)
    with pytest.raises(ValueError, match="pages"):
        eng.run([Request(rid=0, prompt_ids=(1,) * 16, max_new=8)])


def test_cache_constructor_geometry_guard():
    # the admission-time window/capacity rules now live in the cache
    # constructors: a linear cache too small for its context, and a ring
    # narrower than its window, both fail at construction
    with pytest.raises(ValueError, match="does not ring"):
        init_cache(1, 20, 2, 8, jnp.float32, window=12, context=24)
    with pytest.raises(ValueError, match="live tokens would be evicted"):
        init_cache(1, 10, 2, 8, jnp.float32, window=16, context=18)
    with pytest.raises(ValueError, match="no sliding window"):
        init_cache(1, 16, 2, 8, jnp.float32, context=24)
    # capacity == window rings faithfully; fitting contexts are fine
    init_cache(1, 12, 2, 8, jnp.float32, window=12, context=24)
    init_cache(1, 24, 2, 8, jnp.float32, context=24)
