"""Compressed collectives — thin compatibility shims over repro.transport.

The pack -> collective -> unpack pipelines, their custom VJPs, and the
wire-byte accounting all moved to :mod:`repro.transport` (see
docs/transport.md), which dispatches between the Pallas kernels (compiled
on TPU, interpret off-TPU) and the pure-jnp oracle. These wrappers keep
the original call signatures for existing code and scenarios; new code
should use :class:`repro.transport.Transport` /
:class:`repro.transport.CompressionPolicy` directly.
"""
from __future__ import annotations

from typing import Hashable, Sequence

import jax.numpy as jnp

from repro.transport import CompressionPolicy
from repro.transport import transport as _T

AxisNames = Hashable | Sequence[Hashable]


def _axis_size(axis_names: AxisNames) -> int:
    return _T.axis_size(axis_names)


def compressed_all_gather(
    w_local: jnp.ndarray,
    axis_names: AxisNames,
    round_to: int,
    grad_round_to: int = 4,
) -> jnp.ndarray:
    """All-gather a flat fp32 shard ``(S_loc,)`` -> ``(S,)`` in ``round_to``
    bytes; VJP reduce-scatters at ``grad_round_to`` (4 = uncompressed,
    paper-faithful). Deprecated alias for ``transport.all_gather``."""
    policy = CompressionPolicy(round_to=round_to, grad_round_to=grad_round_to)
    return _T.all_gather(w_local, axis_names, policy, 0)


def compressed_psum_scatter(
    g: jnp.ndarray, axis_names: AxisNames, round_to: int
) -> jnp.ndarray:
    """Reduce-scatter a flat fp32 ``(S,)`` -> ``(S_loc,)`` in ``round_to``
    bytes. Deprecated alias for ``transport.reduce_scatter``."""
    policy = CompressionPolicy(grad_round_to=round_to)
    return _T.reduce_scatter(g, axis_names, policy)


def quantize_ste(w: jnp.ndarray, round_to: int) -> jnp.ndarray:
    """Single-device ADT format truncation with a straight-through VJP.
    Deprecated alias for ``transport.quantize``."""
    return _T.quantize(w, CompressionPolicy(round_to=round_to))


# ---------------------------------------------------------------------------
# Collective byte accounting — canonical formulas live on CompressionPolicy
# ---------------------------------------------------------------------------


def all_gather_wire_bytes(s_local: int, axis_size: int, round_to: int) -> int:
    """Bytes received per device for one compressed all-gather."""
    return CompressionPolicy(round_to=round_to).all_gather_wire_bytes(
        s_local, axis_size
    )


def psum_scatter_wire_bytes(s_local: int, axis_size: int, round_to: int) -> int:
    """Bytes received per device for one (compressed) reduce-scatter."""
    return CompressionPolicy(grad_round_to=round_to).reduce_scatter_wire_bytes(
        s_local, axis_size
    )
