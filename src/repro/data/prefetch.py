"""Double-buffered host->device batch prefetcher.

The heterogeneous-SGD line of work (Ma & Rusu, PAPERS.md) overlaps
host-side ingest work with device steps; this module is that overlap for
our training loop. A background thread walks the shard reader (disk
reads + plane decode + staging pack) while the device runs the current
step; the main thread only performs the device put + unpack, so with a
``depth``-deep queue the ingest pipeline is hidden behind compute
whenever a batch's host work is cheaper than a train step.

Staged bytes ride the plan's ``host_device``
:class:`~repro.transport.CompressionPolicy` entry, exactly like the
serve engine's token staging:

  * integer fields (token streams, labels) are packed into lossless
    byte planes at ``CompressionPolicy.token_wire_width`` — an id of a
    65k vocab crosses PCIe as 2 bytes, never truncated below its
    lossless floor;
  * float fields cross raw (fp32) — lossy staging of training inputs
    would silently change the optimization problem, so the policy's
    compressing widths only apply where they are free.

Every yielded batch carries an ``io_log`` dict — ``shard_read`` (stored
bytes the reader moved off disk), ``host_device`` (bytes staged across
the boundary), ``data_state`` (reader state after this batch, the value
a checkpoint written after the matching step persists). The trainer
stores it per step as ``StepRecord.io_by_entry``, and
:func:`repro.roofline.analysis.train_ingest_bytes` reproduces both byte
terms analytically — measured == analytic is pinned by the train-I/O
tests.
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.transport import CompressionPolicy, pack_tokens_host, unpack_tokens


def _resolve_policy(plan_or_policy) -> CompressionPolicy:
    pol = plan_or_policy
    if pol is None:
        return CompressionPolicy()
    if hasattr(pol, "host_device_policies"):  # a PrecisionPlan
        return pol.host_device_policies()[0]
    return pol


def staged_ids_per_batch(kind: str, batch: int, seq: int) -> int:
    """Integer ids staged h2d per batch — the geometry term the analytic
    ingest model shares with the measured pack (LM stores the stream
    once: ``seq+1`` ids per row covers tokens AND labels)."""
    if kind == "lm":
        return batch * (seq + 1)
    if kind == "feature":
        return batch * seq  # labels
    raise ValueError(f"unknown shard kind {kind!r}")


class Prefetcher:
    """Iterate ``(device_batch, io_log)`` over a shard-batch iterator.

    ``batch_iter`` yields ``(host_batch, stored_bytes, state_after)``
    (see :func:`repro.data.shards.batches`). ``kind`` selects the
    device-side batch adaptation: ``lm`` slices the staged stream into
    ``tokens``/``labels`` views on device, ``feature`` passes
    ``features``/``labels`` through.
    """

    def __init__(
        self,
        batch_iter,
        *,
        kind: str,
        vocab: int,
        plan=None,
        depth: int = 2,
    ):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        if kind not in ("lm", "feature"):
            raise ValueError(f"unknown shard kind {kind!r}")
        self.kind = kind
        self.vocab = int(vocab)
        self.policy = _resolve_policy(plan)
        self.width = self.policy.token_wire_width(self.vocab)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._err: BaseException | None = None
        self._unpack_cache: dict = {}
        self._thread = threading.Thread(
            target=self._worker, args=(batch_iter,), daemon=True
        )
        self._thread.start()

    # -- host side (worker thread) -------------------------------------
    def _stage(self, host_batch: dict) -> tuple[dict, int]:
        """Pack one host batch for the boundary crossing; returns the
        staged arrays and their measured byte count."""
        staged, nbytes = {}, 0
        for name in sorted(host_batch):
            arr = np.asarray(host_batch[name])
            if arr.dtype.kind in ("i", "u"):
                planes = pack_tokens_host(arr, self.width)
                staged[name] = planes
                nbytes += planes.nbytes
            else:
                arr = np.ascontiguousarray(arr)
                staged[name] = arr
                nbytes += arr.nbytes
        return staged, nbytes

    def _worker(self, batch_iter):
        try:
            for host_batch, stored_bytes, state in batch_iter:
                if self._stop.is_set():
                    return
                staged, h2d = self._stage(host_batch)
                log = {
                    "shard_read": stored_bytes,
                    "host_device": h2d,
                    "data_state": state,
                }
                while not self._stop.is_set():
                    try:
                        self._q.put((staged, log), timeout=0.1)
                        break
                    except queue.Full:
                        continue
            self._q.put(None)  # finite iterator exhausted
        except BaseException as e:  # surfaced on the consumer side
            self._err = e
            self._q.put(None)

    # -- device side (main thread) -------------------------------------
    def _unpack_fn(self, shapes_key):
        fn = self._unpack_cache.get(shapes_key)
        if fn is None:
            kind = self.kind

            def unpack(staged):
                out = {}
                for name, v in staged.items():
                    if v.dtype == jnp.uint8:
                        out[name] = unpack_tokens(v)
                    else:
                        out[name] = v
                if kind == "lm":
                    stream = out.pop("stream")
                    out["tokens"] = stream[:, :-1]
                    out["labels"] = stream[:, 1:]
                return out

            fn = jax.jit(unpack)
            self._unpack_cache[shapes_key] = fn
        return fn

    def next(self) -> tuple[dict, dict]:
        item = self._q.get()
        if item is None:
            err = self._err
            raise err if err is not None else StopIteration()
        staged, log = item
        shapes_key = tuple(
            (k, v.shape, str(v.dtype)) for k, v in sorted(staged.items())
        )
        device = {k: jnp.asarray(v) for k, v in staged.items()}
        batch = self._unpack_fn(shapes_key)(device)
        return batch, log

    def __iter__(self):
        while True:
            yield self.next()

    def close(self):
        self._stop.set()
        # drain so a blocked worker put() can observe the stop flag
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
